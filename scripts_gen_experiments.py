"""Generate the §Dry-run and §Roofline tables of EXPERIMENTS.md from
results/dryrun/*.json (run after the sweep)."""
import glob
import json

recs = {}
for f in sorted(glob.glob("results/dryrun/*.json")):
    r = json.load(open(f))
    if r.get("skipped"):
        continue
    recs[(r["arch"], r["shape"], r["mesh"])] = r

lines = []
lines.append("### Dry-run matrix (lower + compile, per-device analysis)\n")
lines.append("| arch | shape | mesh | compile s | peak GiB/dev | "
             "coll GiB/dev | HLO GFLOPs/dev |")
lines.append("|---|---|---|---:|---:|---:|---:|")
for (a, sh, m), r in sorted(recs.items()):
    fl = r["jaxpr_costs"]["flops"] / r["n_devices"] / 1e9
    lines.append(
        f"| {a} | {sh} | {m} | {r['compile_s']:.1f} | "
        f"{r['memory']['peak_bytes']/2**30:.2f} | "
        f"{r['collective_bytes_total']/2**30:.2f} | {fl:,.0f} |")

lines.append("\n### Roofline (single-pod 16x16; terms in seconds/step)\n")
lines.append("| arch | shape | compute | memory | collective | dominant | "
             "MODEL/HLO flops | bottleneck note |")
lines.append("|---|---|---:|---:|---:|---|---:|---|")
NOTES = {
    "train": "TP activation AG/AR in layer loop + DP grad sync; SP+bf16 "
             "collectives (TPU) and comm/compute overlap move it",
    "prefill": "KV-cache writes + weight streaming; chunked prefill "
               "would cut peak memory",
    "decode": "cache-read bandwidth bound, as expected for batch decode",
}
for (a, sh, m), r in sorted(recs.items()):
    if m != "16x16":
        continue
    rl = r["roofline"]
    note = NOTES.get(r["kind"], "")
    lines.append(
        f"| {a} | {sh} | {rl['compute_s']:.3f} | {rl['memory_s']:.3f} | "
        f"{rl['collective_s']:.3f} | {rl['dominant']} | "
        f"{rl['useful_flops_ratio']:.2f} | {note} |")

open("results/experiments_tables.md", "w").write("\n".join(lines))
print(f"{len(recs)} records -> results/experiments_tables.md")
