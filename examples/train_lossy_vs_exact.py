"""End-to-end training driver: lossless vs Celeris best-effort sync.

Trains the same model twice on the synthetic Markov corpus — once with
exact (RoCE-semantics) gradient AllReduce, once with Celeris lossy sync
(bounded windows -> drops -> Hadamard recovery), including a simulated
mid-run node failure + checkpoint restart on the Celeris run.

Container default is a ~15M model for CPU speed; pass ``--size 100m``
for the ~100M-parameter configuration (same code path, more compute):

    PYTHONPATH=src python examples/train_lossy_vs_exact.py \
        --size 100m --steps 300
"""
import argparse
import dataclasses
import shutil
import tempfile

import numpy as np

import repro.configs as C
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig
from repro.optim.adamw import OptConfig
from repro.train.trainer import Trainer, StragglerModel
from repro.train.train_step import CelerisConfig

SIZES = {
    # ~15M: CPU-quick;  ~100M: the e2e target (few hundred steps)
    "15m": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=2, d_ff=1024),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2048),
}


def make_cfg(size: str) -> ModelConfig:
    return dataclasses.replace(
        C.get_smoke("qwen2-0.5b"), name=f"qwen2-style-{size}",
        vocab_size=8192, **SIZES[size])


def run_one(cfg, tag, steps, celeris, seed, ckpt_dir=None, fault_at=None):
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8,
                    seed=7)
    tr = Trainer(cfg, data_cfg=dc,
                 opt_cfg=OptConfig(lr=6e-4, warmup_steps=20,
                                   total_steps=steps),
                 celeris=celeris, seed=seed, ckpt_dir=ckpt_dir,
                 ckpt_every=25,
                 straggler=StragglerModel(burst_prob=0.15, burst_scale=2.5))
    try:
        h = tr.run(steps, simulate_fault_at=fault_at)
    except RuntimeError as e:
        print(f"[{tag}] {e} -> restarting from checkpoint")
        tr2 = Trainer(cfg, data_cfg=dc,
                      opt_cfg=OptConfig(lr=6e-4, warmup_steps=20,
                                        total_steps=steps),
                      celeris=celeris, seed=seed, ckpt_dir=ckpt_dir,
                      ckpt_every=25)
        h = tr2.run(steps - tr2.start_step)
    print(f"[{tag}] loss {h['loss'][0]:.4f} -> "
          f"{np.mean(h['loss'][-10:]):.4f} | mean recv_frac "
          f"{np.mean(h['recv_frac']):.3f} | mean drop "
          f"{np.mean(h['drop_rate'])*100:.1f}%")
    return h


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="15m", choices=list(SIZES))
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = make_cfg(args.size)
    print(f"model: {cfg.param_count()/1e6:.0f}M params, {args.steps} steps")

    h_exact = run_one(cfg, "exact  ", args.steps, CelerisConfig(), seed=0)

    tmp = tempfile.mkdtemp()
    try:
        h_lossy = run_one(
            cfg, "celeris", args.steps,
            CelerisConfig(enabled=True, min_coded_size=4096), seed=0,
            ckpt_dir=tmp, fault_at=min(args.steps - 10, 40))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    d = np.mean(h_lossy["loss"][-10:]) - np.mean(h_exact["loss"][-10:])
    print(f"\nfinal-loss delta (celeris - exact): {d:+.4f} "
          f"(paper Fig. 1: small drops are within noise)")


if __name__ == "__main__":
    main()
