"""Transport study: reproduce paper Fig. 2 and explore the design space.

    PYTHONPATH=src python examples/transport_study.py --rounds 300
    PYTHONPATH=src python examples/transport_study.py --sweep-timeout
    PYTHONPATH=src python examples/transport_study.py --scale-sweep
    PYTHONPATH=src python examples/transport_study.py --multi-pod
    PYTHONPATH=src python examples/transport_study.py --faults stall:1e-4
    PYTHONPATH=src python examples/transport_study.py --multi-pod \
        --schedule perrail --faults rail:0.3
"""
import argparse
import dataclasses

import numpy as np

from repro.core.transport import (BatchedEngine, BatchedSimParams,
                                  CollectiveSimulator, DESIGNS, FaultParams,
                                  SimParams, TIERS, coupling, hier_params,
                                  hier_protocol, sweep)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sweep-timeout", action="store_true",
                    help="sweep the bounded-window size: tail vs loss")
    ap.add_argument("--scale-sweep", action="store_true",
                    help="batched-engine sweep: p99 vs cluster size and "
                         "message size")
    ap.add_argument("--multi-pod", action="store_true",
                    help="hierarchical topology: per-tier loss and the "
                         "axis-split drop schedule vs pod count and DCI "
                         "oversubscription")
    ap.add_argument("--schedule", choices=("ring", "hier", "perrail"),
                    default="ring",
                    help="collective schedule riding the fabric in "
                         "--multi-pod: flat ring, hierarchical RS/AG + "
                         "DCI leader exchange, or per-rail all-node "
                         "exchange (core/transport/schedule.py)")
    ap.add_argument("--window", choices=("round", "phase"), default="round",
                    help="Celeris window policy in --multi-pod: one "
                         "deadline per round, or the budget split across "
                         "the schedule's phase blocks by budget_frac "
                         "(params.WindowPolicy)")
    ap.add_argument("--nodes", type=int, default=128)
    ap.add_argument("--faults", type=str, default=None, metavar="KIND:RATE",
                    help="seeded fault injection, e.g. stall:1e-4, "
                         "crash:3e-5, flap:1e-3, rail:0.3, "
                         "straggler:0.25; '+'-join for compound "
                         "scenarios (params.FaultParams)")
    args = ap.parse_args()
    fault = FaultParams.parse(args.faults) if args.faults else None

    sim = CollectiveSimulator(SimParams())

    if args.faults and not args.multi_pod:
        # faults are engine-native (shared-stream mode): run the paper
        # protocol through BatchedEngine with the fault overlay active
        p = dataclasses.replace(
            SimParams(net=dataclasses.replace(SimParams().net,
                                              n_nodes=args.nodes)),
            fault=fault)
        eng = BatchedEngine(p)
        tr = eng.traces(list(DESIGNS), args.rounds, args.seed,
                        legacy_streams=False)
        base = eng.assemble(tr["roce"], args.seed)
        to = float(np.percentile(base.times_us, 50) + base.times_us.std())
        print(f"faults={fault.tag} nodes={args.nodes} "
              f"rounds={args.rounds}")
        print(f"{'design':10s} {'p50 ms':>8s} {'p99 ms':>8s} "
              f"{'loss %':>7s} {'faulted':>8s} {'gupf':>6s} "
              f"{'rec rounds':>11s}")
        for d in DESIGNS:
            s = (eng.assemble(tr[d], args.seed, celeris_timeout_us=to,
                              adaptive=False)
                 if d == "celeris" else eng.assemble(tr[d], args.seed))
            print(f"{d:10s} {s.p50/1e3:8.2f} {s.p99/1e3:8.2f} "
                  f"{s.mean_loss*100:7.2f} "
                  f"{int(s.faulted.sum()):4d}/{s.faulted.size:<3d} "
                  f"{s.goodput_under_failure:6.3f} "
                  f"{s.recovery_rounds():11.2f}")
        return

    if args.multi_pod:
        print(f"schedule={args.schedule} window={args.window}"
              + (f" faults={fault.tag}" if fault else ""))
        print(f"{'pods':>5s} {'oversub':>8s} {'p99 ms':>8s} "
              + "".join(f"{'loss% ' + t:>12s}" for t in TIERS)
              + f" {'sched intra/cross %':>20s}")
        for npods in (2, 4, 8):
            for ov in (2.0, 8.0):
                p = hier_params(npods, n_nodes=args.nodes,
                                dci_oversubscription=ov,
                                schedule=args.schedule, fault=fault)
                cel = hier_protocol(p, n_rounds=args.rounds,
                                    seed=args.seed,
                                    window=args.window)["celeris"]
                sched = coupling.split_schedule_from_round_stats(cel)
                print(f"{npods:5d} {ov:8.0f} {cel.p99/1e3:8.2f} "
                      + "".join(f"{cel.tier_loss(t)*100:12.3f}"
                                for t in TIERS)
                      + f" {sched.intra.mean*100:9.2f}/"
                        f"{sched.cross.mean*100:.2f}")
        return

    if args.scale_sweep:
        res = sweep(BatchedSimParams(
            n_nodes=(128, 256, 512), message_mb=(8.0, 25.0),
            seeds=(args.seed, args.seed + 1), n_rounds=args.rounds))
        print(f"{'design':10s} {'nodes':>6s} {'MB':>5s} "
              f"{'p99 ms (mean+-sd)':>18s}")
        for d in res.params.designs:
            for mb in res.params.message_mb:
                for nn, (mean, sd) in res.p99_vs_scale(d, mb).items():
                    print(f"{d:10s} {nn:6d} {mb:5.0f} "
                          f"{mean/1e3:10.2f}+-{sd/1e3:5.2f}")
        return

    if args.sweep_timeout:
        base = sim.run("roce", args.rounds, seed=args.seed)
        p50, sd = np.percentile(base.times_us, 50), base.times_us.std()
        print(f"baseline p50={p50/1e3:.2f}ms sigma={sd/1e3:.2f}ms")
        print(f"{'window':>12s} {'p99 ms':>8s} {'loss %':>8s}")
        for k in (0.5, 1.0, 2.0, 4.0):
            cel = sim.run("celeris", args.rounds,
                          celeris_timeout_us=p50 + k * sd,
                          adaptive=False, window="round", seed=args.seed)
            print(f"median+{k:3.1f}sd {cel.p99/1e3:8.2f} "
                  f"{cel.mean_loss*100:8.2f}")
        return

    stats = sim.paper_protocol(n_rounds=args.rounds, seed=args.seed)
    print(f"{'design':10s} {'p50 ms':>8s} {'p99 ms':>8s} {'p999 ms':>9s} "
          f"{'loss %':>7s}")
    for d, s in stats.items():
        print(f"{d:10s} {s.p50/1e3:8.2f} {s.p99/1e3:8.2f} "
              f"{s.p999/1e3:9.2f} {s.mean_loss*100:7.2f}")
    print(f"\np99 reduction roce->celeris: "
          f"{stats['roce'].p99/stats['celeris'].p99:.2f}x (paper: 2.3x)")


if __name__ == "__main__":
    main()
