"""Transport study: reproduce paper Fig. 2 and explore the design space.

    PYTHONPATH=src python examples/transport_study.py --rounds 300
    PYTHONPATH=src python examples/transport_study.py --sweep-timeout
    PYTHONPATH=src python examples/transport_study.py --scale-sweep
    PYTHONPATH=src python examples/transport_study.py --multi-pod
    PYTHONPATH=src python examples/transport_study.py --faults stall:1e-4
    PYTHONPATH=src python examples/transport_study.py --multi-pod \
        --schedule perrail --faults rail:0.3
    PYTHONPATH=src python examples/transport_study.py --multi-pod \
        --schedule hier --cut-order priority

Tail attribution (the flight recorder, ``transport.telemetry``) —
``--trace OUT.json`` runs the engine with a ``TraceRecorder`` attached
and writes a Chrome/Perfetto ``trace_event`` JSON (open in
ui.perfetto.dev; see docs/OBSERVABILITY.md):

    PYTHONPATH=src python examples/transport_study.py \
        --trace results/trace.json
    PYTHONPATH=src python examples/transport_study.py --nodes 512 \
        --rounds 40 --trace results/trace_512.json
    PYTHONPATH=src python examples/transport_study.py \
        --faults stall:1e-4 --trace results/trace_faulted.json
    PYTHONPATH=src python examples/transport_study.py --multi-pod \
        --trace results/trace_hier.json
"""
import argparse
import dataclasses

import numpy as np

from repro.core.transport import (BatchedEngine, BatchedSimParams,
                                  CollectiveSimulator, DESIGNS, FaultParams,
                                  SimParams, TIERS, TraceRecorder, coupling,
                                  hier_params, hier_protocol, sweep,
                                  write_trace)


def _dump_trace(rec, path, **meta):
    counts = write_trace(rec, path, meta=meta or None)
    print(f"\nwrote {path} ({counts.get('X', 0)} slices, "
          "schema-validated per chunk) — open in ui.perfetto.dev")


def main():
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sweep-timeout", action="store_true",
                    help="sweep the bounded-window size: tail vs loss")
    ap.add_argument("--scale-sweep", action="store_true",
                    help="batched-engine sweep: p99 vs cluster size and "
                         "message size")
    ap.add_argument("--multi-pod", action="store_true",
                    help="hierarchical topology: per-tier loss and the "
                         "axis-split drop schedule vs pod count and DCI "
                         "oversubscription")
    ap.add_argument("--schedule", choices=("ring", "hier", "perrail"),
                    default="ring",
                    help="collective schedule riding the fabric in "
                         "--multi-pod: flat ring, hierarchical RS/AG + "
                         "DCI leader exchange, or per-rail all-node "
                         "exchange (core/transport/schedule.py)")
    ap.add_argument("--window", choices=("round", "phase"), default="round",
                    help="Celeris window policy in --multi-pod: one "
                         "deadline per round, or the budget split across "
                         "the schedule's phase blocks by budget_frac "
                         "(params.WindowPolicy)")
    ap.add_argument("--cut-order", choices=("arrival", "priority"),
                    default="arrival",
                    help="what a binding Celeris window truncates: "
                         "arrival (trailing steps, bit-pinned default) "
                         "or priority (lowest semantic class first — "
                         "coded DCI shards before exact RS/AG shards; "
                         "round times are identical either way, only "
                         "WHERE the cut lands moves)")
    ap.add_argument("--nodes", type=int, default=128)
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy",
                    help="engine backend for the flat-engine and "
                         "--scale-sweep modes: numpy (bit-pinned "
                         "reference) or the jitted jax hot loop "
                         "(agrees within rtol 1e-5; faster at scale — "
                         "docs/ARCHITECTURE.md 'Engine backends')")
    ap.add_argument("--faults", type=str, default=None, metavar="KIND:RATE",
                    help="seeded fault injection, e.g. stall:1e-4, "
                         "crash:3e-5, flap:1e-3, rail:0.3, "
                         "straggler:0.25; '+'-join for compound "
                         "scenarios (params.FaultParams)")
    ap.add_argument("--trace", type=str, default=None, metavar="OUT.json",
                    help="attach the flight recorder and write a "
                         "Chrome/Perfetto trace_event JSON of the run "
                         "(per-round and per-phase tail attribution; "
                         "transport.telemetry + trace_export)")
    args = ap.parse_args()
    fault = FaultParams.parse(args.faults) if args.faults else None
    if args.trace and (args.scale_sweep or args.sweep_timeout):
        ap.error("--trace supports the default, --faults and "
                 "--multi-pod modes (the sweeps run many engines)")
    if args.backend == "jax":
        if args.trace:
            ap.error("--backend jax: the flight recorder needs the "
                     "numpy engine (the recorder is a numpy overlay)")
        if args.multi_pod or args.sweep_timeout or not (
                args.faults or args.scale_sweep):
            ap.error("--backend jax supports the flat-engine "
                     "(--faults) and --scale-sweep modes")

    sim = CollectiveSimulator(SimParams())

    if (args.faults or args.trace) and not args.multi_pod:
        # engine-native (shared-stream) mode: the fault overlay and the
        # flight recorder both require it; stats stay bit-exact either
        # way (the recorder is a pure overlay)
        p = SimParams(net=dataclasses.replace(SimParams().net,
                                              n_nodes=args.nodes))
        if fault is not None:
            p = dataclasses.replace(p, fault=fault)
        rec = TraceRecorder() if args.trace else None
        eng = BatchedEngine(p, recorder=rec, backend=args.backend)
        tr = eng.traces(list(DESIGNS), args.rounds, args.seed,
                        legacy_streams=False)
        base = eng.assemble(tr["roce"], args.seed)
        to = float(np.percentile(base.times_us, 50) + base.times_us.std())
        print((f"faults={fault.tag} " if fault else "")
              + f"nodes={args.nodes} rounds={args.rounds}"
              + (f" backend={args.backend}" if args.backend != "numpy"
                 else "")
              + (" [flight recorder on]" if rec else ""))
        print(f"{'design':10s} {'p50 ms':>8s} {'p99 ms':>8s} "
              f"{'loss %':>7s} {'faulted':>8s} {'gupf':>6s} "
              f"{'rec rounds':>11s}")
        for d in DESIGNS:
            s = (eng.assemble(tr[d], args.seed, celeris_timeout_us=to,
                              adaptive=False, cut_order=args.cut_order)
                 if d == "celeris" else eng.assemble(tr[d], args.seed))
            print(f"{d:10s} {s.p50/1e3:8.2f} {s.p99/1e3:8.2f} "
                  f"{s.mean_loss*100:7.2f} "
                  f"{int(s.faulted.sum()):4d}/{s.faulted.size:<3d} "
                  f"{s.goodput_under_failure:6.3f} "
                  f"{s.recovery_rounds():11.2f}")
        if rec is not None:
            _dump_trace(rec, args.trace, mode="flat", nodes=args.nodes,
                        faults=fault.tag if fault else "none")
        return

    if args.multi_pod:
        prio = args.cut_order == "priority"
        print(f"schedule={args.schedule} window={args.window} "
              f"cut-order={args.cut_order}"
              + (f" faults={fault.tag}" if fault else "")
              + (" [flight recorder on]" if args.trace else ""))
        print(f"{'pods':>5s} {'oversub':>8s} {'p99 ms':>8s} "
              + "".join(f"{'loss% ' + t:>12s}" for t in TIERS)
              + f" {'sched intra/cross %':>20s}"
              + (f" {'loss% lo/hi cls':>16s}" if prio else ""))
        rec = None
        for npods in (2, 4, 8):
            for ov in (2.0, 8.0):
                p = hier_params(npods, n_nodes=args.nodes,
                                dci_oversubscription=ov,
                                schedule=args.schedule, fault=fault)
                # a recorder serves one traces() pass: record the last
                # cell of the grid (the exported one — noted below)
                rec = TraceRecorder() if args.trace else None
                cel = hier_protocol(p, n_rounds=args.rounds,
                                    seed=args.seed, window=args.window,
                                    cut_order=args.cut_order,
                                    recorder=rec)["celeris"]
                sched = coupling.split_schedule_from_round_stats(cel)
                top = (np.asarray(cel.prio_pkts).size - 1
                       if cel.prio_pkts is not None else 0)
                print(f"{npods:5d} {ov:8.0f} {cel.p99/1e3:8.2f} "
                      + "".join(f"{cel.tier_loss(t)*100:12.3f}"
                                for t in TIERS)
                      + f" {sched.intra.mean*100:9.2f}/"
                        f"{sched.cross.mean*100:.2f}"
                      + (f" {cel.prio_loss(0)*100:8.3f}/"
                         f"{cel.prio_loss(top)*100:.3f}" if prio else ""))
        if rec is not None:
            _dump_trace(rec, args.trace, mode="multi-pod",
                        cell="pods=8 oversub=8", schedule=args.schedule)
        return

    if args.scale_sweep:
        res = sweep(BatchedSimParams(
            n_nodes=(128, 256, 512), message_mb=(8.0, 25.0),
            seeds=(args.seed, args.seed + 1), n_rounds=args.rounds,
            backend=args.backend))
        print(f"{'design':10s} {'nodes':>6s} {'MB':>5s} "
              f"{'p99 ms (mean+-sd)':>18s}")
        for d in res.params.designs:
            for mb in res.params.message_mb:
                for nn, (mean, sd) in res.p99_vs_scale(d, mb).items():
                    print(f"{d:10s} {nn:6d} {mb:5.0f} "
                          f"{mean/1e3:10.2f}+-{sd/1e3:5.2f}")
        return

    if args.sweep_timeout:
        base = sim.run("roce", args.rounds, seed=args.seed)
        p50, sd = np.percentile(base.times_us, 50), base.times_us.std()
        print(f"baseline p50={p50/1e3:.2f}ms sigma={sd/1e3:.2f}ms")
        print(f"{'window':>12s} {'p99 ms':>8s} {'loss %':>8s}")
        for k in (0.5, 1.0, 2.0, 4.0):
            cel = sim.run("celeris", args.rounds,
                          celeris_timeout_us=p50 + k * sd,
                          adaptive=False, window="round", seed=args.seed)
            print(f"median+{k:3.1f}sd {cel.p99/1e3:8.2f} "
                  f"{cel.mean_loss*100:8.2f}")
        return

    stats = sim.paper_protocol(n_rounds=args.rounds, seed=args.seed)
    print(f"{'design':10s} {'p50 ms':>8s} {'p99 ms':>8s} {'p999 ms':>9s} "
          f"{'loss %':>7s}")
    for d, s in stats.items():
        print(f"{d:10s} {s.p50/1e3:8.2f} {s.p99/1e3:8.2f} "
              f"{s.p999/1e3:9.2f} {s.mean_loss*100:7.2f}")
    print(f"\np99 reduction roce->celeris: "
          f"{stats['roce'].p99/stats['celeris'].p99:.2f}x (paper: 2.3x)")


if __name__ == "__main__":
    main()
