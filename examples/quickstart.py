"""Quickstart: build an assigned arch (reduced config), train a few
steps on the synthetic Markov corpus, then greedy-generate.

    PYTHONPATH=src python examples/quickstart.py --arch gemma2-9b
"""
import argparse

import jax.numpy as jnp

import repro.configs as C
from repro.data.pipeline import DataConfig
from repro.optim.adamw import OptConfig
from repro.serve.serve_step import greedy_generate
from repro.train.trainer import Trainer
from repro.train.train_step import CelerisConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b",
                    help="any assigned arch id (dashes or underscores)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--celeris", action="store_true",
                    help="lossy (best-effort) gradient sync")
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"pattern={cfg.block_pattern}")

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    tr = Trainer(cfg, data_cfg=dc,
                 opt_cfg=OptConfig(lr=1e-3, warmup_steps=10,
                                   total_steps=args.steps * 2),
                 celeris=CelerisConfig(enabled=args.celeris,
                                       min_coded_size=1024))
    hist = tr.run(args.steps, on_metrics=lambda s, m: print(
        f"step {s:3d} loss {m['loss']:.4f} recv {m['recv_frac']:.3f} "
        f"({m['wall_s']:.2f}s)"))
    print(f"loss: {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}")

    if cfg.frontend is None and not cfg.is_encdec:
        prompt = jnp.zeros((2, 8), jnp.int32)
        out = greedy_generate(cfg, tr.state["params"], prompt, n_steps=12)
        print("greedy sample token ids:", out[0].tolist())


if __name__ == "__main__":
    main()
