"""Batched serving example: prefill a batch of prompts, decode with a KV
cache (ring caches on local-attention layers), report tokens/s.

    PYTHONPATH=src python examples/serve_batched.py --arch gemma2-9b
"""
import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.models import model as M
from repro.serve import serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    s_max = args.prompt_len + args.gen

    prefill = serve_step.make_prefill(cfg, s_max)
    decode = serve_step.make_decode(cfg)

    t0 = time.perf_counter()
    logits, caches = prefill(params, {"tokens": prompt})
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    tok = jnp.argmax(logits, -1)[:, None]

    t0 = time.perf_counter()
    out = [tok]
    for i in range(args.gen - 1):
        logits, caches = decode(params, caches, {"tokens": out[-1]},
                                jnp.int32(args.prompt_len + i))
        out.append(jnp.argmax(logits, -1)[:, None])
    jax.block_until_ready(out[-1])
    t_dec = time.perf_counter() - t0

    total = args.batch * (args.gen - 1)
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill {args.prompt_len} tokens x{args.batch}: "
          f"{t_prefill*1e3:.1f} ms")
    print(f"decode: {total} tokens in {t_dec:.2f}s -> "
          f"{total/t_dec:.1f} tok/s (CPU container)")
    print("sample:", jnp.concatenate(out, 1)[0, :16].tolist())


if __name__ == "__main__":
    main()
