"""Batched serving example: prefill a batch of prompts, greedy-decode
with a KV cache, report tokens/s — optionally after shipping the caches
through the lossy-transport wire layout (the fig8 serve path).

    PYTHONPATH=src python examples/serve_batched.py --arch gemma2-9b
    PYTHONPATH=src python examples/serve_batched.py --arch qwen2-0.5b \
        --kv-frac 0.9            # decode from Hadamard-coded lossy KV

Uses the host mesh helper (``launch/mesh.py``) + sharding registry like
the production launcher (``repro.launch.serve``); drop ``--mesh`` to
run unsharded.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.models import model as M
from repro.serve import serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", action="store_true",
                    help="shard over a host (data, model) mesh")
    ap.add_argument("--kv-frac", type=float, default=1.0,
                    help="delivered KV fraction; < 1 ships the caches "
                         "through the coded wire layout before decoding")
    args = ap.parse_args()

    if args.mesh:
        from repro import sharding as shd
        from repro.launch import mesh as mesh_mod
        shd.set_global_mesh(mesh_mod.make_host_mesh())

    cfg = C.get_smoke(args.arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    s_max = args.prompt_len + args.gen

    prefill = serve_step.make_prefill(cfg, s_max)
    t0 = time.perf_counter()
    logits, caches = prefill(params, {"tokens": prompt})
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    first = jnp.argmax(logits, -1)[:, None]

    if args.kv_frac < 1.0:
        # prefill -> decode KV transfer over the lossy transport: the
        # delivered fraction becomes a wire-row hole mask and the
        # decode runs from Hadamard-decoded caches (serve/traffic.py
        # maps engine rounds to these fractions in fig8)
        from repro.core.transport import coupling
        mask = jnp.asarray(coupling.kv_hole_masks(
            np.array([args.kv_frac]), 64, seed=0)[0])
        caches = serve_step.degrade_caches(caches, mask,
                                           jax.random.PRNGKey(2))
        print(f"KV shipped at delivered fraction {args.kv_frac:g} "
              f"({64 - int(mask.sum())}/64 wire rows lost, coded)")

    t0 = time.perf_counter()
    out = serve_step.greedy_decode(cfg, params, caches, first,
                                   args.prompt_len, args.gen)
    jax.block_until_ready(out)
    t_dec = time.perf_counter() - t0

    total = args.batch * (args.gen - 1)
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill {args.prompt_len} tokens x{args.batch}: "
          f"{t_prefill*1e3:.1f} ms")
    print(f"decode: {total} tokens in {t_dec:.2f}s -> "
          f"{total/t_dec:.1f} tok/s (CPU container)")
    print("sample:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
