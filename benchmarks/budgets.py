"""Shared Celeris budget-tightening constants for the benchmark suite.

The paper's adaptive-timeout rule sets the Celeris round budget at the
RoCE baseline's median + 1 sigma *on the same fabric trace*.  At that
setting the bounded window rarely binds — it is a tail insurance
policy, not a truncating regime — so figure cells that study what the
window *does* (fig6's policy A/B, fig7's matched-p99 fault cells)
tighten the rule by a scale factor:

- ``TAIL_SCALE`` (full tier): budget = paper rule x 0.25, deep in the
  truncating regime where window policies and fault cuts actually move
  data-loss numbers.  Chosen in PR 5 so the 512-1024-node hier cells
  show the per-phase window's 2-4x loss win at matched p99.
- ``SMOKE_TAIL_SCALE`` (CI smoke tier): x 0.4 — the 32-node smoke
  fabric has milder contention, so the same 0.25 would cut into the
  *median* and make smoke cells noise-dominated; 0.4 lands in the same
  tail-truncating regime relative to the smaller fabric's spread.

fig7 reuses both: its matched-p99 criterion pins each schedule's
Celeris budget from the *clean* (fault-free) RoCE trace at these
scales, then holds that budget fixed while the fault rate sweeps — so
"Celeris sustains N x the fault rate" is measured at an unchanged
deadline, not by quietly relaxing the window.
"""

TAIL_SCALE = 0.25
SMOKE_TAIL_SCALE = 0.4

# fig10 (priority-ordered cuts): x 0.4 at full scale too.  The A/B
# measures what *reordering* the cut buys, so the budget must bind in
# every 128-512-node cell while each binding round's cut mass stays
# well inside the low-class deliverable bytes — at 0.25 the window
# truncates into the median (65-80% cuts) and the comparison saturates
# into "everything below the top class is gone" instead of measuring
# the reorder.
FIG10_TAIL_SCALE = 0.4
