"""Paper Fig. 2: AllReduce step times under contention (128-node sim).

Paper protocol: RoCE baseline; Celeris window fixed at baseline
median + 1 sigma; report p50/p99 per design + data loss.  Also runs the
beyond-paper adaptive per-step window, and (unless ``--quick``) times
the retained sequential reference loop against the batched engine to
report the speedup measured on this machine.
"""
import time

import numpy as np

from repro.core.transport import CollectiveSimulator, SimParams


def run(n_rounds=300, seed=0, bench_sequential=True, params=None,
        prefix="fig2"):
    """``params``/``prefix`` let the CI smoke tier run the same protocol
    on a 32-node fabric under ``smoke_fig2_*`` keys (one code path)."""
    params = params or SimParams()
    sim = CollectiveSimulator(params)
    t0 = time.perf_counter()
    stats = sim.paper_protocol(n_rounds=n_rounds, seed=seed)
    engine_wall = time.perf_counter() - t0
    rows = []
    print(f"\n== Fig. 2: AllReduce step time under contention "
          f"({params.net.n_nodes} nodes) ==")
    print(f"{'design':10s} {'p50 ms':>8s} {'p99 ms':>8s} {'p99/p50':>8s} "
          f"{'loss %':>7s}")
    for d, s in stats.items():
        print(f"{d:10s} {s.p50/1e3:8.2f} {s.p99/1e3:8.2f} "
              f"{s.p99/s.p50:8.2f} {s.mean_loss*100:7.2f}")
        rows.append((f"{prefix}_p99_ms_{d}", round(s.p99 / 1e3, 2), None))
    red = stats["roce"].p99 / stats["celeris"].p99
    print(f"p99 reduction RoCE->Celeris: {red:.2f}x (paper: up to 2.3x; "
          f"ours is larger because our baseline tail is heavier)")
    rows.append((f"{prefix}_p99_reduction", round(red, 2), 2.3))
    rows.append((f"{prefix}_celeris_loss_pct",
                 round(stats["celeris"].mean_loss * 100, 2), 1.0))
    # beyond-paper: adaptive per-ring-step window
    cel2 = sim.run("celeris", n_rounds, adaptive=True, window="step",
                   seed=seed)
    red2 = stats["roce"].p99 / cel2.p99
    print(f"beyond-paper adaptive step-window: p99 {cel2.p99/1e3:.2f} ms, "
          f"loss {cel2.mean_loss*100:.2f}%, reduction {red2:.2f}x")
    rows.append((f"{prefix}_beyond_step_window_reduction", round(red2, 2), None))

    rows.append((f"{prefix}_engine_wall_s", round(engine_wall, 2), None))
    print(f"batched engine wall-clock ({n_rounds} rounds, 4-design "
          f"paper protocol): {engine_wall:.2f}s")
    if bench_sequential:
        from repro.core.transport.reference import (
            SequentialCollectiveSimulator)
        seq = SequentialCollectiveSimulator(params)
        t0 = time.perf_counter()
        base = seq.run("roce", n_rounds, seed=seed)
        to = float(np.percentile(base.times_us, 50) + base.times_us.std())
        for d in ("irn", "srnic"):
            seq.run(d, n_rounds, seed=seed)
        seq.run("celeris", n_rounds, celeris_timeout_us=to,
                adaptive=False, window="round", seed=seed)
        seq_wall = time.perf_counter() - t0
        speedup = seq_wall / engine_wall
        print(f"sequential reference wall-clock: {seq_wall:.2f}s "
              f"-> speedup {speedup:.1f}x")
        rows.append((f"{prefix}_sequential_wall_s", round(seq_wall, 2), None))
        rows.append((f"{prefix}_engine_speedup_x", round(speedup, 1), 10.0))
        # A/B equivalence: the engine's RoCE tail must track the retained
        # sequential reference on the same seeded fabric (legacy-stream
        # replay; RoCE transfer draws are engine-native, so a few percent
        # of noise is expected, not drift)
        parity = stats["roce"].p99 / base.p99
        print(f"engine/sequential RoCE p99 parity: {parity:.3f}")
        rows.append((f"{prefix}_ab_p99_ratio_roce", round(parity, 3), 1.0))
    return rows
