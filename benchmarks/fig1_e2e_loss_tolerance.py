"""Fig. 1, end-to-end: the transport engine drives the trainer.

This is the closed loop the paper argues for: the batched transport
engine simulates a 128-node Celeris fabric under three window
tightnesses, the resulting per-round delivered fractions become the
trainer's per-step drop schedule (``repro.core.transport.coupling``),
and the same smoke LM trains under three collective modes:

- **exact**       — lossless all-reduce (RoCE-semantics baseline);
- **lossy**       — best-effort, no coding: dropped wire rows are holes;
- **lossy+hadamard** — best-effort + randomized-Hadamard recovery
  (paper §III-B).

Headline metric per regime: *recovery* = fraction of the exact run's
loss decrease that the lossy+hadamard run achieves,
``(loss0 - final_had) / (loss0 - final_exact)``.  The paper's Fig.-1
claim is that at its operating regime (<=5% drop) coding keeps training
within noise of lossless — recovery >= 0.9 is the acceptance bar.
"""
import numpy as np

import repro.configs as C
from repro.core.transport import NetworkParams, SimParams, coupling
from repro.data.pipeline import DataConfig
from repro.optim.adamw import OptConfig
from repro.train.train_step import CelerisConfig
from repro.train.trainer import Trainer

# timeout_scale -> realized mean drop at 128 nodes (see coupling docs):
# 1.0 ~ 1% (the protocol operating point), 0.6 ~ 4.5% (the paper's
# Fig.-1 <=5% regime), 0.4 ~ 25% (well past tolerance).
REGIMES = {"light": 1.0, "paper": 0.6, "heavy": 0.4}
# 32-node smoke fabric: same burst-rate downscale the tier-1 transport
# tests use; scale 0.8 lands near the paper's ~5% regime there.
SMOKE_PARAMS = SimParams(net=NetworkParams(n_nodes=32,
                                           burst_on_prob=0.0008))
SMOKE_REGIMES = {"paper": 0.8}


def _train(cfg, steps, seed, celeris, straggler):
    tr = Trainer(cfg, data_cfg=DataConfig(vocab_size=cfg.vocab_size,
                                          seq_len=64, global_batch=8,
                                          seed=1),
                 opt_cfg=OptConfig(lr=1e-3, warmup_steps=10,
                                   total_steps=500),
                 celeris=celeris, seed=seed, straggler=straggler)
    return tr.run(steps)


def run(steps=60, seed=0, smoke=False, prefix="fig1e2e"):
    if smoke:
        regimes, params, n_nodes = SMOKE_REGIMES, SMOKE_PARAMS, 32
    else:
        regimes, params, n_nodes = REGIMES, None, 128

    cfg = C.get_smoke("qwen2-0.5b")
    rows = []
    print(f"\n== Fig. 1 e2e: engine-driven drop schedules "
          f"({n_nodes}-node fabric), exact vs lossy vs lossy+hadamard ==")

    h_exact = _train(cfg, steps, seed, CelerisConfig(mode="exact"), None)
    loss0 = h_exact["loss"][0]
    final_exact = float(np.mean(h_exact["loss"][-5:]))
    delta_exact = loss0 - final_exact
    rows.append((f"{prefix}_final_loss_exact", round(final_exact, 4), None))
    print(f"exact: loss {loss0:.3f} -> {final_exact:.4f}")

    for name, scale in regimes.items():
        sched = coupling.schedule_from_engine(
            steps, seed=seed, params=params, n_nodes=None if params else
            n_nodes, timeout_scale=scale)
        rows.append((f"{prefix}_drop_mean_{name}",
                     round(sched.mean, 4), None))
        finals = {}
        for mode in ("lossy", "lossy_hadamard"):
            h = _train(cfg, steps, seed,
                       CelerisConfig(mode=mode, min_coded_size=1024),
                       coupling.EngineStragglerModel(sched))
            finals[mode] = float(np.mean(h["loss"][-5:]))
            rows.append((f"{prefix}_final_loss_{mode}_{name}",
                         round(finals[mode], 4), None))
        recovery = (loss0 - finals["lossy_hadamard"]) / max(delta_exact,
                                                            1e-9)
        rows.append((f"{prefix}_recovery_{name}", round(recovery, 4),
                     0.9 if name == "paper" else None))
        print(f"{name:6s} (window x{scale}, mean drop "
              f"{sched.mean*100:5.2f}%): "
              f"lossy {finals['lossy']:.4f}  "
              f"+hadamard {finals['lossy_hadamard']:.4f}  "
              f"recovery {recovery*100:5.1f}%")

    paper_rec = [v for n, v, _ in rows
                 if n == f"{prefix}_recovery_paper"][0]
    verdict = "PASS" if paper_rec >= 0.9 else "FAIL"
    print(f"paper-regime recovery {paper_rec*100:.1f}% "
          f"(claim: >=90%) -> {verdict}")
    return rows


if __name__ == "__main__":
    run()
