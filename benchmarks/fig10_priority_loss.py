"""Fig. 10 (beyond-paper): priority-ordered vs arrival-ordered window cuts.

Celeris keeps data prioritization in software; this figure measures
what that buys when the bounded receiver window binds.  Both cut
orders assemble the *same* physics trace under the *same* budget —
``cut_order`` only decides **which** bytes the cut lands on — so round
times (and p99) are identical by construction and the A/B isolates the
semantic reordering:

- **arrival** (uniform, the default): the round budget truncates from
  the end of the round.  On a hierarchical plan the trailing steps are
  the tail of the all-gather — the early-layer exact shards the next
  forward pass consumes *first* (``schedule.layer_priorities``), i.e.
  the cut kills the most valuable bytes first.
- **priority**: low classes are cut first — coded DCI shards (class 0,
  recoverable through the Hadamard path), then early-ag exact shards,
  and the forward-critical top class only after everything below it is
  exhausted.

The sweep: hier schedule, 4 pods, {128, 256, 512} nodes x DCI
oversubscription {2, 8}, round window at the paper budget rule
(RoCE median + 1 sigma) x ``FIG10_TAIL_SCALE`` — tight enough to bind
in every cell, gentle enough that binding rounds' cut mass stays
inside the low classes (see ``budgets.py``).  Per cell and cut order:
per-class loss fractions; the headline
``fig10_hi_loss_ratio_{cell}`` is arrival's top-class loss over
priority's (capped at ``RATIO_CAP`` — the priority path's top-class
loss is typically *zero*, so the uncapped ratio is eps-dominated).
The acceptance bar is >= 2x in every cell; the measured ratios pin at
the cap.

Smoke tier (CI): one 32-node 2-pod cell, ``smoke_fig10_*`` keys;
``smoke_fig10_hi_loss_ratio`` is floor-gated (>= 1.0) by
``check_regression.py`` — prioritized cuts must never lose more
high-priority data than uniform cuts.
"""
import dataclasses
import time

import numpy as np

from repro.core.transport import (BatchedEngine, NetworkParams, SimParams,
                                  topology)
from repro.core.transport.schedule import layer_priorities, make_plan

try:
    from benchmarks.budgets import FIG10_TAIL_SCALE, SMOKE_TAIL_SCALE
except ImportError:  # run as a script from inside benchmarks/
    from budgets import FIG10_TAIL_SCALE, SMOKE_TAIL_SCALE

NODES = (128, 256, 512)
OVERSUBS = (2.0, 8.0)
N_PODS = 4
# the priority path's top-class loss is usually exactly 0 (the cut fits
# in the lower classes), so the loss ratio is reported capped: a stable
# deterministic baseline value instead of an eps-denominated blow-up
RATIO_CAP = 100.0
_EPS = 1e-6

SMOKE_PARAMS = SimParams(net=NetworkParams(n_nodes=32,
                                           burst_on_prob=0.0008))


def _cell(params, n_rounds, seed, timeout_scale):
    """One fabric cell, assembled under both cut orders.

    Returns (p99_ms, {order: RoundStats}) — same trace, same budget,
    so the two stats carry identical times and differ only in where
    the cut landed.
    """
    plan = make_plan(params.net, params.topo, params.work)
    cls = layer_priorities(plan)
    eng = BatchedEngine(params)
    tr = eng.traces(["roce", "celeris"], n_rounds, seed,
                    legacy_streams=False)
    cel = dataclasses.replace(tr["celeris"], step_priority=cls)
    base = eng.assemble(tr["roce"], seed)
    to = float((np.percentile(base.times_us, 50) + base.times_us.std())
               * timeout_scale)
    stats = {order: eng.assemble(cel, seed, celeris_timeout_us=to,
                                 adaptive=False, window="round",
                                 cut_order=order)
             for order in ("arrival", "priority")}
    assert np.array_equal(stats["arrival"].times_us,
                          stats["priority"].times_us), \
        "cut orders must share round times (matched p99 by construction)"
    return float(stats["arrival"].p99) / 1e3, stats


def _emit_cell(rows, prefix, tag, p99_ms, stats):
    top = np.asarray(stats["arrival"].prio_pkts).size - 1
    rows.append((f"{prefix}_p99_ms_{tag}", round(p99_ms, 2), None))
    for order in ("arrival", "priority"):
        st = stats[order]
        rows.append((f"{prefix}_hi_loss_{order}_{tag}",
                     round(st.prio_loss(top), 4), None))
        rows.append((f"{prefix}_lo_loss_{order}_{tag}",
                     round(st.prio_loss(0), 4), None))
    ratio = min(stats["arrival"].prio_loss(top)
                / max(stats["priority"].prio_loss(top), _EPS), RATIO_CAP)
    rows.append((f"{prefix}_hi_loss_ratio_{tag}", round(ratio, 3), None))
    return ratio


def run(n_rounds=40, seed=0, smoke=False, prefix="fig10", n_nodes=NODES):
    rows = []

    if smoke:
        print("\n== Fig. 10 smoke: 2-pod 32-node hier, priority vs "
              "arrival cuts (tight budget) ==")
        p = topology.hier_params(2, base=SMOKE_PARAMS,
                                 dci_oversubscription=8.0, schedule="hier")
        p99_ms, stats = _cell(p, 40, seed, SMOKE_TAIL_SCALE)
        ratio = _emit_cell(rows, prefix, "p2_o8", p99_ms, stats)
        top = np.asarray(stats["arrival"].prio_pkts).size - 1
        print(f"p99 {p99_ms:8.2f} ms  hi loss arrival "
              f"{stats['arrival'].prio_loss(top)*100:6.2f}%  priority "
              f"{stats['priority'].prio_loss(top)*100:6.2f}%  "
              f"ratio {ratio:.1f}x")
        return rows

    t0 = time.perf_counter()
    print(f"\n== Fig. 10: priority vs arrival window cuts "
          f"({N_PODS} pods, {len(n_nodes)} scales x oversub {OVERSUBS}, "
          f"budget = paper rule x {FIG10_TAIL_SCALE}) ==")
    print(f"{'nodes':>6s} {'oversub':>8s} {'p99 ms':>9s} "
          f"{'hi arr%':>8s} {'hi pri%':>8s} {'lo arr%':>8s} "
          f"{'lo pri%':>8s} {'ratio':>7s}")
    for ov in OVERSUBS:
        for nn in n_nodes:
            tag = f"n{nn}_o{int(ov)}"
            p = topology.hier_params(N_PODS, n_nodes=nn,
                                     dci_oversubscription=ov,
                                     schedule="hier")
            p99_ms, stats = _cell(p, n_rounds, seed, FIG10_TAIL_SCALE)
            ratio = _emit_cell(rows, prefix, tag, p99_ms, stats)
            top = np.asarray(stats["arrival"].prio_pkts).size - 1
            print(f"{nn:6d} {ov:8.0f} {p99_ms:9.2f} "
                  f"{stats['arrival'].prio_loss(top)*100:8.2f} "
                  f"{stats['priority'].prio_loss(top)*100:8.2f} "
                  f"{stats['arrival'].prio_loss(0)*100:8.2f} "
                  f"{stats['priority'].prio_loss(0)*100:8.2f} "
                  f"{ratio:7.1f}")

    rows.append((f"{prefix}_wall_s",
                 round(time.perf_counter() - t0, 1), None))
    return rows


if __name__ == "__main__":
    run()
