"""Fig. 4 (beyond-paper): cross-pod tails and hierarchical recovery.

The multi-pod experiment the flat Fig.-1/2 protocols cannot express:

1. **Engine sweep** — DCI oversubscription x pod count on the
   hierarchical fabric (:mod:`repro.core.transport.topology`).  Per
   cell: Celeris round-time p99 (window fixed by the RoCE baseline on
   the *same* fabric, paper rule) and the DCI tier's data loss.  The
   headline is that the cross-pod (dci) tier loses strictly more than
   the intra-pod tiers once the DCI is oversubscribed — the regime
   where axis-split drop schedules earn their keep.

2. **Hierarchical recovery** — the closed loop at topology granularity:
   the 2-pod engine's per-tier delivered fractions become an axis-split
   ``AxisSchedules`` (intra vs cross), and the smoke LM trains under
   ``CollectiveMode.HIERARCHICAL`` (intra-pod sync exact, cross-pod
   best-effort + Hadamard at the DCI drop rate).  Recovery is measured
   against the exact baseline exactly like Fig. 1; the paper's >= 0.9
   bar applies at its <= 5% regime.

Smoke tier (CI): one 2-pod 32-node engine pass -> axis-split schedule ->
tiny hierarchical step, ~10 s, ``smoke_fig4``-prefixed keys.
"""
import numpy as np

import repro.configs as C
from repro.core.transport import (NetworkParams, SimParams, coupling,
                                  topology)
from repro.data.pipeline import DataConfig
from repro.optim.adamw import OptConfig
from repro.train.train_step import CelerisConfig
from repro.train.trainer import Trainer

# engine sweep grid (full tier)
POD_COUNTS = (2, 4, 8)
OVERSUBS = (2.0, 4.0, 8.0)
SWEEP_NODES = 128

# recovery experiment: 2 pods at the paper's <= 5% regime (same window
# scale fig1 uses for its "paper" regime)
RECOVERY_PODS = 2
RECOVERY_SCALE = 0.6

# 32-node smoke fabric: same burst-rate downscale the tier-1 transport
# tests use; the DCI tier keeps its (much busier) defaults.
SMOKE_PARAMS = SimParams(net=NetworkParams(n_nodes=32,
                                           burst_on_prob=0.0008))
SMOKE_SCALE = 0.8


def _train(cfg, steps, seed, celeris, straggler):
    tr = Trainer(cfg, data_cfg=DataConfig(vocab_size=cfg.vocab_size,
                                          seq_len=64, global_batch=8,
                                          seed=1),
                 opt_cfg=OptConfig(lr=1e-3, warmup_steps=10,
                                   total_steps=500),
                 celeris=celeris, seed=seed, straggler=straggler)
    return tr.run(steps)


def _recovery(cfg, steps, seed, sched, rows, prefix):
    """Exact vs hierarchical training on an axis-split schedule."""
    h_exact = _train(cfg, steps, seed, CelerisConfig(mode="exact"), None)
    loss0 = h_exact["loss"][0]
    final_exact = float(np.mean(h_exact["loss"][-5:]))
    rows.append((f"{prefix}_final_loss_exact", round(final_exact, 4), None))

    h_hier = _train(cfg, steps, seed,
                    CelerisConfig(mode="hierarchical", min_coded_size=1024),
                    coupling.HierStragglerModel(sched))
    final_hier = float(np.mean(h_hier["loss"][-5:]))
    rows.append((f"{prefix}_final_loss_hierarchical",
                 round(final_hier, 4), None))
    recovery = (loss0 - final_hier) / max(loss0 - final_exact, 1e-9)
    rows.append((f"{prefix}_recovery", round(recovery, 4), 0.9))
    print(f"recovery: exact {loss0:.3f} -> {final_exact:.4f}, "
          f"hierarchical -> {final_hier:.4f}  "
          f"(intra drop {sched.intra.mean*100:.2f}%, cross "
          f"{sched.cross.mean*100:.2f}%)  recovery {recovery*100:5.1f}%")
    return recovery


def run(steps=40, seed=0, n_rounds=100, smoke=False, prefix="fig4"):
    cfg = C.get_smoke("qwen2-0.5b")
    rows = []

    if smoke:
        print("\n== Fig. 4 smoke: 2-pod 32-node engine -> axis-split "
              "schedule -> hierarchical step ==")
        p = topology.hier_params(2, base=SMOKE_PARAMS)
        stats = topology.hier_protocol(p, n_rounds=60, seed=seed,
                                       timeout_scale=SMOKE_SCALE)
        cel = stats["celeris"]
        rows.append((f"{prefix}_p99_ms_celeris", round(cel.p99 / 1e3, 2),
                     None))
        rows.append((f"{prefix}_dci_loss", round(cel.tier_loss("dci"), 4),
                     None))
        sched = coupling.split_schedule_from_round_stats(cel)
        rows.append((f"{prefix}_drop_mean_intra",
                     round(sched.intra.mean, 4), None))
        rows.append((f"{prefix}_drop_mean_cross",
                     round(sched.cross.mean, 4), None))
        _recovery(cfg, 6, seed, sched, rows, prefix)
        return rows

    print(f"\n== Fig. 4: DCI oversubscription x pod count "
          f"({SWEEP_NODES}-node hierarchical fabric) ==")
    print(f"{'pods':>5s} {'oversub':>8s} {'p99 ms':>8s} {'dci loss %':>11s} "
          f"{'intra loss %':>13s}")
    for npods in POD_COUNTS:
        for ov in OVERSUBS:
            p = topology.hier_params(npods, n_nodes=SWEEP_NODES,
                                     dci_oversubscription=ov)
            stats = topology.hier_protocol(p, n_rounds=n_rounds, seed=seed)
            cel = stats["celeris"]
            intra_loss = coupling.split_schedule_from_round_stats(
                cel).intra.mean
            tag = f"p{npods}_o{int(ov)}"
            rows.append((f"{prefix}_p99_ms_celeris_{tag}",
                         round(cel.p99 / 1e3, 2), None))
            rows.append((f"{prefix}_dci_loss_{tag}",
                         round(cel.tier_loss("dci"), 4), None))
            print(f"{npods:5d} {ov:8.0f} {cel.p99/1e3:8.2f} "
                  f"{cel.tier_loss('dci')*100:11.2f} "
                  f"{intra_loss*100:13.2f}")

    print(f"\n== Fig. 4 recovery: {RECOVERY_PODS}-pod axis-split schedule "
          f"(window x{RECOVERY_SCALE}) ==")
    sched = coupling.split_schedule_from_engine(
        steps, seed=seed, n_pods=RECOVERY_PODS, n_nodes=SWEEP_NODES,
        timeout_scale=RECOVERY_SCALE)
    rows.append((f"{prefix}_drop_mean_intra", round(sched.intra.mean, 4),
                 None))
    rows.append((f"{prefix}_drop_mean_cross", round(sched.cross.mean, 4),
                 None))
    rec = _recovery(cfg, steps, seed, sched, rows, prefix)
    verdict = "PASS" if rec >= 0.9 else "FAIL"
    print(f"hierarchical recovery {rec*100:.1f}% (claim: >=90%) "
          f"-> {verdict}")
    return rows


if __name__ == "__main__":
    run()
