"""Engine backend throughput: jitted lax.scan backend vs numpy reference.

Times ``BatchedEngine`` trace generation end-to-end (host stream replay
+ per-block math) for ``backend="numpy"`` — a python loop over seeds —
against ``backend="jax"`` — every seed batched through one jitted,
vmapped core (``core/transport/engine_jax.py``).  Both backends pay the
same per-seed host pass (the replay contract consumes the numpy
generator streams identically), so the measured gap is the vectorized
rate/queue/transfer math; it grows with nodes x seeds, which is why the
timed cell is a 512-node fabric rather than the 32-node test fixture.

Methodology: one warmup call compiles the jax core (the jit cache then
serves every later block of the same shape — compile time is a one-off,
not throughput, and is excluded); both backends then take the **min of
N trials**, so one GC pause or noisy CI neighbor cannot sink the gate.

Keys:
- ``smoke_engine_speedup`` — numpy wall / jax wall on the smoke cell.
  Floor-gated by ``check_regression.py`` (must stay >= 1.0: the
  accelerated backend never slower than the reference); deliberately
  *not* ``_speedup_x``-suffixed, which would make it volatile and
  invisible to the gate.
- ``smoke_engine_p99_{roce,celeris}_ms``, ``*_backends_agree``,
  ``smoke_engine_sweep_p99_roce_ms`` — deterministic consistency pins
  (numpy vs jax within rtol 1e-4; standard symmetric 25% gate).  The
  sweep pin drives one small ``sweep()`` cell under ``backend="jax"``
  so CI exercises the public batched entry point, not just
  ``traces_batched``.
- full tier: the same protocol at 512 nodes x 4 seeds under
  ``engine_scale512_*`` with volatile ``_wall_s``/``_speedup_x`` keys.
"""
import dataclasses
import time

import numpy as np

SMOKE_CELL = dict(n_nodes=512, n_rounds=10, seeds=(0, 1), trials=3)
FULL_CELL = dict(n_nodes=512, n_rounds=30, seeds=(0, 1, 2, 3), trials=2)
_RTOL = 1e-4


def _p99_ms(stats) -> float:
    return float(stats.p99) / 1e3


def _cell(n_nodes: int, n_rounds: int, seeds, trials: int):
    """Returns (numpy_wall_s, jax_wall_s, p99_ms by design from the jax
    backend, agree flag) for one engine cell."""
    from repro.core.transport import (BatchedEngine, DESIGNS, NetworkParams,
                                      SimParams, engine_jax)
    p = SimParams(net=dataclasses.replace(
        SimParams().net, n_nodes=n_nodes))
    designs = list(DESIGNS)
    eng_np = BatchedEngine(p)
    eng_jx = BatchedEngine(p, backend="jax")
    seeds = list(seeds)

    engine_jax.traces_batched(eng_jx, designs, n_rounds, seeds)  # compile
    tj = min(_timed(lambda: engine_jax.traces_batched(
        eng_jx, designs, n_rounds, seeds)) for _ in range(trials))
    tn = min(_timed(lambda: [eng_np.traces(designs, n_rounds, s,
                                           legacy_streams=False)
                             for s in seeds]) for _ in range(trials))

    # deterministic pins: assemble seed[0] on both backends and compare
    s0 = seeds[0]
    tr_np = eng_np.traces(designs, n_rounds, s0, legacy_streams=False)
    tr_jx = engine_jax.traces_batched(eng_jx, designs, n_rounds, [s0])[0]
    base = eng_np.assemble(tr_np["roce"], s0)
    to = float(np.percentile(base.times_us, 50) + base.times_us.std())
    p99, agree = {}, True
    for d in designs:
        kw = (dict(celeris_timeout_us=to, adaptive=False)
              if d == "celeris" else {})
        a = eng_np.assemble(tr_np[d], s0, **kw)
        b = eng_jx.assemble(tr_jx[d], s0, **kw)
        p99[d] = _p99_ms(b)
        agree &= bool(np.allclose(b.times_us, a.times_us, rtol=_RTOL))
        agree &= bool(np.allclose(b.recv_frac, a.recv_frac,
                                  rtol=_RTOL, atol=1e-9))
    return tn, tj, p99, float(agree)


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _sweep_pin():
    """One small sweep() cell under backend='jax' vs numpy: pins the
    public batched entry point, not just traces_batched."""
    from repro.core.transport import (BatchedSimParams, NetworkParams,
                                      SimParams, sweep)
    small = SimParams(net=NetworkParams(n_nodes=64, burst_on_prob=0.0008))
    grid = dict(n_nodes=(64,), message_mb=(25.0,), seeds=(0, 1),
                n_rounds=12, base=small)
    res_j = sweep(BatchedSimParams(backend="jax", **grid))
    res_np = sweep(BatchedSimParams(**grid))
    agree = res_j.stats.keys() == res_np.stats.keys()
    for k, b in res_j.stats.items():
        a = res_np.stats[k]
        agree &= bool(np.allclose(b.times_us, a.times_us, rtol=_RTOL))
    roce = [s for k, s in res_j.stats.items() if k[0] == "roce"]
    p99 = float(np.mean([_p99_ms(s) for s in roce]))
    return p99, float(agree)


def run(smoke: bool = False):
    rows = []
    cell = SMOKE_CELL if smoke else FULL_CELL
    prefix = "smoke_engine" if smoke else "engine_scale512"
    print(f"\n== engine backend: numpy reference vs jax lax.scan "
          f"({cell['n_nodes']} nodes, {cell['n_rounds']} rounds, "
          f"{len(cell['seeds'])} seeds, min of {cell['trials']}) ==")
    tn, tj, p99, agree = _cell(**cell)
    speedup = tn / tj
    print(f"numpy {tn:6.2f} s   jax {tj:6.2f} s   speedup {speedup:.2f}x"
          f"   backends_agree={agree:.0f}")
    for d, v in p99.items():
        print(f"  p99[{d}] = {v:.2f} ms (jax backend)")
    rows.append((f"{prefix}_numpy_wall_s", round(tn, 3), None))
    rows.append((f"{prefix}_jax_wall_s", round(tj, 3), None))
    if smoke:
        # floor-gated key: check_regression requires >= 1.0
        rows.append((f"{prefix}_speedup", round(speedup, 3), ">=1.0"))
    else:
        rows.append((f"{prefix}_speedup_x", round(speedup, 3), None))
    rows.append((f"{prefix}_backends_agree", agree, "1.0"))
    rows.append((f"{prefix}_p99_roce_ms", round(p99["roce"], 3), None))
    rows.append((f"{prefix}_p99_celeris_ms", round(p99["celeris"], 3),
                 None))
    if smoke:
        sp99, sagree = _sweep_pin()
        print(f"  sweep cell (64 nodes, backend=jax): p99[roce]="
              f"{sp99:.2f} ms  agree={sagree:.0f}")
        rows.append(("smoke_engine_sweep_p99_roce_ms", round(sp99, 3),
                     None))
        rows.append(("smoke_engine_sweep_backends_agree", sagree, "1.0"))
    return rows


if __name__ == "__main__":
    run(smoke=True)
