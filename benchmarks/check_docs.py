"""Docs health gate (CI `docs` job + tier-1 test).

Two checks, both cheap and hermetic:

1. **Intra-repo markdown links resolve.**  Every ``[text](target)``
   in README.md, ROADMAP.md and docs/*.md whose target is not an
   external URL or a pure ``#anchor`` must point at an existing file
   (anchors on existing files are accepted; we don't parse heading
   slugs).
2. **Benchmark figure scripts import.**  Every ``benchmarks/fig*.py``
   must import cleanly and expose a ``run`` callable — the docs/RESULTS
   table points readers at these entry points, so a renamed or broken
   module is a stale-docs bug even when CI's smoke tier doesn't call
   it.  ``examples/*.py`` must import cleanly too (they're the README's
   onboarding path); their ``main()`` is not run.

Exit code 0 = healthy; 1 = problems (listed on stdout).

    PYTHONPATH=src python benchmarks/check_docs.py
"""
import glob
import importlib
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) — excluding images' src use is fine to include too
_LINK_RE = re.compile(r"\[[^\]^\[]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def _md_files():
    files = [os.path.join(REPO, "README.md"),
             os.path.join(REPO, "ROADMAP.md")]
    files += sorted(glob.glob(os.path.join(REPO, "docs", "*.md")))
    return [f for f in files if os.path.exists(f)]


def check_links() -> list:
    problems = []
    for md in _md_files():
        rel_md = os.path.relpath(md, REPO)
        base = os.path.dirname(md)
        with open(md) as f:
            text = f.read()
        for m in _LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            resolved = os.path.normpath(os.path.join(base, path))
            if not os.path.exists(resolved):
                line = text[: m.start()].count("\n") + 1
                problems.append(f"{rel_md}:{line}: broken link -> {target}")
    return problems


def check_imports() -> list:
    problems = []
    sys.path.insert(0, REPO)                      # benchmarks package
    sys.path.insert(0, os.path.join(REPO, "src"))
    figs = sorted(glob.glob(os.path.join(REPO, "benchmarks", "fig*.py")))
    for f in figs:
        mod = "benchmarks." + os.path.splitext(os.path.basename(f))[0]
        try:
            m = importlib.import_module(mod)
            if not callable(getattr(m, "run", None)):
                problems.append(f"{mod}: no callable run()")
        except Exception as e:                      # noqa: BLE001
            problems.append(f"{mod}: import failed: {e!r}")
    for f in sorted(glob.glob(os.path.join(REPO, "examples", "*.py"))):
        name = os.path.relpath(f, REPO)
        try:
            code = compile(open(f).read(), f, "exec")
            scope = {"__name__": "examples_smoke", "__file__": f}
            exec(code, scope)                       # imports only: main()
            if not callable(scope.get("main")):     # is __main__-gated
                problems.append(f"{name}: no main() entry point")
        except Exception as e:                      # noqa: BLE001
            problems.append(f"{name}: import failed: {e!r}")
    return problems


def main() -> int:
    problems = check_links() + check_imports()
    for p in problems:
        print(p)
    n_md = len(_md_files())
    print(f"checked {n_md} markdown files, benchmarks/fig*.py and "
          f"examples/*.py: {len(problems)} problem(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
