"""Benchmark driver: one function per paper table/figure.

Prints ``name,value,paper_reference`` CSV at the end and merges the
machine-readable metrics into ``BENCH_sim.json`` next to the repo root
for CI consumption (merge, not overwrite, so the full run and the smoke
run can share one committed baseline file).

Tiers:
- default      — every table/figure at paper scale (several minutes);
- ``--quick``  — shrunk rounds/steps, no sequential-reference timing,
  no 512/1024-node sweep tiers;
- ``--smoke``  — the CI tier (aims for about a minute): 32-node engine
  A/B against the sequential reference, kernel micro-bench, and a tiny
  engine-driven e2e lossy train step.  Same code paths, same JSON
  schema, ``smoke_``-prefixed keys.

``--out PATH`` writes the JSON elsewhere (CI uses this to compare a
fresh smoke run against the committed baseline via
``benchmarks/check_regression.py``).
"""
import json
import os
import sys
import time

# allow both `python -m benchmarks.run` and `python benchmarks/run.py`
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO_ROOT not in sys.path:
    sys.path.insert(0, _REPO_ROOT)

# rows with these prefixes are persisted to BENCH_sim.json (most are
# deterministic simulation metrics the regression gate compares;
# check_regression.py separately skips the _wall_s/_us/kernel timing
# keys, which are machine-dependent)
_KEY_PREFIXES = ("fig1e2e_", "fig2_", "fig3_", "fig4_", "fig5_", "fig6_",
                 "fig7_", "fig8_", "kernel_", "smoke_")

_DEFAULT_OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_sim.json")


def run_full(quick: bool):
    from benchmarks import (table1_qp_state, table2_resources,
                            fig2_tail_latency, fig1_e2e_loss_tolerance,
                            fig3_scale_sweep, fig4_cross_pod_tail,
                            fig5_schedule_tail, fig6_scale_schedule,
                            fig7_fault_resilience, fig8_serving_tail,
                            kernel_bench, roofline)
    rows = []
    rows += table1_qp_state.run()
    rows += table2_resources.run()
    rows += fig2_tail_latency.run(n_rounds=120 if quick else 300,
                                  bench_sequential=not quick)
    fig3_rows, _ = fig3_scale_sweep.run(
        n_rounds=60 if quick else 120,
        seeds=(0, 1) if quick else (0, 1, 2, 3),
        n_nodes=(128, 256) if quick else (128, 256, 512, 1024))
    rows += fig3_rows
    rows += fig1_e2e_loss_tolerance.run(steps=25 if quick else 60)
    rows += fig4_cross_pod_tail.run(steps=25 if quick else 40,
                                    n_rounds=60 if quick else 100)
    rows += fig5_schedule_tail.run(n_rounds=60 if quick else 100)
    rows += fig6_scale_schedule.run(
        n_rounds=40 if quick else 60,
        n_nodes=(128, 512) if quick else fig6_scale_schedule.NODES)
    rows += fig7_fault_resilience.run(steps=25 if quick else 40,
                                      n_rounds=40 if quick else 60,
                                      scale_cell=not quick)
    rows += fig8_serving_tail.run(n_rounds=120 if quick else 300)
    rows += kernel_bench.run()
    rows += roofline.run()
    return rows


def run_smoke():
    """CI tier: one engine A/B + kernels + one e2e lossy step + one
    2-pod topology case + one ring-vs-hier schedule A/B + one
    window-policy (round-vs-phase) A/B + one stall fault-injection
    cell + one serving incast sweep, about a minute, exercising the
    same code paths as the full run."""
    from benchmarks import (fig2_tail_latency, fig1_e2e_loss_tolerance,
                            fig4_cross_pod_tail, fig5_schedule_tail,
                            fig6_scale_schedule, fig7_fault_resilience,
                            fig8_serving_tail, kernel_bench)
    from repro.core.transport import SimParams, NetworkParams
    rows = []
    rows += fig2_tail_latency.run(
        n_rounds=60, bench_sequential=True,
        params=SimParams(net=NetworkParams(n_nodes=32,
                                           burst_on_prob=0.0008)),
        prefix="smoke_fig2")
    rows += fig1_e2e_loss_tolerance.run(steps=6, smoke=True,
                                        prefix="smoke_fig1e2e")
    rows += fig4_cross_pod_tail.run(smoke=True, prefix="smoke_fig4")
    rows += fig5_schedule_tail.run(smoke=True, prefix="smoke_fig5")
    rows += fig6_scale_schedule.run(smoke=True, prefix="smoke_fig6")
    rows += fig7_fault_resilience.run(smoke=True, prefix="smoke_fig7")
    rows += fig8_serving_tail.run(smoke=True, prefix="smoke_fig8")
    rows += [(f"smoke_{n}" if n.startswith("kernel_") else n, v, r)
             for n, v, r in kernel_bench.run()]
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()
    quick, smoke = args.quick, args.smoke
    out_path = args.out or _DEFAULT_OUT
    if quick and args.out is None:
        # the quick tier reuses full-run key names at shrunk protocol
        # scales — merging it into the committed baseline would corrupt
        # the CI regression gate
        out_path = _DEFAULT_OUT.replace(".json", "_quick.json")
        print(f"[--quick] writing to {out_path} so the committed "
              "baseline keeps full-protocol values")

    t_start = time.perf_counter()
    rows = run_smoke() if smoke else run_full(quick)

    print("\nname,value,paper_reference")
    for name, val, ref in rows:
        print(f"{name},{val},{'' if ref is None else ref}")

    bench = {}
    if os.path.exists(out_path):        # merge so full + smoke coexist
        try:
            with open(out_path) as f:
                bench = json.load(f)
        except (json.JSONDecodeError, OSError):
            bench = {}
    bench.update({name: val for name, val, _ in rows
                  if name.startswith(_KEY_PREFIXES)})
    tag = "smoke" if smoke else "full"
    bench[f"total_bench_wall_s_{tag}"] = round(
        time.perf_counter() - t_start, 1)
    bench.pop("total_bench_wall_s", None)   # legacy key
    bench.pop("quick", None)
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main()
