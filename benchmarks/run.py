"""Benchmark driver: one function per paper table/figure.

Prints ``name,value,paper_reference`` CSV at the end and merges the
machine-readable metrics into ``BENCH_sim.json`` next to the repo root
for CI consumption (merge, not overwrite, so the full run and the smoke
run can share one committed baseline file).

Every invocation also writes a **run manifest** to
``results/manifest_<tier>.json`` — git sha, a hash of every section's
parameters, per-section wall-clock and row counts — so any figure
number in the baseline can be traced back to the exact code + config
that produced it (see ``docs/OBSERVABILITY.md``).  Per-section
wall-clock also lands in the CSV/JSON as ``timing_<section>_wall_s``
rows (the ``_wall_s`` suffix is regression-exempt: machine-dependent).

Tiers:
- default      — every table/figure at paper scale (several minutes);
- ``--quick``  — shrunk rounds/steps, no sequential-reference timing,
  no 512/1024-node sweep tiers;
- ``--smoke``  — the CI tier (aims for about a minute): 32-node engine
  A/B against the sequential reference, kernel micro-bench, and a tiny
  engine-driven e2e lossy train step.  Same code paths, same JSON
  schema, ``smoke_``-prefixed keys.

``--out PATH`` writes the JSON elsewhere (CI uses this to compare a
fresh smoke run against the committed baseline via
``benchmarks/check_regression.py``).
"""
import hashlib
import json
import os
import subprocess
import sys
import time

# allow both `python -m benchmarks.run` and `python benchmarks/run.py`
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO_ROOT not in sys.path:
    sys.path.insert(0, _REPO_ROOT)

# rows with these prefixes are persisted to BENCH_sim.json (most are
# deterministic simulation metrics the regression gate compares;
# check_regression.py separately skips the _wall_s/_us/kernel timing
# keys, which are machine-dependent)
_KEY_PREFIXES = ("engine_", "fig1e2e_", "fig2_", "fig3_", "fig4_", "fig5_",
                 "fig6_", "fig7_", "fig8_", "fig9_", "fig10_", "kernel_",
                 "smoke_", "timing_")

_DEFAULT_OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_sim.json")


def _git_sha() -> str:
    """Current commit (+'-dirty' when the tree differs); 'unknown' when
    git is unavailable — the manifest must never fail the run."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=_REPO_ROOT, timeout=10,
            capture_output=True, text=True)
        if sha.returncode != 0:
            return "unknown"
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], cwd=_REPO_ROOT, timeout=10,
            capture_output=True, text=True)
        mark = "-dirty" if dirty.returncode == 0 and dirty.stdout.strip() \
            else ""
        return sha.stdout.strip() + mark
    except (OSError, subprocess.SubprocessError):
        return "unknown"


class _Sections:
    """Collects benchmark rows per named section, timing each one for
    the run manifest and the ``timing_*_wall_s`` rows."""

    def __init__(self):
        self.rows = []
        self.entries = []

    def add(self, name, fn, **kwargs):
        t0 = time.perf_counter()
        out = fn(**kwargs)
        dt = round(time.perf_counter() - t0, 2)
        if isinstance(out, tuple):       # fig3 returns (rows, extras)
            out = out[0]
        self.entries.append({"name": name, "wall_s": dt,
                             "kwargs": kwargs, "n_rows": len(out)})
        self.rows += out
        self.rows.append((f"timing_{name}_wall_s", dt, None))
        return out


def run_full(quick: bool) -> _Sections:
    from benchmarks import (table1_qp_state, table2_resources,
                            engine_backend, fig2_tail_latency,
                            fig1_e2e_loss_tolerance, fig3_scale_sweep,
                            fig4_cross_pod_tail, fig5_schedule_tail,
                            fig6_scale_schedule, fig7_fault_resilience,
                            fig8_serving_tail, fig9_tail_attribution,
                            fig10_priority_loss, kernel_bench, roofline)
    s = _Sections()
    s.add("table1", table1_qp_state.run)
    s.add("table2", table2_resources.run)
    s.add("fig2", fig2_tail_latency.run, n_rounds=120 if quick else 300,
          bench_sequential=not quick)
    s.add("fig3", fig3_scale_sweep.run,
          n_rounds=60 if quick else 120,
          seeds=(0, 1) if quick else (0, 1, 2, 3),
          n_nodes=(128, 256) if quick else (128, 256, 512, 1024))
    s.add("fig1e2e", fig1_e2e_loss_tolerance.run, steps=25 if quick else 60)
    s.add("fig4", fig4_cross_pod_tail.run, steps=25 if quick else 40,
          n_rounds=60 if quick else 100)
    s.add("fig5", fig5_schedule_tail.run, n_rounds=60 if quick else 100)
    s.add("fig6", fig6_scale_schedule.run,
          n_rounds=40 if quick else 60,
          n_nodes=(128, 512) if quick else fig6_scale_schedule.NODES)
    s.add("fig7", fig7_fault_resilience.run, steps=25 if quick else 40,
          n_rounds=40 if quick else 60, scale_cell=not quick)
    s.add("fig8", fig8_serving_tail.run, n_rounds=120 if quick else 300)
    s.add("fig9", fig9_tail_attribution.run)
    s.add("fig10", fig10_priority_loss.run,
          n_rounds=25 if quick else 40,
          n_nodes=(128, 256) if quick else fig10_priority_loss.NODES)
    s.add("kernels", kernel_bench.run)
    s.add("roofline", roofline.run)
    s.add("engine", engine_backend.run)
    return s


def run_smoke() -> _Sections:
    """CI tier: one engine A/B + kernels + one e2e lossy step + one
    2-pod topology case + one ring-vs-hier schedule A/B + one
    window-policy (round-vs-phase) A/B + one stall fault-injection
    cell + one serving incast sweep + one recorded tail-attribution
    cell + one priority-vs-arrival cut A/B (its high-priority loss
    ratio is floor-gated at 1.0x) + one jax-vs-numpy engine-backend
    throughput cell (its speedup key is floor-gated at 1.0x), about a
    minute, exercising the same code paths as the full run."""
    from benchmarks import (engine_backend, fig2_tail_latency,
                            fig1_e2e_loss_tolerance, fig4_cross_pod_tail,
                            fig5_schedule_tail, fig6_scale_schedule,
                            fig7_fault_resilience, fig8_serving_tail,
                            fig9_tail_attribution, fig10_priority_loss,
                            kernel_bench)
    from repro.core.transport import SimParams, NetworkParams
    s = _Sections()
    s.add("fig2", fig2_tail_latency.run,
          n_rounds=60, bench_sequential=True,
          params=SimParams(net=NetworkParams(n_nodes=32,
                                             burst_on_prob=0.0008)),
          prefix="smoke_fig2")
    s.add("fig1e2e", fig1_e2e_loss_tolerance.run, steps=6, smoke=True,
          prefix="smoke_fig1e2e")
    s.add("fig4", fig4_cross_pod_tail.run, smoke=True, prefix="smoke_fig4")
    s.add("fig5", fig5_schedule_tail.run, smoke=True, prefix="smoke_fig5")
    s.add("fig6", fig6_scale_schedule.run, smoke=True, prefix="smoke_fig6")
    s.add("fig7", fig7_fault_resilience.run, smoke=True,
          prefix="smoke_fig7")
    s.add("fig8", fig8_serving_tail.run, smoke=True, prefix="smoke_fig8")
    s.add("fig9", fig9_tail_attribution.run, smoke=True,
          prefix="smoke_fig9")
    s.add("fig10", fig10_priority_loss.run, smoke=True,
          prefix="smoke_fig10")
    s.add("kernels", lambda: [
        (f"smoke_{n}" if n.startswith("kernel_") else n, v, r)
        for n, v, r in kernel_bench.run()])
    s.add("engine", engine_backend.run, smoke=True)
    return s


def write_manifest(sections: _Sections, tag: str, out_path: str,
                   total_wall_s: float) -> str:
    """``results/manifest_<tier>.json``: enough provenance to re-derive
    (or distrust) every number the run merged into the baseline."""
    # the params hash covers section names + kwargs: two runs with the
    # same hash ran the same figure protocol (repr() covers SimParams
    # and other non-JSON kwargs deterministically)
    spec = [{"name": e["name"], "kwargs": e["kwargs"]}
            for e in sections.entries]
    spec_json = json.dumps(spec, sort_keys=True, default=repr)
    manifest = {
        "generator": "benchmarks/run.py",
        "tier": tag,
        "git_sha": _git_sha(),
        "params_hash": hashlib.sha256(spec_json.encode()).hexdigest()[:16],
        "argv": sys.argv[1:],
        "out_path": os.path.relpath(out_path, _REPO_ROOT),
        "python": sys.version.split()[0],
        "timestamp_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "total_wall_s": round(total_wall_s, 1),
        "sections": [{**e, "kwargs": {k: v if isinstance(
            v, (int, float, str, bool, type(None))) else repr(v)
            for k, v in e["kwargs"].items()}} for e in sections.entries],
    }
    path = os.path.join(_REPO_ROOT, "results", f"manifest_{tag}.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return path


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()
    quick, smoke = args.quick, args.smoke
    out_path = args.out or _DEFAULT_OUT
    if quick and args.out is None:
        # the quick tier reuses full-run key names at shrunk protocol
        # scales — merging it into the committed baseline would corrupt
        # the CI regression gate
        out_path = _DEFAULT_OUT.replace(".json", "_quick.json")
        print(f"[--quick] writing to {out_path} so the committed "
              "baseline keeps full-protocol values")

    t_start = time.perf_counter()
    sections = run_smoke() if smoke else run_full(quick)
    rows = sections.rows

    print("\nname,value,paper_reference")
    for name, val, ref in rows:
        print(f"{name},{val},{'' if ref is None else ref}")

    bench = {}
    if os.path.exists(out_path):        # merge so full + smoke coexist
        try:
            with open(out_path) as f:
                bench = json.load(f)
        except (json.JSONDecodeError, OSError):
            bench = {}
    bench.update({name: val for name, val, _ in rows
                  if name.startswith(_KEY_PREFIXES)})
    tag = "smoke" if smoke else ("quick" if quick else "full")
    total = time.perf_counter() - t_start
    bench[f"total_bench_wall_s_{tag if tag != 'quick' else 'full'}"] = \
        round(total, 1)
    bench.pop("total_bench_wall_s", None)   # legacy key
    bench.pop("quick", None)
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
    print(f"\nwrote {out_path}")
    mpath = write_manifest(sections, tag, out_path, total)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
