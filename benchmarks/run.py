"""Benchmark driver: one function per paper table/figure.

Prints ``name,value,paper_reference`` CSV at the end and writes
``BENCH_sim.json`` (machine-readable transport-simulation metrics:
wall-clocks, speedup vs the sequential reference, p99s per design and
scale) next to the repo root for CI consumption.

``--quick`` shrinks rounds/steps and skips the sequential-reference
timing and the 512/1024-node sweep tiers.
"""
import json
import os
import sys
import time


def main() -> None:
    from benchmarks import (table1_qp_state, table2_resources,
                            fig2_tail_latency, fig1_loss_tolerance,
                            fig3_scale_sweep, kernel_bench, roofline)
    quick = "--quick" in sys.argv
    t_start = time.perf_counter()
    rows = []
    rows += table1_qp_state.run()
    rows += table2_resources.run()
    rows += fig2_tail_latency.run(n_rounds=120 if quick else 300,
                                  bench_sequential=not quick)
    fig3_rows, _ = fig3_scale_sweep.run(
        n_rounds=60 if quick else 120,
        seeds=(0, 1) if quick else (0, 1, 2, 3),
        n_nodes=(128, 256) if quick else (128, 256, 512, 1024))
    rows += fig3_rows
    rows += fig1_loss_tolerance.run(steps=25 if quick else 60)
    rows += kernel_bench.run()
    rows += roofline.run()

    print("\nname,value,paper_reference")
    for name, val, ref in rows:
        print(f"{name},{val},{'' if ref is None else ref}")

    bench = {name: val for name, val, _ in rows
             if name.startswith(("fig2_", "fig3_", "kernel_"))}
    bench["total_bench_wall_s"] = round(time.perf_counter() - t_start, 1)
    bench["quick"] = quick
    out_path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_sim.json")
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main()
