"""Benchmark driver: one function per paper table/figure.

Prints ``name,value,paper_reference`` CSV at the end.
"""
import sys


def main() -> None:
    from benchmarks import (table1_qp_state, table2_resources,
                            fig2_tail_latency, fig1_loss_tolerance,
                            kernel_bench, roofline)
    quick = "--quick" in sys.argv
    rows = []
    rows += table1_qp_state.run()
    rows += table2_resources.run()
    rows += fig2_tail_latency.run(n_rounds=120 if quick else 300)
    rows += fig1_loss_tolerance.run(steps=25 if quick else 60)
    rows += kernel_bench.run()
    rows += roofline.run()

    print("\nname,value,paper_reference")
    for name, val, ref in rows:
        print(f"{name},{val},{'' if ref is None else ref}")


if __name__ == "__main__":
    main()
