"""Paper Table II: BRAM / MTBF / ASIC area (analytic reproduction)."""
from repro.core import resource_model as rm


def run():
    rows = []
    t = rm.table2()
    print("\n== Table II: FPGA resources & MTBF ==")
    print(f"{'design':10s} {'BRAM':>8s} {'paper':>8s} {'MTBF h':>8s} "
          f"{'paper':>7s} {'ASIC a.u.':>10s}")
    for d in ("roce", "irn", "srnic", "celeris"):
        print(f"{d:10s} {t[d]['bram']:8.1f} {rm.PAPER_BRAM[d]:8.1f} "
              f"{t[d]['mtbf_hrs']:8.1f} {rm.PAPER_MTBF_HRS[d]:7.1f} "
              f"{t[d]['asic_area_au']:10.0f}")
        rows.append((f"table2_mtbf_{d}", t[d]["mtbf_hrs"],
                     rm.PAPER_MTBF_HRS[d]))
    bram_cut = 1 - t["celeris"]["bram"] / t["irn"]["bram"]
    mtbf_gain = t["celeris"]["mtbf_hrs"] / t["roce"]["mtbf_hrs"]
    print(f"BRAM cut vs IRN: {bram_cut*100:.1f}% (paper 72.7%) | "
          f"MTBF gain vs RoCE: {mtbf_gain:.2f}x (paper ~1.9x)")
    rows.append(("table2_bram_cut_vs_irn_pct", round(bram_cut * 100, 1), 72.7))
    rows.append(("table2_mtbf_gain", round(mtbf_gain, 2), 1.88))
    return rows
