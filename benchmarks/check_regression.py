"""Compare a fresh benchmark JSON against the committed baseline.

Usage:
    python benchmarks/check_regression.py NEW.json [BASELINE.json]
        [--tol 0.25] [--require-all]

Compares every *simulation metric* key present in BOTH files and fails
(exit 1) when any relative deviation exceeds ``--tol`` (default 25%).
Wall-clock / microsecond timing keys are machine-dependent and skipped;
the simulation metrics (engine p99s, losses, drop rates, recovery
fractions) are deterministic given seeds, so drift there means behavior
changed.

``--require-all`` hardens the missing-key rule: *every* non-volatile
baseline key must be present in the new run — not just keys of tiers
the new run demonstrably executed.  Without it, a whole tier silently
disappearing (e.g. a smoke section that stopped emitting) shrinks the
gate instead of failing it.  CI wires this into the smoke job by
gating the fresh run against the committed baseline *restricted to the
smoke tier* — so any committed ``smoke_*`` key the run no longer emits
fails the build, while full-tier keys don't false-positive.

Keys present in the new run but absent from the baseline are reported
as a NEW-keys drift list (informational): that's the signal to commit
a refreshed baseline so the new metrics become gated too.

A few keys carry a **floor gate** instead of the symmetric rule
(``_FLOOR_GATES``): ``smoke_engine_speedup`` must stay >= 1.0 — the
jax engine backend never slower than the numpy reference.  The
symmetric 25% rule would be wrong for it twice over: it is wall-clock
derived (machine-dependent), and getting *faster* must never fail the
build.  Floor keys are checked against their floor whenever the new
run emits them (baseline value irrelevant) and still count as
non-volatile for the disappeared-key rule.
"""
import argparse
import json
import os
import sys

_SKIP_SUFFIXES = ("_wall_s", "_us", "_speedup_x")
_SKIP_PREFIXES = ("total_bench_wall_s",)

# key -> minimum allowed value; exempt from the symmetric tolerance
_FLOOR_GATES = {
    "smoke_engine_speedup": 1.0,
    # prioritized cuts must never lose MORE high-priority data than
    # uniform (arrival) cuts at the same budget; the ratio is
    # arrival-over-priority, capped upstream (fig10_priority_loss
    # .RATIO_CAP), so >= 1.0 is the "priority mode works" floor
    "smoke_fig10_hi_loss_ratio_p2_o8": 1.0,
}

_DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_sim.json")


def volatile(key: str) -> bool:
    return (key.endswith(_SKIP_SUFFIXES) or key.startswith(_SKIP_PREFIXES)
            or "kernel_" in key)


def _tier(key: str) -> str:
    return "smoke" if key.startswith("smoke_") else "full"


def compare(new: dict, base: dict, tol: float, require_all: bool = False):
    """Returns (checked, failures, missing, fresh, floors).

    ``missing`` lists baseline metrics the new run no longer emits — a
    silently-disappeared metric must fail the gate, not shrink it.  By
    default the rule is tier-scoped (only tiers the new run clearly
    executed, i.e. emitted other keys of); ``require_all`` demands
    every non-volatile baseline key unconditionally.  ``fresh`` lists
    new-run metrics absent from the baseline (the drift report — new
    keys awaiting a baseline refresh; informational, never fails).
    ``floors`` lists the ``_FLOOR_GATES`` checks as ``(key, floor,
    value, ok)``; a failed floor is also appended to ``failures``.
    """
    checked, failures = [], []
    for key in sorted(set(new) & set(base)):
        if volatile(key) or key in _FLOOR_GATES:
            continue
        try:
            b, n = float(base[key]), float(new[key])
        except (TypeError, ValueError):
            continue
        rel = abs(n - b) / max(abs(b), 1e-9)
        checked.append((key, b, n, rel))
        if rel > tol:
            failures.append((key, b, n, rel))
    floors = []
    for key, floor in sorted(_FLOOR_GATES.items()):
        if key not in new:
            continue
        try:
            n = float(new[key])
        except (TypeError, ValueError):
            continue
        ok = n >= floor
        floors.append((key, floor, n, ok))
        if not ok:
            failures.append((key, floor, n, (floor - n) / floor))
    if require_all:
        missing = [k for k in sorted(base)
                   if not volatile(k) and k not in new]
    else:
        new_tiers = {_tier(k) for k in new if not volatile(k)}
        missing = [k for k in sorted(base)
                   if not volatile(k) and _tier(k) in new_tiers
                   and k not in new]
    fresh = [k for k in sorted(new) if not volatile(k)
             and k not in base and k not in _FLOOR_GATES]
    return checked, failures, missing, fresh, floors


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("new_json")
    ap.add_argument("baseline_json", nargs="?", default=_DEFAULT_BASELINE)
    ap.add_argument("--tol", type=float, default=0.25)
    ap.add_argument("--require-all", action="store_true",
                    help="fail when ANY non-volatile baseline key is "
                         "missing from the new run (default: only keys "
                         "of tiers the new run executed)")
    args = ap.parse_args()
    with open(args.new_json) as f:
        new = json.load(f)
    with open(args.baseline_json) as f:
        base = json.load(f)
    new_path, base_path, tol = args.new_json, args.baseline_json, args.tol

    checked, failures, missing, fresh, floors = compare(new, base, tol,
                                                        args.require_all)
    if not checked and not floors:
        sys.exit(f"no comparable keys between {new_path} and {base_path} "
                 "— baseline missing the tier that just ran?")
    for key, b, n, rel in checked:
        mark = "FAIL" if rel > tol else "ok  "
        print(f"{mark} {key}: baseline={b} new={n} rel={rel*100:.1f}%")
    for key, floor, n, ok in floors:
        mark = "ok  " if ok else "FAIL"
        print(f"{mark} {key}: floor={floor} new={n} (floor gate, "
              "tolerance-exempt)")
    for key in missing:
        print(f"GONE {key}: in baseline but not emitted by this run")
    for key in fresh:
        print(f"NEW  {key}: emitted by this run but not in the baseline "
              "(commit a refreshed baseline to gate it)")
    print(f"\n{len(checked)} metrics checked (+{len(floors)} floor-"
          f"gated), {len(failures)} failed, {len(missing)} disappeared, "
          f"{len(fresh)} new")
    if failures or missing:
        sys.exit(1)


if __name__ == "__main__":
    main()
