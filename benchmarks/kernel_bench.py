"""Kernel micro-bench: FWHT pallas (interpret) vs jnp oracle us/call.

On this CPU container the pallas kernels run in interpret mode, so the
timing column is an interface check, not a perf claim; the TPU path is
exercised by setting REPRO_PALLAS_INTERPRET=0 on real hardware.
"""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(f, *args, n=3):
    out = f(*args)                       # one warmup: compile + execute
    jax.block_until_ready(out)           # handles tuples/pytrees too
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run():
    rows = []
    print("\n== kernels: us/call (CPU; pallas in interpret mode) ==")
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 4096))
    signs = jax.random.rademacher(jax.random.PRNGKey(7), (4096,),
                                  dtype=jnp.float32)

    jit_ref = jax.jit(ref.fwht)
    us_ref = _time(jit_ref, x)
    print(f"fwht jnp-oracle    (256,4096): {us_ref:10.1f} us")
    rows.append(("kernel_fwht_ref_us", round(us_ref, 1), None))

    us_pal = _time(lambda a: ops.fwht(a), x)
    print(f"fwht pallas        (256,4096): {us_pal:10.1f} us")
    rows.append(("kernel_fwht_pallas_us", round(us_pal, 1), None))

    # fused sign-multiply + scale (what coding.encode issues)
    us_fused = _time(lambda a, s: ops.fwht(a, signs=s, scale=4096 ** -0.5),
                     x, signs)
    print(f"fwht pallas fused  (256,4096): {us_fused:10.1f} us")
    rows.append(("kernel_fwht_pallas_fused_us", round(us_fused, 1), None))

    noise = jax.random.uniform(jax.random.PRNGKey(1), (256, 4096))
    jit_q = jax.jit(lambda a, b: ref.quantize_int8(a, b))
    us_q = _time(jit_q, x, noise)
    print(f"quantize jnp       (256,4096): {us_q:10.1f} us")
    rows.append(("kernel_quant_ref_us", round(us_q, 1), None))

    us_qp = _time(lambda a, b: ops.quantize_int8(a, b), x, noise)
    print(f"quantize pallas    (256,4096): {us_qp:10.1f} us")
    rows.append(("kernel_quant_pallas_us", round(us_qp, 1), None))

    # fused rotate+quantize (one kernel, no HBM round trip between the
    # stages — what coding.encode_quantized issues) vs the unfused pair
    us_pair = _time(
        lambda a, s, b: ops.quantize_int8(
            ops.fwht(a, signs=s, scale=4096 ** -0.5), b),
        x, signs, noise)
    print(f"fwht+quant unfused (256,4096): {us_pair:10.1f} us")
    rows.append(("kernel_fwht_quant_unfused_us", round(us_pair, 1), None))

    us_fq = _time(
        lambda a, s, b: ops.fwht_quantize(a, b, signs=s,
                                          scale=4096 ** -0.5),
        x, signs, noise)
    print(f"fwht+quant fused   (256,4096): {us_fq:10.1f} us")
    rows.append(("kernel_fwht_quant_fused_us", round(us_fq, 1), None))
    return rows
