"""Kernel micro-bench: FWHT pallas (interpret) vs jnp oracle us/call.

On this CPU container the pallas kernels run in interpret mode, so the
timing column is an interface check, not a perf claim; the TPU path is
exercised by setting REPRO_PALLAS_INTERPRET=0 on real hardware.
"""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(f, *args, n=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run():
    rows = []
    print("\n== kernels: us/call (CPU; pallas in interpret mode) ==")
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 4096))
    jit_ref = jax.jit(ref.fwht)
    us_ref = _time(jit_ref, x)
    print(f"fwht jnp-oracle  (256,4096): {us_ref:10.1f} us")
    rows.append(("kernel_fwht_ref_us", round(us_ref, 1), None))
    noise = jax.random.uniform(jax.random.PRNGKey(1), (256, 4096))
    jit_q = jax.jit(lambda a, b: ref.quantize_int8(a, b))
    us_q = _time(jit_q, x, noise)
    print(f"quantize jnp     (256,4096): {us_q:10.1f} us")
    rows.append(("kernel_quant_ref_us", round(us_q, 1), None))
    return rows
