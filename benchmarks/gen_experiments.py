"""Generate ``results/experiments_tables.md`` from the repo's current
experiment outputs (run after ``run.py`` / ``dryrun --scale-check``).

Ported from the stale repo-root ``scripts_gen_experiments.py``, which
(a) executed at import time and (b) expected a pre-sweep dry-run record
format (``jaxpr_costs`` / ``roofline`` keys) that no longer exists.
This version is importable (tier-1 smoke-imports it), reads the actual
artifacts, and builds its transport tables from the current sweep API:

- **Dry-run matrix** — ``results/dryrun/scale_check__*.json`` /
  ``serve_check__*.json`` records (mesh, collective census, lowering
  wall time);
- **Transport sweep tables** — ``BENCH_sim.json``'s ``fig5_*`` /
  ``fig6_*`` keys, laid out on the grid the benchmarks actually swept
  (``BatchedSimParams.schedules`` x ``windows`` x ``n_nodes``, imported
  from the fig modules so the table can't drift from the sweep).
"""
import glob
import json
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# allow both `python -m benchmarks.gen_experiments` and
# `python benchmarks/gen_experiments.py`
if _REPO not in sys.path:
    sys.path.insert(0, _REPO)


def build_dryrun_tables(results_dir=None):
    """Markdown lines for the scale/serve dry-run matrix."""
    results_dir = results_dir or os.path.join(_REPO, "results", "dryrun")
    recs = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        recs.extend(r if isinstance(r, list) else [r])
    lines = ["### Dry-run matrix (lowering + collective census)", ""]
    if not recs:
        lines.append("_no dry-run records under results/dryrun_")
        return lines
    lines.append("| arch | shape | mode | mesh | devices | lower s | "
                 "collectives | ok |")
    lines.append("|---|---|---|---|---:|---:|---|---|")
    for r in recs:
        if r.get("skipped"):
            continue
        colls = ", ".join(f"{k}x{v}"
                          for k, v in sorted(r.get("collective_ops",
                                                   {}).items()))
        lines.append(
            f"| {r.get('arch', '?')} | {r.get('shape', '?')} | "
            f"{r.get('mode', r.get('kind', '?'))} | {r.get('mesh', '?')} | "
            f"{r.get('n_devices', 0)} | {r.get('lower_s', 0)} | "
            f"{colls or '-'} | {'yes' if r.get('ok') else 'NO'} |")
    return lines


def build_transport_tables(bench=None, bench_path=None):
    """Markdown lines for the fig5/fig6 transport sweeps, on the exact
    grids the benchmark modules sweep (imported, not re-typed).
    ``bench_path`` points at a fresh metrics JSON (e.g. a nightly's
    ``/tmp/bench_full.json``); default is the committed baseline."""
    from benchmarks import fig5_schedule_tail as f5
    from benchmarks import fig6_scale_schedule as f6
    if bench is None:
        with open(bench_path
                  or os.path.join(_REPO, "BENCH_sim.json")) as fh:
            bench = json.load(fh)

    lines = ["### Fig. 5 — collective schedule vs cross-pod tail "
             f"({f5.SWEEP_NODES} nodes)", ""]
    lines.append("| pods | oversub | ring p99 ms | hier p99 ms | "
                 "ring/hier |")
    lines.append("|---:|---:|---:|---:|---:|")
    for npods in f5.POD_COUNTS:
        for ov in f5.OVERSUBS:
            tag = f"p{npods}_o{int(ov)}"
            ring = bench.get(f"fig5_p99_ms_ring_{tag}")
            hier = bench.get(f"fig5_p99_ms_hier_{tag}")
            ratio = bench.get(f"fig5_p99_ratio_{tag}")
            if ring is None:
                continue
            lines.append(f"| {npods} | {ov:.0f} | {ring} | {hier} | "
                         f"{ratio} |")

    lines += ["", "### Fig. 6 — window policy x schedule at scale "
              f"({f6.N_PODS} pods)", ""]
    lines.append("| nodes | oversub | schedule | round p99 ms | "
                 "phase p99 ms | round dci loss | phase dci loss |")
    lines.append("|---:|---:|---|---:|---:|---:|---:|")
    for nn in f6.NODES:
        for ov in f6.OVERSUBS:
            tag = f"n{nn}_o{int(ov)}"
            for sched in f6.SCHEDULES:
                cells = {w: (bench.get(f"fig6_p99_ms_{sched}_{w}_{tag}"),
                             bench.get(f"fig6_dci_loss_{sched}_{w}_{tag}"))
                         for w in f6.WINDOWS}
                if cells["round"][0] is None:
                    continue
                lines.append(
                    f"| {nn} | {ov:.0f} | {sched} | {cells['round'][0]} | "
                    f"{cells['phase'][0]} | {cells['round'][1]} | "
                    f"{cells['phase'][1]} |")
    return lines


def main(out_path=None, bench_path=None):
    out_path = out_path or os.path.join(_REPO, "results",
                                        "experiments_tables.md")
    lines = (build_dryrun_tables() + [""]
             + build_transport_tables(bench_path=bench_path))
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as fh:
        fh.write("\n".join(lines) + "\n")
    print(f"{len(lines)} lines -> {out_path}")
    return out_path


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", default=None,
                    help="metrics JSON to tabulate (default: the "
                         "committed BENCH_sim.json)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    main(out_path=args.out, bench_path=args.bench)
