"""Fig. 6 (beyond-paper): window policy x collective schedule at scale.

The last closed-loop lever (ISSUE 5): the Celeris budget was per-round,
so a hierarchical schedule's cheap in-pod steps and expensive DCI steps
shared one deadline.  A per-round budget tight enough to control the
tail truncates *from the end of the round*: whenever the DCI exchange
runs long, the cut lands on the trailing all-gather phase and destroys
intra-pod data that the fat in-pod fabric delivered perfectly well —
the per-round budget drowns in DCI variance.  The per-phase window
(``WindowPolicy("phase")``) splits the same budget across the
schedule's phase blocks by their ``budget_frac`` weights (DCI phases
weighted by oversubscription + extra RTT), so each tier is bounded by
its own deadline: intra data survives, and residual loss concentrates
on the cross-pod (DCI) axis — exactly where the trainer's
``CollectiveMode.HIERARCHICAL`` coded recovery operates.

The sweep: {ring, hier, perrail} schedules x {round, phase} windows at
{128, 512, 1024} nodes x DCI oversubscription {2, 8}, 4 pods, via the
engine's ``BatchedSimParams.schedules``/``windows`` dimensions (window
policies share each cell's physics trace — only the budget assembly
differs).  The Celeris budget follows the paper rule (RoCE median + 1
sigma per schedule) tightened by ``TAIL_SCALE`` into the truncating
regime where window policies actually bind.  Per cell: round p99,
total data loss, and DCI-tier loss.

Headlines (``fig6_*`` keys in ``BENCH_sim.json``):

- ``fig6_loss_ratio_round_phase_hier_*`` — total data loss, per-round
  over per-phase budget on the same hier schedule/fabric/budget
  (> 1 means the phase window saves data at matched p99; the measured
  win is 2-4x at 512-1024 nodes);
- ``fig6_p99_ratio_ring_hier_round_*`` — the hier-schedule win itself,
  now measured at 512/1024 nodes;
- ``fig6_p99_ms_perrail_*`` — the per-rail exchange's tail (its
  m-fold smaller DCI shards cut the leader bottleneck).

Smoke tier (CI): 32-node 2-pod {hier, perrail} x {round, phase} A/B,
a few seconds, ``smoke_fig6``-prefixed keys.
"""
import time

from repro.core.transport import (BatchedSimParams, NetworkParams, SimParams,
                                  sweep, topology)

NODES = (128, 512, 1024)
OVERSUBS = (2.0, 8.0)
SCHEDULES = ("ring", "hier", "perrail")
WINDOWS = ("round", "phase")
N_PODS = 4
# budget tightening into the truncating tail regime (paper rule x this);
# shared with fig7's matched-p99 fault cells — see budgets.py.  Kept as
# module attributes too (gen_experiments and older callers read them
# from here).
try:
    from benchmarks.budgets import SMOKE_TAIL_SCALE, TAIL_SCALE  # noqa: E402
except ImportError:  # run as a script from inside benchmarks/
    from budgets import SMOKE_TAIL_SCALE, TAIL_SCALE  # noqa: E402

# 32-node smoke fabric: same burst-rate downscale the tier-1 transport
# tests use; the DCI tier keeps its (much busier) defaults.
SMOKE_PARAMS = SimParams(net=NetworkParams(n_nodes=32,
                                           burst_on_prob=0.0008))


def _emit_cell(rows, prefix, st, sched, win, tag):
    rows.append((f"{prefix}_p99_ms_{sched}_{win}_{tag}",
                 round(st.p99 / 1e3, 2), None))
    rows.append((f"{prefix}_loss_{sched}_{win}_{tag}",
                 round(st.mean_loss, 4), None))
    rows.append((f"{prefix}_dci_loss_{sched}_{win}_{tag}",
                 round(st.tier_loss("dci"), 4), None))


def run(n_rounds=60, seed=0, smoke=False, prefix="fig6", n_nodes=NODES):
    rows = []

    if smoke:
        print("\n== Fig. 6 smoke: 2-pod 32-node {hier, perrail} x "
              "{round, phase} windows (tight budget) ==")
        res = sweep(BatchedSimParams(
            n_nodes=(32,), seeds=(seed,), n_pods=(2,),
            schedules=("hier", "perrail"), windows=WINDOWS,
            designs=("roce", "celeris"), n_rounds=40,
            timeout_scale=SMOKE_TAIL_SCALE,
            base=topology.hier_params(2, base=SMOKE_PARAMS,
                                      dci_oversubscription=8.0)))
        cel = {}
        for sched in ("hier", "perrail"):
            for win in WINDOWS:
                st = res.stats[("celeris", 32, 25.0, seed, 2, sched, win)]
                cel[(sched, win)] = st
                _emit_cell(rows, prefix, st, sched, win, "p2_o8")
                print(f"{sched:8s} {win:6s} p99 {st.p99/1e3:8.2f} ms  "
                      f"loss {st.mean_loss*100:6.2f}%  "
                      f"dci loss {st.tier_loss('dci')*100:6.2f}%")
        rows.append((f"{prefix}_loss_ratio_round_phase",
                     round(max(cel[('hier', 'round')].mean_loss, 1e-4)
                           / max(cel[('hier', 'phase')].mean_loss, 1e-4),
                           3), None))
        return rows

    t0 = time.perf_counter()
    print(f"\n== Fig. 6: schedule x window policy at scale "
          f"({N_PODS} pods, {len(n_nodes)} scales x oversub {OVERSUBS}, "
          f"budget = paper rule x {TAIL_SCALE}) ==")
    print(f"{'nodes':>6s} {'oversub':>8s} {'sched':>8s} "
          f"{'round p99':>10s} {'phase p99':>10s} "
          f"{'round loss%':>12s} {'phase loss%':>12s} "
          f"{'round dci%':>11s} {'phase dci%':>11s}")
    for ov in OVERSUBS:
        res = sweep(
            BatchedSimParams(
                n_nodes=tuple(n_nodes), seeds=(seed,), n_pods=(N_PODS,),
                schedules=SCHEDULES, windows=WINDOWS,
                designs=("roce", "celeris"), n_rounds=n_rounds,
                timeout_scale=TAIL_SCALE,
                base=topology.hier_params(N_PODS,
                                          dci_oversubscription=ov)),
            progress=lambda msg: print(f"  [fig6 o={ov:.0f}] {msg}",
                                       flush=True))
        for nn in n_nodes:
            tag = f"n{nn}_o{int(ov)}"
            cel = {}
            for sched in SCHEDULES:
                for win in WINDOWS:
                    st = res.stats[("celeris", nn, 25.0, seed, N_PODS,
                                    sched, win)]
                    cel[(sched, win)] = st
                    _emit_cell(rows, prefix, st, sched, win, tag)
                r, p = cel[(sched, "round")], cel[(sched, "phase")]
                print(f"{nn:6d} {ov:8.0f} {sched:>8s} "
                      f"{r.p99/1e3:10.2f} {p.p99/1e3:10.2f} "
                      f"{r.mean_loss*100:12.2f} {p.mean_loss*100:12.2f} "
                      f"{r.tier_loss('dci')*100:11.2f} "
                      f"{p.tier_loss('dci')*100:11.2f}")
            # headline ratios: the schedule win under the tight budget,
            # and the data the per-phase budget saves on top of it
            rows.append((
                f"{prefix}_p99_ratio_ring_hier_round_{tag}",
                round(cel[("ring", "round")].p99
                      / cel[("hier", "round")].p99, 3), None))
            for sched in ("hier", "perrail"):
                rows.append((
                    f"{prefix}_loss_ratio_round_phase_{sched}_{tag}",
                    round(max(cel[(sched, "round")].mean_loss, 1e-4)
                          / max(cel[(sched, "phase")].mean_loss, 1e-4),
                          3), None))

    rows.append((f"{prefix}_wall_s",
                 round(time.perf_counter() - t0, 1), None))
    return rows


if __name__ == "__main__":
    run()
