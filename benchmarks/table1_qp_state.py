"""Paper Table I: per-QP NIC state + QP scalability."""
from repro.core import qp_state


def run():
    rows = []
    print("\n== Table I: per-QP context & scalability ==")
    print(f"{'design':10s} {'per-QP B':>9s} {'paperB':>7s} "
          f"{'rel+ord B':>10s} {'QPs@4.16MB':>11s} {'paper QPs':>10s}")
    for d in ("roce", "irn", "srnic", "celeris"):
        b = qp_state.qp_bytes(d)
        rel = qp_state.reliability_state_bytes(d)
        cap = qp_state.qp_capacity(d)
        print(f"{d:10s} {b:9d} {qp_state.PAPER_QP_BYTES[d]:7d} "
              f"{rel:10d} {cap:11d} {qp_state.PAPER_QP_SCALABILITY[d]:10d}")
        rows.append(("table1_qp_bytes_" + d, b, qp_state.PAPER_QP_BYTES[d]))
    ratio = qp_state.qp_capacity("celeris") / qp_state.qp_capacity("roce")
    rows.append(("table1_qp_density_gain", round(ratio, 2), 8.0))
    return rows
