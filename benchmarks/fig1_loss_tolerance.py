"""Paper Fig. 1: model quality stable under partial network drops (<=5%).

Trains the same smoke LM on the Markov corpus with Celeris lossy
gradient sync at several drop rates (Hadamard recovery on) and compares
final losses.  Paper claim: <=5% drop is within noise; heavy drop
degrades.
"""
import numpy as np

import repro.configs as C
from repro.data.pipeline import DataConfig
from repro.optim.adamw import OptConfig
from repro.train.train_step import CelerisConfig
from repro.train.trainer import Trainer, StragglerModel


class _FixedDrop(StragglerModel):
    def __init__(self, p):
        super().__init__()
        self.p = p

    def drop_rate(self, timeout, rng):
        return self.p


def run(steps=60, seed=0):
    cfg = C.get_smoke("qwen2-0.5b")
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                    seed=1)
    rows = []
    print("\n== Fig. 1: training quality vs drop rate (Hadamard on) ==")
    finals = {}
    for drop in (0.0, 0.01, 0.05, 0.20):
        tr = Trainer(cfg, data_cfg=dc,
                     opt_cfg=OptConfig(lr=1e-3, warmup_steps=10,
                                       total_steps=500),
                     celeris=CelerisConfig(enabled=drop > 0,
                                           min_coded_size=1024),
                     seed=seed, straggler=_FixedDrop(drop))
        h = tr.run(steps)
        final = float(np.mean(h["loss"][-10:]))
        finals[drop] = final
        print(f"drop={drop*100:5.1f}%  final loss {final:.4f}  "
              f"recv_frac {np.mean(h['recv_frac'][-10:]):.3f}")
        rows.append((f"fig1_final_loss_drop{int(drop*100)}",
                     round(final, 4), None))
    delta5 = finals[0.05] - finals[0.0]
    print(f"delta(5% vs lossless) = {delta5:+.4f}  "
          f"(paper: stable under <=5% drops)")
    rows.append(("fig1_delta_loss_at_5pct", round(delta5, 4), 0.0))
    return rows
