"""Fig. 9: tail attribution — what every p99 is *made of*.

Every other figure states a tail number; this one explains it.  The
flight-recorder telemetry layer (``transport.telemetry``) rides the
same seeded engine pass that produces figs 2-8 and decomposes each
round's critical path into serialization (DCQCN rate-throttled wire
time), queueing, RTT, PFC pause, retransmit episodes, incast
contention and fault stalls — conserving exactly to the pinned round
totals (``audit_round`` raises otherwise, and ``fig9_audit_pass``
pins that it didn't).

**Protocol.**  Per cell, one recorded ``traces()`` pass assembles both
designs under the paper window rule (RoCE median + 1 sigma, scaled by
the shared ``budgets`` factors).  Tail rounds are the >= p99 (smoke:
p90, 40-60 rounds can't resolve a p99 bucket) of the *natural*
(un-windowed) round time; the **tail excess** is the mean tail-round
component vector minus the median round's — the part of the tail that
is not just a round's base cost.

**Headline decomposition (the paper's asymmetry).**

- RoCE's tail excess carries a large *recovery* share — PFC pause +
  go-back-N retransmit storms (+ fault stalls when injected): loss
  recovery machinery amplifying the tail.  ``fig9_recovery_share_
  tailex_roce`` pins it positive and dominant over Celeris's.
- Celeris's tail excess has **zero** recovery component — by
  construction it never pauses or retransmits — so its residual tail
  is pure data-path (rate-throttled serialization + queueing):
  ``fig9_celeris_tailex_datapath_share`` = 1.0 exactly.  What RoCE
  pays in time, Celeris pays as attributed loss: the
  ``fig9_loss_*_celeris`` keys split its dropped fraction by cause
  (wire vs window cut vs fault), which is exactly the provenance the
  coupling layer forwards to training/serving.

Smoke tier (CI): the 32-node smoke fabric, flat ring, recorder on,
``smoke_fig9``-prefixed keys gated by ``check_regression
--require-all``.  Full tier adds a 128-node flat cell and a 2-pod
hierarchical cell with NIC stall faults injected (the fault component
appears in RoCE's tail, the fault cause in Celeris's loss split), and
writes a validated Perfetto trace of the faulted cell to
``results/fig9_trace.json`` (open in ui.perfetto.dev).
"""
import os
import time

import numpy as np

from repro.core.transport import (FaultParams, NetworkParams, SimParams,
                                  telemetry, topology, trace_export)
from repro.core.transport.engine import BatchedEngine

try:
    from benchmarks.budgets import SMOKE_TAIL_SCALE, TAIL_SCALE
except ImportError:  # run as a script from inside benchmarks/
    from budgets import SMOKE_TAIL_SCALE, TAIL_SCALE

NODES = 128
N_ROUNDS = 60
FAULT_CELL = {"n_pods": 2, "n_nodes": 32, "oversub": 4.0,
              "stall_rate": 3e-4, "stall_steps": 40, "n_rounds": 40}
TRACE_OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "fig9_trace.json")

SMOKE_PARAMS = SimParams(net=NetworkParams(n_nodes=32,
                                           burst_on_prob=0.0008))

_RECOVERY = [telemetry.COMPONENTS.index(c)
             for c in telemetry.RECOVERY_COMPONENTS]


def _recorded_pair(params, n_rounds, seed, tail_scale):
    """One recorded engine pass -> {design: (stats, record)} + audits."""
    rec = telemetry.TraceRecorder()
    eng = BatchedEngine(params, recorder=rec)
    tr = eng.traces(["roce", "celeris"], n_rounds, seed,
                    legacy_streams=False)
    base = eng.assemble(tr["roce"], seed)
    to = float((np.percentile(base.times_us, 50) + base.times_us.std())
               * tail_scale)
    cel = eng.assemble(tr["celeris"], seed, celeris_timeout_us=to)
    return {"roce": (base, rec.record("roce")),
            "celeris": (cel, rec.record("celeris"))}, rec


def _tail_excess(record, q):
    """Per-component tail excess: mean >= q-percentile round minus the
    median round, floored at zero (a component can't *relieve* the
    tail; tiny negative medians-vs-mean wiggle is noise)."""
    comp = record.round_components()
    tail = record.tail_rounds(q)
    ex = np.maximum(comp[tail].mean(axis=0) - np.median(comp, axis=0), 0.0)
    return ex, float(max(ex.sum(), 1e-12))


def _cell_rows(cells, q, prefix, tag, rows):
    """Shared row emission for one {design: (stats, record)} cell."""
    sfx = f"_{tag}" if tag else ""
    shares = {}
    for d, (st, r) in cells.items():
        audit = telemetry.audit_round(st, r)
        ex, tot = _tail_excess(r, q)
        rec_share = float(ex[_RECOVERY].sum() / tot)
        shares[d] = rec_share
        for i, c in enumerate(telemetry.COMPONENTS):
            v = float(ex[i] / tot)
            if v > 5e-4 or c in telemetry.RECOVERY_COMPONENTS:
                rows.append((f"{prefix}_tailex_{c}_{d}{sfx}",
                             round(v, 4), None))
        rows.append((f"{prefix}_p99_ms_{d}{sfx}",
                     round(float(np.percentile(st.times_us, 99)) / 1e3, 2),
                     None))
        print(f"  {d:>8s}{sfx}: recovery share of tail excess "
              f"{rec_share:.3f}  (time audit rel err "
              f"{audit['time_rel_err']:.1e}, pkt {audit['pkt_rel_err']:.1e})")
    # the asymmetry the paper's design implies: recovery machinery in
    # the reliable tail, none at all in the bounded-window tail
    rows.append((f"{prefix}_recovery_share_tailex_roce{sfx}",
                 round(shares["roce"], 4), None))
    rows.append((f"{prefix}_celeris_tailex_datapath_share{sfx}",
                 round(1.0 - shares["celeris"], 4), 1.0))
    rows.append((f"{prefix}_roce_recovery_gt_celeris{sfx}",
                 float(shares["roce"] > shares["celeris"] + 0.01), 1.0))
    # Celeris pays in attributed loss instead: split by cause
    _, cr = cells["celeris"]
    lr = cr.loss_rates().mean(axis=0)
    for i, c in enumerate(telemetry.CAUSES):
        rows.append((f"{prefix}_loss_{c}_celeris{sfx}",
                     round(float(lr[i]), 4), None))
    print("  celeris loss by cause: " + "  ".join(
        f"{c}={lr[i]:.4f}" for i, c in enumerate(telemetry.CAUSES)))
    return shares


def run(n_rounds=N_ROUNDS, seed=0, smoke=False, prefix="fig9",
        write_trace=True):
    rows = []
    t0 = time.perf_counter()

    if smoke:
        print("\n== Fig. 9 smoke: 32-node tail attribution, recorder on ==")
        cells, _ = _recorded_pair(SMOKE_PARAMS, 60, seed, SMOKE_TAIL_SCALE)
        _cell_rows(cells, 90.0, prefix, "", rows)
        rows.append((f"{prefix}_audit_pass", 1.0, 1.0))
        return rows

    print(f"\n== Fig. 9: tail attribution ({NODES}-node flat ring) ==")
    p = SimParams(net=NetworkParams(n_nodes=NODES))
    cells, _ = _recorded_pair(p, n_rounds, seed, TAIL_SCALE)
    _cell_rows(cells, 99.0, prefix, "", rows)

    fc = FAULT_CELL
    print(f"\n-- {fc['n_pods']}-pod hier cell, NIC stalls "
          f"(rate {fc['stall_rate']:g}) --")
    fp = FaultParams(stall_rate=fc["stall_rate"],
                     stall_steps=fc["stall_steps"])
    hp = topology.hier_params(
        fc["n_pods"],
        base=SimParams(net=NetworkParams(n_nodes=fc["n_nodes"],
                                         burst_on_prob=0.0008)),
        dci_oversubscription=fc["oversub"], fault=fp)
    rec = telemetry.TraceRecorder()
    stats = topology.hier_protocol(hp, fc["n_rounds"], seed + 1,
                                   timeout_scale=TAIL_SCALE, recorder=rec)
    fcells = {d: (stats[d], rec.record(d)) for d in ("roce", "celeris")}
    _cell_rows(fcells, 90.0, prefix, "fault", rows)
    # the fault component must show up in RoCE's attributed tail and
    # the fault cause in Celeris's loss split — injected faults are
    # visible end-to-end, not smeared into "queueing"
    _, rr = fcells["roce"]
    fshare = float(rr.round_components()[:, telemetry.COMPONENTS.index(
        "fault")].sum() / max(rr.round_components().sum(), 1e-12))
    rows.append((f"{prefix}_fault_visible_roce", float(fshare > 0.0), 1.0))
    _, cr = fcells["celeris"]
    rows.append((f"{prefix}_fault_loss_visible_celeris",
                 float(cr.loss_rates()[:, telemetry.CAUSES.index(
                     "fault")].sum() > 0.0), 1.0))
    prov = telemetry.provenance_from_record(cr, "cross")
    print(f"  cross-axis provenance: {prov.describe()}")

    if write_trace:
        os.makedirs(os.path.dirname(TRACE_OUT), exist_ok=True)
        counts = trace_export.write_trace(rec, TRACE_OUT,
                                          meta={"figure": "fig9",
                                                "cell": "fault"})
        n_slices = counts.get("X", 0)
        print(f"  perfetto trace -> {TRACE_OUT} "
              f"({n_slices} slices, validated)")

    rows.append((f"{prefix}_audit_pass", 1.0, 1.0))
    print(f"\nfig9 headline: recovery machinery in the RoCE tail, "
          f"zero in Celeris's  [{time.perf_counter()-t0:.0f} s]")
    return rows


if __name__ == "__main__":
    run()
