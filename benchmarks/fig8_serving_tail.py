"""Fig. 8: serve-path token-latency tail under lossy transport.

The "millions of users" workload (ROADMAP item 1): a disaggregated
serving mesh ships KV caches prefill→decode as point-to-point transport
flows (``serve/traffic.py`` builds the incast ``FlowPlan``; the engine
charges per-receiver contention on the decode ports).  An open-loop
Poisson request process — identical arrival times for every design —
feeds a FIFO block queue over each design's engine rounds, and the
serving SLO quantity is **time-to-first-token p99 vs load vs design**:

- RoCE/IRN retransmit into the incast: their rounds run far past the
  unloaded reference, so offered load near 1 is effective load >> 1 and
  the queue (hence token p99) blows up.
- Celeris pins its round at an SLO budget (``SLO_SCALE`` x the natural
  median — the serving deadline, set once from its own clean trace):
  rounds stay bounded, the queue stays stable, and the price is cut KV
  blocks (``recv_frac < 1`` on slow rounds).

The cut blocks are the coded-KV story: each affected request's
delivered fraction becomes a wire-row hole mask
(``coupling.kv_hole_masks``) and a real decode runs from the degraded
caches (``serve_step.degrade_caches`` on the smoke LM).  Uncoded
shipping loses contiguous chunks — whole cache positions gone at the
decode node; the Hadamard layout (``core/coding.py``) spreads the same
loss as small dense noise over every position.  Recovery is the
**usable-context fraction**: cache positions whose K/V relative error
stays under ``TAU`` after transfer (the serving analogue of the
trainer's gradient-recovery metric); the paper-regime claim is coded
recovery >= 0.9 at the delivered fraction Celeris actually measured at
the highest swept load.

Smoke tier (CI): 32-node mesh (28 prefill -> 4 decode), two loads,
``smoke_fig8``-prefixed keys gated by ``check_regression
--require-all``.
"""
import dataclasses
import time

import numpy as np

import repro.configs as C
from repro.core.transport import BatchedEngine, SimParams
from repro.core.transport import coupling
from repro.serve import traffic

# full tier: 128-node mesh, 16-node decode pod (fan-in 7)
FULL_TP = traffic.ServeTrafficParams(n_prefill=112, n_decode=16)
LOADS = (0.5, 0.75, 0.9)
DESIGNS = ("roce", "irn", "celeris")
N_ROUNDS = 300

# smoke tier: 32-node mesh, same fan-in
SMOKE_TP = traffic.ServeTrafficParams(n_prefill=28, n_decode=4)
SMOKE_LOADS = (0.6, 0.9)
SMOKE_ROUNDS = 120

# Celeris serving SLO: the bounded round deadline, as a multiple of the
# design's own natural (uncut) median round — the serving counterpart
# of the paper's "median + sigma" training rule, set once per scenario
SLO_SCALE = 1.1

# coded-KV recovery cell: wire rows per payload and the usable-context
# error threshold (positions with K/V relative error <= TAU still serve
# their context faithfully)
N_ROT = 64
TAU = 0.6
RECOVERY_GEN = 8        # decode tokens checked from the degraded cache


def _ltag(load):
    return f"{load:g}".replace(".", "p")


def _engine_rounds(tp, n_rounds, seed):
    """One physics pass per design over the static KV incast plan.

    Returns per-design ``(times_us, recv_frac)`` plus the Celeris SLO
    budget.  The plan (and so the physics) is load-independent — load
    lives in the arrival process — so one pass serves every load.
    """
    net = traffic.serve_net_params(tp)
    params = SimParams(net=dataclasses.replace(net, burst_on_prob=0.0008))
    eng = BatchedEngine(params, plan=traffic.kv_flow_plan(tp))
    tr = eng.traces(list(DESIGNS), n_rounds, seed, legacy_streams=False)
    steps = tr["celeris"].steps_per_round
    nat_rounds = tr["celeris"].nat_us.reshape(-1, steps).sum(axis=1)
    budget = float(np.percentile(nat_rounds, 50)) * SLO_SCALE
    out = {}
    for d in DESIGNS:
        if d == "celeris":
            st = eng.assemble(tr[d], seed, celeris_timeout_us=budget,
                              adaptive=False, window="round")
        else:
            st = eng.assemble(tr[d], seed)
        out[d] = st
    return out, budget


def _recovery_cell(kv_frac, seed, rows, prefix, tag):
    """Decode the smoke LM from caches degraded at ``kv_frac``.

    Emits usable-context fractions (coded vs uncoded) and the coded
    path's greedy-token agreement vs the clean decode.
    """
    import jax
    import jax.numpy as jnp
    from repro.models import model as M
    from repro.serve import serve_step

    cfg = C.get_smoke("qwen2-0.5b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    plen = 48
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, plen), 0,
                                cfg.vocab_size)
    prefill = serve_step.make_prefill(cfg, plen + RECOVERY_GEN)
    # reference caches are kept for the error metric; decode donates its
    # cache argument, so every decode gets its own prefill
    logits, clean_caches = prefill(params, {"tokens": prompt})
    first = jnp.argmax(logits, -1)[:, None]
    _, scratch = prefill(params, {"tokens": prompt})
    clean_toks = serve_step.greedy_decode(cfg, params, scratch, first,
                                          plen, RECOVERY_GEN)
    mask = jnp.asarray(coupling.kv_hole_masks(
        np.array([kv_frac]), N_ROT, seed=seed)[0])
    key = jax.random.PRNGKey(42)
    out = {}
    for coded in (True, False):
        _, caches = prefill(params, {"tokens": prompt})
        caches = serve_step.degrade_caches(caches, mask, key, coded=coded)
        err = serve_step.kv_position_error(clean_caches, caches, plen)
        usable = float((err <= TAU).mean())
        toks = serve_step.greedy_decode(cfg, params, caches, first, plen,
                                        RECOVERY_GEN)
        agree = float((toks == clean_toks).mean())
        out[coded] = (usable, agree)
        kind = "coded" if coded else "uncoded"
        rows.append((f"{prefix}_kv_recovery_{kind}_{tag}", round(usable, 4),
                     0.9 if coded else None))
        print(f"  kv_frac {kv_frac:.3f} {kind:>7s}: usable context "
              f"{usable:.3f}  token agreement {agree:.3f}")
    rows.append((f"{prefix}_token_agree_coded_{tag}",
                 round(out[True][1], 4), None))
    return out


def run(seed=0, n_rounds=None, smoke=False, prefix="fig8"):
    t0 = time.perf_counter()
    tp0 = SMOKE_TP if smoke else FULL_TP
    loads = SMOKE_LOADS if smoke else LOADS
    n_rounds = n_rounds or (SMOKE_ROUNDS if smoke else N_ROUNDS)
    rows = []

    print(f"\n== Fig. 8: serving token p99 vs load vs design "
          f"({tp0.n_prefill} prefill -> {tp0.n_decode} decode, fan-in "
          f"{tp0.fan_in}, {n_rounds} rounds) ==")
    stats, budget = _engine_rounds(tp0, n_rounds, seed)
    print(f"SLO budget {budget/1e3:.2f} ms/round; engine round p99: "
          + "  ".join(f"{d} {stats[d].p99/1e3:.2f} ms" for d in DESIGNS))
    rows.append((f"{prefix}_slo_round_ms", round(budget / 1e3, 3), None))
    rows.append((f"{prefix}_kv_loss_celeris",
                 round(stats["celeris"].mean_loss, 4), None))

    hiload = loads[-1]
    p99 = {}
    kv_frac_tail = 1.0
    for load in loads:
        tp = dataclasses.replace(tp0, load=load)
        tag = _ltag(load)
        for d in DESIGNS:
            st = stats[d]
            trace = traffic.request_trace(tp, float(st.times_us.sum()),
                                          budget, seed)
            sim = traffic.simulate_serving(tp, st.times_us, st.recv_frac,
                                           trace)
            p99[(d, load)] = sim.p99_latency_us
            rows.append((f"{prefix}_token_p99_ms_{d}_load{tag}",
                         round(sim.p99_latency_us / 1e3, 2), None))
            rows.append((f"{prefix}_completion_{d}_load{tag}",
                         round(sim.completion_frac, 4), None))
            if d == "celeris":
                rows.append((f"{prefix}_kv_frac_celeris_load{tag}",
                             round(sim.mean_kv_frac, 4), None))
                if load == hiload and sim.completed.any():
                    # the requests the coding exists for: the tail that
                    # rode the window-cut rounds
                    kv_frac_tail = float(np.percentile(
                        sim.kv_frac[sim.completed], 1))
            print(f"load {load:4.2f} {d:>8s}: token p99 "
                  f"{sim.p99_latency_us/1e3:9.2f} ms  completed "
                  f"{sim.completion_frac*100:5.1f}%  ({trace.n_requests} "
                  f"requests, kv {sim.mean_kv_frac:.3f})")

    # the figure's headline: at the highest load the bounded window
    # keeps the queue stable while the reliable designs melt
    ratio = p99[("roce", hiload)] / max(p99[("celeris", hiload)], 1e-9)
    rows.append((f"{prefix}_p99_ratio_roce_celeris_hiload",
                 round(min(ratio, 1000.0), 2), None))
    rows.append((f"{prefix}_celeris_beats_roce_hiload",
                 float(p99[("celeris", hiload)] < p99[("roce", hiload)]),
                 1.0))

    # coded-KV recovery at the tail delivered fraction Celeris actually
    # measured at the highest load (p1 over completed requests — the
    # requests whose rounds the window cut; clamped away from both the
    # degenerate no-loss case and catastrophic loss)
    f_cell = float(np.clip(kv_frac_tail, 0.5, 0.95))
    rows.append((f"{prefix}_kv_frac_tail_celeris_hiload",
                 round(f_cell, 4), None))
    print(f"-- coded-KV recovery at tail delivered fraction {f_cell:.3f} "
          f"(celeris p1, load {hiload:g}) --")
    _recovery_cell(f_cell, seed, rows, prefix, "hiload")

    print(f"fig8 headline: roce/celeris token p99 ratio at load "
          f"{hiload:g} = {ratio:.1f}x  [{time.perf_counter()-t0:.0f} s]")
    return rows


if __name__ == "__main__":
    run()
