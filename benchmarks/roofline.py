"""Roofline table from the dry-run artifacts (results/dryrun/*.json)."""
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def run():
    rows = []
    files = sorted(glob.glob(os.path.join(RESULTS, "*__16x16.json")))
    if not files:
        print("\n== roofline: no dry-run artifacts yet "
              "(run python -m repro.launch.dryrun --all) ==")
        return rows
    print("\n== Roofline (single-pod 16x16, per-step seconds) ==")
    print(f"{'arch':22s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
          f"{'coll':>9s} {'dominant':>10s} {'useful':>7s} {'peakGiB':>8s}")
    for f in files:
        rec = json.load(open(f))
        if rec.get("skipped"):
            continue
        rl = rec["roofline"]
        print(f"{rec['arch']:22s} {rec['shape']:12s} "
              f"{rl['compute_s']:9.3f} {rl['memory_s']:9.3f} "
              f"{rl['collective_s']:9.3f} {rl['dominant']:>10s} "
              f"{rl['useful_flops_ratio']:7.2f} "
              f"{rec['memory']['peak_bytes']/2**30:8.2f}")
        rows.append((f"roofline_{rec['arch']}_{rec['shape']}_dominant_s",
                     round(max(rl['compute_s'], rl['memory_s'],
                               rl['collective_s']), 3), None))
    return rows
