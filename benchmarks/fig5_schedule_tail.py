"""Fig. 5 (beyond-paper): collective schedules vs the cross-pod tail.

The schedule A/B the hardcoded flat ring could never ask: on the *same*
hierarchical fabric (same pods, same DCI oversubscription, same seed),
does a hierarchy-aware reduce-scatter/all-gather schedule
(:class:`repro.core.transport.schedule.HierarchicalSchedule`: RS within
pod → pod-leader DCI exchange of 1/n_pods shards → AG within pod) move
the tail versus the flat ``2(N-1)``-step ring?

1. **Schedule sweep** — ring vs hier across pod count x DCI
   oversubscription at 128 nodes, via the engine's new
   ``BatchedSimParams.schedules`` dimension.  Per cell: Celeris round
   p99 (window fixed by the RoCE baseline *per schedule*, paper rule)
   and the DCI tier's data loss.  Headline: the hierarchical schedule
   pays the oversubscription penalty on ``2(n_pods-1)`` leader steps
   instead of every one of ``2(N-1)`` hops, so its p99 lands well below
   the ring's once the DCI is oversubscribed (>= 2:1) — recorded as
   ``fig5_p99_ratio_*`` (ring/hier, > 1 means hier wins).

2. **Hot pod** — the per-pod oversubscription vector
   (``TopologyParams.dci_oversubscription`` as a tuple): one pod at 8:1
   while the rest sit at 2:1, versus uniform 2:1 — the asymmetric
   scenario a scalar knob cannot express.

Smoke tier (CI): 2-pod 32-node ring-vs-hier A/B, ~5 s,
``smoke_fig5``-prefixed keys.
"""
import numpy as np

from repro.core.transport import (BatchedSimParams, NetworkParams, SimParams,
                                  sweep, topology)

POD_COUNTS = (2, 4)
OVERSUBS = (2.0, 4.0, 8.0)
SWEEP_NODES = 128

# hot-pod cell: 4 pods, one uplink 4x worse than the rest
HOTPOD_BASE = 2.0
HOTPOD_HOT = 8.0

# 32-node smoke fabric: same burst-rate downscale the tier-1 transport
# tests use; the DCI tier keeps its (much busier) defaults.
SMOKE_PARAMS = SimParams(net=NetworkParams(n_nodes=32,
                                           burst_on_prob=0.0008))


def _cell(n_pods, oversub, n_rounds, seed, *, base=None, n_nodes=None):
    """{schedule: celeris RoundStats} for one fabric configuration."""
    out = {}
    for sched in ("ring", "hier"):
        p = topology.hier_params(n_pods, base=base, n_nodes=n_nodes,
                                 dci_oversubscription=oversub,
                                 schedule=sched)
        out[sched] = topology.hier_protocol(p, n_rounds=n_rounds,
                                            seed=seed)["celeris"]
    return out


def run(n_rounds=100, seed=0, smoke=False, prefix="fig5"):
    rows = []

    if smoke:
        print("\n== Fig. 5 smoke: 2-pod 32-node ring vs hierarchical "
              "schedule ==")
        cell = _cell(2, 8.0, 60, seed, base=SMOKE_PARAMS)
        ratio = cell["ring"].p99 / cell["hier"].p99
        for sched in ("ring", "hier"):
            rows.append((f"{prefix}_p99_ms_{sched}",
                         round(cell[sched].p99 / 1e3, 2), None))
        rows.append((f"{prefix}_dci_loss_hier",
                     round(cell["hier"].tier_loss("dci"), 4), None))
        rows.append((f"{prefix}_p99_ratio", round(ratio, 3), 1.0))
        print(f"ring p99 {cell['ring'].p99/1e3:.2f} ms, hier p99 "
              f"{cell['hier'].p99/1e3:.2f} ms -> ratio {ratio:.2f}x")
        return rows

    print(f"\n== Fig. 5: collective schedule x DCI oversubscription x pod "
          f"count ({SWEEP_NODES}-node hierarchical fabric) ==")
    print(f"{'pods':>5s} {'oversub':>8s} {'ring p99':>9s} {'hier p99':>9s} "
          f"{'ratio':>6s} {'ring dci%':>10s} {'hier dci%':>10s}")
    worst_ratio = np.inf
    uniform_hier = None       # the hot-pod section's uniform baseline
    for npods in POD_COUNTS:
        for ov in OVERSUBS:
            res = sweep(BatchedSimParams(
                n_nodes=(SWEEP_NODES,), seeds=(seed,), n_pods=(npods,),
                schedules=("ring", "hier"), designs=("roce", "celeris"),
                n_rounds=n_rounds,
                base=topology.hier_params(npods,
                                          dci_oversubscription=ov)))
            p99 = {s: res.p99_vs_schedule("celeris")[s][0]
                   for s in ("ring", "hier")}
            cel = {key[-1]: st for key, st in res.stats.items()
                   if key[0] == "celeris"}
            dci = {s: st.tier_loss("dci") for s, st in cel.items()}
            if npods == 4 and ov == HOTPOD_BASE:
                uniform_hier = cel["hier"]
            ratio = p99["ring"] / p99["hier"]
            worst_ratio = min(worst_ratio, ratio)
            tag = f"p{npods}_o{int(ov)}"
            for s in ("ring", "hier"):
                rows.append((f"{prefix}_p99_ms_{s}_{tag}",
                             round(p99[s] / 1e3, 2), None))
                rows.append((f"{prefix}_dci_loss_{s}_{tag}",
                             round(dci[s], 4), None))
            rows.append((f"{prefix}_p99_ratio_{tag}", round(ratio, 3), 1.0))
            print(f"{npods:5d} {ov:8.0f} {p99['ring']/1e3:9.2f} "
                  f"{p99['hier']/1e3:9.2f} {ratio:6.2f} "
                  f"{dci['ring']*100:10.2f} {dci['hier']*100:10.2f}")

    print(f"\n== Fig. 5 hot pod: per-pod oversubscription vector "
          f"(4 pods, one at {HOTPOD_HOT:.0f}:1, rest {HOTPOD_BASE:.0f}:1) ==")
    p = topology.hier_params(
        4, n_nodes=SWEEP_NODES, schedule="hier",
        dci_oversubscription=(HOTPOD_HOT,) + (HOTPOD_BASE,) * 3)
    hot = topology.hier_protocol(p, n_rounds=n_rounds, seed=seed)["celeris"]
    # the uniform baseline is the sweep's (4 pods, oversub 2, hier) cell
    for name, cel in (("uniform", uniform_hier), ("hotpod", hot)):
        rows.append((f"{prefix}_{name}_p99_ms", round(cel.p99 / 1e3, 2),
                     None))
        print(f"{name:8s} p99 {cel.p99/1e3:8.2f} ms  "
              f"dci loss {cel.tier_loss('dci')*100:.2f}%")

    verdict = "PASS" if worst_ratio > 1.0 else "FAIL"
    print(f"\nhierarchical schedule beats the flat ring in every "
          f"oversubscribed cell (min ring/hier p99 ratio "
          f"{worst_ratio:.2f}x, claim: > 1) -> {verdict}")
    return rows


if __name__ == "__main__":
    run()
