"""Fig. 7 (paper headline): NIC fault injection and resilience.

The abstract's third claim — "nearly doubles NIC resilience to faults"
— measured with the seeded fault model (``FaultParams`` /
``transport.faults``): NIC stalls, NIC crashes (with restart), link
flaps, rail failures, and slow-NIC stragglers, injected into the same
whole-trace engine that produces every other figure (zero-fault runs
stay bit-exact with the committed seed stats).

**Protocol (matched p99/goodput).**  Per collective schedule the
Celeris budget is fixed from the *clean* trace by the paper rule (RoCE
median + 1 sigma) tightened by the shared ``budgets.TAIL_SCALE``; the
fault-rate sweep then runs with that budget pinned, so a design never
"sustains" a fault rate by quietly relaxing its deadline.  A design
*sustains* a fault rate when, relative to its own clean run on the same
schedule:

- round p99 <= ``P99_SLACK`` x clean p99 (the reliable designs' failure
  mode: blocked flows retransmit into the stall and the tail blows up),
  and
- mean normalized goodput >= ``GOODPUT_FLOOR`` x its *clean* mean
  goodput (Celeris's failure mode: the bounded window cuts the faulted
  flows, so its p99 holds by construction and data loss is what
  degrades).  Both sides are design-relative — a heavy clean tail is
  the fabric's contention story (figs 2/4/6), not a fault effect, so
  it must not leak into the resilience scan.

The **resilience ratio** per (kind, schedule) is the highest sustained
fault rate of Celeris over that of the RoCE baseline, scanned
monotonically up the rate grid; the paper-regime claim is ratio >= ~2
(``fig7_resilience_ratio_*`` keys, threshold 2.0).

**Blast radius.**  A rail failure under the ``hier`` leader exchange
(leaders are rank 0 = rail 0) kills the *entire* DCI phase; under
``perrail`` it kills 1/m of the rails.  The ``fig7_rail_*`` keys pin
the asymmetry (perrail's DCI loss strictly smaller at the same rail
failure rate).

**End-to-end.**  The faulted 2-pod engine feeds
``coupling.split_schedule_from_engine(fault=...)`` and the smoke LM
trains under ``CollectiveMode.HIERARCHICAL`` — the faulted pods' drop
masks reach the gradients, and recovery vs the exact baseline stays
>= 0.9 at the paper-regime fault cell (``fig7_recovery``).

Smoke tier (CI): 32-node 2-pod hier, clean + two stall rates,
``smoke_fig7``-prefixed keys gated by ``check_regression
--require-all``.  Full tier adds a 512-node stall cell.
"""
import time

import numpy as np

import repro.configs as C
from repro.core.transport import (BatchedSimParams, FaultParams,
                                  NetworkParams, SimParams, coupling,
                                  sweep, topology)

try:
    from benchmarks.budgets import SMOKE_TAIL_SCALE, TAIL_SCALE
    from benchmarks import fig4_cross_pod_tail as f4
except ImportError:  # run as a script from inside benchmarks/
    from budgets import SMOKE_TAIL_SCALE, TAIL_SCALE
    import fig4_cross_pod_tail as f4

NODES = 128
N_PODS = 4
OVERSUB = 4.0
SCHEDULES = ("ring", "hier", "perrail")
SCALE_NODES = 512          # the big-fabric stall cell (full tier only)

# sustainability criterion (see module docstring)
P99_SLACK = 1.5
GOODPUT_FLOOR = 0.8

# fault-rate grids, low -> high.  Rates are per node-step (stall,
# crash), per edge-step (flap) or per round (rail); the grids bracket
# the regime where the RoCE baseline stops sustaining but Celeris still
# does — the resilience ratio reads directly off the scan.
RATE_GRID = {
    "stall": (1e-5, 3e-5, 1e-4, 3e-4, 1e-3),
    "crash": (3e-6, 1e-5, 3e-5, 1e-4, 3e-4),
    "flap": (1e-4, 3e-4, 1e-3, 3e-3, 1e-2),
}
FAULT_KW = {"crash": {"crash_restart_steps": 64}}
RAIL_RATES = (0.1, 0.3)
PAPER_CELL = ("stall", 1e-4)    # the paper-regime fault cell
RECOVERY_PODS = 2

SMOKE_PARAMS = SimParams(net=NetworkParams(n_nodes=32,
                                           burst_on_prob=0.0008))
SMOKE_OVERSUB = 2.0     # milder DCI tier: fault signal, not contention
SMOKE_RATES = (3e-4, 3e-3)


def _rtag(rate):
    """Key-safe rate tag: 3e-05 -> '3em05'."""
    return f"{rate:g}".replace(".", "p").replace("-", "m").replace("+", "")


def _goodput(st):
    """Absolute mean goodput (delivered fraction per unit time).  The
    fault overlay never perturbs the contention streams, so a faulted
    run and its clean pass are perfectly paired round-for-round —
    comparing absolute goodput between them isolates the fault effect
    from the fabric's contention variance."""
    return float(np.mean(st.recv_frac / np.maximum(st.times_us, 1e-9)))


def _gupf(st, clean_st):
    """Paired goodput-under-failure: the faulted rounds' mean goodput
    over the *same rounds* of the paired clean run (same seed, same
    contention trace).  Removes the cross-round skew that makes the
    within-trace ``RoundStats.goodput_under_failure`` noisy when only a
    handful of rounds fault."""
    f = st.faulted
    if not f.any():
        return 1.0
    g = st.recv_frac / np.maximum(st.times_us, 1e-9)
    g0 = clean_st.recv_frac / np.maximum(clean_st.times_us, 1e-9)
    return float(g[f].mean() / max(float(g0[f].mean()), 1e-30))


def _sustained(st, clean):
    return (st.p99 <= P99_SLACK * clean.p99
            and _goodput(st) >= GOODPUT_FLOOR * _goodput(clean))


def _max_sustained(cells, clean, rates):
    """Monotone scan up the grid: highest rate with every rate at or
    below it sustained.  0.0 if even the lowest rate fails."""
    best = 0.0
    for r in rates:
        if not _sustained(cells[r], clean):
            break
        best = r
    return best


def _fault_sweep(base, nn, npods, sched, kinds_rates, budget, n_rounds,
                 seed, progress=None):
    """One pinned-budget sweep over a list of (kind, rate) cells."""
    faults = tuple(FaultParams.of_kind(k, r, **FAULT_KW.get(k, {}))
                   for k, r in kinds_rates)
    res = sweep(BatchedSimParams(
        n_nodes=(nn,), seeds=(seed,), n_pods=(npods,), schedules=(sched,),
        designs=("roce", "celeris"), n_rounds=n_rounds,
        celeris_timeout_us=budget, faults=faults, base=base),
        progress=progress)
    return {(k, r): {d: res.stats[res._key(d, nn, 25.0, seed, npods,
                                           sched, "round", fp.tag)]
                     for d in ("roce", "celeris")}
            for (k, r), fp in zip(kinds_rates, faults)}


def _clean_pass(base, nn, npods, sched, n_rounds, seed, tail_scale,
                progress=None):
    """Clean (fault-free) stats + the pinned Celeris budget."""
    res = sweep(BatchedSimParams(
        n_nodes=(nn,), seeds=(seed,), n_pods=(npods,), schedules=(sched,),
        designs=("roce", "celeris"), n_rounds=n_rounds,
        timeout_scale=tail_scale, base=base), progress=progress)
    clean = {d: res.stats[res._key(d, nn, 25.0, seed, npods, sched)]
             for d in ("roce", "celeris")}
    roce = clean["roce"]
    budget = float((np.percentile(roce.times_us, 50) + roce.times_us.std())
                   * tail_scale)
    return clean, budget


def run(steps=40, seed=0, n_rounds=60, smoke=False, prefix="fig7",
        scale_cell=True):
    rows = []

    if smoke:
        print("\n== Fig. 7 smoke: 2-pod 32-node hier, stall faults at "
              "pinned budget ==")
        base = topology.hier_params(2, base=SMOKE_PARAMS,
                                    dci_oversubscription=SMOKE_OVERSUB)
        clean, budget = _clean_pass(base, 32, 2, "hier", 40, seed,
                                    SMOKE_TAIL_SCALE)
        cells = _fault_sweep(base, 32, 2, "hier",
                             [("stall", r) for r in SMOKE_RATES],
                             budget, 40, seed)
        rows.append((f"{prefix}_p99_ms_roce_clean",
                     round(clean["roce"].p99 / 1e3, 2), None))
        rows.append((f"{prefix}_p99_ms_celeris_clean",
                     round(clean["celeris"].p99 / 1e3, 2), None))
        for r in SMOKE_RATES:
            cel, roc = cells[("stall", r)]["celeris"], cells[("stall", r)]["roce"]
            tag = _rtag(r)
            gupf = _gupf(cel, clean["celeris"])
            rows.append((f"{prefix}_p99_ms_roce_stall_{tag}",
                         round(roc.p99 / 1e3, 2), None))
            rows.append((f"{prefix}_gupf_celeris_stall_{tag}",
                         round(gupf, 4), None))
            rows.append((f"{prefix}_loss_celeris_stall_{tag}",
                         round(cel.mean_loss, 4), None))
            print(f"stall {r:g}: roce p99 {roc.p99/1e3:8.2f} ms "
                  f"(clean {clean['roce'].p99/1e3:.2f})  "
                  f"celeris p99 {cel.p99/1e3:.2f} ms  "
                  f"loss {cel.mean_loss*100:5.2f}%  gupf {gupf:.3f}")
        # the smoke resilience check: at the high smoke rate celeris
        # still sustains while roce's tail has blown past the slack
        hi = cells[("stall", SMOKE_RATES[-1])]
        rows.append((f"{prefix}_celeris_sustains_hi",
                     float(_sustained(hi["celeris"], clean["celeris"])),
                     1.0))
        rows.append((f"{prefix}_roce_p99_blowup_hi",
                     round(hi["roce"].p99 / clean["roce"].p99, 2), None))
        return rows

    t0 = time.perf_counter()
    base = topology.hier_params(N_PODS, dci_oversubscription=OVERSUB)
    print(f"\n== Fig. 7: fault rate x kind x design x schedule "
          f"({NODES} nodes, {N_PODS} pods, oversub {OVERSUB:.0f}, "
          f"budget = paper rule x {TAIL_SCALE}) ==")

    ratios = {}
    for sched in SCHEDULES:
        clean, budget = _clean_pass(
            base, NODES, N_PODS, sched, n_rounds, seed, TAIL_SCALE,
            progress=lambda m: print(f"  [fig7 clean] {m}", flush=True))
        kinds_rates = [(k, r) for k in RATE_GRID for r in RATE_GRID[k]]
        cells = _fault_sweep(
            base, NODES, N_PODS, sched, kinds_rates, budget, n_rounds,
            seed, progress=lambda m: print(f"  [fig7] {m}", flush=True))
        print(f"\n-- schedule {sched} (clean roce p99 "
              f"{clean['roce'].p99/1e3:.2f} ms, celeris "
              f"{clean['celeris'].p99/1e3:.2f} ms, budget "
              f"{budget/1e3:.2f} ms) --")
        print(f"{'kind':>6s} {'rate':>8s} {'roce p99':>9s} {'roce gp':>8s} "
              f"{'cel p99':>8s} {'cel gp':>7s} {'cel loss%':>10s} "
              f"{'sustained':>16s}")
        for k in RATE_GRID:
            per_rate = {}
            for r in RATE_GRID[k]:
                cell = cells[(k, r)]
                per_rate[r] = cell
                roc, cel = cell["roce"], cell["celeris"]
                sus = (("roce" if _sustained(roc, clean["roce"]) else "-")
                       + "/" + ("cel" if _sustained(cel, clean["celeris"])
                                else "-"))
                print(f"{k:>6s} {r:8.0e} {roc.p99/1e3:9.2f} "
                      f"{_goodput(roc)/_goodput(clean['roce']):8.3f} "
                      f"{cel.p99/1e3:8.2f} "
                      f"{_goodput(cel)/_goodput(clean['celeris']):7.3f} "
                      f"{cel.mean_loss*100:10.2f} {sus:>16s}")
                tag = f"{k}_{_rtag(r)}_{sched}"
                rows.append((f"{prefix}_p99_ms_roce_{tag}",
                             round(roc.p99 / 1e3, 2), None))
                rows.append((f"{prefix}_gupf_celeris_{tag}",
                             round(_gupf(cel, clean["celeris"]), 4),
                             None))
            roce_max = _max_sustained(
                {r: per_rate[r]["roce"] for r in RATE_GRID[k]},
                clean["roce"], RATE_GRID[k])
            cel_max = _max_sustained(
                {r: per_rate[r]["celeris"] for r in RATE_GRID[k]},
                clean["celeris"], RATE_GRID[k])
            # floor the denominator at half the lowest grid rate so a
            # baseline that sustains nothing reads as "ratio vs below
            # the grid", not infinity; cap the report symmetrically
            ratio = min(cel_max / max(roce_max, RATE_GRID[k][0] / 2),
                        100.0)
            ratios[(k, sched)] = ratio
            rows.append((f"{prefix}_max_rate_roce_{k}_{sched}",
                         roce_max, None))
            rows.append((f"{prefix}_max_rate_celeris_{k}_{sched}",
                         cel_max, None))
            rows.append((f"{prefix}_resilience_ratio_{k}_{sched}",
                         round(ratio, 2), 2.0))
            print(f"   -> {k}: max sustained rate roce {roce_max:g}, "
                  f"celeris {cel_max:g}, resilience ratio {ratio:.1f}x")
        # recovery time at the paper-regime cell
        if PAPER_CELL in cells:
            cel = cells[PAPER_CELL]["celeris"]
            rows.append((f"{prefix}_recovery_rounds_celeris_"
                         f"{PAPER_CELL[0]}_{sched}",
                         round(cel.recovery_rounds(), 2), None))
            rows.append((f"{prefix}_gupf_paper_cell_{sched}",
                         round(_gupf(cel, clean["celeris"]), 4), None))

    # rail-failure blast radius: hier leader exchange vs perrail
    print("\n-- rail failure blast radius (hier vs perrail) --")
    dci = {}
    for sched in ("hier", "perrail"):
        clean, budget = _clean_pass(base, NODES, N_PODS, sched,
                                    n_rounds, seed, TAIL_SCALE)
        cells = _fault_sweep(base, NODES, N_PODS, sched,
                             [("rail", r) for r in RAIL_RATES],
                             budget, n_rounds, seed)
        for rate in RAIL_RATES:
            cel = cells[("rail", rate)]["celeris"]
            dci[(sched, rate)] = cel.tier_loss("dci")
            gupf = _gupf(cel, clean["celeris"])
            tag = f"rail_{_rtag(rate)}_{sched}"
            rows.append((f"{prefix}_dci_loss_{tag}",
                         round(dci[(sched, rate)], 4), None))
            rows.append((f"{prefix}_gupf_celeris_{tag}",
                         round(gupf, 4), None))
            print(f"rail rate {rate:g} {sched:>8s}: dci loss "
                  f"{dci[(sched, rate)]*100:6.2f}%  gupf {gupf:.3f}")
    for rate in RAIL_RATES:
        rows.append((f"{prefix}_rail_blast_ratio_{_rtag(rate)}",
                     round(dci[('hier', rate)]
                           / max(dci[('perrail', rate)], 1e-4), 2),
                     None))

    # the 512-node stall cell (scale check for the nightly job)
    if scale_cell:
        print(f"\n-- {SCALE_NODES}-node stall cell --")
        clean, budget = _clean_pass(
            base, SCALE_NODES, N_PODS, "hier", n_rounds, seed, TAIL_SCALE,
            progress=lambda m: print(f"  [fig7 n{SCALE_NODES}] {m}",
                                     flush=True))
        cell = _fault_sweep(base, SCALE_NODES, N_PODS, "hier",
                            [PAPER_CELL], budget, n_rounds,
                            seed)[PAPER_CELL]
        roc, cel = cell["roce"], cell["celeris"]
        rows.append((f"{prefix}_p99_ms_roce_stall_n{SCALE_NODES}",
                     round(roc.p99 / 1e3, 2), None))
        rows.append((f"{prefix}_p99_ms_celeris_stall_n{SCALE_NODES}",
                     round(cel.p99 / 1e3, 2), None))
        gupf = _gupf(cel, clean["celeris"])
        rows.append((f"{prefix}_gupf_celeris_stall_n{SCALE_NODES}",
                     round(gupf, 4), None))
        print(f"n={SCALE_NODES} stall {PAPER_CELL[1]:g}: roce p99 "
              f"{roc.p99/1e3:.2f} ms (clean {clean['roce'].p99/1e3:.2f})  "
              f"celeris p99 {cel.p99/1e3:.2f} ms  gupf {gupf:.3f}")

    # end-to-end: faulted 2-pod schedule -> hierarchical training
    print(f"\n== Fig. 7 recovery: faulted {RECOVERY_PODS}-pod axis-split "
          f"schedule -> hierarchical step ==")
    fp = FaultParams.of_kind(PAPER_CELL[0], PAPER_CELL[1],
                             **FAULT_KW.get(PAPER_CELL[0], {}))
    sched = coupling.split_schedule_from_engine(
        steps, seed=seed, n_pods=RECOVERY_PODS, n_nodes=NODES,
        timeout_scale=f4.RECOVERY_SCALE, fault=fp)
    rows.append((f"{prefix}_drop_mean_intra", round(sched.intra.mean, 4),
                 None))
    rows.append((f"{prefix}_drop_mean_cross", round(sched.cross.mean, 4),
                 None))
    cfg = C.get_smoke("qwen2-0.5b")
    rec = f4._recovery(cfg, steps, seed, sched, rows, prefix)
    verdict = "PASS" if rec >= 0.9 else "FAIL"
    print(f"faulted hierarchical recovery {rec*100:.1f}% (claim: >=90%) "
          f"-> {verdict}")

    worst = min(ratios.values())
    print(f"\nfig7 headline: worst-case resilience ratio "
          f"{worst:.1f}x (claim: ~2x)  [{time.perf_counter()-t0:.0f} s]")
    return rows


if __name__ == "__main__":
    run()
