"""Fig. 3 (beyond-paper): p99 AllReduce time vs cluster size, 128-1024
nodes, multi-seed confidence intervals.

The tail-at-scale effect the paper argues about compounds with N (a
ring round is 2(N-1) synchronized steps, each gated by the slowest of N
flows), so the p99/p50 separation between RoCE and Celeris should widen
with cluster size.  The pre-refactor per-step simulator could not reach
these scales; the batched engine sweeps them in shared-fabric mode (one
contention trace and one DCQCN trace per seed, every design riding it).
"""
import time

from repro.core.transport import BatchedSimParams, sweep


def run(n_rounds=120, seeds=(0, 1, 2, 3), n_nodes=(128, 256, 512, 1024),
        message_mb=25.0):
    t0 = time.perf_counter()
    res = sweep(BatchedSimParams(
        n_nodes=tuple(n_nodes), message_mb=(message_mb,),
        seeds=tuple(seeds), n_rounds=n_rounds),
        progress=lambda msg: print(f"  [fig3] {msg}", flush=True))
    wall = time.perf_counter() - t0

    rows = []
    print(f"\n== Fig. 3: p99 vs cluster size ({len(seeds)} seeds, "
          f"{n_rounds} rounds, {message_mb:.0f} MB) ==")
    header = "nodes " + "".join(f"{d:>16s}" for d in res.params.designs)
    print(header + "      (p99 ms, mean +/- std over seeds)")
    for nn in n_nodes:
        cells = []
        for d in res.params.designs:
            mean, std = res.p99_vs_scale(d, message_mb)[nn]
            cells.append(f"{mean / 1e3:9.2f}+-{std / 1e3:5.2f}")
        print(f"{nn:5d} " + "".join(f"{c:>16s}" for c in cells))
    for d in res.params.designs:
        curve = res.p99_vs_scale(d, message_mb)
        for nn in n_nodes:
            rows.append((f"fig3_p99_ms_{d}_n{nn}",
                         round(curve[nn][0] / 1e3, 2), None))
    # the headline: does the RoCE->Celeris reduction grow with scale?
    for nn in (n_nodes[0], n_nodes[-1]):
        red = (res.p99_vs_scale("roce", message_mb)[nn][0]
               / res.p99_vs_scale("celeris", message_mb)[nn][0])
        rows.append((f"fig3_p99_reduction_n{nn}", round(red, 2), None))
        print(f"p99 reduction RoCE->Celeris at {nn} nodes: {red:.2f}x")
    rows.append(("fig3_wall_s", round(wall, 1), None))
    print(f"sweep wall-clock: {wall:.1f}s")
    return rows, res


if __name__ == "__main__":
    run()
