"""Granite-3.0 MoE [hf:ibm-granite]: 40 routed experts top-8, d_expert=512."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab_size=49155,
    block_pattern=("moe",), mlp_type="swiglu",
    moe=MoEConfig(n_experts=40, top_k=8, d_expert=512, n_shared=0),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-moe-3b-a800m-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512,
    block_pattern=("moe",), mlp_type="swiglu",
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=64, n_shared=0),
)
