"""Nemotron-4 15B [arXiv:2402.16819]: GQA kv=8, squared-ReLU MLP."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=24576, vocab_size=256000,
    block_pattern=("global",), mlp_type="sqrelu",
    rope_theta=10_000.0, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="nemotron-4-15b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab_size=512,
    block_pattern=("global",), mlp_type="sqrelu", tie_embeddings=False,
)
