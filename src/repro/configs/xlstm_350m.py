"""xLSTM 350M [arXiv:2405.04517]: alternating mLSTM / sLSTM blocks.

Pattern mlstm:slstm = 3:1 (paper uses mLSTM-heavy stacks); d_ff=0 in the
assignment => no separate FFN (xLSTM blocks carry their own up/down
projections).  Pure recurrent => runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    mlp_type="none", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="xlstm-350m-smoke", family="ssm",
    n_layers=4, d_model=128, n_heads=2, n_kv_heads=2,
    d_ff=0, vocab_size=512,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    mlp_type="none", tie_embeddings=True,
)
