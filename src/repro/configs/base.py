"""Model / run configuration system.

Every assigned architecture is a :class:`ModelConfig` in its own module
(``repro/configs/<id>.py``); ``repro.configs.get(name)`` resolves them.
Layer heterogeneity (gemma2 local/global, recurrentgemma R-R-A, xlstm
mLSTM/sLSTM) is expressed as a repeating ``block_pattern`` so the model
can scan over pattern groups with stacked params (keeps HLO small enough
to compile 60+ dry-run cells on one CPU core).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int                  # routed experts
    top_k: int
    d_expert: int                   # per-expert FFN hidden dim
    n_shared: int = 0               # always-on shared experts
    capacity_factor: float = 1.25
    router_z_weight: float = 1e-3   # router z-loss
    aux_weight: float = 1e-2        # load-balance aux loss


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads

    # layer pattern, cycled to n_layers:
    #   "global" | "local" | "rglru" | "mlstm" | "slstm" | "moe"
    block_pattern: Tuple[str, ...] = ("global",)
    window_size: int = 4096         # local-attention window

    # attention options
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0      # chatglm partial rotary = 0.5

    # mlp
    mlp_type: str = "swiglu"        # swiglu | geglu | sqrelu

    moe: Optional[MoEConfig] = None

    # encoder-decoder (seamless): n_layers applies to EACH stack
    encoder_layers: int = 0

    # modality frontend stubs
    frontend: Optional[str] = None  # "vision_stub" | "audio_stub"
    n_frontend_tokens: int = 0
    frontend_dim: int = 0

    norm_eps: float = 1e-6
    post_norm: bool = False         # gemma2: extra post-block norms
    tie_embeddings: bool = True
    dtype: str = "bfloat16"

    # recurrent blocks
    rglru_conv_width: int = 4
    lru_width: Optional[int] = None

    # TP head padding: production meshes shard attention heads 16-way;
    # archs whose head count doesn't divide (qwen2: 14, granite: 24) get
    # inert padding heads (zero-init wq rows / wo cols — forward-identical
    # at init).  See DESIGN.md "hardware adaptation".
    head_pad_multiple: int = 16

    @property
    def n_heads_padded(self) -> int:
        m = self.head_pad_multiple
        return -(-self.n_heads // m) * m

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_subquadratic(self) -> bool:
        """True when no block needs full-sequence quadratic attention
        (long_500k eligibility)."""
        return all(k in ("rglru", "mlstm", "slstm", "local")
                   for k in self.block_pattern)

    def pattern_layout(self) -> Tuple[int, Tuple[str, ...]]:
        """(n_groups, tail_kinds): n_layers = n_groups*len(pattern)+tail."""
        plen = len(self.block_pattern)
        return self.n_layers // plen, self.block_pattern[: self.n_layers % plen]

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, dff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        qo = d * (self.n_heads * hd) * 2
        kv = d * (self.n_kv_heads * hd) * 2
        mlp_mult = 3 if self.mlp_type in ("swiglu", "geglu") else 2
        per_kind = {}
        for kind in set(self.block_pattern):
            if kind in ("global", "local"):
                per_kind[kind] = qo + kv + mlp_mult * d * dff
            elif kind == "rglru":
                w = self.lru_width or d
                per_kind[kind] = 2 * d * w + w * d + 3 * w + mlp_mult * d * dff
            elif kind == "mlstm":
                per_kind[kind] = qo + kv + 2 * d * (2 * d)
            elif kind == "slstm":
                per_kind[kind] = 4 * d * d + 4 * d * d // 4 + 2 * d * (2 * d)
            elif kind == "moe":
                m = self.moe
                e_params = (m.n_experts + m.n_shared) * 3 * d * m.d_expert
                per_kind[kind] = qo + kv + e_params + d * m.n_experts
        n_groups, tail = self.pattern_layout()
        blocks = n_groups * sum(per_kind[k] for k in self.block_pattern)
        blocks += sum(per_kind[k] for k in tail)
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.is_encdec:
            blocks *= 2   # encoder + decoder stacks (cross-attn ~ attn)
        if self.frontend:
            emb += self.frontend_dim * d
        return blocks + emb

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        d = self.d_model
        total = self.param_count()
        all_e = (m.n_experts + m.n_shared) * 3 * d * m.d_expert
        act_e = (m.top_k + m.n_shared) * 3 * d * m.d_expert
        return total - self.n_layers * (all_e - act_e)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def runnable_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """Which of the 4 assigned shapes this arch runs (spec skip rules)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.is_subquadratic:
        out.append("long_500k")
    return tuple(out)
