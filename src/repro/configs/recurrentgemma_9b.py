"""RecurrentGemma 9B (Griffin) [arXiv:2402.19427]: RG-LRU + local attn 1:2.

38 layers: pattern (rglru, rglru, local) x12 + tail (rglru, rglru).
Sub-quadratic (bounded local window + recurrent state) => runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab_size=256000, head_dim=256,
    block_pattern=("rglru", "rglru", "local"), window_size=2048,
    mlp_type="geglu", lru_width=4096, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke", family="hybrid",
    n_layers=5, d_model=128, n_heads=4, n_kv_heads=1,
    d_ff=384, vocab_size=512, head_dim=32,
    block_pattern=("rglru", "rglru", "local"), window_size=64,
    mlp_type="geglu", lru_width=128, tie_embeddings=True,
)
