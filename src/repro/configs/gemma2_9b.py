"""Gemma 2 9B [arXiv:2408.00118]: local+global alternating, softcaps."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
    d_ff=14336, vocab_size=256000, head_dim=256,
    block_pattern=("local", "global"), window_size=4096,
    mlp_type="geglu", attn_softcap=50.0, logit_softcap=30.0,
    post_norm=True, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma2-9b-smoke", family="dense",
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab_size=512, head_dim=32,
    block_pattern=("local", "global"), window_size=64,
    mlp_type="geglu", attn_softcap=50.0, logit_softcap=30.0,
    post_norm=True, tie_embeddings=True,
)
