"""ChatGLM3 6B [arXiv:2406.12793]: 2d (partial) RoPE, GQA kv=2."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab_size=65024,
    block_pattern=("global",), rope_fraction=0.5, qkv_bias=True,
    mlp_type="swiglu", tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="chatglm3-6b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=384, vocab_size=512,
    block_pattern=("global",), rope_fraction=0.5, qkv_bias=True,
    mlp_type="swiglu", tie_embeddings=False,
)
