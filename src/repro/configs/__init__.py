"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

import importlib

from repro.configs.base import (
    ModelConfig, MoEConfig, ShapeConfig, SHAPES, runnable_shapes)

ARCHS = (
    "nemotron_4_15b",
    "gemma2_9b",
    "qwen2_0_5b",
    "chatglm3_6b",
    "recurrentgemma_9b",
    "qwen2_moe_a2_7b",
    "granite_moe_3b_a800m",
    "xlstm_350m",
    "phi_3_vision_4_2b",
    "seamless_m4t_medium",
)


def canonical(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.SMOKE


__all__ = ["ModelConfig", "MoEConfig", "ShapeConfig", "SHAPES", "ARCHS",
           "runnable_shapes", "get", "get_smoke", "canonical"]
