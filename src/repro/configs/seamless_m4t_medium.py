"""SeamlessM4T medium [arXiv:2308.11596]: encoder-decoder, multimodal.

"12L" = 12 encoder + 12 decoder layers (released medium config).  The
audio frontend (conformer feature extractor) is a STUB — input_specs()
supplies precomputed frame embeddings (B, S_frames, 1024) fed to the
encoder; the decoder is a standard causal stack with cross-attention.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256206,
    block_pattern=("global",), mlp_type="swiglu",
    encoder_layers=12,
    frontend="audio_stub", frontend_dim=1024,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="seamless-m4t-medium-smoke", family="audio",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=512,
    block_pattern=("global",), mlp_type="swiglu",
    encoder_layers=2,
    frontend="audio_stub", frontend_dim=64,
    tie_embeddings=True,
)
