"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B]: 4 shared + 60 routed top-4."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=151936,
    block_pattern=("moe",), qkv_bias=True, mlp_type="swiglu",
    moe=MoEConfig(n_experts=60, top_k=4, d_expert=1408, n_shared=4),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen2-moe-a2.7b-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512,
    block_pattern=("moe",), qkv_bias=True, mlp_type="swiglu",
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=128, n_shared=1),
)
