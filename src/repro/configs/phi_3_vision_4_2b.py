"""Phi-3-vision 4.2B [hf:microsoft/Phi-3-vision-128k-instruct].

phi3-mini text backbone; the CLIP ViT frontend is a STUB — input_specs()
supplies precomputed patch embeddings (B, 144, 1024) which a linear
projector maps into d_model and prepends to the text sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32064,
    block_pattern=("global",), mlp_type="swiglu",
    frontend="vision_stub", n_frontend_tokens=144, frontend_dim=1024,
    rope_theta=10_000.0, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="phi-3-vision-4.2b-smoke", family="vlm",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=512,
    block_pattern=("global",), mlp_type="swiglu",
    frontend="vision_stub", n_frontend_tokens=16, frontend_dim=64,
    tie_embeddings=False,
)
