"""Qwen2 0.5B [arXiv:2407.10671]: GQA kv=2, QKV bias."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab_size=151936,
    block_pattern=("global",), qkv_bias=True,
    rope_theta=1_000_000.0, mlp_type="swiglu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen2-0.5b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=7, n_kv_heads=1,
    d_ff=256, vocab_size=512,
    block_pattern=("global",), qkv_bias=True, mlp_type="swiglu",
)
