"""Foundational model layers (pure-functional JAX).

Covers every attention/MLP variant the assigned architectures need:
GQA with arbitrary kv-head counts, QKV bias, attention/logit softcaps
(gemma2), local sliding windows, partial RoPE (chatglm's 2d rope =
rotary on half the head dim), squared-ReLU / SwiGLU / GeGLU MLPs.

Parameters are plain pytrees; ``init_*`` builds them, ``apply_*`` runs
them.  Everything is shape-polymorphic over (batch, seq).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ----------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------

def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.zeros((d,), jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + p["scale"])).astype(dt)


# ----------------------------------------------------------------------
# RoPE (standard + partial fraction for chatglm 2d rope)
# ----------------------------------------------------------------------

def rope_tables(positions: jax.Array, head_dim: int, theta: float,
                fraction: float) -> tuple[jax.Array, jax.Array, int]:
    """cos/sin tables over the rotary sub-dimension.

    positions: (..., S) int32.  Returns (cos, sin, rot_dim) where
    rot_dim = head_dim * fraction (rounded to even).
    """
    rot_dim = int(head_dim * fraction) // 2 * 2
    freq = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32)
                            / rot_dim))
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, rot/2)
    return jnp.cos(angles), jnp.sin(angles), rot_dim


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array,
               rot_dim: int) -> jax.Array:
    """x: (B, S, H, Dh); rotates the first rot_dim dims, pass-through rest."""
    rot, rest = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = rot[..., 0::2], rot[..., 1::2]
    c, s = cos[:, :, None, :], sin[:, :, None, :]
    y1 = x1 * c - x2 * s
    y2 = x1 * s + x2 * c
    y = jnp.stack([y1, y2], axis=-1).reshape(rot.shape)
    return jnp.concatenate([y, rest], axis=-1) if rest.shape[-1] else y


# ----------------------------------------------------------------------
# Attention (GQA / local / softcap / bias / cache)
# ----------------------------------------------------------------------

def _trunc_normal(key, shape, scale, dtype):
    fan_in = shape[0] if len(shape) >= 1 else 1
    std = scale / (fan_in ** 0.5)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def init_attention(key: jax.Array, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, hp, kv = cfg.n_heads, cfg.n_heads_padded, cfg.n_kv_heads
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    wq = _trunc_normal(ks[0], (d, hp * hd), 1.0, dt)
    wo = _trunc_normal(ks[3], (hp * hd, d), 1.0, dt)
    if hp != h:   # inert TP-padding heads: zeroed in and out at init
        mask = (jnp.arange(hp * hd) < h * hd).astype(dt)
        wq = wq * mask[None, :]
        wo = wo * mask[:, None]
    p: Params = {
        "wq": wq,
        "wk": _trunc_normal(ks[1], (d, kv * hd), 1.0, dt),
        "wv": _trunc_normal(ks[2], (d, kv * hd), 1.0, dt),
        "wo": wo,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hp * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
    return p


def _head_shard(x: jax.Array) -> jax.Array:
    """Constrain (B,S,H,Dh) onto the model axis over heads when legal."""
    from repro import sharding as shd
    mesh = shd.get_global_mesh()
    if mesh is None or shd.MODEL_AXIS not in mesh.shape:
        return x
    tp = mesh.shape[shd.MODEL_AXIS]
    if x.ndim != 4 or x.shape[2] % tp:
        return x
    U = jax.sharding.PartitionSpec.UNCONSTRAINED
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(U, None, shd.MODEL_AXIS,
                                             None)))


def _proj_shard(t: jax.Array, n_heads: int) -> jax.Array:
    """Pin a (B,S,n*Dh) projection BEFORE the head reshape.

    The old SPMD partitioner cannot reshard seq-sharded -> head-sharded
    through a reshape (it falls back to full rematerialization, and for
    tiny kv-head counts even hits a partitioner CHECK crash).  Pinning
    the merged dim here makes the later reshape a clean H-major split:
    - heads % tp == 0 (always true for padded q heads): shard last dim;
    - small kv: force replicated over model (GQA kv tensors are tiny —
      that is the entire point of GQA).
    Batch stays UNCONSTRAINED so serving jits keep dp batch sharding.
    """
    from repro import sharding as shd
    mesh = shd.get_global_mesh()
    if (mesh is None or t.ndim != 3
            or shd.MODEL_AXIS not in mesh.shape):
        return t
    tp = mesh.shape[shd.MODEL_AXIS]
    last = shd.MODEL_AXIS if (n_heads % tp == 0
                              and t.shape[-1] % tp == 0) else None
    U = jax.sharding.PartitionSpec.UNCONSTRAINED
    return jax.lax.with_sharding_constraint(
        t, jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(U, None, last)))


def seq_unpin(x: jax.Array) -> jax.Array:
    """Identity.  [Perf-iteration H2, REFUTED: forcing one full-sequence
    materialization per sub-block did not deduplicate the per-projection
    gathers (GSPMD already shares them), and its backward transpose
    added a (B,S,D) f32 grad all-reduce per use: nemotron train AR bytes
    +96 GiB, collective term 13.5s -> 15.3s.  Kept as a hook; the
    constraint itself was removed.]"""
    return x


def _softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("k", "v", "pos"), meta_fields=())
@dataclasses.dataclass
class AttnCache:
    """Decode-time KV cache for one attention layer.

    Local-attention layers use a ring cache of window size: slot =
    position % S_cache; ``pos`` tracks each slot's true position (-1 =
    empty) so masking and RoPE stay exact after wraparound.
    """
    k: jax.Array      # (B, S_cache, KV, Dh)
    v: jax.Array
    pos: jax.Array    # (S_cache,) int32, -1 when empty


def _cache_prefill(cache: "AttnCache", k, v) -> "AttnCache":
    """Write a length-L prefix into the (possibly smaller ring) cache."""
    b, l = k.shape[:2]
    sc = cache.k.shape[1]
    if l <= sc:
        ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                          (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                          (0, 0, 0, 0))
        pos = cache.pos.at[:l].set(jnp.arange(l, dtype=jnp.int32))
        return AttnCache(k=ck, v=cv, pos=pos)
    # ring: keep the last sc tokens; slot(i) = i % sc
    kt, vt = k[:, -sc:], v[:, -sc:]
    start = (l - sc) % sc          # slot of the oldest kept token
    split = sc - start
    ck, cv, pos = cache.k, cache.v, cache.pos
    ck = jax.lax.dynamic_update_slice(ck, kt[:, :split].astype(ck.dtype),
                                      (0, start, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, vt[:, :split].astype(cv.dtype),
                                      (0, start, 0, 0))
    pos = jax.lax.dynamic_update_slice(
        pos, jnp.arange(l - sc, l - sc + split, dtype=jnp.int32), (start,))
    if start:
        ck = jax.lax.dynamic_update_slice(ck, kt[:, split:].astype(ck.dtype),
                                          (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, vt[:, split:].astype(cv.dtype),
                                          (0, 0, 0, 0))
        pos = jax.lax.dynamic_update_slice(
            pos, jnp.arange(l - start, l, dtype=jnp.int32), (0,))
    return AttnCache(k=ck, v=cv, pos=pos)


def _cache_decode(cache: "AttnCache", k, v, index) -> "AttnCache":
    """Insert one token at true position ``index`` (ring slot = mod)."""
    sc = cache.k.shape[1]
    slot = jax.lax.rem(index.astype(jnp.int32), jnp.int32(sc))
    ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                      (0, slot, 0, 0))
    pos = jax.lax.dynamic_update_slice(
        cache.pos, index.astype(jnp.int32)[None], (slot,))
    return AttnCache(k=ck, v=cv, pos=pos)


FLASH_THRESHOLD = 4 * 1024 * 1024   # s_q * s_kv above which we tile


def _flash_attention(q, k, v, *, qpos, kpos, kind: str, cfg: ModelConfig,
                     causal: bool, q_blk: int = 1024, kv_blk: int = 1024):
    """Memory-efficient attention (Rabe–Staats style, mask-aware).

    q: (B,Sq,H,D), k/v: (B,Skv,H,D); qpos (Sq,), kpos (Skv,) true
    positions.  Online softmax over kv tiles inside a scan over q tiles;
    each q-tile is jax.checkpoint'ed so backward recomputes tiles instead
    of storing O(Sq*Skv) residuals.  Never materializes (Sq, Skv).
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    q_blk = min(q_blk, sq)
    kv_blk = min(kv_blk, skv)
    assert sq % q_blk == 0 and skv % kv_blk == 0, (sq, q_blk, skv, kv_blk)
    nq, nk = sq // q_blk, skv // kv_blk
    scale = d ** -0.5

    qr = q.reshape(b, nq, q_blk, h, d).swapaxes(0, 1)     # (nq,B,qb,H,D)
    kr = k.reshape(b, nk, kv_blk, h, d).swapaxes(0, 1)
    vr = v.reshape(b, nk, kv_blk, h, d).swapaxes(0, 1)
    qpr = qpos.reshape(nq, q_blk)
    kpr = kpos.reshape(nk, kv_blk)

    def q_tile(qt, qp):
        """qt: (B,qb,H,D); returns (B,qb,H,D)."""
        def kv_step(carry, t):
            m, l, acc = carry
            kt, vt, kp = t
            # bf16 operands + f32 accumulation (preferred_element_type):
            # keeps backward cotangents in bf16 — [perf-iteration H5:
            # nemotron train f32 activation AG/AR bytes halved]
            s = jnp.einsum("bqhd,bkhd->bhqk", qt, kt,
                           preferred_element_type=jnp.float32) * scale
            if cfg.attn_softcap is not None:
                s = _softcap(s, cfg.attn_softcap)
            mask = kp[None, None, None, :] >= 0
            if causal:
                mask = mask & (kp[None, None, None, :]
                               <= qp[None, None, :, None])
            if kind == "local":
                mask = mask & (kp[None, None, None, :]
                               > qp[None, None, :, None] - cfg.window_size)
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p_ = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p_.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p_.astype(vt.dtype), vt,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_blk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, q_blk), jnp.float32)
        a0 = jnp.zeros((b, h, q_blk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kr, vr, kpr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.swapaxes(1, 2)                        # (B,qb,H,D)

    outs = jax.lax.scan(
        lambda _, t: (None, jax.checkpoint(q_tile)(t[0], t[1])),
        None, (qr, qpr))[1]                              # (nq,B,qb,H,D)
    return outs.swapaxes(0, 1).reshape(b, sq, h, d)


def attention(p: Params, cfg: ModelConfig, x: jax.Array, *,
              kind: str = "global",
              positions: Optional[jax.Array] = None,
              causal: bool = True,
              cache: Optional[AttnCache] = None,
              cache_index: Optional[jax.Array] = None,
              memory: Optional[jax.Array] = None,
              ) -> tuple[jax.Array, Optional[AttnCache]]:
    """GQA attention.

    Modes:
    - train/prefill: full (B,S,D) in, optional returned cache.
    - decode: S==1 with ``cache``+``cache_index`` (static-shape update).
    - cross-attention: ``memory`` (B,S_enc,D) supplies K/V, no cache/rope.
    """
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads_padded, cfg.n_kv_heads, cfg.resolved_head_dim

    q = x @ p["wq"]
    kv_src = memory if memory is not None else x
    k = kv_src @ p["wk"]
    v = kv_src @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = _proj_shard(q, h)
    k = _proj_shard(k, kv)
    v = _proj_shard(v, kv)
    q = _head_shard(q.reshape(b, s, h, hd))
    k = k.reshape(b, kv_src.shape[1], kv, hd)
    v = v.reshape(b, kv_src.shape[1], kv, hd)

    if memory is None:   # self-attention: rope
        if positions is None:
            positions = jnp.arange(s, dtype=jnp.int32)[None, :]
        cos, sin, rot = rope_tables(positions, hd, cfg.rope_theta,
                                    cfg.rope_fraction)
        q = apply_rope(q, cos, sin, rot)
        k = apply_rope(k, cos, sin, rot)

    new_cache = None
    if cache is not None:
        if s == 1 and cache_index is not None:     # decode: insert at index
            new_cache = _cache_decode(cache, k, v, cache_index)
        else:                                       # prefill
            new_cache = _cache_prefill(cache, k, v)

    decode = new_cache is not None and s == 1
    if decode:
        kq, vq, kpos1 = new_cache.k, new_cache.v, new_cache.pos
    else:
        kq, vq = k, v
        kpos1 = None
    s_kv = kq.shape[1]

    rep = h // kv

    # large attention tiles -> memory-efficient path (never builds the
    # (Sq,Skv) matrix; required for 32k prefill / 4k train cells).
    # Cross-attention uses it too (causal=False, all-valid kpos).
    if not decode and s * s_kv > FLASH_THRESHOLD:
        kq = _head_shard(jnp.repeat(kq, rep, axis=2))
        vq = _head_shard(jnp.repeat(vq, rep, axis=2))
        qpos1 = positions[0] if positions.ndim == 2 else positions
        kpos_arr = jnp.arange(s_kv, dtype=jnp.int32)
        out = _flash_attention(
            q, kq, vq, qpos=qpos1, kpos=kpos_arr,
            kind=("global" if memory is not None else kind), cfg=cfg,
            causal=(causal and memory is None))
        out = out.reshape(b, s, h * hd).astype(x.dtype) @ p["wo"]
        return out, new_cache

    # dense path: grouped-GQA einsums against the UNREPEATED kv (a
    # materialized repeat of a 32k-token cache would cost GBs at decode)
    scale = hd ** -0.5
    qg = q.reshape(b, s, kv, rep, hd)
    logits = jnp.einsum("bqkrd,bskd->bkrqs", qg, kq,
                        preferred_element_type=jnp.float32) * scale
    logits = logits.reshape(b, h, s, s_kv)
    logits = _softcap(logits, cfg.attn_softcap)

    # masks
    if memory is None:
        if decode:
            kpos = kpos1[None, None, None, :]      # true positions per slot
            mask = (kpos >= 0) & (kpos <= cache_index)
            if kind == "local":
                mask = mask & (kpos > cache_index - cfg.window_size)
        else:
            qpos = positions[:, None, :, None]
            kpos = jnp.arange(s_kv)[None, None, None, :]
            mask = (kpos <= qpos) if causal else jnp.ones(
                (1, 1, s, s_kv), bool)
            if kind == "local":
                mask = mask & (kpos > qpos - cfg.window_size)
        logits = jnp.where(mask, logits, -1e30)

    attn = jax.nn.softmax(logits, axis=-1).astype(vq.dtype)
    attn_g = attn.reshape(b, kv, rep, s, s_kv)
    out = jnp.einsum("bkrqs,bskd->bqkrd", attn_g, vq)
    out = out.reshape(b, s, h * hd) @ p["wo"]
    return out, new_cache


# ----------------------------------------------------------------------
# MLP variants
# ----------------------------------------------------------------------

def init_mlp(key: jax.Array, cfg: ModelConfig) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {"wi": _trunc_normal(ks[0], (d, f), 1.0, dt),
                "wg": _trunc_normal(ks[1], (d, f), 1.0, dt),
                "wo": _trunc_normal(ks[2], (f, d), 1.0, dt)}
    if cfg.mlp_type == "sqrelu":
        return {"wi": _trunc_normal(ks[0], (d, f), 1.0, dt),
                "wo": _trunc_normal(ks[2], (f, d), 1.0, dt)}
    raise ValueError(cfg.mlp_type)


def mlp(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.mlp_type == "swiglu":
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]
    if cfg.mlp_type == "geglu":
        return (jax.nn.gelu(x @ p["wg"], approximate=True) * (x @ p["wi"])) @ p["wo"]
    if cfg.mlp_type == "sqrelu":
        return jnp.square(jax.nn.relu(x @ p["wi"])) @ p["wo"]
    raise ValueError(cfg.mlp_type)


# ----------------------------------------------------------------------
# Embedding
# ----------------------------------------------------------------------

def init_embedding(key: jax.Array, cfg: ModelConfig) -> Params:
    dt = _dtype(cfg)
    p: Params = {"table": _trunc_normal(key, (cfg.vocab_size, cfg.d_model),
                                        1.0, dt)}
    if not cfg.tie_embeddings:
        p["unembed"] = _trunc_normal(
            jax.random.fold_in(key, 1), (cfg.d_model, cfg.vocab_size), 1.0, dt)
    return p


def embed(p: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["table"], tokens, axis=0)
    return x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)


def unembed(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Returns logits in the compute dtype (bf16), vocab-sharded.

    Keeping (B,S,V) out of f32/replicated is what keeps the train step's
    temp memory sane at 256k vocabs — the loss does its reductions in
    f32 without materializing a full-precision logits tensor.
    """
    if cfg.tie_embeddings:
        logits = x @ p["table"].T
    else:
        logits = x @ p["unembed"]
    if cfg.logit_softcap is not None:
        logits = _softcap(logits.astype(jnp.float32),
                          cfg.logit_softcap).astype(x.dtype)
    from repro import sharding as shd
    mesh = shd.get_global_mesh()
    if (mesh is not None and shd.MODEL_AXIS in mesh.shape
            and logits.ndim == 3
            and logits.shape[-1] % mesh.shape[shd.MODEL_AXIS] == 0):
        logits = jax.lax.with_sharding_constraint(
            logits, jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(None, None, shd.MODEL_AXIS)))
    return logits
