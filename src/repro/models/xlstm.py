"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, exponential gating, true recurrence).

**mLSTM** — per head, with exponential input gate and stabilizer m:
    C_t = f'_t C_{t-1} + i'_t v_t k_t^T      (d_k x d_v matrix memory)
    n_t = f'_t n_{t-1} + i'_t k_t
    h_t = (C_t^T q_t) / max(|n_t . q_t|, 1)
Training uses the **chunkwise-parallel** form (quadratic inside chunks
of length 64, recurrent state only at chunk boundaries) so scan-carry
storage stays O(S/64 * d_k * d_v) instead of O(S * ...); decode is the
exact sequential step.  The chunkwise path is validated against the
sequential reference in tests.

**sLSTM** — scalar memory with recurrent gate input R h_{t-1}
(block-diagonal per head) — inherently sequential: ``lax.scan`` over
time.  Exponential gating stabilized with m_t.

Both carry their own up/down projections (assignment gives d_ff = 0).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, Any]
CHUNK = 64


def _tn(key, shape, fan_in, dt):
    return (jax.random.truncated_normal(key, -2., 2., shape, jnp.float32)
            * (fan_in ** -0.5)).astype(dt)


# ======================================================================
# mLSTM
# ======================================================================

@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("c", "n", "m"), meta_fields=())
@dataclasses.dataclass
class MlstmCache:
    c: jax.Array    # (B, H, Dk, Dv) matrix memory
    n: jax.Array    # (B, H, Dk) normalizer
    m: jax.Array    # (B, H) stabilizer


def init_mlstm(key: jax.Array, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di = 2 * d                       # up-projected inner width
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    return {
        "w_up": _tn(ks[0], (d, di), d, dt),
        "w_gate": _tn(ks[1], (d, di), d, dt),
        "wq": _tn(ks[2], (di, di), di, dt),
        "wk": _tn(ks[3], (di, di), di, dt),
        "wv": _tn(ks[4], (di, di), di, dt),
        "w_if": _tn(ks[5], (di, 2 * cfg.n_heads), di, jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((cfg.n_heads,)),
                                 jnp.linspace(3.0, 6.0, cfg.n_heads)]),
        "w_down": _tn(ks[6], (di, d), di, dt),
    }


def _mlstm_seq(q, k, v, logi, logf, c0, n0, m0):
    """Sequential reference / decode step.  q,k,v: (B,S,H,Dk|Dv)."""
    def step(carry, t):
        c, n, m = carry
        qt, kt, vt, li, lf = t
        m_new = jnp.maximum(lf + m, li)
        fp = jnp.exp(lf + m - m_new)[..., None, None]
        ip = jnp.exp(li - m_new)[..., None, None]
        c = fp * c + ip * (kt[..., :, None] * vt[..., None, :])
        n = fp[..., 0] * n + ip[..., 0] * kt
        num = jnp.einsum("bhkv,bhk->bhv", c, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)), 1.0)
        return (c, n, m_new), num / den[..., None]

    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          logi.swapaxes(0, 1), logf.swapaxes(0, 1))
    (c, n, m), hs = jax.lax.scan(step, (c0, n0, m0), xs)
    return hs.swapaxes(0, 1), (c, n, m)


def mlstm_parallel(q, k, v, logi, logf, c0, n0, m0):
    """Chunkwise-parallel mLSTM (clean implementation)."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    nc = s // CHUNK

    def r(x):
        return x.reshape(b, nc, CHUNK, *x.shape[2:]).swapaxes(0, 1)

    q_, k_, v_ = r(q), r(k), r(v)                   # (nc,B,L,H,D*)
    li, lf = r(logi), r(logf)                       # (nc,B,L,H)

    def scan_chunk(carry, t):
        c, n, m = carry
        qc, kc, vc, lic, lfc = t                    # (B,L,H,*) / (B,L,H)
        csf = jnp.cumsum(lfc, axis=1)               # (B,L,H) inclusive
        ftot = csf[:, -1]                           # (B,H)

        # intra weights w[j,l] = csf[j]-csf[l]+li[l] for l<=j
        ac = csf[:, :, None, :] - csf[:, None, :, :] + lic[:, None, :, :]
        mask = jnp.arange(CHUNK)[:, None] >= jnp.arange(CHUNK)[None, :]
        ac = jnp.where(mask[None, :, :, None], ac, -1e30)
        b_in = csf + m[:, None, :]                  # (B,L,H) carry weight
        m_j = jnp.maximum(jnp.max(ac, axis=2), b_in)
        w_intra = jnp.exp(ac - m_j[:, :, None, :])
        w_carry = jnp.exp(b_in - m_j)

        scores = jnp.einsum("bjhd,blhd->bjlh", qc.astype(jnp.float32),
                            kc.astype(jnp.float32))
        num = (jnp.einsum("bjlh,bjlh,blhv->bjhv", scores, w_intra,
                          vc.astype(jnp.float32))
               + jnp.einsum("bhkv,bjhk->bjhv", c, qc.astype(jnp.float32))
               * w_carry[..., None])
        den = (jnp.einsum("bjlh,bjlh,blhd,bjhd->bjh", scores, w_intra,
                          kc.astype(jnp.float32), qc.astype(jnp.float32))
               + jnp.einsum("bhk,bjhk->bjh", n, qc.astype(jnp.float32))
               * w_carry)
        h_out = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]

        # ---- state to chunk end (stabilized)
        wl = ftot[:, None, :] - csf + lic            # (B,L,H)
        m_state = jnp.maximum(ftot + m, jnp.max(wl, axis=1))
        w_new = jnp.exp(wl - m_state[:, None, :])    # (B,L,H)
        w_old = jnp.exp(ftot + m - m_state)          # (B,H)
        c_new = (w_old[..., None, None] * c
                 + jnp.einsum("blh,blhk,blhv->bhkv", w_new,
                              kc.astype(jnp.float32), vc.astype(jnp.float32)))
        n_new = (w_old[..., None] * n
                 + jnp.einsum("blh,blhk->bhk", w_new, kc.astype(jnp.float32)))
        return (c_new, n_new, m_state), h_out

    (c, n, m), hs = jax.lax.scan(
        scan_chunk, (c0, n0, m0), (q_, k_, v_, li, lf))
    hs = hs.swapaxes(0, 1).reshape(b, s, h, dv)
    return hs, (c, n, m)


def mlstm_block(p: Params, cfg: ModelConfig, x: jax.Array, *,
                cache: Optional[MlstmCache] = None,
                ) -> tuple[jax.Array, Optional[MlstmCache]]:
    b, s, d = x.shape
    hh = cfg.n_heads
    gate = jax.nn.silu(x @ p["w_gate"])
    u = x @ p["w_up"]
    di = u.shape[-1]
    dh = di // hh
    q = (u @ p["wq"]).reshape(b, s, hh, dh) * dh ** -0.5
    k = (u @ p["wk"]).reshape(b, s, hh, dh) * dh ** -0.5
    v = (u @ p["wv"]).reshape(b, s, hh, dh)
    gates = u.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    logi, logf = gates[..., :hh], jax.nn.log_sigmoid(gates[..., hh:])

    if cache is not None:
        c0, n0, m0 = cache.c, cache.n, cache.m
    else:
        c0 = jnp.zeros((b, hh, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, hh, dh), jnp.float32)
        m0 = jnp.zeros((b, hh), jnp.float32)

    if s == 1:
        hs, (c, n, m) = _mlstm_seq(q, k, v, logi, logf, c0, n0, m0)
    else:
        pad = (-s) % CHUNK
        if pad:
            zp = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
            q, k, v = zp(q), zp(k), zp(v)
            logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)),
                           constant_values=-1e30)   # pad tokens never write
            logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))
        hs, (c, n, m) = mlstm_parallel(q, k, v, logi, logf, c0, n0, m0)
        hs = hs[:, :s]
    new_cache = MlstmCache(c=c, n=n, m=m) if cache is not None else None
    out = (hs.reshape(b, s, di).astype(gate.dtype) * gate) @ p["w_down"]
    return out, new_cache


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> MlstmCache:
    dh = 2 * cfg.d_model // cfg.n_heads
    return MlstmCache(
        c=jnp.zeros((batch, cfg.n_heads, dh, dh), jnp.float32),
        n=jnp.zeros((batch, cfg.n_heads, dh), jnp.float32),
        m=jnp.zeros((batch, cfg.n_heads), jnp.float32))


# ======================================================================
# sLSTM
# ======================================================================

@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("h", "c", "n", "m"), meta_fields=())
@dataclasses.dataclass
class SlstmCache:
    h: jax.Array    # (B, D)
    c: jax.Array    # (B, D)
    n: jax.Array    # (B, D)
    m: jax.Array    # (B, D)


def init_slstm(key: jax.Array, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    hh = cfg.n_heads
    dh = d // hh
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    return {
        "w_x": _tn(ks[0], (d, 4 * d), d, dt),
        "r_h": _tn(ks[1], (hh, dh, 4 * dh), dh, jnp.float32),
        "b": jnp.concatenate([jnp.zeros((2 * d,)),
                              jnp.linspace(3.0, 6.0, d),      # forget bias
                              jnp.zeros((d,))]),
        "w_up1": _tn(ks[2], (d, 2 * d), d, dt),
        "w_up2": _tn(ks[3], (d, 2 * d), d, dt),
        "w_down": _tn(ks[4], (2 * d, d), 2 * d, dt),
    }


def _slstm_cell(p, cfg, xg, state):
    """One step.  xg: (B, 4D) precomputed x-part; state: SlstmCache."""
    d = cfg.d_model
    hh = cfg.n_heads
    dh = d // hh
    h, c, n, m = state
    rec = jnp.einsum("bhd,hdk->bhk", h.reshape(-1, hh, dh),
                     p["r_h"]).reshape(-1, 4 * d)
    g = xg.astype(jnp.float32) + rec + p["b"]
    zt = jnp.tanh(g[:, 0 * d:1 * d])
    it = g[:, 1 * d:2 * d]                       # log-space input gate
    ft = jax.nn.log_sigmoid(g[:, 2 * d:3 * d])   # log forget
    ot = jax.nn.sigmoid(g[:, 3 * d:4 * d])
    m_new = jnp.maximum(ft + m, it)
    ip = jnp.exp(it - m_new)
    fp = jnp.exp(ft + m - m_new)
    c_new = fp * c + ip * zt
    n_new = fp * n + ip
    h_new = ot * c_new / jnp.maximum(n_new, 1.0)
    return h_new, c_new, n_new, m_new


def slstm_block(p: Params, cfg: ModelConfig, x: jax.Array, *,
                cache: Optional[SlstmCache] = None,
                ) -> tuple[jax.Array, Optional[SlstmCache]]:
    b, s, d = x.shape
    xg = x @ p["w_x"]                              # (B,S,4D)
    if cache is not None:
        st = (cache.h, cache.c, cache.n, cache.m)
    else:
        z = jnp.zeros((b, d), jnp.float32)
        st = (z, z, z - 1e30 * 0, z)               # m starts at 0

    def step(carry, xt):
        new = _slstm_cell(p, cfg, xt, carry)
        return new, new[0]

    st_new, hs = jax.lax.scan(step, st, xg.swapaxes(0, 1))
    hs = hs.swapaxes(0, 1).astype(x.dtype)         # (B,S,D)
    new_cache = SlstmCache(*st_new) if cache is not None else None
    out = (jax.nn.silu(hs @ p["w_up1"]) * (hs @ p["w_up2"])) @ p["w_down"]
    return out, new_cache


def init_slstm_cache(cfg: ModelConfig, batch: int) -> SlstmCache:
    z = jnp.zeros((batch, cfg.d_model), jnp.float32)
    return SlstmCache(h=z, c=z, n=z, m=z)
