"""Top-level model assembly.

One unified causal LM core covers all ten assigned architectures via the
config's ``block_pattern``; encoder-decoder (seamless) and modality
frontends (phi-3-vision / seamless stubs) layer on top.

HLO-size discipline: layers are grouped into repeating pattern units and
executed with ``lax.scan`` over *stacked* per-unit parameters (+
``jax.checkpoint`` per unit for remat), so a 42-layer model lowers to a
single rolled loop — essential for compiling 60+ dry-run cells on one
CPU core, and the standard production trick for fast TPU compiles.

Caches mirror the parameter structure (stacked per pattern position) so
serve_step scans over them in lockstep.  Local-attention layers use
modular (ring) KV caches of window size; recurrent layers carry their
own state types.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import xlstm as XL

Params = Dict[str, Any]

ATTN_KINDS = ("global", "local", "moe", "xattn")


@dataclasses.dataclass(frozen=True)
class LossyCtx:
    """Celeris context threaded into collectives inside the model."""
    enabled: bool = False
    key: Optional[jax.Array] = None
    drop_rate: jax.Array | float = 0.0


# ----------------------------------------------------------------------
# Per-block init / apply
# ----------------------------------------------------------------------

def init_block(key: jax.Array, kind: str, cfg: ModelConfig,
               cross: bool = False) -> Params:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: Params = {"ln1": L.init_rmsnorm(d)}
    if kind in ("global", "local", "moe"):
        p["attn"] = L.init_attention(ks[0], cfg)
        p["ln2"] = L.init_rmsnorm(d)
        if kind == "moe":
            p["moe"] = MOE.init_moe(ks[1], cfg)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg)
        if cfg.post_norm:
            p["pn1"] = L.init_rmsnorm(d)
            p["pn2"] = L.init_rmsnorm(d)
        if cross:
            p["xattn"] = L.init_attention(ks[2], cfg)
            p["lnx"] = L.init_rmsnorm(d)
    elif kind == "rglru":
        p["rglru"] = RG.init_rglru(ks[0], cfg)
        p["ln2"] = L.init_rmsnorm(d)
        p["mlp"] = L.init_mlp(ks[1], cfg)
    elif kind == "mlstm":
        p["mlstm"] = XL.init_mlstm(ks[0], cfg)
    elif kind == "slstm":
        p["slstm"] = XL.init_slstm(ks[0], cfg)
    else:
        raise ValueError(kind)
    return p


def apply_block(p: Params, kind: str, cfg: ModelConfig, x: jax.Array, *,
                positions, cache=None, cache_index=None, memory=None,
                causal: bool = True, lossy: Optional[LossyCtx] = None,
                layer_key: Optional[jax.Array] = None):
    """Returns (x, new_cache, aux_loss)."""
    eps = cfg.norm_eps
    aux = jnp.zeros((), jnp.float32)

    if kind in ("global", "local", "moe"):
        h = L.seq_unpin(L.rmsnorm(p["ln1"], x, eps))
        a_cache = cache.get("attn") if cache else None
        h, new_attn_cache = L.attention(
            p["attn"], cfg, h, kind=("local" if kind == "local" else "global"),
            positions=positions, causal=causal,
            cache=a_cache, cache_index=cache_index)
        if cfg.post_norm:
            h = L.rmsnorm(p["pn1"], h, eps)
        x = x + h

        if "xattn" in p and memory is not None:
            h = L.rmsnorm(p["lnx"], x, eps)
            h, _ = L.attention(p["xattn"], cfg, h, memory=memory,
                               positions=positions)
            x = x + h

        h = L.seq_unpin(L.rmsnorm(p["ln2"], x, eps))
        if kind == "moe":
            h, aux = MOE.moe_block(
                p["moe"], cfg, h,
                lossy=bool(lossy and lossy.enabled),
                key=(layer_key if lossy and lossy.enabled else None),
                drop_rate=(lossy.drop_rate if lossy else 0.0))
        else:
            h = L.mlp(p["mlp"], cfg, h)
        if cfg.post_norm:
            h = L.rmsnorm(p["pn2"], h, eps)
        x = x + h
        new_cache = {"attn": new_attn_cache} if new_attn_cache else None
        return x, new_cache, aux

    if kind == "rglru":
        h = L.seq_unpin(L.rmsnorm(p["ln1"], x, eps))
        h, new_rg = RG.rglru_block(p["rglru"], cfg, h,
                                   cache=cache.get("rglru") if cache else None)
        x = x + h
        h = L.seq_unpin(L.rmsnorm(p["ln2"], x, eps))
        x = x + L.mlp(p["mlp"], cfg, h)
        return x, ({"rglru": new_rg} if new_rg else None), aux

    if kind == "mlstm":
        h = L.rmsnorm(p["ln1"], x, eps)
        h, new_c = XL.mlstm_block(p["mlstm"], cfg, h,
                                  cache=cache.get("mlstm") if cache else None)
        return x + h, ({"mlstm": new_c} if new_c else None), aux

    if kind == "slstm":
        h = L.rmsnorm(p["ln1"], x, eps)
        h, new_c = XL.slstm_block(p["slstm"], cfg, h,
                                  cache=cache.get("slstm") if cache else None)
        return x + h, ({"slstm": new_c} if new_c else None), aux

    raise ValueError(kind)


# ----------------------------------------------------------------------
# Stacks (scan over pattern groups)
# ----------------------------------------------------------------------

def _init_stack(key: jax.Array, cfg: ModelConfig, n_layers: int,
                cross: bool = False) -> Params:
    plen = len(cfg.block_pattern)
    n_groups, tail = n_layers // plen, cfg.block_pattern[: n_layers % plen]
    stacked = []
    for j, kind in enumerate(cfg.block_pattern):
        keys = jax.random.split(jax.random.fold_in(key, j), max(n_groups, 1))
        init_one = functools.partial(init_block, kind=kind, cfg=cfg,
                                     cross=cross)
        stacked.append(jax.vmap(init_one)(keys) if n_groups else None)
    tailp = [init_block(jax.random.fold_in(key, 1000 + i), kind, cfg, cross)
             for i, kind in enumerate(tail)]
    return {"groups": stacked, "tail": tailp}


def _apply_stack(stack: Params, cfg: ModelConfig, n_layers: int,
                 x: jax.Array, *,
                 positions, caches=None, cache_index=None, memory=None,
                 causal: bool = True, lossy: Optional[LossyCtx] = None,
                 base_key: Optional[jax.Array] = None, remat: bool = True):
    """caches: {"groups": [stacked per position], "tail": [per layer]}."""
    plen = len(cfg.block_pattern)
    n_groups = n_layers // plen
    tail_kinds = cfg.block_pattern[: n_layers % plen]
    aux_total = jnp.zeros((), jnp.float32)
    base_key = base_key if base_key is not None else jax.random.PRNGKey(0)

    # Sequence parallelism: pin the residual stream seq-sharded at unit
    # boundaries.  The remat/scan-carried activations then live at 1/TP
    # per device; attention/MLP internals reshard (all-to-all to heads,
    # reduce-scatter back) per Megatron-SP, emitted by GSPMD from the
    # constraints.  SP is a *training* trade (it shrinks remat storage);
    # forward-only serving pays its gathers for nothing [perf-iteration
    # H1: gemma2 prefill collective term dropped ~30x], so it is gated
    # on ``remat``.
    from repro import sharding as shd
    mesh = shd.get_global_mesh()
    seq_pin = None
    if (remat and mesh is not None and shd.MODEL_AXIS in mesh.shape
            and x.shape[1] > 1
            and x.shape[1] % mesh.shape[shd.MODEL_AXIS] == 0):
        nsp = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(None, shd.MODEL_AXIS, None))
        seq_pin = lambda t: jax.lax.with_sharding_constraint(t, nsp)

    def unit(x, slices, caches_slice, idx):
        new_caches, aux = [], jnp.zeros((), jnp.float32)
        if seq_pin is not None:
            x = seq_pin(x)
        for j, kind in enumerate(cfg.block_pattern):
            c = caches_slice[j] if caches_slice is not None else None
            lk = jax.random.fold_in(base_key, idx * plen + j)
            x, nc, a = apply_block(
                slices[j], kind, cfg, x, positions=positions, cache=c,
                cache_index=cache_index, memory=memory, causal=causal,
                lossy=lossy, layer_key=lk)
            new_caches.append(nc)
            aux = aux + a
        if seq_pin is not None:
            x = seq_pin(x)
        return x, new_caches, aux

    if n_groups:
        unit_fn = jax.checkpoint(unit) if remat else unit

        def body(carry, inp):
            x, aux = carry
            slices, cache_slice, idx = inp
            x, ncs, a = unit_fn(x, slices, cache_slice, idx)
            return (x, aux + a), ncs

        group_caches = caches["groups"] if caches is not None else None
        xs = (stack["groups"], group_caches, jnp.arange(n_groups))
        (x, aux_total), new_group_caches = jax.lax.scan(
            body, (x, aux_total), xs)
    else:
        new_group_caches = None

    new_tail = []
    for i, kind in enumerate(tail_kinds):
        c = caches["tail"][i] if caches is not None else None
        lk = jax.random.fold_in(base_key, n_groups * plen + i)
        def blk(p_, x_, *, _kind=kind, _c=c, _lk=lk):
            return apply_block(p_, _kind, cfg, x_, positions=positions,
                               cache=_c, cache_index=cache_index,
                               memory=memory, causal=causal, lossy=lossy,
                               layer_key=_lk)
        if remat:
            blk = jax.checkpoint(blk)
        x, nc, a = blk(stack["tail"][i], x)
        new_tail.append(nc)
        aux_total = aux_total + a

    new_caches = None
    if caches is not None:
        new_caches = {"groups": new_group_caches, "tail": new_tail}
    return x, new_caches, aux_total


# ----------------------------------------------------------------------
# Full model
# ----------------------------------------------------------------------

def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 5)
    p: Params = {
        "embed": L.init_embedding(ks[0], cfg),
        "decoder": _init_stack(ks[1], cfg, cfg.n_layers,
                               cross=cfg.is_encdec),
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if cfg.is_encdec:
        p["encoder"] = _init_stack(ks[2], cfg, cfg.encoder_layers)
        p["enc_norm"] = L.init_rmsnorm(cfg.d_model)
    if cfg.frontend:
        dt = jnp.dtype(cfg.dtype)
        p["frontend_proj"] = (
            jax.random.truncated_normal(ks[3], -2., 2.,
                                        (cfg.frontend_dim, cfg.d_model),
                                        jnp.float32)
            * cfg.frontend_dim ** -0.5).astype(dt)
    return p


def _encode(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    """Audio/enc-dec encoder: frame embeddings -> memory (B,S_enc,D)."""
    frames = batch["frame_embeds"]                      # (B, S_enc, F)
    x = frames.astype(jnp.dtype(cfg.dtype)) @ params["frontend_proj"]
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
    x, _, _ = _apply_stack(params["encoder"], cfg, cfg.encoder_layers, x,
                           positions=pos, causal=False)
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            caches=None, cache_index=None, memory=None,
            lossy: Optional[LossyCtx] = None, remat: bool = True,
            positions: Optional[jax.Array] = None, last_only: bool = False):
    """Returns (logits, new_caches, aux_loss).

    batch keys: "tokens" (B,S) always; "image_embeds" (vlm);
    "frame_embeds" (audio, encoder side — triggers encoder unless
    ``memory`` is already given).
    """
    tokens = batch["tokens"]
    x = L.embed(params["embed"], cfg, tokens)

    if cfg.frontend == "vision_stub" and "image_embeds" in batch:
        img = batch["image_embeds"].astype(x.dtype) @ params["frontend_proj"]
        x = jnp.concatenate([img, x], axis=1)

    if cfg.is_encdec and memory is None and "frame_embeds" in batch:
        memory = _encode(params, cfg, batch)

    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]

    x, new_caches, aux = _apply_stack(
        params["decoder"], cfg, cfg.n_layers, x, positions=positions,
        caches=caches, cache_index=cache_index, memory=memory, lossy=lossy,
        remat=remat)

    if last_only:   # prefill: only the last position's logits are used
        x = x[:, -1:]
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], cfg, x)
    return logits, new_caches, aux


def lm_loss(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            lossy: Optional[LossyCtx] = None, remat: bool = True):
    """Next-token cross-entropy (+ MoE aux).  Loss only on text tokens."""
    logits, _, aux = forward(params, cfg, batch, lossy=lossy, remat=remat)
    labels = batch["labels"]
    n_txt = labels.shape[1]
    logits = logits[:, -n_txt:][:, :-1]            # skip frontend positions
    tgt = labels[:, 1:]
    # Sharding-safe CE: every reduction runs over the (model-sharded)
    # vocab axis; no replicated f32 (B,S,V) tensor is ever materialized.
    mx = jax.lax.stop_gradient(
        jnp.max(logits, axis=-1, keepdims=True)).astype(jnp.float32)
    shifted = logits.astype(jnp.float32) - mx
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + mx[..., 0]
    onehot = jax.nn.one_hot(tgt, logits.shape[-1], dtype=logits.dtype)
    label_logit = jnp.sum(logits * onehot, axis=-1).astype(jnp.float32)
    nll = lse - label_logit
    return nll.mean() + aux, (nll.mean(), aux)


# ----------------------------------------------------------------------
# Caches
# ----------------------------------------------------------------------

def _cache_len(kind: str, cfg: ModelConfig, s_max: int) -> int:
    if kind == "local":
        return min(cfg.window_size, s_max)
    return s_max


def init_layer_cache(kind: str, cfg: ModelConfig, batch: int, s_max: int):
    dt = jnp.dtype(cfg.dtype)
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    if kind in ("global", "local", "moe", "xattn"):
        sc = _cache_len(kind, cfg, s_max)
        return {"attn": L.AttnCache(
            k=jnp.zeros((batch, sc, kv, hd), dt),
            v=jnp.zeros((batch, sc, kv, hd), dt),
            pos=jnp.full((sc,), -1, jnp.int32))}
    if kind == "rglru":
        return {"rglru": RG.init_cache(cfg, batch)}
    if kind == "mlstm":
        return {"mlstm": XL.init_mlstm_cache(cfg, batch)}
    if kind == "slstm":
        return {"slstm": XL.init_slstm_cache(cfg, batch)}
    raise ValueError(kind)


def init_caches(cfg: ModelConfig, batch: int, s_max: int):
    plen = len(cfg.block_pattern)
    n_groups, tail = cfg.n_layers // plen, cfg.block_pattern[: cfg.n_layers % plen]
    groups = []
    for kind in cfg.block_pattern:
        one = init_layer_cache(kind, cfg, batch, s_max)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape), one) \
            if n_groups else None
        groups.append(stacked)
    tailc = [init_layer_cache(k, cfg, batch, s_max) for k in tail]
    return {"groups": groups, "tail": tailc}
