"""Mixture-of-Experts block with expert parallelism (EP).

Design (TPU/XLA-friendly — every shape static):

- router: softmax top-k over routed experts (+ optional always-on shared
  experts implemented as a dense TP MLP).
- dispatch: capacity-based.  Each token's top-k picks get a slot in a
  per-expert capacity buffer via a cumsum-over-one-hot position
  computation; overflow tokens are dropped (standard "token dropping").
  Scatter/gather move only G*k rows — no O(G*E*C) dispatch einsums.
- EP (train/prefill) runs in **pure GSPMD form** (works inside the
  dp-manual Celeris train island, where a nested manual shard_map over
  'model' is illegal): the sequence axis folds into a leading "sender
  shard" dim constrained onto the model axis, per-sender dispatch runs
  under vmap (batched scatters partition cleanly), and the
  (TP,E,..) -> (E,TP,..) resharding constraint lowers to the EP
  all-to-all.  With Celeris enabled, dispatch is *lossy*: a
  (sender, expert-shard) block that misses the bounded window is
  dropped before the reshard — the expert sees zeros (swiglu(0)=0) and
  those tokens fall back to the shared-expert/residual path (paper
  §II-B "expert fallback paths").
- decode (S==1): local dispatch (tiny); expert weights stay E-sharded.
- single-device fallback (smoke tests): local dense EP.

Experts are zero-padded to a multiple of the model-axis size (dummy
experts are unroutable: router logits forced to -inf).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import sharding as shd
from repro.configs.base import ModelConfig

Params = Dict[str, Any]


EXPERT_PAD_MULTIPLE = 16   # fixed (= production TP degree) so param
                           # shapes are mesh-independent (checkpoints
                           # stay elastic across topologies)


def padded_experts(cfg: ModelConfig, tp: int = EXPERT_PAD_MULTIPLE) -> int:
    e = cfg.moe.n_experts
    return -(-e // EXPERT_PAD_MULTIPLE) * EXPERT_PAD_MULTIPLE


def init_moe(key: jax.Array, cfg: ModelConfig, tp: int = 1) -> Params:
    m = cfg.moe
    d, f = cfg.d_model, m.d_expert
    e_pad = padded_experts(cfg, tp)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)

    def tn(k, shape, fan_in):
        return (jax.random.truncated_normal(k, -2., 2., shape, jnp.float32)
                * (fan_in ** -0.5)).astype(dt)

    p: Params = {
        "router": tn(ks[0], (d, e_pad), d).astype(jnp.float32),
        "wi": tn(ks[1], (e_pad, d, f), d),
        "wg": tn(ks[2], (e_pad, d, f), d),
        "wo": tn(ks[3], (e_pad, f, d), f),
    }
    if m.n_shared:
        fs = m.n_shared * m.d_expert
        p["shared"] = {
            "wi": tn(ks[4], (d, fs), d),
            "wg": tn(jax.random.fold_in(ks[4], 1), (d, fs), d),
            "wo": tn(jax.random.fold_in(ks[4], 2), (fs, d), fs),
        }
    return p


def param_specs(cfg: ModelConfig) -> Params:
    """PartitionSpecs for MoE params (experts sharded over model)."""
    specs: Params = {
        "router": P(),
        "wi": P(shd.MODEL_AXIS, None, None),
        "wg": P(shd.MODEL_AXIS, None, None),
        "wo": P(shd.MODEL_AXIS, None, None),
    }
    if cfg.moe and cfg.moe.n_shared:
        specs["shared"] = {"wi": P(None, shd.MODEL_AXIS),
                           "wg": P(None, shd.MODEL_AXIS),
                           "wo": P(shd.MODEL_AXIS, None)}
    return specs


def _capacity(cfg: ModelConfig, g_tokens: int, tp: int) -> int:
    m = cfg.moe
    c = int(g_tokens * m.top_k * m.capacity_factor) // padded_experts(cfg, tp)
    return max(8, -(-c // 8) * 8)   # round up to 8 for TPU tiling


def _route(p: Params, cfg: ModelConfig, x2d: jax.Array, e_pad: int):
    """Top-k routing.  x2d: (G, d) -> (probs (G,k), ids (G,k), aux)."""
    m = cfg.moe
    logits = x2d.astype(jnp.float32) @ p["router"]
    if e_pad > m.n_experts:   # dummy padded experts are unroutable
        pad_mask = jnp.arange(e_pad) >= m.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(0)
    ce = jnp.zeros((e_pad,)).at[top_i.reshape(-1)].add(1.0) / top_i.size
    aux = (m.aux_weight * e_pad * jnp.sum(me * ce)
           + m.router_z_weight * jnp.mean(
               jnp.square(jax.nn.logsumexp(logits, axis=-1))))
    return top_p, top_i, aux


def _dispatch_indices(top_i: jax.Array, e_pad: int, cap: int):
    """Slot assignment: (G,k) expert ids -> (flat ids, slots, keep mask)."""
    flat = top_i.reshape(-1)                                   # (G*k,)
    onehot = jax.nn.one_hot(flat, e_pad, dtype=jnp.int32)      # (G*k, E)
    pos = (jnp.cumsum(onehot, axis=0) - 1) * onehot
    slot = pos.sum(-1)                                         # (G*k,)
    keep = slot < cap
    return flat, slot, keep


def _expert_ffn(wi, wg, wo, h: jax.Array) -> jax.Array:
    """h: (E_local, C_total, d) -> same; batched swiglu per expert."""
    a = jnp.einsum("ecd,edf->ecf", h, wg)
    b = jnp.einsum("ecd,edf->ecf", h, wi)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(a) * b, wo)


def _scatter_combine(x2d, top_p, flat, slot, keep, out_buf, cap):
    """Gather expert outputs back to token order, weighted by router."""
    g, d = x2d.shape
    k = top_p.shape[-1]
    got = out_buf[flat, jnp.minimum(slot, cap - 1)]
    got = got * (keep[:, None] * top_p.reshape(-1)[:, None]).astype(got.dtype)
    return got.reshape(g, k, d).sum(1)


def _moe_local(p, cfg, x2d, e_pad, cap):
    """Single-device path (no EP collectives)."""
    top_p, top_i, aux = _route(p, cfg, x2d, e_pad)
    flat, slot, keep = _dispatch_indices(top_i, e_pad, cap)
    k = cfg.moe.top_k
    rows = jnp.repeat(x2d, k, axis=0) * keep[:, None].astype(x2d.dtype)
    buf = jnp.zeros((e_pad, cap, x2d.shape[-1]), x2d.dtype)
    buf = buf.at[flat, jnp.minimum(slot, cap - 1)].add(rows)
    out_buf = _expert_ffn(p["wi"], p["wg"], p["wo"], buf)
    return _scatter_combine(x2d, top_p, flat, slot, keep, out_buf, cap), aux


def _dispatch_2d(p, cfg, x2d, e_pad, cap, src_mask=None):
    """Route+dispatch a (G,d) token block into (E,C,d) capacity buffers.

    ``src_mask`` (E,) optional arrival mask for this sender's blocks
    (Celeris lossy dispatch: tokens bound for a dropped (sender, expert-
    shard) block never arrive; swiglu(0)=0 so they contribute nothing
    and fall back to shared-expert/residual).
    Returns (buf, combine_fn, aux).
    """
    g, d = x2d.shape
    k = cfg.moe.top_k
    top_p, top_i, aux = _route(p, cfg, x2d, e_pad)
    flat, slot, keep = _dispatch_indices(top_i, e_pad, cap)
    if src_mask is not None:
        keep = keep & src_mask[flat]
    rows = jnp.repeat(x2d, k, axis=0) * keep[:, None].astype(x2d.dtype)
    buf = jnp.zeros((e_pad, cap, d), x2d.dtype)
    buf = buf.at[flat, jnp.minimum(slot, cap - 1)].add(rows)

    def combine(out_buf):
        return _scatter_combine(x2d, top_p, flat, slot, keep, out_buf, cap)

    return buf, combine, aux


def _constrain(x, spec):
    mesh = shd.get_global_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def _moe_ep_gspmd(p, cfg, x, e_pad, tp, lossy, key, drop_rate):
    """Expert parallelism in pure GSPMD (auto) form.

    The sequence axis is folded into a leading "sender shard" dim that
    rides the model axis; per-sender dispatch runs under vmap (batched
    scatters partition cleanly), and the (TP,E,..) -> (E,TP,..)
    resharding constraint lowers to the EP all-to-all.  Works both
    inside the dp-manual train island and in plain serving jits.
    """
    from jax.sharding import PartitionSpec as P
    b, s, d = x.shape
    s_loc = s // tp
    xs = x.reshape(b, tp, s_loc, d).swapaxes(0, 1).reshape(tp, b * s_loc, d)
    xs = _constrain(xs, P(shd.MODEL_AXIS, None, None))
    cap = _capacity(cfg, b * s_loc, tp)

    if lossy:
        # (sender, dest-shard) arrival coins -> expand to (sender, expert)
        key = key if key is not None else jax.random.PRNGKey(0)
        coins = jax.random.uniform(key, (tp, tp)) >= drop_rate
        src_masks = jnp.repeat(coins, e_pad // tp, axis=1)     # (TP, E)
    else:
        src_masks = jnp.ones((tp, e_pad), bool)

    def one_sender(x2d, mask):
        buf, _, aux = _dispatch_2d(p, cfg, x2d, e_pad, cap, src_mask=mask)
        return buf, aux

    bufs, auxs = jax.vmap(one_sender)(xs, src_masks)   # (TP,E,C,d)
    bufs = _constrain(bufs, P(shd.MODEL_AXIS, None, None, None))

    # ---- EP "all-to-all": reshard sender-major -> expert-major
    h = bufs.swapaxes(0, 1)                            # (E,TP,C,d)
    h = _constrain(h, P(shd.MODEL_AXIS, None, None, None))
    h = h.reshape(e_pad, tp * cap, d)
    out = _expert_ffn(p["wi"], p["wg"], p["wo"], h)    # E-sharded
    out = _constrain(out, P(shd.MODEL_AXIS, None, None))

    # ---- return path
    back = out.reshape(e_pad, tp, cap, d).swapaxes(0, 1)
    back = _constrain(back, P(shd.MODEL_AXIS, None, None, None))

    def one_receiver(x2d, mask, out_buf):
        # recompute indices (cheap) to combine; same routing as dispatch
        _, combine, _ = _dispatch_2d(p, cfg, x2d, e_pad, cap, src_mask=mask)
        return combine(out_buf)

    ys = jax.vmap(one_receiver)(xs, src_masks, back)   # (TP, B*S_loc, d)
    ys = _constrain(ys, P(shd.MODEL_AXIS, None, None))
    y = ys.reshape(tp, b, s_loc, d).swapaxes(0, 1).reshape(b, s, d)
    return y, auxs.mean()


def moe_block(p: Params, cfg: ModelConfig, x: jax.Array, *,
              lossy: bool = False,
              key: Optional[jax.Array] = None,
              drop_rate: jax.Array | float = 0.0,
              ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).  Adds shared-expert output."""
    mesh = shd.get_global_mesh()
    tp = mesh.shape[shd.MODEL_AXIS] if mesh is not None else 1
    e_pad = padded_experts(cfg, tp)
    b, s, d = x.shape

    if mesh is None or tp == 1 or s % tp or s < tp:
        # single-device / decode path: local dispatch; expert weights may
        # be sharded over E (GSPMD gathers them - tiny at decode sizes).
        cap = _capacity(cfg, b * s, 1)
        routed, aux = _moe_local(p, cfg, x.reshape(-1, d), e_pad, cap)
        routed = routed.reshape(b, s, d)
    else:
        routed, aux = _moe_ep_gspmd(
            p, cfg, x, e_pad, tp, lossy, key,
            jnp.asarray(drop_rate, jnp.float32))

    if "shared" in p:
        sp = p["shared"]
        shared = (jax.nn.silu(x @ sp["wg"]) * (x @ sp["wi"])) @ sp["wo"]
        routed = routed + shared
    return routed, aux
