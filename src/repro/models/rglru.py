"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block structure (per Griffin):
    x -> [gate branch: GeLU(x W_g)]                          (B,S,W)
      -> [rec branch:  x W_in -> causal conv1d(4) -> RG-LRU] (B,S,W)
    out = (gate * rglru) W_out                                (B,S,D)

RG-LRU cell (diagonal gated linear recurrence):
    r_t = sigmoid(w_a . u_t + b_a)          recurrence gate
    i_t = sigmoid(w_x . u_t + b_x)          input gate
    a_t = exp(-c * softplus(lam) * r_t)     per-channel decay, c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t u_t)

Training/prefill uses ``jax.lax.associative_scan`` over the sequence
(O(log S) depth — TPU-friendly; this is the sub-quadratic path that
makes long_500k runnable).  Decode is the exact single-step update with
carried (conv window, h) state.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, Any]
_C = 8.0


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("conv", "h"), meta_fields=())
@dataclasses.dataclass
class RglruCache:
    conv: jax.Array    # (B, conv_width-1, W) trailing inputs
    h: jax.Array       # (B, W) recurrent state


def init_rglru(key: jax.Array, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    w = cfg.lru_width or d
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)

    def tn(k, shape, fan_in):
        return (jax.random.truncated_normal(k, -2., 2., shape, jnp.float32)
                * (fan_in ** -0.5)).astype(dt)

    # lambda init so a^c in [0.9, 0.999] (Griffin's stable-decay init)
    u = jax.random.uniform(ks[4], (w,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))   # softplus^-1(-log u / c)
    return {
        "w_in": tn(ks[0], (d, w), d),
        "w_gate": tn(ks[1], (d, w), d),
        "w_out": tn(ks[2], (w, d), w),
        "conv_w": tn(ks[3], (cfg.rglru_conv_width, w), cfg.rglru_conv_width),
        "conv_b": jnp.zeros((w,), dt),
        "gate_a_w": jnp.zeros((w,), jnp.float32),
        "gate_a_b": jnp.zeros((w,), jnp.float32),
        "gate_x_w": jnp.zeros((w,), jnp.float32),
        "gate_x_b": jnp.zeros((w,), jnp.float32),
        "lam": lam,
    }


def _causal_conv(p: Params, u: jax.Array, prev: Optional[jax.Array]):
    """Depthwise causal conv over time.  u: (B,S,W)."""
    kw = p["conv_w"].shape[0]
    if prev is None:
        pad = jnp.zeros((u.shape[0], kw - 1, u.shape[2]), u.dtype)
    else:
        pad = prev.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)           # (B, S+kw-1, W)
    out = sum(full[:, i: i + u.shape[1]] * p["conv_w"][i]
              for i in range(kw))
    new_prev = full[:, -(kw - 1):] if kw > 1 else pad[:, :0]
    return out + p["conv_b"], new_prev


def _cell_coeffs(p: Params, u: jax.Array):
    """Per-step (a_t, b_t) of h_t = a_t h_{t-1} + b_t.  u: (..., W)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * p["gate_a_w"] + p["gate_a_b"])
    i = jax.nn.sigmoid(uf * p["gate_x_w"] + p["gate_x_b"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-9, 1.0)) * (i * uf)
    return a, b


def rglru_block(p: Params, cfg: ModelConfig, x: jax.Array, *,
                cache: Optional[RglruCache] = None,
                ) -> tuple[jax.Array, Optional[RglruCache]]:
    """x: (B, S, D) -> (out (B,S,D), new cache).

    S > 1: parallel associative scan (train/prefill).
    S == 1 with cache: exact recurrent decode step.
    """
    b, s, d = x.shape
    gate = jax.nn.gelu((x @ p["w_gate"]), approximate=True)
    u = x @ p["w_in"]

    prev = cache.conv if cache is not None else None
    u, new_conv = _causal_conv(p, u, prev)

    # keep the LRU width on the model axis through the (elementwise)
    # recurrence: the associative scan then stays collective-free and
    # its O(S*W) intermediates stay sharded.
    from repro import sharding as shd
    mesh = shd.get_global_mesh()
    if (mesh is not None and s > 1
            and u.shape[-1] % mesh.shape.get(shd.MODEL_AXIS, 1) == 0):
        ns = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(None, None, shd.MODEL_AXIS))
        u = jax.lax.with_sharding_constraint(u, ns)
        gate = jax.lax.with_sharding_constraint(gate, ns)

    a, bt = _cell_coeffs(p, u)

    if s == 1 and cache is not None:
        h = a[:, 0] * cache.h + bt[:, 0]               # (B, W)
        hs = h[:, None, :]
        new_cache = RglruCache(conv=new_conv, h=h)
    else:
        h0 = cache.h if cache is not None else jnp.zeros(
            (b, a.shape[-1]), jnp.float32)

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        # Chunked recurrence: assoc-scan inside chunks of 512, linear
        # scan of boundary states across chunks.  Bounds the O(S*W*logS)
        # assoc-scan intermediates (which dominated train-cell memory at
        # W=4096) to one chunk, at unchanged math.
        chunk = 512
        if s % chunk == 0 and s > chunk:
            nc = s // chunk
            ar = a.reshape(b, nc, chunk, -1).swapaxes(0, 1)
            br = bt.reshape(b, nc, chunk, -1).swapaxes(0, 1)

            def chunk_step(h, t):
                ac, bc = t
                bc = bc.at[:, 0].add(ac[:, 0] * h)
                _, hc = jax.lax.associative_scan(combine, (ac, bc), axis=1)
                return hc[:, -1], hc

            h_last, hs = jax.lax.scan(chunk_step, h0, (ar, br))
            hs = hs.swapaxes(0, 1).reshape(b, s, -1)
        else:
            bt = bt.at[:, 0].add(a[:, 0] * h0)
            _, hs = jax.lax.associative_scan(combine, (a, bt), axis=1)
            h_last = hs[:, -1]
        new_cache = RglruCache(conv=new_conv, h=h_last) \
            if cache is not None else None

    out = (hs.astype(gate.dtype) * gate) @ p["w_out"]
    return out, new_cache


def init_cache(cfg: ModelConfig, batch: int) -> RglruCache:
    w = cfg.lru_width or cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    return RglruCache(
        conv=jnp.zeros((batch, cfg.rglru_conv_width - 1, w), dt),
        h=jnp.zeros((batch, w), jnp.float32))
