"""Train-step factory: GSPMD TP/SP + transport-coupled gradient sync.

The gradient collective dispatches on
:class:`repro.core.transport.coupling.CollectiveMode`
(``CelerisConfig.mode``):

- **exact** — lossless all-reduce (RoCE-like semantics).  On a mesh
  this is pure GSPMD: the batch is dp-sharded and value_and_grad of the
  global batch-mean loss makes the partitioner insert the all-reduces.
- **lossy** — best-effort WITHOUT coding, the Fig.-1 ablation: wire
  rows beyond the bounded receiver window are holes in the raw
  gradient (:func:`_mask_grads_plain`, GSPMD-composable).
- **lossy_hadamard** — the paper's §III-B path, a **shard_map island,
  manual over the dp axes ('pod','data'), auto (GSPMD) over 'model'**:
  each dp shard runs value_and_grad on its local batch, then per-leaf
  randomized-Hadamard encode (wire-interleaved), per-(peer, wire-row)
  arrival masks drawn from the step's drop probability (fed by the
  transport engine through ``coupling.DropSchedule``), count-unbiased
  decode.  The realized received fraction is returned for the timeout
  controller.  Sharding hint: rotation blocks ride the 'model' axis so
  the FWHT is collective-free and nothing de-shards.
- **hierarchical** — the multi-pod topology split: gradients first
  reduce *exactly* over the intra-pod 'data' axis (the fat in-pod
  fabric is effectively lossless), then the pod-mean gradients take
  the best-effort + Hadamard path over the 'pod' axis only — arrival
  masks are per-(pod, wire-row) at the DCI tier's drop rate.  The
  step's ``drop_rate`` input is the axis vector produced by
  ``coupling.AxisSchedules`` / ``HierStragglerModel``: the ``(2,)``
  aggregate ``[intra, cross]`` consumes ``drop_rate[-1]``; the per-pod
  ``(n_pods + 1,)`` form ``[intra_pod0..., cross]`` charges each pod's
  mask the combined rate ``1 - (1 - intra_pod)(1 - cross)`` (the shard
  rides its pod fabric before the DCI exchange).
  This sync order mirrors the transport engine's
  ``schedule.HierarchicalSchedule`` phase plan — intra-pod
  reduce-scatter, then the lossy cross-pod DCI exchange, then
  intra-pod all-gather — and ``make_train_step`` asserts against its
  ``PHASE_ORDER`` so the two layers cannot drift apart silently.
  Composing ``quantize_wire=True`` with this mode quantizes *only* the
  cross-pod shards: the intra-pod pmean runs before the coded island's
  encode/quantize stage, so in-pod sync stays full-precision f32 while
  the DCI payload ships int8 (the bandwidth-starved hop is the only
  one paying the precision cost).

On jax >= 0.8 (``sharding.plain_lossy_island_supported``) the **lossy**
mode also runs as a shard_map island with per-(peer, wire-row) masks
applied *before* the plain psum — true sender-side loss without
recovery.  The 0.4.x CPU partitioner CHECK-crashes on that island shape
(only the coded psum graph survives partial-auto), so there the mode
keeps the receiver-window fallback: masking the already-synced
gradient.

Then the optimizer update (AdamW, fp32 master, ZeRO-1-sharded state)
under plain GSPMD.  The factory precomputes the per-leaf Hadamard
coding plans from the static param shapes (block counts padded to the
TP degree).

The ``drop_rate`` step input is where the transport engine couples in:
``Trainer`` walks an engine-derived ``DropSchedule`` (or the standalone
straggler model) and feeds one scalar per step.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as shd
from repro.configs.base import ModelConfig
from repro.core import coding
from repro.core import lossy_collectives as lc
from repro.core.transport.coupling import MAX_DROP, CollectiveMode
from repro.models import model as M
from repro.optim import adamw
from repro.train import sharding_rules as rules


@dataclasses.dataclass(frozen=True)
class CelerisConfig:
    """Celeris integration knobs for training."""
    enabled: bool = False            # legacy switch: True == lossy_hadamard
    mode: str | CollectiveMode | None = None
                                     # "exact" | "lossy" | "lossy_hadamard"
                                     # | "hierarchical"; None defers to
                                     # ``enabled``.  "lossy" is the uncoded
                                     # ablation: dropped wire rows stay
                                     # dropped, so the Fig.-1 A/B isolates
                                     # what the Hadamard layer buys.
                                     # "hierarchical" needs a 'pod' mesh
                                     # axis and a (2,) [intra, cross] drop
                                     # input (coupling.AxisSchedules).
    lossy_moe: bool = False          # lossy expert-parallel All-to-All
    n_rot: int = 4096                # Hadamard rotation width
    use_pallas: bool = False         # FWHT via Pallas kernel (TPU) vs jnp
    min_coded_size: int = 65536      # leaves smaller than this sync exactly
    wire_dtype: str = "float32"      # collective payload dtype.  H3: set
                                     # "bfloat16" on TPU to halve DP sync
                                     # bytes (decode stays f32).  Default
                                     # f32: XLA *CPU*'s AllReducePromotion
                                     # pass crashes on mixed-dtype variadic
                                     # all-reduces (see dryrun.py flags).
    quantize_wire: bool = False      # H6 (beyond-paper): int8-quantized
                                     # wire with shared per-row scales,
                                     # summed over dp in int16 -> 2x fewer
                                     # collective bytes than f32 (max peer
                                     # sum 16*127 < 2^15).  Composes with
                                     # the Hadamard rotation (QSGD-style:
                                     # rotation whitens the per-row range
                                     # so one scale fits all peers).
                                     # Under mode="hierarchical" only the
                                     # cross-pod (DCI) psum is quantized —
                                     # the intra-pod exact pmean happens
                                     # before encode, so in-pod sync stays
                                     # full precision.

    def collective_mode(self) -> CollectiveMode:
        if self.mode is not None:
            return CollectiveMode.parse(self.mode)
        return (CollectiveMode.LOSSY_HADAMARD if self.enabled
                else CollectiveMode.EXACT)


def _sync_grads_exact(grads, dp):
    # reduce in f32: uniform collective dtype (XLA CPU's AllReducePromotion
    # crashes on mixed-dtype variadic all-reduce) and better accumulation.
    sync = lambda g: jax.lax.pmean(g.astype(jnp.float32), dp).astype(g.dtype)
    return jax.tree.map(sync, grads), jnp.float32(1.0)


def _dp_size(dp, mesh):
    n_dp = 1
    for ax in dp:
        n_dp *= mesh.shape[ax] if mesh is not None else 1
    return n_dp


def _leaf_mask(key, i, peer_id, n_rot, drop_rate):
    """Per-(leaf, peer) arrival mask.  ``peer_id`` is this shard's index
    along the dp axes, passed in explicitly: ``axis_index`` inside a
    partially-auto shard_map lowers to a PartitionId op the SPMD
    partitioner rejects (jax 0.4.x CPU)."""
    k = jax.random.fold_in(jax.random.fold_in(key, 2 * i + 1), peer_id)
    return lc.arrival_mask(k, n_rot, drop_rate)


def _sync_grads_celeris(grads, dp, plans, key, drop_rate, celeris, mesh,
                        peer_id, lossy_axes=None, exact_axes=()):
    """Per-leaf lossy pmean with Hadamard recovery (sharding-aware ND
    form: rotation runs along each leaf's unsharded axes only, so no
    reshape ever crosses the TP sharding — see coding.encode_nd).

    ``lossy_axes``/``exact_axes`` split the dp group for hierarchical
    topologies: coded leaves first pmean *exactly* over ``exact_axes``
    (intra-pod), then run the lossy coded psum over ``lossy_axes`` only
    (cross-pod), with ``peer_id`` the shard's index along the lossy
    group.  Defaults reproduce the flat behavior (whole dp lossy).
    """
    lossy_axes = tuple(lossy_axes) if lossy_axes is not None else tuple(dp)
    flat, treedef = jax.tree_util.tree_flatten(grads)
    n_lossy = _dp_size(lossy_axes, mesh)
    out, fracs = [], []
    for i, (g, plan) in enumerate(zip(flat, plans)):
        if plan is None:   # small leaf: exact sync (f32, see exact path)
            out.append(jax.lax.pmean(g.astype(jnp.float32), dp)
                       .astype(g.dtype))
            continue
        if exact_axes:     # intra-pod reduction: exact, f32
            g = jax.lax.pmean(g.astype(jnp.float32), exact_axes)
        signs = coding.rademacher_nd(jax.random.fold_in(key, 2 * i), plan)
        tiles = coding.encode_nd(g, signs, plan)
        mask = _leaf_mask(key, i, peer_id, plan.n_rot, drop_rate)
        contrib = tiles * mask[None, :, None].astype(tiles.dtype)
        if celeris.quantize_wire:
            # shared scale per wire row: psum-max of |contrib| so every
            # peer's int8 payload lives on one grid (tiny f32 pre-pass:
            # n_rot scalars per leaf)
            absmax = jax.lax.pmax(
                jnp.max(jnp.abs(contrib), axis=(0, 2)), lossy_axes)
            scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
            noise = jax.random.uniform(
                jax.random.fold_in(key, 3 * i + 2), contrib.shape)
            q = jnp.clip(jnp.floor(contrib / scale[None, :, None] + noise),
                         -127, 127).astype(jnp.int16)
            tiles_sum = (jax.lax.psum(q, lossy_axes).astype(jnp.float32)
                         * scale[None, :, None])
        else:
            contrib = contrib.astype(jnp.dtype(celeris.wire_dtype))
            tiles_sum = jax.lax.psum(contrib, lossy_axes).astype(jnp.float32)
        counts = jax.lax.psum(mask.astype(jnp.float32), lossy_axes)
        est = coding.decode_nd(tiles_sum, counts, signs, plan,
                               total_peers=n_lossy)
        out.append((est / n_lossy).astype(g.dtype))
        fracs.append(jnp.sum(counts) / (n_lossy * plan.n_rot))
    frac = jnp.stack(fracs).mean() if fracs else jnp.float32(1.0)
    return jax.tree_util.tree_unflatten(treedef, out), frac


def _sync_grads_plain_island(grads, dp, plans, key, drop_rate, mesh,
                             peer_id):
    """Per-(peer, wire-row) loss WITHOUT coding, inside the island
    (jax >= 0.8 only — see ``sharding.plain_lossy_island_supported``):
    each peer masks its own contribution *before* the plain psum, so a
    dropped row is missing from that peer only, with no recovery and no
    rescaling — the uncoded sender-side ablation the 0.4.x partitioner
    can't lower."""
    flat, treedef = jax.tree_util.tree_flatten(grads)
    n_dp = _dp_size(dp, mesh)
    out, fracs = [], []
    for i, (g, plan) in enumerate(zip(flat, plans)):
        if plan is None:
            out.append(jax.lax.pmean(g.astype(jnp.float32), dp)
                       .astype(g.dtype))
            continue
        tiles = coding.to_tiles_nd(g.astype(jnp.float32), plan)
        mask = _leaf_mask(key, i, peer_id, plan.n_rot, drop_rate)
        masked = tiles * mask[None, :, None].astype(tiles.dtype)
        tiles_sum = jax.lax.psum(masked, dp)
        counts = jax.lax.psum(mask.astype(jnp.float32), dp)
        out.append(coding.from_tiles_nd(tiles_sum / n_dp, plan)
                   .astype(g.dtype))
        fracs.append(jnp.sum(counts) / (n_dp * plan.n_rot))
    frac = jnp.stack(fracs).mean() if fracs else jnp.float32(1.0)
    return jax.tree_util.tree_unflatten(treedef, out), frac


def _mask_grads_plain(grads, plans, key, drop_rate):
    """Receiver-window loss WITHOUT coding — the Fig.-1 ablation.

    One arrival mask per leaf is applied to the (already synced or
    local) gradient: wire rows that miss the bounded window are holes in
    the raw gradient, with no recovery — exactly the damage §III-B's
    coding absorbs.  This is receiver-granularity loss (a late row is
    lost from every peer at once); the per-(peer, row) form lives in the
    Hadamard island, whose coded psum is the only shape the jax 0.4.x
    CPU partitioner lowers under partial-auto shard_map.  Pure
    elementwise + reshape ops, so it composes with any mesh via GSPMD.
    """
    flat, treedef = jax.tree_util.tree_flatten(grads)
    out, fracs = [], []
    for i, (g, plan) in enumerate(zip(flat, plans)):
        if plan is None:
            out.append(g)
            continue
        mask = _leaf_mask(key, i, 0, plan.n_rot, drop_rate)
        tiles = coding.to_tiles_nd(g.astype(jnp.float32), plan)
        masked = tiles * mask[None, :, None].astype(tiles.dtype)
        out.append(coding.from_tiles_nd(masked, plan).astype(g.dtype))
        fracs.append(mask.mean())
    frac = jnp.stack(fracs).mean() if fracs else jnp.float32(1.0)
    return jax.tree_util.tree_unflatten(treedef, out), frac


def make_train_step(cfg: ModelConfig, mesh, opt_cfg: adamw.OptConfig,
                    celeris: Optional[CelerisConfig] = None,
                    donate: bool = True, microbatches: int = 1):
    """Returns jitted ``step(state, batch, key, drop_rate) -> (state, metrics)``.

    state = {"params", "opt", "step"}; batch = {"tokens","labels",...}.
    ``microbatches > 1``: gradient accumulation — the local batch is
    split and scanned, dividing activation memory by the count (the
    standard way multi-billion-param train cells fit HBM); the (lossy)
    gradient sync still happens once per step on the accumulated grads.
    """
    celeris = celeris or CelerisConfig()
    mode = celeris.collective_mode()
    dp = shd.dp_axes(mesh)
    if mode is CollectiveMode.HIERARCHICAL and dp and shd.POD_AXIS not in dp:
        raise ValueError(
            "hierarchical collective mode needs a 'pod' mesh axis "
            "(launch.mesh.make_pod_mesh / make_scale_mesh >= 512); "
            f"got dp axes {dp}")
    if mode is CollectiveMode.HIERARCHICAL:
        # contract with the transport engine's collective schedule: the
        # sync below runs exact-intra first ('data' axes), then the
        # coded lossy cross-pod psum ('pod' axis) — the same order as
        # HierarchicalSchedule's phases (rs -> dci -> ag).  If the
        # schedule's phase order ever changes, this mode's sync (and
        # the [intra, cross] drop-vector convention) must change with
        # it, so fail loudly instead of silently mismatching.
        from repro.core.transport.schedule import HierarchicalSchedule
        order = HierarchicalSchedule.PHASE_ORDER
        assert order[0] == "rs" and order[-1] == "ag" and "dci" in order, (
            f"CollectiveMode.HIERARCHICAL assumes intra-reduce -> DCI "
            f"exchange -> intra-gather; HierarchicalSchedule.PHASE_ORDER "
            f"is {order}")
        # priority contract (cut_order="priority" coupling): this mode
        # masks ONLY the cross-pod (DCI) shards — the coded, int8-able,
        # recoverable bytes — so the schedule must place the DCI
        # exchange in the strictly lowest priority class, i.e. the
        # window cuts exactly the bytes the trainer knows how to lose
        # (coupling.PrioritySchedules.low == the masked cross axis).
        prio = HierarchicalSchedule.PRIORITY
        assert prio["dci"] < min(prio["rs"], prio["ag"]), (
            f"CollectiveMode.HIERARCHICAL masks only DCI shards, so the "
            f"DCI phase must be the lowest (cut-first) priority class; "
            f"HierarchicalSchedule.PRIORITY is {prio}")

    def _grads_one(params, batch, key, drop_rate):
        # the MoE all-to-all coin expects one scalar; hierarchical mode
        # feeds a (2,) [intra, cross] vector — expert exchange crosses
        # pods, so it takes the cross component
        moe_rate = jnp.reshape(drop_rate, (-1,))[-1]
        lossy_ctx = M.LossyCtx(enabled=celeris.lossy_moe, key=key,
                               drop_rate=moe_rate)

        def loss_fn(p):
            return M.lm_loss(p, cfg, batch, lossy=lossy_ctx)

        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def _accum_grads(params, batch, key, drop_rate):
        if microbatches > 1:
            mb = jax.tree.map(
                lambda a: a.reshape((microbatches,
                                     a.shape[0] // microbatches)
                                    + a.shape[1:]), batch)

            def mb_step(carry, xs):
                gacc, lacc, nacc, aacc = carry
                b_i, i = xs
                (l, (n, a_)), g = _grads_one(
                    params, b_i, jax.random.fold_in(key, i), drop_rate)
                gacc = jax.tree.map(
                    lambda x, y: x + y.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l, nacc + n, aacc + a_), None

            g0 = jax.tree.map(
                lambda p_: jnp.zeros(p_.shape, jnp.float32), params)
            z = jnp.zeros((), jnp.float32)
            (gsum, loss, nll, aux), _ = jax.lax.scan(
                mb_step, (g0, z, z, z), (mb, jnp.arange(microbatches)))
            inv = 1.0 / microbatches
            grads = jax.tree.map(
                lambda g_, p_: (g_ * inv).astype(p_.dtype), gsum, params)
            loss, nll, aux = loss * inv, nll * inv, aux * inv
        else:
            (loss, (nll, aux)), grads = _grads_one(params, batch, key,
                                                   drop_rate)
        return loss, nll, aux, grads

    pod_axes = tuple(a for a in dp if a == shd.POD_AXIS)
    data_axes = tuple(a for a in dp if a != shd.POD_AXIS)

    def island(params, batch, key, drop_rate, plans, peer=None):
        # this shard's index along the dp axes (None when no lossy sync
        # consumes it: an unused manual-sharded input CHECK-crashes the
        # jax 0.4.x CPU SPMD partitioner)
        peer_id = peer[0] if peer is not None else 0
        loss, nll, aux, grads = _accum_grads(params, batch, key, drop_rate)

        if mode is CollectiveMode.HIERARCHICAL:
            # intra-pod exact, cross-pod coded-lossy: every data shard
            # in a pod shares the pod's wire, so the mask peer is the
            # pod index and the drop is the cross-pod (DCI) component
            # of the axis vector (scalar inputs work too:
            # reshape(-1)[-1] is the scalar itself).  A per-pod
            # (n_pods + 1,) vector ([intra_pod..., cross], from
            # coupling.AxisSchedules.per_pod) additionally charges each
            # pod's DCI contribution its own pod fabric: the shard
            # rides pod p's intra fabric before the DCI exchange, so
            # its arrival probability is the product of surviving both
            # — rate = 1 - (1 - intra_p)(1 - cross).
            pod_id = peer_id // _dp_size(data_axes, mesh)
            dr = jnp.reshape(drop_rate, (-1,))
            cross = dr[-1]
            n_pods_mesh = _dp_size(pod_axes, mesh)
            if dr.shape[0] == n_pods_mesh + 1 and n_pods_mesh > 1:
                intra_p = jnp.take(dr, pod_id)
                # both components are individually clamped at MAX_DROP
                # by DropSchedule, but their product form can exceed it
                # (up to 0.75) for a heavily faulted pod — hold the
                # combined rate to the same decodability ceiling
                cross = jnp.minimum(
                    1.0 - (1.0 - intra_p) * (1.0 - cross), MAX_DROP)
            grads, frac = _sync_grads_celeris(
                grads, dp, plans, key, cross, celeris, mesh, pod_id,
                lossy_axes=pod_axes, exact_axes=data_axes)
        elif mode is CollectiveMode.LOSSY:
            grads, frac = _sync_grads_plain_island(grads, dp, plans, key,
                                                   drop_rate, mesh, peer_id)
        else:
            grads, frac = _sync_grads_celeris(grads, dp, plans, key,
                                              drop_rate, celeris, mesh,
                                              peer_id)
        loss = jax.lax.pmean(loss, dp)
        nll = jax.lax.pmean(nll, dp)
        aux = jax.lax.pmean(aux, dp)
        return loss, nll, aux, grads, frac

    def train_step(state, batch, key, drop_rate):
        params = state["params"]
        flat = jax.tree_util.tree_leaves(params)
        if mesh is not None:
            pspecs = rules.param_specs(params, mesh)
            flat_specs = jax.tree_util.tree_leaves(
                pspecs, is_leaf=lambda x: isinstance(x, P))
        else:
            flat_specs = [P()] * len(flat)

        def sharded_dim(leaf, spec):
            for i, sname in enumerate(spec):
                if sname == shd.MODEL_AXIS and i < leaf.ndim:
                    return i
            return None

        plans = [coding.plan_nd(l.shape, sharded_dim(l, sp), celeris.n_rot)
                 if l.size >= celeris.min_coded_size else None
                 for l, sp in zip(flat, flat_specs)]

        island_modes = {CollectiveMode.LOSSY_HADAMARD}
        if pod_axes:
            island_modes.add(CollectiveMode.HIERARCHICAL)
        if shd.plain_lossy_island_supported():
            # jax >= 0.8: the uncoded island lowers too, unlocking
            # per-(peer,row) plain-lossy (0.4.x keeps the post-sync
            # receiver-window fallback below)
            island_modes.add(CollectiveMode.LOSSY)
        use_island = (dp and mode in island_modes
                      and any(p is not None for p in plans))
        if use_island:
            # params/grads are dp-replicated: every in/out spec is P();
            # their 'model' shardings ride through the auto axis.  Each
            # shard's dp index arrives as data (P(dp)-sharded arange)
            # because axis_index doesn't lower under partial-auto.
            rep = jax.tree.map(lambda _: P(), params)
            fn = lambda p_, b_, k_, d_, pe_: island(
                p_, b_, k_, d_, plans, peer=pe_)
            loss, nll, aux, grads, frac = shd.shard_map(
                fn, mesh=mesh,
                in_specs=(rep, rules.batch_specs(mesh, batch), P(), P(),
                          P(dp)),
                out_specs=(P(), P(), P(), rep, P()),
                axis_names=set(dp), check_vma=False,
            )(params, batch, key, drop_rate,
              jnp.arange(_dp_size(dp, mesh), dtype=jnp.int32))
        elif dp:
            # Exact (and plain-lossy, and hadamard-with-nothing-to-code)
            # collectives on a mesh need no manual island: with the
            # batch dp-sharded, value_and_grad of the global batch-mean
            # loss makes GSPMD insert exactly the lossless all-reduces
            # the island's pmean would (and the jax 0.4.x CPU
            # partitioner CHECK-crashes on a partial-auto island whose
            # gradients cross the boundary uncoded).  Plain-lossy then
            # applies the receiver window to the synced gradient.
            loss, nll, aux, grads = _accum_grads(params, batch, key,
                                                 drop_rate)
            if mode is CollectiveMode.LOSSY:
                grads, frac = _mask_grads_plain(grads, plans, key,
                                                drop_rate)
            else:
                frac = jnp.float32(1.0)
        else:   # single-device / no-dp path
            lossy_ctx = M.LossyCtx(enabled=celeris.lossy_moe, key=key,
                                   drop_rate=jnp.reshape(drop_rate,
                                                         (-1,))[-1])
            (loss, (nll, aux)), grads = jax.value_and_grad(
                lambda p: M.lm_loss(p, cfg, batch, lossy=lossy_ctx),
                has_aux=True)(params)
            if mode.coded:
                # no dp axis to lose data across, but the node itself
                # still receives only (1 - drop_rate) of each collective
                # payload inside its bounded window: emulate via
                # single-peer encode -> mask -> unbiased decode (this is
                # what the Fig.-1 loss-tolerance benchmark measures).
                # Hierarchical mode loses only on the cross-pod axis, so
                # its emulation rate is the vector's cross component.
                rate = jnp.reshape(drop_rate, (-1,))[-1]
                flat, tdef = jax.tree_util.tree_flatten(grads)
                out, fr = [], []
                for i, (g, plan) in enumerate(zip(flat, plans)):
                    if plan is None:
                        out.append(g)
                        continue
                    mask = _leaf_mask(key, i, 0, plan.n_rot, rate)
                    signs = coding.rademacher_nd(
                        jax.random.fold_in(key, 2 * i), plan)
                    tiles = coding.encode_nd(g, signs, plan)
                    est = coding.decode_nd(
                        tiles * mask[None, :, None].astype(tiles.dtype),
                        mask.astype(jnp.float32), signs, plan,
                        total_peers=1)
                    out.append(est.astype(g.dtype))
                    fr.append(mask.mean())
                grads = jax.tree_util.tree_unflatten(tdef, out)
                frac = jnp.stack(fr).mean() if fr else jnp.float32(1.0)
            elif mode is CollectiveMode.LOSSY:
                grads, frac = _mask_grads_plain(grads, plans, key,
                                                drop_rate)
            else:
                frac = jnp.float32(1.0)

        new_params, new_opt, om = adamw.apply_updates(
            params, grads, state["opt"], opt_cfg)
        metrics = {"loss": loss, "nll": nll, "aux": aux,
                   "recv_frac": frac, **om}
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    donate_args = (0,) if donate else ()
    return jax.jit(train_step, donate_argnums=donate_args)


def init_state(key, cfg: ModelConfig):
    params = M.init_params(key, cfg)
    opt = adamw.init_opt_state(params)
    return {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}


def state_shardings(state, mesh):
    """NamedShardings for the full train state on ``mesh``."""
    ps = rules.param_shardings(state["params"], mesh)
    return {
        "params": ps,
        "opt": rules.opt_state_shardings(state["opt"], state["params"], mesh),
        "step": NamedSharding(mesh, P()),
    }
