"""Host training loop: data prefetch, Celeris timeout coupling,
checkpoint/restart, straggler mitigation.

Fault-tolerance story (designed for 1000+ nodes, exercised here at
container scale):

- **checkpoint/restart**: atomic sharded checkpoints every
  ``ckpt_every`` steps (async, overlapped with compute); on start the
  trainer resumes from LATEST automatically.  Checkpoints are
  mesh-agnostic, so a job can restart elastically on a different
  topology (``Trainer(..., mesh=new_mesh)``).
- **straggler mitigation** IS the paper's mechanism: each step's
  collective is bounded by the timeout controller; the realized
  received fraction feeds back into the controller (EWMA + cluster
  median), and late data is simply dropped and recovered by the
  Hadamard pipeline.  A ``straggler_model`` maps the current timeout to
  a drop probability via the transport latency distribution.
- **data restart safety**: batches are pure functions of (seed, step,
  shard) — no data-iterator state to lose.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as shd
from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import ModelConfig
from repro.core import timeout as timeout_mod
from repro.data import pipeline as data_pipe
from repro.models import model as M
from repro.optim import adamw
from repro.train import train_step as ts
from repro.train import sharding_rules as rules


@dataclasses.dataclass
class StragglerModel:
    """Maps the controller's current timeout to a per-step drop rate.

    The per-chunk latency is modeled lognormal(mu, sigma) (matching the
    transport simulator's contention tails); drop = P(latency > T).
    """
    median_latency: float = 1.0       # in units of clean step time
    sigma: float = 0.6
    burst_prob: float = 0.08          # step hit by a burst
    burst_scale: float = 3.0

    def drop_rate(self, timeout: float, rng: np.random.Generator) -> float:
        med = self.median_latency
        if rng.random() < self.burst_prob:
            med *= self.burst_scale
        # P(lognormal(ln med, sigma) > timeout)
        z = (np.log(max(timeout, 1e-9)) - np.log(med)) / self.sigma
        from math import erf
        p_late = 0.5 * (1 - erf(z / np.sqrt(2)))
        return float(np.clip(p_late, 0.0, 0.5))


class Trainer:
    def __init__(self, cfg: ModelConfig, *,
                 data_cfg: data_pipe.DataConfig,
                 opt_cfg: Optional[adamw.OptConfig] = None,
                 celeris: Optional[ts.CelerisConfig] = None,
                 mesh=None,
                 ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 50,
                 seed: int = 0,
                 straggler: Optional[StragglerModel] = None):
        self.cfg = cfg
        self.opt_cfg = opt_cfg or adamw.OptConfig()
        self.celeris = celeris or ts.CelerisConfig()
        self.mesh = mesh
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.source = data_pipe.make_source(data_cfg)
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.PRNGKey(seed)
        self.straggler = straggler or StragglerModel()
        self.controller = timeout_mod.TimeoutController(
            timeout_mod.TimeoutConfig(init_timeout=2.0, min_timeout=0.5,
                                      max_timeout=8.0))
        if mesh is not None:
            shd.set_global_mesh(mesh)
        self.step_fn = ts.make_train_step(cfg, mesh, self.opt_cfg,
                                          self.celeris)
        self.state = ts.init_state(jax.random.fold_in(self.key, 0), cfg)
        self.start_step = 0
        self._pending_ckpt = None
        if ckpt_dir is not None and ckpt.latest_step(ckpt_dir) is not None:
            self.restore()

    # ------------------------------------------------------------------
    def restore(self):
        shardings = None
        if self.mesh is not None:
            shardings = ts.state_shardings(self.state, self.mesh)
        self.state, step, extra = ckpt.restore(
            self.ckpt_dir, self.state, shardings=shardings)
        self.start_step = int(step)
        if "timeout" in (extra or {}):
            self.controller.adopt(extra["timeout"])

    def _put_batch(self, step: int) -> Dict[str, Any]:
        if self.mesh is None:
            return {k: jnp.asarray(v)
                    for k, v in self.source.global_batch(step).items()}
        dp = shd.dp_axes(self.mesh)
        n_shards = 1
        for a in dp:
            n_shards *= self.mesh.shape[a]
        host = self.source.global_batch(step, n_shards)
        specs = rules.batch_specs(self.mesh, host)
        return {k: jax.device_put(
                    v, jax.sharding.NamedSharding(self.mesh, specs[k]))
                for k, v in host.items()}

    # ------------------------------------------------------------------
    def run(self, n_steps: int,
            on_metrics: Optional[Callable[[int, Dict], None]] = None,
            simulate_fault_at: Optional[int] = None) -> Dict[str, list]:
        """Train ``n_steps`` (from the resumed position).

        ``simulate_fault_at``: raise after that step to exercise
        checkpoint/restart in tests.
        """
        history: Dict[str, list] = {"loss": [], "nll": [], "recv_frac": [],
                                    "drop_rate": [], "timeout": []}
        for step in range(self.start_step, self.start_step + n_steps):
            batch = self._put_batch(step)
            if self.celeris.collective_mode().lossy or self.celeris.lossy_moe:
                # scalar for the flat modes; a (2,) [intra, cross] axis
                # vector when a HierStragglerModel drives hierarchical
                # mode (the step consumes whichever shape it was traced
                # with)
                drop = self.straggler.drop_rate(self.controller.timeout,
                                                self.rng)
            else:
                drop = 0.0
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(
                self.state, batch, jax.random.fold_in(self.key, step),
                jnp.asarray(drop, dtype=jnp.float32))
            metrics = {k: float(v) for k, v in metrics.items()}
            wall = time.perf_counter() - t0

            # --- Celeris software stack: bounded-window adaptation.
            # duration is the emulated step latency: stragglers that got
            # dropped no longer extend it (min with the timeout).
            emu = min(self.straggler.median_latency
                      * (1 + self.rng.lognormal(0, 0.2)),
                      self.controller.timeout)
            local = self.controller.update(emu, metrics["recv_frac"])
            # cluster coordination (median of emulated node estimates)
            agreed = timeout_mod.coordinate(
                [local * (1 + self.rng.normal(0, 0.01)) for _ in range(8)])
            self.controller.adopt(agreed)

            history["loss"].append(metrics["loss"])
            history["nll"].append(metrics["nll"])
            history["recv_frac"].append(metrics["recv_frac"])
            history["drop_rate"].append(drop)
            history["timeout"].append(self.controller.timeout)
            if on_metrics:
                on_metrics(step, {**metrics, "wall_s": wall,
                                  "drop_rate": drop})

            if self.ckpt_dir and (step + 1) % self.ckpt_every == 0:
                if self._pending_ckpt is not None:
                    self._pending_ckpt.result()
                self._pending_ckpt = ckpt.save_async(
                    self.ckpt_dir, step + 1, self.state,
                    extra={"timeout": self.controller.timeout,
                           "arch": self.cfg.name})

            if simulate_fault_at is not None and step == simulate_fault_at:
                if self._pending_ckpt is not None:
                    self._pending_ckpt.result()
                raise RuntimeError(f"simulated node failure at step {step}")

        if self._pending_ckpt is not None:
            self._pending_ckpt.result()
        self.start_step += n_steps
        return history
