"""PartitionSpec rules (GSPMD) for params, optimizer state, and batches.

TP policy (megatron-style over the ``model`` axis):
- attention/MLP in-projections: column-parallel  P(..., None, "model")
- out-projections: row-parallel                  P(..., "model", None)
- embedding: vocab-sharded; unembed column-parallel
- MoE experts: expert-sharded (EP) via moe.param_specs
- norms / small diagonals: replicated

ZeRO-1: optimizer-state leaves additionally shard their largest
dp-divisible dimension over ``data`` (master/mu/nu are fp32 — the
dominant memory term at 12 bytes/param).

Stacked layer groups (leading n_groups dim from the scan) get a None
prepended automatically: rules match on the *path string*.
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as shd

# (path regex, spec builder given leaf ndim) — first match wins.
# Specs are written for the UNSTACKED leaf; leading extra dims -> None.
_RULES = [
    # embedding
    (r"embed.*table", lambda nd: ("model", None)),
    (r"embed.*unembed", lambda nd: (None, "model")),
    (r"frontend_proj", lambda nd: (None, "model")),
    # attention
    (r"\['attn'\].*w[qkv]", lambda nd: (None, "model")),
    (r"\['attn'\].*wo", lambda nd: ("model", None)),
    (r"\['xattn'\].*w[qkv]", lambda nd: (None, "model")),
    (r"\['xattn'\].*wo", lambda nd: ("model", None)),
    (r"\['attn'\].*b[qkv]", lambda nd: ("model",)),
    (r"\['xattn'\].*b[qkv]", lambda nd: ("model",)),
    # dense mlp
    (r"\['mlp'\].*w[ig]", lambda nd: (None, "model")),
    (r"\['mlp'\].*wo", lambda nd: ("model", None)),
    # moe (expert-sharded; shared experts like dense mlp)
    (r"\['moe'\].*shared.*w[ig]", lambda nd: (None, "model")),
    (r"\['moe'\].*shared.*wo", lambda nd: ("model", None)),
    (r"\['moe'\].*router", lambda nd: (None, None)),
    (r"\['moe'\].*w[igo]", lambda nd: ("model", None, None)),
    # rglru
    (r"\['rglru'\].*w_in", lambda nd: (None, "model")),
    (r"\['rglru'\].*w_gate", lambda nd: (None, "model")),
    (r"\['rglru'\].*w_out", lambda nd: ("model", None)),
    (r"\['rglru'\].*conv_[wb]", lambda nd: (None, "model")[-nd:]),
    (r"\['rglru'\].*(gate_._[wb]|lam)", lambda nd: ("model",)),
    # xlstm
    (r"\['mlstm'\].*w_(up|gate)", lambda nd: (None, "model")),
    (r"\['mlstm'\].*w[qkv]", lambda nd: (None, "model")),
    (r"\['mlstm'\].*w_down", lambda nd: ("model", None)),
    (r"\['slstm'\].*w_x", lambda nd: (None, "model")),
    (r"\['slstm'\].*w_up[12]", lambda nd: (None, "model")),
    (r"\['slstm'\].*w_down", lambda nd: ("model", None)),
]


def _spec_for(path: str, shape, mesh) -> P:
    ndim = len(shape)
    for pat, builder in _RULES:
        if re.search(pat, path):
            spec = list(builder(ndim))
            # stacked group leading dims
            while len(spec) < ndim:
                spec.insert(0, None)
            spec = spec[:ndim]
            # divisibility guard: replicate dims the axis doesn't divide
            # (e.g. seamless vocab 256206 % 16 != 0) or that the mesh
            # doesn't carry at all (TP-less hierarchical test meshes)
            out = []
            for s, n in zip(spec, shape):
                if s is not None and (s not in mesh.shape
                                      or n % mesh.shape[s] != 0):
                    s = None
                out.append(s)
            return P(*out)
    return P()   # replicated (norms, biases, scalars)


def param_specs(params: Any, mesh) -> Any:
    """Pytree of PartitionSpec matching ``params``."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    specs = []
    for path, leaf in flat:
        specs.append(_spec_for(jax.tree_util.keystr(path), leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params: Any, mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh),
                        is_leaf=lambda x: isinstance(x, P))


def zero1_specs(params: Any, mesh) -> Any:
    """Optimizer-state specs: param spec + 'data' on the largest free,
    divisible dim (ZeRO-1)."""
    pspecs = param_specs(params, mesh)
    dp = [a for a in ("data",) if a in mesh.shape]
    dp_size = mesh.shape.get("data", 1)

    def add_data(leaf, spec):
        if not dp or leaf.ndim == 0:
            return spec
        parts = list(spec) + [None] * (leaf.ndim - len(spec))
        # pick largest dim that is unsharded and divisible by dp
        best, best_dim = -1, -1
        for i, (s, n) in enumerate(zip(parts, leaf.shape)):
            if s is None and n % dp_size == 0 and n > best:
                best, best_dim = n, i
        if best_dim >= 0:
            parts[best_dim] = "data"
        return P(*parts)

    flat_p = jax.tree_util.tree_leaves(params)
    flat_s = jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: isinstance(x, P))
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(
        treedef, [add_data(l, s) for l, s in zip(flat_p, flat_s)])


def opt_state_shardings(opt_state: Any, params: Any, mesh) -> Any:
    z = zero1_specs(params, mesh)
    ns = lambda s: NamedSharding(mesh, s)
    return {
        "master": jax.tree.map(ns, z, is_leaf=lambda x: isinstance(x, P)),
        "mu": jax.tree.map(ns, z, is_leaf=lambda x: isinstance(x, P)),
        "nu": jax.tree.map(ns, z, is_leaf=lambda x: isinstance(x, P)),
        "count": NamedSharding(mesh, P()),
    }


def batch_specs(mesh, batch: Any) -> Any:
    dp = shd.dp_axes(mesh)
    return jax.tree.map(lambda a: P(dp, *([None] * (a.ndim - 1))), batch)


def cache_specs(mesh, caches: Any) -> Any:
    """KV caches / recurrent state: batch over dp.

    Cache leaves are stacked (n_groups, B, ...) or plain (B, ...); the
    (S_cache,) pos arrays are replicated.  We shard the batch dim, which
    is dim 0 for tail caches and dim 1 for stacked group caches — picked
    by matching known layouts.
    """
    dp = shd.dp_axes(mesh)

    def spec(leaf):
        if leaf.ndim <= 1:
            return P()
        # stacked group caches: (n_groups, B, ...); tail: (B, ...)
        return P(None, dp, *([None] * (leaf.ndim - 2)))

    # group caches get (n_groups,) leading; tail caches don't.  We mark
    # by path: ['groups'] vs ['tail'].
    flat = jax.tree_util.tree_flatten_with_path(caches)[0]
    treedef = jax.tree_util.tree_structure(caches)
    specs = []
    for path, leaf in flat:
        ps = jax.tree_util.keystr(path)
        if leaf.ndim <= 1:
            specs.append(P())
        elif "'groups'" in ps:
            if leaf.ndim == 2:   # (n_groups, S_cache) pos arrays
                specs.append(P())
            else:
                specs.append(P(None, dp, *([None] * (leaf.ndim - 2))))
        else:
            specs.append(P(dp, *([None] * (leaf.ndim - 1))))
    return jax.tree_util.tree_unflatten(treedef, specs)
