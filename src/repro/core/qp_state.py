"""Per-QP NIC context accounting (paper Table I).

Each RDMA NIC design keeps a per-queue-pair (QP) context in on-chip SRAM.
The byte layouts below are field-level inventories that reproduce the
paper's per-QP totals exactly:

    RoCE  407 B   (go-back-N, PFC, WQE cache)
    IRN   596 B   (selective repeat + SACK bitmaps in NIC)
    SRNIC 242 B   (retransmission/reordering offloaded to host SW)
    Celeris 52 B  (best-effort: 20 B base + 32 B DCQCN)

Note: the paper's evaluation text says the Coyote SRNIC port used 210 B;
Table I lists 242 B for the design itself.  We model the design (242 B)
and expose the Coyote port variant as ``SRNIC_COYOTE_BYTES``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

SRNIC_COYOTE_BYTES = 210


@dataclasses.dataclass(frozen=True)
class Field:
    name: str
    bytes: int
    category: str  # "addressing" | "reliability" | "ordering" | "cc" | "wqe"


def _f(name: str, nbytes: int, cat: str) -> Field:
    return Field(name, nbytes, cat)


# ----------------------------------------------------------------------
# Shared building blocks
# ----------------------------------------------------------------------

_BASE_ADDRESSING: List[Field] = [
    _f("qpn", 3, "addressing"),
    _f("dest_qpn", 3, "addressing"),
    _f("dest_ip", 4, "addressing"),
    _f("remote_base_va", 8, "addressing"),
    _f("rkey", 4, "addressing"),
    _f("pd_handle", 3, "addressing"),
    _f("mtu_log2_state_flags", 1, "addressing"),
]  # = 26 B

_DCQCN: List[Field] = [
    _f("rate_current", 4, "cc"),
    _f("rate_target", 4, "cc"),
    _f("alpha", 4, "cc"),
    _f("byte_counter", 4, "cc"),
    _f("timer_rate_increase", 4, "cc"),
    _f("timer_alpha_update", 4, "cc"),
    _f("bc_stage_count", 4, "cc"),
    _f("t_stage_count", 4, "cc"),
]  # = 32 B


def _sum(fields: List[Field]) -> int:
    return sum(f.bytes for f in fields)


# ----------------------------------------------------------------------
# Per-design layouts
# ----------------------------------------------------------------------

def celeris_context() -> List[Field]:
    """20 B push-engine context + 32 B DCQCN = 52 B.

    No PSNs, no retry counters, no timers, no windows: the NIC only needs
    to know where to push.  Packets self-describe placement via a logical
    offset carried in the header.
    """
    base = [
        _f("qpn", 3, "addressing"),
        _f("dest_qpn", 3, "addressing"),
        _f("dest_ip", 4, "addressing"),
        _f("remote_base_va", 8, "addressing"),
        _f("rkey_compressed", 1, "addressing"),
        _f("state_flags", 1, "addressing"),
    ]
    assert _sum(base) == 20, _sum(base)
    return base + _DCQCN


def roce_context() -> List[Field]:
    """RoCE RC: go-back-N reliability, strict ordering, WQE cache. 407 B."""
    fields = list(_BASE_ADDRESSING) + list(_DCQCN) + [
        # reliability: go-back-N
        _f("sq_psn", 3, "reliability"),
        _f("rq_epsn", 3, "reliability"),
        _f("msn", 3, "reliability"),
        _f("last_acked_psn", 3, "reliability"),
        _f("retry_counter", 1, "reliability"),
        _f("rnr_retry_counter", 1, "reliability"),
        _f("retransmit_timer", 4, "reliability"),
        _f("rnr_timer", 2, "reliability"),
        _f("ack_timeout_cfg", 1, "reliability"),
        _f("outstanding_req_window", 16, "reliability"),
        # ordering
        _f("irrq_slots", 32, "ordering"),          # inbound RDMA read/atomic queue
        _f("orrq_slots", 48, "ordering"),          # outbound read request queue
        _f("reorder_head_tail", 8, "ordering"),
        # WQE cache + doorbells
        _f("sq_wqe_cache", 128, "wqe"),
        _f("rq_wqe_cache", 64, "wqe"),
        _f("sq_pi_ci", 8, "wqe"),
        _f("rq_pi_ci", 8, "wqe"),
        _f("cq_state", 8, "wqe"),
        _f("dma_scratch", 8, "wqe"),
    ]
    assert _sum(fields) == 407, _sum(fields)
    return fields


def irn_context() -> List[Field]:
    """IRN: selective repeat with per-packet bitmap tracking in NIC. 596 B."""
    fields = list(_BASE_ADDRESSING) + list(_DCQCN) + [
        # BDP-bounded windows + selective repeat state
        _f("sq_psn", 3, "reliability"),
        _f("rq_epsn", 3, "reliability"),
        _f("msn", 3, "reliability"),
        _f("last_acked_psn", 3, "reliability"),
        _f("recovery_psn", 3, "reliability"),
        _f("rto_timer", 4, "reliability"),
        _f("rto_low_timer", 4, "reliability"),
        _f("retry_counter", 1, "reliability"),
        # bitmaps (BDP-cap of packets tracked per QP)
        _f("tx_bitmap", 96, "reliability"),
        _f("rx_bitmap", 96, "reliability"),
        _f("sack_blocks", 32, "reliability"),
        # ordering / reassembly tracking
        _f("ooo_tracking", 58, "ordering"),
        _f("irrq_slots", 64, "ordering"),
        _f("reorder_head_tail", 8, "ordering"),
        # WQE cache + doorbells
        _f("sq_wqe_cache", 128, "wqe"),
        _f("sq_pi_ci", 8, "wqe"),
        _f("rq_pi_ci", 8, "wqe"),
        _f("cq_state", 8, "wqe"),
        _f("dma_scratch", 8, "wqe"),
    ]
    assert _sum(fields) == 596, _sum(fields)
    return fields


def srnic_context() -> List[Field]:
    """SRNIC: retransmission + reordering moved to host SW; no WQE cache.

    NIC keeps only what the fast path needs. 242 B.
    """
    fields = list(_BASE_ADDRESSING) + list(_DCQCN) + [
        _f("sq_psn", 3, "reliability"),
        _f("rq_epsn", 3, "reliability"),
        _f("msn", 3, "reliability"),
        _f("last_acked_psn", 3, "reliability"),
        _f("credit_state", 8, "reliability"),      # receiver-driven credits
        _f("slowpath_flag_epoch", 4, "reliability"),
        _f("ooo_metadata", 32, "ordering"),         # compact OOO summary for SW
        _f("sq_pi_ci", 8, "wqe"),
        _f("rq_pi_ci", 8, "wqe"),
        _f("cq_state", 8, "wqe"),
        _f("event_queue_state", 8, "wqe"),
        _f("doorbell_coalescing", 96, "wqe"),       # per-QP doorbell/batch state
    ]
    assert _sum(fields) == 242, _sum(fields)
    return fields


DESIGNS: Dict[str, List[Field]] = {
    "roce": roce_context(),
    "irn": irn_context(),
    "srnic": srnic_context(),
    "celeris": celeris_context(),
}

# Paper Table I published values (for validation).
PAPER_QP_BYTES: Dict[str, int] = {"roce": 407, "irn": 596, "srnic": 242, "celeris": 52}
PAPER_QP_SCALABILITY: Dict[str, int] = {
    "roce": 10_000, "irn": 8_000, "srnic": 20_000, "celeris": 80_000,
}


def qp_bytes(design: str) -> int:
    return _sum(DESIGNS[design])


def qp_capacity(design: str, sram_bytes: int = 4_160_000) -> int:
    """QPs supported by an SRAM budget (default ≈ Celeris@80K QPs)."""
    return sram_bytes // qp_bytes(design)


def category_breakdown(design: str) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for f in DESIGNS[design]:
        out[f.category] = out.get(f.category, 0) + f.bytes
    return out


def reliability_state_bytes(design: str) -> int:
    b = category_breakdown(design)
    return b.get("reliability", 0) + b.get("ordering", 0)


def table1() -> List[Tuple[str, int, int, int]]:
    """(design, per-QP bytes, reliability+ordering bytes, QPs @ budget)."""
    return [
        (d, qp_bytes(d), reliability_state_bytes(d), qp_capacity(d))
        for d in ("roce", "irn", "srnic", "celeris")
    ]
