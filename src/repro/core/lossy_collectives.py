"""Lossy (best-effort) collectives — Celeris semantics on a TPU mesh.

TPU ICI is lossless, so Celeris's "packets that miss the bounded window
are discarded" is emulated at *wire-chunk granularity inside the
collective*: every participant samples a per-(peer, wire-row) arrival
mask from the step's drop probability (itself derived from the timeout
controller + transport latency model) and contributes only the rows that
"arrived".  Receivers finalize with what they have — exactly the
receiver-side semantics of the paper's §III-B — and recover through the
Hadamard/XOR coding layer (:mod:`repro.core.coding`).

Everything here is shard_map-compatible and lowers to plain
``psum`` / ``all_gather`` / ``all_to_all`` HLOs plus elementwise masking,
so the dry-run (16x16 and 2x16x16 meshes) sees ordinary TPU collectives.

Provided:
- :func:`lossy_psum` / :func:`lossy_pmean` — gradient AllReduce (DP).
- :func:`lossy_all_gather` — TP gather with optional XOR parity repair.
- :func:`lossy_all_to_all` — MoE dispatch; dropped blocks surface as an
  arrival mask so the router can take the shared-expert fallback path.
- exact twins (``exact_*``) with identical signatures for A/B runs.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.core import coding

AxisNames = str | Sequence[str]


def _axis_size(axis_name: AxisNames) -> int:
    return shd.axis_size(axis_name)


def _peer_key(key: jax.Array, axis_name: AxisNames) -> jax.Array:
    """Fold the device's coordinate along ``axis_name`` into the key so
    each peer samples an independent arrival mask (same key across the
    rest of the mesh)."""
    if isinstance(axis_name, str):
        return jax.random.fold_in(key, jax.lax.axis_index(axis_name))
    k = key
    for a in axis_name:
        k = jax.random.fold_in(k, jax.lax.axis_index(a))
    return k


def arrival_mask(key: jax.Array, n_rows: int, drop_rate: jax.Array) -> jax.Array:
    """Bernoulli(1 - drop_rate) per wire row: True = arrived in window."""
    return jax.random.uniform(key, (n_rows,)) >= drop_rate


# ----------------------------------------------------------------------
# AllReduce (data-parallel gradient sync)
# ----------------------------------------------------------------------

def lossy_psum(x: jax.Array, axis_name: AxisNames, *, key: jax.Array,
               drop_rate: jax.Array, signs: jax.Array,
               code: coding.HadamardCode,
               use_pallas: bool = True,
               quantize_wire: bool = False,
               constrain=None, out_blocks: bool = False
               ) -> tuple[jax.Array, jax.Array]:
    """Best-effort AllReduce of a flat f32 payload.

    Returns (unbiased sum estimate, realized received fraction).
    ``signs``/``code`` must be identical on every participant.

    ``quantize_wire=True`` additionally quantizes each peer's wire
    contribution to absmax int8 per rotation block before the reduce
    (``coding.encode_quantized`` — rotate and quantize fused in one
    Pallas kernel), modeling a 4x-smaller collective payload; the
    stochastic-rounding noise key is derived from ``key`` per peer, so
    the ``False`` path's draws are untouched.
    """
    peers = _axis_size(axis_name)
    if quantize_wire:
        nk = jax.random.fold_in(_peer_key(key, axis_name), 1)
        q_wire, scales = coding.encode_quantized(
            x, signs, code, nk, use_pallas=use_pallas, constrain=constrain)
        wire = coding.dequantize_wire(q_wire, scales)
        if constrain is not None:
            wire = constrain(wire, "wire")
    else:
        wire = coding.encode(x, signs, code, use_pallas=use_pallas,
                             constrain=constrain)
    mask = arrival_mask(_peer_key(key, axis_name), code.n_rot, drop_rate)
    contrib = wire * mask[:, None].astype(wire.dtype)
    counts = mask.astype(jnp.float32)
    wire_sum = jax.lax.psum(contrib, axis_name)
    count_sum = jax.lax.psum(counts, axis_name)
    est = coding.decode(wire_sum, count_sum, signs, code,
                        total_peers=peers, use_pallas=use_pallas,
                        constrain=constrain, out_blocks=out_blocks)
    frac = jnp.sum(count_sum) / (peers * code.n_rot)
    return est, frac


def lossy_pmean(x: jax.Array, axis_name: AxisNames, **kw):
    peers = _axis_size(axis_name)
    s, frac = lossy_psum(x, axis_name, **kw)
    return s / peers, frac


def exact_psum(x: jax.Array, axis_name: AxisNames) -> jax.Array:
    return jax.lax.psum(x, axis_name)


def exact_pmean(x: jax.Array, axis_name: AxisNames) -> jax.Array:
    return jax.lax.pmean(x, axis_name)


# ----------------------------------------------------------------------
# AllGather (tensor-parallel activations) with XOR parity repair
# ----------------------------------------------------------------------

def lossy_all_gather(x: jax.Array, axis_name: str, *, key: jax.Array,
                     drop_rate: jax.Array, parity: bool = True,
                     tiled: bool = False) -> tuple[jax.Array, jax.Array]:
    """Best-effort AllGather of this shard.

    Each peer's shard is one "chunk".  A dropped chunk is zero-filled;
    when ``parity`` is on, an XOR parity chunk rides along (1/P bandwidth
    overhead) and repairs any *single* lost shard exactly — the paper's
    prioritized-data path for activations, where statistical tolerance
    alone is weaker than for gradients.

    Returns (gathered (P, ...) or tiled, arrived mask (P,)).
    """
    p = shd.axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    mask = arrival_mask(_peer_key(key, axis_name), p, drop_rate)
    arrived_here = mask[me]
    contrib = jnp.where(arrived_here, x, jnp.zeros_like(x))
    gathered = jax.lax.all_gather(contrib, axis_name)          # (P, ...)
    arrived = jax.lax.all_gather(arrived_here, axis_name)      # (P,)
    if parity:
        flat = gathered.reshape(p, -1)
        # parity of *all* shards is an XOR all-reduce of bit patterns;
        # it rides along the same step (counts as collective bytes).
        pbits = jax.lax.bitcast_convert_type(x.reshape(-1), jnp.int32)
        parity_bits = _xor_allreduce(pbits, axis_name)
        parity_chunk = jax.lax.bitcast_convert_type(parity_bits, jnp.float32)
        flat = coding.xor_parity_decode(flat, parity_chunk, arrived)
        gathered = flat.reshape(gathered.shape)
    if tiled:
        gathered = gathered.reshape((p * x.shape[0],) + x.shape[1:])
    return gathered, arrived


def _xor_allreduce(bits: jax.Array, axis_name: str) -> jax.Array:
    """XOR all-reduce via gather+fold (XLA has no XOR all-reduce op)."""
    g = jax.lax.all_gather(bits, axis_name)                    # (P, n)
    return jax.lax.reduce(g, jnp.int32(0), jax.lax.bitwise_xor, (0,))


def exact_all_gather(x: jax.Array, axis_name: str, *, tiled: bool = False):
    return jax.lax.all_gather(x, axis_name, tiled=tiled)


# ----------------------------------------------------------------------
# All-to-All (expert-parallel dispatch)
# ----------------------------------------------------------------------

def lossy_all_to_all(x: jax.Array, axis_name: str, *, key: jax.Array,
                     drop_rate: jax.Array,
                     split_axis: int = 0, concat_axis: int = 0
                     ) -> tuple[jax.Array, jax.Array]:
    """Best-effort All-to-All.

    ``x`` is split into P blocks along ``split_axis``; block j travels to
    peer j.  Each (src, dst) block is dropped i.i.d. with ``drop_rate``.
    Returns (received tensor with dropped blocks zeroed, arrival mask of
    shape (P,) — True where the block from peer j arrived here).  The
    MoE layer routes un-arrived tokens to the shared-expert fallback
    (paper §II-B "expert fallback paths").
    """
    p = shd.axis_size(axis_name)
    assert x.shape[split_axis] == p, (x.shape, split_axis, p)
    # (src=me, dst=j) arrival coin for every destination block
    mask_out = arrival_mask(_peer_key(key, axis_name), p, drop_rate)  # (P,)
    shape = [1] * x.ndim
    shape[split_axis] = p
    masked = x * mask_out.reshape(shape).astype(x.dtype)
    recv = jax.lax.all_to_all(masked, axis_name, split_axis=split_axis,
                              concat_axis=concat_axis)
    arrived = jax.lax.all_to_all(mask_out[:, None], axis_name,
                                 split_axis=0, concat_axis=0)[:, 0]
    return recv, arrived


def exact_all_to_all(x: jax.Array, axis_name: str, *, split_axis: int = 0,
                     concat_axis: int = 0) -> jax.Array:
    return jax.lax.all_to_all(x, axis_name, split_axis=split_axis,
                              concat_axis=concat_axis)
