"""ML-pipeline loss recovery (paper §III-B, last paragraph).

Celeris ships no transport-layer recovery; instead the framework encodes
collective payloads so that *bounded, partial* loss is absorbed:

**Randomized Hadamard rotation** (a la OptiReduce / Fig. 1):
    encode:  y = (1/sqrt(n)) H D x     per rotation block of width n
    decode:  x_hat = (n/k) (1/sqrt(n)) D H S y   (S = arrival mask, k = |S|)
  which is exactly unbiased (E[x_hat] = x) and lossless when k = n.

**Wire interleaving** — rotation must span *more* than the loss
granularity or a dropped chunk would take a whole rotation block with
it.  After rotating each (B, n) block-row we transpose to (n, B) "wire
layout": network chunk j carries coordinate j of *every* rotation block,
so any lost chunk removes a 1/n coordinate slice from each block and the
unbiased rescale recovers the rest.  This implements the paper's
"critical information ... split across packets for partial recovery".

**XOR parity** — exact recovery of any single lost chunk per parity
group (the paper's lightweight coding alternative for prioritized data,
e.g. activation shards under lossy TP).

All transforms run through the Pallas FWHT kernel (MXU path on TPU);
``use_pallas=False`` routes to the jnp oracle for dry-run lowering.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class HadamardCode:
    """Static coding geometry for one flat payload."""
    n_rot: int          # rotation block width (power of two)
    n_blocks: int       # number of rotation blocks  (padded_len = n_rot*n_blocks)
    orig_len: int       # unpadded payload length

    @property
    def padded_len(self) -> int:
        return self.n_rot * self.n_blocks

    @property
    def wire_shape(self) -> tuple[int, int]:
        """(n_rot, n_blocks): wire row j = coordinate j of every block."""
        return (self.n_rot, self.n_blocks)


def plan(orig_len: int, n_rot: int = 4096, block_multiple: int = 1
         ) -> HadamardCode:
    """``block_multiple``: round n_blocks up so the block dim shards
    cleanly over the model axis (keeps the FWHT collective-free)."""
    while n_rot > 1 and n_rot > orig_len:
        n_rot //= 2
    n_rot = max(n_rot, 2)
    n_blocks = -(-orig_len // n_rot)
    n_blocks = -(-n_blocks // block_multiple) * block_multiple
    return HadamardCode(n_rot=n_rot, n_blocks=n_blocks, orig_len=orig_len)


def rademacher(key: jax.Array, code: HadamardCode) -> jax.Array:
    """Random sign diagonal D, shared by every participant (same key).

    One (n_rot,) vector shared across rotation blocks — per-block signs
    would double parameter-scale memory at 15B-model size, and per-block
    unbiasedness holds either way (OptiReduce likewise reuses one
    rotation per chunk).
    """
    return jax.random.rademacher(key, (code.n_rot,), dtype=jnp.float32)


def encode(x: jax.Array, signs: jax.Array, code: HadamardCode, *,
           use_pallas: bool = True, constrain=None) -> jax.Array:
    """flat (orig_len,) -> wire layout (n_rot, n_blocks).

    ``constrain(a, kind)`` (kind in {"blocks","wire"}): optional sharding
    hint applied inside — used by the trainer to keep the block dim on
    the model axis so the FWHT stays collective-free under GSPMD.
    """
    if x.ndim == 2 and x.shape == (code.n_blocks, code.n_rot):
        blocks = x          # pre-blocked (keeps big leaves sharded)
    else:
        x = x.reshape(-1)
        x = jnp.pad(x, (0, code.padded_len - code.orig_len))
        blocks = x.reshape(code.n_blocks, code.n_rot)
    if constrain is not None:
        blocks = constrain(blocks, "blocks")
    # sign-multiply + 1/sqrt(n) normalization fused into the kernel
    # (saves two full HBM round-trips per encode on the Pallas path)
    rot = ops.fwht(blocks, signs=signs, scale=code.n_rot ** -0.5,
                   use_pallas=use_pallas)
    wire = rot.T
    if constrain is not None:
        wire = constrain(wire, "wire")
    return wire


def encode_quantized(x: jax.Array, signs: jax.Array, code: HadamardCode,
                     noise_key: jax.Array, *, use_pallas: bool = True,
                     constrain=None) -> tuple[jax.Array, jax.Array]:
    """:func:`encode` with the wire payload quantized to int8.

    Per rotation block the rotated coordinates are stochastically
    rounded to absmax-scaled int8 (QSGD-style; the rotation's variance
    flattening is exactly what makes a shared per-block scale cheap) —
    a 4x cut in collective wire bytes.  The rotate and quantize stages
    run as ONE fused Pallas kernel (``ops.fwht_quantize``): the rotated
    tile never round-trips through HBM between them.

    Returns ``(q_wire (n_rot, n_blocks) int8, scales (n_blocks,))``;
    :func:`dequantize_wire` restores the f32 wire layout that
    :func:`decode` consumes.
    """
    if x.ndim == 2 and x.shape == (code.n_blocks, code.n_rot):
        blocks = x
    else:
        x = x.reshape(-1)
        x = jnp.pad(x, (0, code.padded_len - code.orig_len))
        blocks = x.reshape(code.n_blocks, code.n_rot)
    if constrain is not None:
        blocks = constrain(blocks, "blocks")
    noise = jax.random.uniform(noise_key, blocks.shape)
    q, scales = ops.fwht_quantize(blocks, noise, signs=signs,
                                  scale=code.n_rot ** -0.5,
                                  use_pallas=use_pallas)
    return q.T, scales


def dequantize_wire(q_wire: jax.Array, scales: jax.Array) -> jax.Array:
    """int8 wire layout (n_rot, n_blocks) -> f32 wire layout."""
    return q_wire.astype(jnp.float32) * scales[None, :]


def decode(wire_sum: jax.Array, counts: jax.Array, signs: jax.Array,
           code: HadamardCode, *, total_peers: int = 1,
           use_pallas: bool = True, constrain=None,
           out_blocks: bool = False) -> jax.Array:
    """Inverse of :func:`encode` over *summed received* wire data.

    ``wire_sum`` (n_rot, n_blocks): per-wire-row sums of the
    contributions that arrived inside the window.  ``counts`` (n_rot,):
    how many of the ``total_peers`` expected contributions arrived per
    row (rows with 0 arrivals hold zeros).

    Two unbiasing stages (both exact in expectation, both no-ops when
    nothing was lost):
      1. peer unbias — scale row r by total_peers/counts[r] so each
         present row estimates the *full-peer* sum of that coordinate;
      2. sampling unbias — scale every present row by n_rot/k
         (k = rows with any arrival) so the inverse rotation of the
         zero-filled coordinate vector is unbiased.
    """
    row_est = ops.masked_unbias(wire_sum, counts, total_peers,
                                use_pallas=use_pallas)       # stage 1
    k = jnp.sum(counts > 0)
    scale = jnp.where(k > 0, code.n_rot / jnp.maximum(k, 1), 0.0)
    rot = row_est.T * scale                                  # stage 2
    if constrain is not None:
        rot = constrain(rot, "blocks")
    blocks = (ops.fwht(rot, scale=code.n_rot ** -0.5, use_pallas=use_pallas)
              * signs[None, :])
    if constrain is not None:
        blocks = constrain(blocks, "blocks")
    if out_blocks:
        return blocks       # (n_blocks, n_rot), caller reshapes in place
    return blocks.reshape(-1)[: code.orig_len]


# ----------------------------------------------------------------------
# XOR parity (exact single-loss recovery per group)
# ----------------------------------------------------------------------

def xor_parity_encode(chunks: jax.Array) -> jax.Array:
    """chunks (g, m) float32 -> parity chunk (m,) via bitwise XOR."""
    bits = jax.lax.bitcast_convert_type(chunks, jnp.int32)
    parity = jax.lax.reduce(bits, jnp.int32(0), jax.lax.bitwise_xor, (0,))
    return jax.lax.bitcast_convert_type(parity, jnp.float32)


def xor_parity_decode(chunks: jax.Array, parity: jax.Array,
                      arrived: jax.Array) -> jax.Array:
    """Recover at most one lost chunk in the group.

    ``chunks`` (g, m) with lost rows zeroed, ``arrived`` (g,) bool.
    If exactly one row is lost it is reconstructed exactly; with zero
    losses the input is returned unchanged; with >1 losses the lost rows
    stay zero (decoder falls back to statistical tolerance).
    """
    n_lost = jnp.sum(~arrived)
    bits = jax.lax.bitcast_convert_type(chunks, jnp.int32)
    # Zeroed-by-mask rows can carry -0.0 (sign bit set) — scrub them so
    # lost rows contribute true zero bits to the XOR.
    bits = jnp.where(arrived[:, None], bits, 0)
    pbits = jax.lax.bitcast_convert_type(parity, jnp.int32)
    xor_all = jax.lax.reduce(bits, jnp.int32(0), jax.lax.bitwise_xor, (0,))
    recovered = jax.lax.bitwise_xor(xor_all, pbits)          # = missing row
    rec_f = jax.lax.bitcast_convert_type(recovered, jnp.float32)
    fill = jnp.where((n_lost == 1) & ~arrived[:, None], rec_f[None, :], 0.0)
    return jnp.where(arrived[:, None], chunks, fill)


# ----------------------------------------------------------------------
# Convenience: pytree-level encode/decode used by the trainer
# ----------------------------------------------------------------------

def tree_ravel(tree) -> tuple[jax.Array, object]:
    flat, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [(l.shape, l.dtype) for l in flat]
    vec = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in flat])
    return vec, (treedef, shapes)


def tree_unravel(vec: jax.Array, spec) -> object:
    treedef, shapes = spec
    out, off = [], 0
    for shape, dtype in shapes:
        size = 1
        for s in shape:
            size *= s
        out.append(vec[off: off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


# ----------------------------------------------------------------------
# Sharding-aware ND coding (the form the trainer uses at scale)
# ----------------------------------------------------------------------
#
# Rotating a TP-sharded gradient leaf through the flat (n_blocks, n_rot)
# layout forces SPMD to reshard through a reshape — the old partitioner
# handles that by full rematerialization (GiB-scale replicated buffers
# at 15B params).  Instead we rotate along the *unsharded* axes only:
# the sharded dim is transposed to the end (transpose carries sharding;
# it is reshapes that break it), the remaining dims flatten into tiles
# of n_rot, and the FWHT runs along the middle axis.  Every reshape
# splits/merges only unsharded dims => no collective, no remat.

def _fwht_axis1(x: jax.Array) -> jax.Array:
    """Unnormalized FWHT along axis 1 of (A, n, Ns) via butterflies that
    never touch the other (possibly sharded) axes."""
    a_dim, n, ns = x.shape
    m = 1
    while m < n:
        x = x.reshape(a_dim, n // (2 * m), 2, m, ns)
        lo = x[:, :, 0]
        hi = x[:, :, 1]
        x = jnp.stack([lo + hi, lo - hi], axis=2).reshape(a_dim, n, ns)
        m *= 2
    return x


@dataclasses.dataclass(frozen=True)
class NdPlan:
    n_rot: int
    tiles: int          # flattened-unsharded length = tiles * n_rot (padded)
    sharded_dim: int | None
    shape: tuple        # original leaf shape
    m_orig: int         # unpadded flattened-unsharded length


def rademacher_nd(key: jax.Array, plan: "NdPlan") -> jax.Array:
    return jax.random.rademacher(key, (plan.n_rot,), dtype=jnp.float32)


def plan_nd(shape, sharded_dim, n_rot: int = 4096) -> NdPlan:
    m = 1
    for i, d in enumerate(shape):
        if i != sharded_dim:
            m *= d
    while n_rot > 1 and n_rot > m:
        n_rot //= 2
    n_rot = max(n_rot, 2)
    tiles = -(-m // n_rot)
    return NdPlan(n_rot=n_rot, tiles=tiles, sharded_dim=sharded_dim,
                  shape=tuple(shape), m_orig=m)


def _to_tiles(g: jax.Array, plan: NdPlan) -> jax.Array:
    """leaf -> (tiles, n_rot, Ns) with only unsharded dims reshaped."""
    sd = plan.sharded_dim
    if sd is not None:
        perm = [i for i in range(g.ndim) if i != sd] + [sd]
        g = g.transpose(perm)
        ns = g.shape[-1]
        g = g.reshape(-1, ns)
    else:
        g = g.reshape(-1, 1)
        ns = 1
    pad = plan.tiles * plan.n_rot - plan.m_orig
    if pad:
        g = jnp.pad(g, ((0, pad), (0, 0)))
    return g.reshape(plan.tiles, plan.n_rot, ns)


def _from_tiles(t: jax.Array, plan: NdPlan) -> jax.Array:
    sd = plan.sharded_dim
    ns = t.shape[-1]
    g = t.reshape(-1, ns)[: plan.m_orig]
    if sd is None:
        return g.reshape(plan.shape)
    rest = [d for i, d in enumerate(plan.shape) if i != sd]
    g = g.reshape(rest + [ns])
    inv = list(range(len(rest)))
    inv.insert(sd, len(rest))
    return g.transpose(inv)


# Public tile layout (no rotation): the plain-lossy ablation path drops
# wire rows straight out of this layout, so what Hadamard buys is exactly
# the delta between the two modes on identical tilings.
def to_tiles_nd(g: jax.Array, plan: NdPlan) -> jax.Array:
    return _to_tiles(g, plan)


def from_tiles_nd(t: jax.Array, plan: NdPlan) -> jax.Array:
    return _from_tiles(t, plan)


def fwht_nd(t: jax.Array, plan: NdPlan) -> jax.Array:
    """Normalized (self-inverse) FWHT along the rotation axis of a
    (tiles, n_rot, Ns) block: fwht_nd(fwht_nd(t)) == t."""
    return _fwht_axis1(t) * (plan.n_rot ** -0.5)


def encode_nd(g: jax.Array, signs: jax.Array, plan: NdPlan) -> jax.Array:
    """leaf -> rotated tiles (tiles, n_rot, Ns); signs: (n_rot,)."""
    t = _to_tiles(g.astype(jnp.float32), plan)
    t = t * signs[None, :, None]
    return _fwht_axis1(t) * (plan.n_rot ** -0.5)


def decode_nd(tiles_sum: jax.Array, counts: jax.Array, signs: jax.Array,
              plan: NdPlan, *, total_peers: int = 1) -> jax.Array:
    """Inverse of encode_nd over summed received tiles; counts (n_rot,)."""
    c = counts[None, :, None]
    safe = jnp.maximum(c, 1.0)
    est = jnp.where(c > 0, tiles_sum * (total_peers / safe), 0.0)
    k = jnp.sum(counts > 0)
    est = est * jnp.where(k > 0, plan.n_rot / jnp.maximum(k, 1), 0.0)
    est = _fwht_axis1(est) * (plan.n_rot ** -0.5) * signs[None, :, None]
    return _from_tiles(est, plan)
