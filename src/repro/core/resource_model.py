"""FPGA resource + MTBF analytic model (paper Table II).

This container cannot run Vivado synthesis, so Table II is reproduced
through a *structural* analytic model:

- **BRAM** is built bottom-up: a common NIC-shell component plus the
  per-QP context SRAM (from :mod:`repro.core.qp_state`, scaled by QP
  count) plus per-design reliability buffers (retransmission queues,
  reorder buffers, SACK engines).  At the paper's 10K-QP operating point
  the component sums equal Table II exactly; the model stays predictive
  at other QP counts.
- **LUT / LUTRAM / FF / Power** are the paper's published synthesis
  results, kept as calibrated per-design constants (base + reliability
  logic deltas).
- **MTBF** is *recomputed from first principles* with the Xilinx-SEU
  two-component model::

      upsets/hour/node = FIT_bit x (BRAM_bits + essential_ratio x CRAM_bits)
      MTBF_cluster     = 1 / (upsets/hour/node x n_nodes)

  with ``essential_ratio = 0.10`` (paper's 10% CRAM essential-bit ratio),
  ``CRAM_bits ~= 692 x LUTs`` (config + routing bits per LUT,
  UltraScale+-plausible), and the per-bit rate calibrated on the RoCE row
  only (2.07e-14 upsets/bit/hour at 100 degC ~= 20.7 FIT/Mbit — in the
  published UltraScale SEU range after temperature derating).  The other
  three designs' MTBFs are then *predictions* — they land within ~1% of
  Table II, which is the model-validation test in
  ``tests/test_resource_model.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core import qp_state

BRAM_BLOCK_BITS = 36 * 1024          # one BRAM36 block
BYTES_PER_BRAM_BLOCK = BRAM_BLOCK_BITS // 8

# --- MTBF model constants (see module docstring) ----------------------
ESSENTIAL_RATIO = 0.10               # paper: 10% CRAM essential bits
CRAM_BITS_PER_LUT = 692.0            # config+routing bits per LUT (calibrated)
FIT_PER_BIT_HOUR = 2.0743e-14        # calibrated on RoCE @ 100 degC
DEFAULT_NODES = 15_000               # paper: 15,000-node datacenter

# --- Published synthesis constants (Vivado 2022.1, Alveo U250, 10K QPs) ---
_PAPER_LUT = {"roce": 312_449, "irn": 319_567, "srnic": 304_497, "celeris": 298_435}
_PAPER_LUTRAM = {"roce": 23_277, "irn": 24_221, "srnic": 22_460, "celeris": 21_743}
_PAPER_FF = {"roce": 562_129, "irn": 573_116, "srnic": 551_526, "celeris": 542_972}
_PAPER_POWER_W = {"roce": 34.7, "irn": 35.9, "srnic": 33.5, "celeris": 32.5}
PAPER_BRAM = {"roce": 1450.5, "irn": 1941.5, "srnic": 939.5, "celeris": 529.5}
PAPER_MTBF_HRS = {"roce": 42.8, "irn": 34.3, "srnic": 57.8, "celeris": 80.5}

CALIBRATION_QPS = 10_000


@dataclasses.dataclass(frozen=True)
class BramBreakdown:
    """BRAM36 blocks by component at a given QP count."""
    shell: float               # DMA, parser, MMU, packet FIFOs, CC tables
    qp_context: float          # per-QP SRAM (scales with n_qps)
    retransmit_buffers: float  # go-back-N / selective-repeat payload staging
    reorder_buffers: float     # OOO reassembly / IRRQ
    tracking: float            # bitmaps / SACK engines / doorbell queues

    @property
    def total(self) -> float:
        return (self.shell + self.qp_context + self.retransmit_buffers
                + self.reorder_buffers + self.tracking)


def _ctx_blocks(design: str, n_qps: int) -> float:
    return qp_state.qp_bytes(design) * n_qps / BYTES_PER_BRAM_BLOCK


# Per-design non-context components, calibrated so totals match Table II
# at 10K QPs.  SRNIC's shell is slightly smaller (no WQE-cache FIFO path).
_NON_CTX = {
    "roce":    dict(shell=416.65, retransmit_buffers=112.0, reorder_buffers=38.6, tracking=0.0),
    "irn":     dict(shell=416.65, retransmit_buffers=0.0, reorder_buffers=158.0, tracking=73.44),
    "srnic":   dict(shell=401.63, retransmit_buffers=0.0, reorder_buffers=0.0, tracking=12.7),
    "celeris": dict(shell=416.65, retransmit_buffers=0.0, reorder_buffers=0.0, tracking=0.0),
}


def bram_breakdown(design: str, n_qps: int = CALIBRATION_QPS) -> BramBreakdown:
    parts = _NON_CTX[design]
    return BramBreakdown(qp_context=_ctx_blocks(design, n_qps), **parts)


def bram_blocks(design: str, n_qps: int = CALIBRATION_QPS) -> float:
    return bram_breakdown(design, n_qps).total


def lut(design: str) -> int:
    return _PAPER_LUT[design]


def lutram(design: str) -> int:
    return _PAPER_LUTRAM[design]


def ff(design: str) -> int:
    return _PAPER_FF[design]


def power_w(design: str) -> float:
    return _PAPER_POWER_W[design]


# ----------------------------------------------------------------------
# MTBF (SEU) model
# ----------------------------------------------------------------------

def essential_bits(design: str, n_qps: int = CALIBRATION_QPS) -> float:
    bram_bits = bram_blocks(design, n_qps) * BRAM_BLOCK_BITS
    cram_bits = CRAM_BITS_PER_LUT * lut(design)
    return bram_bits + ESSENTIAL_RATIO * cram_bits


def node_upset_rate(design: str, n_qps: int = CALIBRATION_QPS) -> float:
    """Upsets per hour for one NIC."""
    return FIT_PER_BIT_HOUR * essential_bits(design, n_qps)


def cluster_mtbf_hours(design: str, n_nodes: int = DEFAULT_NODES,
                       n_qps: int = CALIBRATION_QPS) -> float:
    return 1.0 / (node_upset_rate(design, n_qps) * n_nodes)


# ----------------------------------------------------------------------
# ASIC scaling (paper: ~57% less silicon than IRN, ~28% less than SRNIC)
# ----------------------------------------------------------------------

# Standard FPGA->ASIC scaling: logic ~ LUT-equivalents, memory ~ bits.
# Area(a.u.) = logic_area_per_lut*LUT + mem_area_per_bit*BRAM_bits, with
# memory denser on ASIC than logic (7nm SRAM macro vs std-cell).
# Solved from the paper's own two area claims (-57% vs IRN, -28% vs
# SRNIC) which are mutually consistent at ~69 bit-equivalents per LUT.
_ASIC_LOGIC_PER_LUT = 69.0
_ASIC_MEM_PER_BIT = 1.0


def asic_area_au(design: str, n_qps: int = CALIBRATION_QPS) -> float:
    return (_ASIC_LOGIC_PER_LUT * lut(design)
            + _ASIC_MEM_PER_BIT * bram_blocks(design, n_qps) * BRAM_BLOCK_BITS)


def table2(n_qps: int = CALIBRATION_QPS, n_nodes: int = DEFAULT_NODES) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {}
    for d in ("roce", "irn", "srnic", "celeris"):
        out[d] = dict(
            lut=lut(d), lutram=lutram(d), ff=ff(d),
            bram=round(bram_blocks(d, n_qps), 1),
            power_w=power_w(d),
            mtbf_hrs=round(cluster_mtbf_hours(d, n_nodes, n_qps), 1),
            asic_area_au=round(asic_area_au(d, n_qps), 0),
        )
    return out
