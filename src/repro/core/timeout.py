"""Bounded delivery windows (paper §III-B).

Celeris replaces NIC-managed reliability with software step-level
timeouts.  Per collective *group* (data-parallel, tensor-parallel,
expert-parallel ... each concurrent collective keeps its own profile):

- after each step, measure (duration, received_fraction);
- if everything arrived, track the observed duration;
- if only partial data arrived, estimate the duration needed for full
  delivery (duration / received_fraction) and aim there;
- smooth with exponential averaging and clamp to a fixed range;
- nodes exchange local estimates and all adopt the **median** for the
  next round (straggler-robust cluster coordination).

Two implementations are provided with identical semantics:

- :class:`TimeoutController` — host-side Python (drives the transport
  simulator and the trainer's loss model);
- :func:`update_jax` / :func:`coordinate_jax` — pure-``jnp`` versions
  usable inside a jitted train step (the state rides in the loop carry),
  property-tested for equivalence against the host version.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TimeoutConfig:
    alpha: float = 0.25          # EWMA smoothing factor
    margin: float = 1.10         # headroom over the estimated full-delivery time
    min_timeout: float = 1e-4    # clamp range (seconds)
    max_timeout: float = 10.0
    init_timeout: float = 0.05
    eps: float = 1e-3            # floor on received_fraction in the estimate


@dataclasses.dataclass
class TimeoutState:
    timeout: float
    smoothed_target: float

    @classmethod
    def init(cls, cfg: TimeoutConfig) -> "TimeoutState":
        return cls(timeout=cfg.init_timeout, smoothed_target=cfg.init_timeout)


def _target(duration: float, received_fraction: float, cfg: TimeoutConfig):
    """Estimated duration for full delivery of the next step."""
    frac = max(float(received_fraction), cfg.eps)
    if frac >= 1.0:
        return duration                      # everything arrived: track observed
    return duration / frac * cfg.margin      # extrapolate to full delivery


class TimeoutController:
    """Host-side adaptive timeout for one collective group."""

    def __init__(self, cfg: TimeoutConfig | None = None):
        self.cfg = cfg or TimeoutConfig()
        self.state = TimeoutState.init(self.cfg)

    @property
    def timeout(self) -> float:
        return self.state.timeout

    def update(self, duration: float, received_fraction: float) -> float:
        cfg = self.cfg
        tgt = _target(duration, received_fraction, cfg)
        sm = (1.0 - cfg.alpha) * self.state.smoothed_target + cfg.alpha * tgt
        to = float(np.clip(sm, cfg.min_timeout, cfg.max_timeout))
        self.state = TimeoutState(timeout=to, smoothed_target=sm)
        return to

    def adopt(self, cluster_timeout: float) -> float:
        """Adopt the cluster-coordinated (median) timeout for the next round."""
        to = float(np.clip(cluster_timeout, self.cfg.min_timeout, self.cfg.max_timeout))
        self.state = TimeoutState(timeout=to, smoothed_target=self.state.smoothed_target)
        return to


def coordinate(local_timeouts: Sequence[float]) -> float:
    """Cluster coordination: all nodes adopt the median of reported values."""
    return float(np.median(np.asarray(local_timeouts)))


# ----------------------------------------------------------------------
# Vectorized (whole-cluster) forms used by the batched transport engine:
# one (n_nodes,) array replaces n TimeoutController objects.  Semantics
# match the host controller per node exactly; the property test pins it.
# ----------------------------------------------------------------------

def update_array(smoothed: np.ndarray, duration: float,
                 received_fraction: np.ndarray, cfg: TimeoutConfig
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Per-node :meth:`TimeoutController.update` over an (n,) state array.

    Returns (local_timeouts, new_smoothed) — the local timeouts are what
    each node would report for coordination.
    """
    frac = np.maximum(received_fraction, cfg.eps)
    tgt = np.where(frac >= 1.0, duration, duration / frac * cfg.margin)
    sm = (1.0 - cfg.alpha) * smoothed + cfg.alpha * tgt
    return np.clip(sm, cfg.min_timeout, cfg.max_timeout), sm


def adopt_scalar(cluster_timeout: float, cfg: TimeoutConfig) -> float:
    """:meth:`TimeoutController.adopt` for the coordinated median."""
    return float(np.clip(cluster_timeout, cfg.min_timeout, cfg.max_timeout))


# ----------------------------------------------------------------------
# In-graph (jnp) versions — state is a (timeout, smoothed_target) pair of
# scalars; semantics match the host implementation bit-for-bit in f64.
# ----------------------------------------------------------------------

def init_jax(cfg: TimeoutConfig) -> jax.Array:
    return jnp.array([cfg.init_timeout, cfg.init_timeout], dtype=jnp.float32)


def update_jax(state: jax.Array, duration: jax.Array, received_fraction: jax.Array,
               cfg: TimeoutConfig) -> jax.Array:
    frac = jnp.maximum(received_fraction, cfg.eps)
    tgt = jnp.where(frac >= 1.0, duration, duration / frac * cfg.margin)
    sm = (1.0 - cfg.alpha) * state[1] + cfg.alpha * tgt
    to = jnp.clip(sm, cfg.min_timeout, cfg.max_timeout)
    return jnp.stack([to, sm])


def coordinate_jax(local_timeouts: jax.Array, axis_name: str) -> jax.Array:
    """Median across a mesh axis, inside shard_map.

    ``local_timeouts`` is this shard's scalar estimate; returns the median
    of all participants along ``axis_name`` (an all-gather + sort —
    exactly the per-step estimate exchange from the paper).
    """
    gathered = jax.lax.all_gather(local_timeouts, axis_name)
    return jnp.median(gathered)
