"""Sequential (pre-refactor) collective simulator — verification oracle.

This is the original step-at-a-time ``CollectiveSimulator`` loop,
retained verbatim after the batched-engine refactor for two jobs:

- **verification**: the engine's legacy-stream mode must reproduce this
  implementation's seeded statistics (regression tests compare the two
  live at small scale; the irn/srnic/celeris-fixed paths match to
  float32 rounding because their random streams are replayed
  bit-exactly);
- **benchmarking**: ``benchmarks/run.py`` times this loop against the
  engine to report the speedup honestly on the machine at hand.

It is 1-2 orders of magnitude slower than
:class:`repro.core.transport.engine.BatchedEngine` — do not use it for
real studies.
"""
from __future__ import annotations

import numpy as np

from repro.core import timeout as timeout_mod
from repro.core.transport import dcqcn, designs
from repro.core.transport.engine import RoundStats
from repro.core.transport.network import ClosFabric
from repro.core.transport.params import SimParams


def _transfer_reference(design, n_pkts, occ, rate, drop_p, pfc_pause,
                        queue_delay, rel, net, rng):
    """The original dense-draw transfer model, byte-for-byte.

    The refactored :func:`designs.transfer` draws loss variates only on
    the drop-capable subset (same distribution, different stream
    order); the reference keeps the seed implementation's dense
    consumption so its seeded streams — which the engine's replay mode
    reproduces — stay byte-identical to the pre-refactor simulator.
    """
    n_flows = occ.shape[0]
    pkt_time = net.pkt_time_us / np.maximum(rate, 1e-3)
    serialize = n_pkts * pkt_time
    base = serialize + queue_delay + net.base_rtt_us / 2

    if design == "roce":
        p = drop_p * designs.PFC_DROP_SUPPRESSION
        k = rng.binomial(n_pkts, p)
        tail_lost = rng.random(n_flows) < p
        extra = np.zeros(n_flows)
        remaining = k.copy()
        for _ in range(rel.max_retries):
            has_loss = remaining > 0
            pos = rng.integers(0, n_pkts, n_flows)
            n_resend = np.where(has_loss, n_pkts - pos, 0)
            detect = np.where(tail_lost, rel.rto_us,
                              rel.nack_delay_us + net.base_rtt_us)
            extra += np.where(has_loss, detect + n_resend * pkt_time, 0.0)
            remaining = rng.binomial(np.maximum(n_resend, 0), p)
            tail_lost = tail_lost & (rng.random(n_flows) < p)
        t = base + extra + pfc_pause
        full = np.full(n_flows, n_pkts)
        return designs.TransferResult(t, full, full)

    if design in ("irn", "srnic"):
        k = rng.binomial(n_pkts, drop_p)
        tail_lost = rng.random(n_flows) < drop_p
        detect = np.where(tail_lost, rel.rto_low_us,
                          rel.nack_delay_us + net.base_rtt_us)
        extra = np.where(k > 0, detect + k * pkt_time, 0.0)
        if design == "srnic":
            extra += k * rel.host_slowpath_us
        k2 = rng.binomial(k, drop_p)
        extra += np.where(k2 > 0, rel.rto_low_us + k2 * pkt_time, 0.0)
        t = base + extra
        full = np.full(n_flows, n_pkts)
        return designs.TransferResult(t, full, full)

    if design == "celeris":
        k = rng.binomial(n_pkts, drop_p)
        t = (serialize + designs.CELERIS_QUEUE_OVERLAP * queue_delay
             + net.base_rtt_us / 2)
        full = np.full(n_flows, n_pkts)
        return designs.TransferResult(t, n_pkts - k, full)

    raise ValueError(design)


class SequentialCollectiveSimulator:
    """The pure-Python ``rounds x 2(N-1)`` reference loop."""

    def __init__(self, params: SimParams | None = None):
        self.p = params or SimParams()

    # ------------------------------------------------------------------
    def run(self, design: str, n_rounds: int = 400, *,
            celeris_timeout_us: float | None = None,
            adaptive: bool = True, window: str = "round",
            seed: int | None = None) -> RoundStats:
        p = self.p
        net, rel = p.net, p.rel
        rng = np.random.default_rng(p.seed if seed is None else seed)
        fabric = ClosFabric(net, seed=int(rng.integers(2**31)))

        n = net.n_nodes
        steps = 2 * (n - 1)
        chunk_bytes = p.work.message_bytes // n
        n_pkts = max(1, chunk_bytes // net.mtu_bytes)
        src = np.arange(n)
        dst = (src + 1) % n

        cc = dcqcn.DcqcnState.init(n)

        controllers = None
        if design == "celeris":
            init_to = (celeris_timeout_us or 50_000.0) / 1e6
            cfg = timeout_mod.TimeoutConfig(
                init_timeout=init_to, min_timeout=init_to * 0.25,
                max_timeout=init_to * 8.0, alpha=0.25)
            controllers = [timeout_mod.TimeoutController(cfg) for _ in range(n)]

        times = np.zeros(n_rounds)
        fracs = np.ones(n_rounds)

        for r in range(n_rounds):
            if controllers is not None:
                round_budget_us = controllers[0].timeout * 1e6
                step_timeout_us = round_budget_us / steps

            step_nat = np.zeros(steps)
            step_deliv = np.zeros(steps)
            step_total = np.zeros(steps)

            for s in range(steps):
                fabric.advance()
                occ = fabric.path_occupancy(src, dst)
                drop_p = fabric.drop_prob(occ)
                qd = fabric.queue_delay_us(occ)
                pfc = fabric.pfc_pause_us(occ) if design == "roce" else np.zeros(n)

                eff_rate = cc.rate * fabric.avail_bandwidth(occ)
                res = _transfer_reference(design, n_pkts, occ, eff_rate,
                                          drop_p, pfc, qd, rel, net, rng)

                if design == "celeris" and window == "step":
                    t_nat = float(res.time_us.max())
                    step_nat[s] = min(t_nat, step_timeout_us)
                    late_frac = np.clip(
                        (res.time_us - step_timeout_us)
                        / np.maximum(res.time_us, 1e-9), 0, 1)
                    step_deliv[s] = float(
                        (res.delivered_pkts * (1 - late_frac)).sum())
                else:
                    step_nat[s] = float(res.time_us.max())
                    step_deliv[s] = float(res.delivered_pkts.sum())
                step_total[s] = float(res.total_pkts.sum())

                cnp = rng.random(n) < fabric.ecn_mark_prob(occ)
                cc = dcqcn.step(cc, cnp, p.dcqcn)

            if design == "celeris" and window == "round":
                cum = np.cumsum(step_nat)
                total_t = float(cum[-1])
                if total_t <= round_budget_us:
                    times[r] = total_t
                    fracs[r] = step_deliv.sum() / max(step_total.sum(), 1.0)
                else:
                    times[r] = round_budget_us
                    done = cum <= round_budget_us
                    bidx = int(np.argmax(~done))
                    prev = float(cum[bidx - 1]) if bidx > 0 else 0.0
                    part = (round_budget_us - prev) / max(step_nat[bidx], 1e-9)
                    got = step_deliv[done].sum() + step_deliv[bidx] * part
                    fracs[r] = got / max(step_total.sum(), 1.0)
            else:
                times[r] = step_nat.sum()
                fracs[r] = step_deliv.sum() / max(step_total.sum(), 1.0)

            if controllers is not None and adaptive:
                node_frac = np.clip(
                    fracs[r] + rng.normal(0, 0.002, n), 0.0, 1.0)
                local = [c.update(times[r] / 1e6, node_frac[i])
                         for i, c in enumerate(controllers)]
                agreed = timeout_mod.coordinate(local)
                for c in controllers:
                    c.adopt(agreed)

        return RoundStats(times_us=times, recv_frac=fracs, design=design)
