"""Flight-recorder telemetry: tail attribution for the batched engine.

Every headline this repo reproduces is a tail number (p99 x2.3, the
~260x serve-path gap, the fault-sustain ratios), and until now the
stack could only *state* them: :class:`~repro.core.transport.engine
.RoundStats` says a round was slow, not whether the time went to
retransmit storms, PFC cascade pauses, DCI queueing, fault stalls, or
window cuts.  This module records exactly that decomposition as an
**opt-in pure overlay** on the vectorized physics pass:

- The engine's per-phase transfer path (``designs.transfer`` →
  ``topology.add_dci_latency`` → ``faults.apply_to_result``) fills an
  optional ``parts`` dict with the component arrays it *already
  computes* — serialization, queueing, RTT, PFC pause, retransmit
  episodes, fault stalls — plus per-flow loss attribution
  (``wire_lost`` / ``fault_lost``).  No extra random draws, no changed
  arithmetic: with the recorder off nothing is allocated and the seeded
  traces stay bit-exact (pinned by ``tests/test_telemetry.py`` against
  ``tests/data/ring_schedule_seed_stats.json``); with it on, the stats
  are *still* bit-exact — recording only reads.
- :class:`TraceRecorder` reduces those arrays per ``(step, phase,
  tier)`` into a :class:`DesignRecord`: the critical (slowest) flow's
  component breakdown per step — whose sum telescopes to the round
  times in ``RoundStats`` — per-tier component sums over *all* flows,
  and per-(step, tier, cause) lost packets.  Window cuts are attributed
  at ``assemble`` time from the trace/stats pair.
- :func:`audit_round` asserts the conservation laws that make the
  attribution trustworthy: component times sum to the pinned round
  totals, delivered + per-cause losses sum to offered bytes, tier and
  pod groupings recombine to the scalar delivered fraction.  The
  silent-undercount class of bug PR 7 fixed (``.ravel()[idx] +=`` on a
  non-contiguous block) now fails loudly here.
- :class:`DropProvenance` carries the attribution across the stack
  boundary: ``coupling.DropSchedule`` tags each dropped fraction with
  its originating (tier, cause, phase) so trainer/serve recovery
  metrics can say "this 0.04 recovery loss came from DCI fault stalls
  in the AG phase".

Memory: the recorder keeps O(T * n_tiers * n_components) float64 —
a few MB for the CI scales, ~7 MB for a 512-node x 40-round trace —
plus transiently a handful of block-sized component arrays while a
phase is being reduced (comparable to the engine's own temporaries).

See ``docs/OBSERVABILITY.md`` for the full event schema and the
Perfetto export (``transport/trace_export.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core.transport import topology

# Time components of a flow's completion, in display order.  "incast"
# is the egress-sharing share of serialization on fan-in > 1 columns
# (fan senders share one receiver port: of the fan-x serialization
# stretch, the 1 - 1/fan share is contention, not wire time).
COMPONENTS = ("serialize", "queue", "rtt", "pfc", "retransmit",
              "incast", "fault")
N_COMPONENTS = len(COMPONENTS)

# Loss causes, in attribution order: packets dropped on the wire
# (Celeris's unrecovered overflow losses), packets swallowed by a NIC
# fault (stall / crash), packets cut by the bounded receiver window.
CAUSES = ("wire_drop", "fault", "window_cut")
N_CAUSES = len(CAUSES)
_WIRE, _FAULT, _CUT = range(N_CAUSES)

# Components that are recovery machinery rather than data movement —
# the "why reliable tails explode" bucket fig9 headlines.
RECOVERY_COMPONENTS = ("pfc", "retransmit", "fault")


class ConservationError(AssertionError):
    """A recorded attribution failed to conserve to the engine totals."""


def _ck(ok: bool, msg: str) -> None:
    if not ok:
        raise ConservationError(msg)


@dataclasses.dataclass
class DesignRecord:
    """Attribution events for one design over one ``traces()`` pass.

    All arrays are float64 reductions of the engine's own blocks; T is
    the full trace length (rounds x steps_per_round).  The critical
    flow of a step is the argmax-completion-time flow — the one whose
    time *is* the step's natural duration, so ``comp_crit.sum(-1)``
    telescopes to the round times (up to engine float32 rounding;
    :func:`audit_round` pins the tolerance).
    """
    design: str
    n_rounds: int
    steps: int
    phase_names: tuple
    phase_of_step: np.ndarray           # (steps,) in-round phase index
    comp_crit: np.ndarray               # (T, n_components) critical flow
    crit_tier: np.ndarray               # (T,) critical flow's tier index
    crit_src: np.ndarray                # (T,) critical flow's sender node
    comp_tier: np.ndarray               # (T, n_tiers, n_components)
    lost_pkts: np.ndarray               # (T, n_tiers, 2) wire/fault lost
    offered_pkts: np.ndarray            # (T, n_tiers)
    delivered_pkts: np.ndarray          # (T, n_tiers) post-fault, pre-window
    # filled by TraceRecorder.record_assemble (None until assembled)
    natural_us: np.ndarray | None = None     # (R,) un-windowed round time
    elapsed_us: np.ndarray | None = None     # (R,) stats.times_us
    windowed_pkts: np.ndarray | None = None  # (R, n_tiers) survive window
    window_cut_pkts: np.ndarray | None = None  # (R, n_tiers)
    prio_offered_pkts: np.ndarray | None = None    # (R, n_classes)
    prio_window_cut_pkts: np.ndarray | None = None  # (R, n_classes)
    stats: "object | None" = None            # the assembled RoundStats

    # -- derived views -------------------------------------------------
    def round_components(self) -> np.ndarray:
        """(R, n_components) critical-path time per round per component."""
        return self.comp_crit.reshape(self.n_rounds, self.steps,
                                      N_COMPONENTS).sum(axis=1)

    def phase_components(self) -> np.ndarray:
        """(R, n_phases, n_components) critical-path time by phase."""
        cc = self.comp_crit.reshape(self.n_rounds, self.steps, N_COMPONENTS)
        out = np.zeros((self.n_rounds, len(self.phase_names), N_COMPONENTS))
        for k in range(len(self.phase_names)):
            out[:, k] = cc[:, self.phase_of_step == k].sum(axis=1)
        return out

    def loss_by_cause(self) -> np.ndarray:
        """(R, n_tiers, n_causes) lost packets per round, all causes.

        The window_cut column requires :meth:`TraceRecorder
        .record_assemble` to have run (i.e. the trace was assembled by
        an engine holding this recorder); it is zero otherwise.
        """
        lp = self.lost_pkts.reshape(self.n_rounds, self.steps,
                                    topology.N_TIERS, 2).sum(axis=1)
        out = np.zeros((self.n_rounds, topology.N_TIERS, N_CAUSES))
        out[:, :, _WIRE] = lp[:, :, 0]
        out[:, :, _FAULT] = lp[:, :, 1]
        if self.window_cut_pkts is not None:
            out[:, :, _CUT] = self.window_cut_pkts
        return out

    def phase_lost_pkts(self) -> np.ndarray:
        """(R, n_phases, n_tiers, 2) wire/fault lost packets by phase.

        Window cuts are not phase-resolved (the round/phase window cut
        is accounted per tier group at assemble time); use
        :meth:`loss_by_cause` for the full three-cause picture.
        """
        lp = self.lost_pkts.reshape(self.n_rounds, self.steps,
                                    topology.N_TIERS, 2)
        out = np.zeros((self.n_rounds, len(self.phase_names),
                        topology.N_TIERS, 2))
        for k in range(len(self.phase_names)):
            out[:, k] = lp[:, self.phase_of_step == k].sum(axis=1)
        return out

    def offered_round(self) -> np.ndarray:
        """(R, n_tiers) offered packets per round."""
        return self.offered_pkts.reshape(self.n_rounds, self.steps,
                                         topology.N_TIERS).sum(axis=1)

    def delivered_round(self) -> np.ndarray:
        """(R, n_tiers) post-fault (pre-window) delivered packets."""
        return self.delivered_pkts.reshape(self.n_rounds, self.steps,
                                           topology.N_TIERS).sum(axis=1)

    def loss_rates(self) -> np.ndarray:
        """(R, n_causes) lost fraction of the round's offered payload
        by cause — the serve path's per-request attribution input."""
        lost = self.loss_by_cause().sum(axis=1)
        offered = np.maximum(self.offered_round().sum(axis=1), 1.0)
        return lost / offered[:, None]

    def tail_rounds(self, q: float = 99.0) -> np.ndarray:
        """(R,) bool — rounds at or above the q-th natural-time
        percentile (natural = un-windowed: the tail the fabric
        produced, before any window policy bounded it)."""
        t = (self.natural_us if self.natural_us is not None
             else self.round_components().sum(axis=1))
        return t >= np.percentile(t, q)


class TraceRecorder:
    """Opt-in flight recorder for :class:`~repro.core.transport.engine
    .BatchedEngine` (shared-fabric mode).

    Pass one to the engine (``BatchedEngine(params, recorder=rec)``)
    and run ``traces`` + ``assemble`` as usual; the recorder fills one
    :class:`DesignRecord` per design, readable via :meth:`record`.
    Recording draws no random numbers and mutates nothing the physics
    reads, so stats with the recorder on are bit-identical to stats
    with it off.  One recorder serves one ``traces()`` pass at a time
    (``begin`` resets it); legacy stream-replay mode is unsupported.
    """

    def __init__(self):
        self.records: Dict[str, DesignRecord] = {}
        # design-independent fabric counters (export counter tracks)
        self.fabric: Dict[str, np.ndarray] = {}
        self._active = False

    # -- engine-facing hooks -------------------------------------------
    def begin(self, design_list, *, plan, n_rounds: int, steps: int) -> None:
        T = n_rounds * steps
        names = tuple(ph.name for ph in plan.phases)
        pos = np.asarray(plan.phase_of_step)
        self.records = {
            d: DesignRecord(
                design=d, n_rounds=n_rounds, steps=steps,
                phase_names=names, phase_of_step=pos,
                comp_crit=np.zeros((T, N_COMPONENTS)),
                crit_tier=np.full(T, -1, dtype=np.int8),
                crit_src=np.full(T, -1, dtype=np.int32),
                comp_tier=np.zeros((T, topology.N_TIERS, N_COMPONENTS)),
                lost_pkts=np.zeros((T, topology.N_TIERS, 2)),
                offered_pkts=np.zeros((T, topology.N_TIERS)),
                delivered_pkts=np.zeros((T, topology.N_TIERS)))
            for d in design_list}
        self.fabric = {}
        self._active = True

    @staticmethod
    def new_parts() -> dict:
        """The per-phase component scratchpad ``designs.transfer`` /
        ``topology.add_dci_latency`` / ``faults.apply_to_result`` fill."""
        return {}

    def record_fabric(self, rows: np.ndarray, counters: Dict[str, np.ndarray],
                      T: int) -> None:
        """Design-independent per-step fabric counters (see
        ``network.congestion_counters``), keyed by absolute step rows."""
        for name, v in counters.items():
            if name not in self.fabric:
                self.fabric[name] = np.zeros(T)
            self.fabric[name][rows] = v

    def record_phase(self, design: str, rows: np.ndarray, ph, hg, fan,
                     res, parts: dict) -> None:
        """Reduce one (design, phase, block) transfer into the record.

        ``rows`` are absolute step indices, ``ph`` the SchedulePhase,
        ``hg`` its HierGeometry (flow→tier columns), ``fan`` its
        per-flow receiver fan-in, ``res`` the (mutated) TransferResult
        and ``parts`` the component scratchpad the physics path filled.
        """
        rec = self.records[design]
        shape = res.time_us.shape
        n_rows = rows.size
        rg = np.arange(n_rows)
        ar = np.argmax(res.time_us, axis=-1)

        # incast carve-out: on fan-in > 1 columns the serialization
        # stretch is fan-x wire time; the (1 - 1/fan) share is receiver
        # egress contention.  Exact split: the two parts sum back to
        # the recorded serialization by construction.
        ser = np.array(np.broadcast_to(
            np.asarray(parts.get("serialize", 0.0), np.float64), shape))
        inc = np.zeros_like(ser)
        fan = np.asarray(fan)
        im = fan > 1
        if im.any():
            inc[:, im] = ser[:, im] * (1.0 - 1.0 / fan[im])
            ser[:, im] -= inc[:, im]

        comps = {"serialize": ser, "incast": inc,
                 "queue": parts.get("queue", 0.0),
                 "rtt": parts.get("rtt", 0.0),
                 "pfc": parts.get("pfc", 0.0),
                 "retransmit": parts.get("retransmit", 0.0),
                 "fault": parts.get("fault", 0.0)}
        for ci, name in enumerate(COMPONENTS):
            a = np.asarray(comps[name], np.float64)
            if a.ndim == 0:
                v = float(a)
                rec.comp_crit[rows, ci] = v
                for k, cols in enumerate(hg.tier_cols):
                    if cols.size:
                        rec.comp_tier[rows, k, ci] = v * cols.size
                continue
            b = np.broadcast_to(a, shape)
            rec.comp_crit[rows, ci] = b[rg, ar]
            for k, cols in enumerate(hg.tier_cols):
                if cols.size:
                    rec.comp_tier[rows, k, ci] = b[:, cols].sum(axis=-1)

        tier_of_flow = np.full(ph.src.size, -1, dtype=np.int8)
        for k, cols in enumerate(hg.tier_cols):
            tier_of_flow[cols] = k
        rec.crit_tier[rows] = tier_of_flow[ar]
        rec.crit_src[rows] = np.asarray(ph.src)[ar]

        deliv = np.broadcast_to(
            np.asarray(res.delivered_pkts, np.float64), shape)
        total = np.broadcast_to(np.asarray(res.total_pkts, np.float64), shape)
        wire = parts.get("wire_lost")
        flost = parts.get("fault_lost")
        for k, cols in enumerate(hg.tier_cols):
            if not cols.size:
                continue
            rec.offered_pkts[rows, k] = total[:, cols].sum(axis=-1)
            rec.delivered_pkts[rows, k] = deliv[:, cols].sum(axis=-1)
            if wire is not None:
                rec.lost_pkts[rows, k, 0] = np.asarray(
                    wire, np.float64)[:, cols].sum(axis=-1)
            if flost is not None:
                rec.lost_pkts[rows, k, 1] = np.asarray(
                    flost, np.float64)[:, cols].sum(axis=-1)

    def record_assemble(self, trace, stats) -> None:
        """Window attribution: called by ``BatchedEngine.assemble`` on
        every packed RoundStats.  The cut per (round, tier) is the gap
        between what the fabric delivered (post-fault) and what
        survived the bounded window; for reliable designs it is zero
        by construction."""
        rec = self.records.get(trace.design)
        if rec is None:
            return
        steps = trace.steps_per_round
        R = trace.nat_us.shape[0] // steps
        rec.natural_us = trace.nat_us.reshape(R, steps).sum(axis=1)
        rec.elapsed_us = np.asarray(stats.times_us, np.float64)
        rec.stats = stats
        if trace.tier_deliv is not None and stats.tier_recv_frac is not None:
            full = trace.tier_deliv.reshape(R, steps, -1).sum(axis=1)
            tot = trace.tier_total.reshape(R, steps, -1).sum(axis=1)
            windowed = np.asarray(stats.tier_recv_frac, np.float64) * tot
            rec.windowed_pkts = windowed
            rec.window_cut_pkts = np.maximum(full - windowed, 0.0)
        if (trace.step_priority is not None
                and stats.prio_recv_frac is not None):
            # same attribution regrouped by priority class: under
            # cut_order="priority" the cut concentrates in class 0, and
            # the per-class columns sum to the per-tier cut exactly
            # (audit_round pins the recombination)
            cls = np.asarray(trace.step_priority, dtype=int)
            C = np.asarray(stats.prio_recv_frac).shape[1]
            onehot = (cls[:, None] == np.arange(C)[None, :])
            d = trace.deliv.reshape(R, steps)
            t = trace.total.reshape(R, steps)
            full_c = (d[:, :, None] * onehot[None, :, :]).sum(axis=1)
            tot_c = (t[:, :, None] * onehot[None, :, :]).sum(axis=1)
            windowed_c = np.asarray(stats.prio_recv_frac,
                                    np.float64) * tot_c
            rec.prio_offered_pkts = tot_c
            rec.prio_window_cut_pkts = np.maximum(full_c - windowed_c, 0.0)

    # -- reading -------------------------------------------------------
    def record(self, design: str) -> DesignRecord:
        try:
            return self.records[design]
        except KeyError:
            raise KeyError(
                f"no record for design {design!r}: recorder saw "
                f"{sorted(self.records)} — was it attached before "
                "traces() ran?") from None


# ----------------------------------------------------------------------
# Conservation audit (tier-1 satellite)
# ----------------------------------------------------------------------

def audit_round(stats, record: DesignRecord | None = None, *,
                time_rtol: float = 2e-5,
                pkt_rtol: float = 1e-9) -> Dict[str, float]:
    """Assert the conservation laws tying attribution to round totals.

    Standalone (``record=None``) it audits :class:`RoundStats` internal
    consistency: finite positive times, fractions in [0, 1], tier
    fractions recombining (offered-packet weighted) to the scalar
    delivered fraction, pod + DCI accounting recombining to the tier
    accounting.  With a :class:`DesignRecord` it additionally asserts

    - critical-path component sums equal the un-windowed round times
      (within engine float32 accumulation rounding: ``time_rtol``),
    - for reliable designs, un-windowed equals assembled round time
      exactly; for Celeris, elapsed <= natural and the cut is >= 0,
    - delivered + wire_drop + fault + window_cut == offered, per
      (round, tier), exactly up to float64 rounding (``pkt_rtol``),
    - the recorder's own offered/delivered reductions match the
      engine trace's independent tier reductions.

    Returns a small summary dict (max relative errors observed).
    Raises :class:`ConservationError` on any violation — the loud
    failure mode the PR-7 ``.ravel→.flat`` silent-undercount bug
    class now gets.
    """
    times = np.asarray(stats.times_us, np.float64)
    fr = np.asarray(stats.recv_frac, np.float64)
    _ck(bool(np.isfinite(times).all()) and bool((times > 0).all()),
        "round times must be finite and positive")
    _ck(bool((fr > -1e-12).all()) and bool((fr < 1 + 1e-9).all()),
        "recv_frac out of [0, 1]")
    out: Dict[str, float] = {"rounds": float(times.size)}

    if stats.tier_recv_frac is not None and stats.tier_pkts is not None:
        w = np.asarray(stats.tier_pkts, np.float64)
        if w.sum() > 0:
            recomb = (np.asarray(stats.tier_recv_frac, np.float64)
                      * w).sum(axis=1) / w.sum()
            err = float(np.abs(recomb - fr).max())
            out["tier_recomb_abs_err"] = err
            _ck(err < 1e-9, f"tier fractions do not recombine to "
                            f"recv_frac (abs err {err:.2e})")
    if (stats.pod_recv_frac is not None and stats.pod_pkts is not None
            and stats.tier_recv_frac is not None
            and stats.tier_pkts is not None):
        w = np.asarray(stats.tier_pkts, np.float64)
        intra_pod = (np.asarray(stats.pod_recv_frac, np.float64)
                     * np.asarray(stats.pod_pkts, np.float64)).sum(axis=1)
        intra_tier = (np.asarray(stats.tier_recv_frac, np.float64)[:, :2]
                      * w[:2]).sum(axis=1)
        err = float(np.abs(intra_pod - intra_tier).max()
                    / max(float(w[:2].sum()), 1.0))
        out["pod_recomb_rel_err"] = err
        _ck(err < 1e-9, f"pod intra accounting does not recombine to "
                        f"tier intra accounting (rel err {err:.2e})")

    if record is None:
        return out

    _ck(record.natural_us is not None,
        "record not assembled: run engine.assemble() with the recorder "
        "attached before auditing")
    comp = record.round_components()
    nat = record.natural_us
    err = float(np.abs(comp.sum(axis=1) - nat).max()
                / max(float(nat.max()), 1e-9))
    out["time_rel_err"] = err
    _ck(err < time_rtol,
        f"component times do not conserve to round times "
        f"(rel err {err:.2e} > {time_rtol:.0e})")
    if record.design != "celeris":
        _ck(bool(np.array_equal(record.elapsed_us, nat)),
            "reliable-design assembled times differ from natural times")
    else:
        _ck(bool((record.elapsed_us <= nat * (1 + 1e-12) + 1e-9).all()),
            "celeris elapsed time exceeds natural time")
        if record.window_cut_pkts is not None:
            _ck(bool((record.window_cut_pkts > -1e-6).all()),
                "negative window cut")

    offered = record.offered_round()
    delivered = record.delivered_round()
    lost = record.loss_by_cause()
    scale = max(float(offered.max()), 1.0)
    # recorder reduction vs the engine trace's independent reduction
    if record.windowed_pkts is not None:
        accounted = record.windowed_pkts + lost.sum(axis=2)
    else:
        accounted = delivered + lost[:, :, :2].sum(axis=2)
    err = float(np.abs(accounted - offered).max() / scale)
    out["pkt_rel_err"] = err
    _ck(err < max(pkt_rtol, 1e-12),
        f"delivered + per-cause losses do not conserve to offered "
        f"packets (rel err {err:.2e})")
    wf = delivered + lost[:, :, :2].sum(axis=2)
    err = float(np.abs(wf - offered).max() / scale)
    _ck(err < max(pkt_rtol, 1e-12),
        f"pre-window delivered + wire/fault losses do not conserve "
        f"(rel err {err:.2e})")
    if (record.prio_window_cut_pkts is not None
            and record.window_cut_pkts is not None):
        # the priority-class regrouping must account for the same cut
        # bytes as the tier grouping (both slice one survive vector)
        err = float(np.abs(record.prio_window_cut_pkts.sum(axis=1)
                           - record.window_cut_pkts.sum(axis=1)).max()
                    / scale)
        out["prio_cut_recomb_rel_err"] = err
        _ck(err < 1e-9,
            f"per-priority-class window cuts do not recombine to the "
            f"per-tier window cuts (rel err {err:.2e})")
    if record.stats is not None and record.stats.tier_pkts is not None:
        tp = np.asarray(record.stats.tier_pkts, np.float64)
        err = float(np.abs(offered - tp[None, :]).max() / scale)
        out["offered_vs_plan_rel_err"] = err
        _ck(err < 1e-9,
            f"recorder offered packets disagree with the plan's "
            f"tier_pkts (rel err {err:.2e})")
    return out


# ----------------------------------------------------------------------
# Drop provenance (the stack-boundary tag coupling/serve carry)
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DropProvenance:
    """Where a :class:`~repro.core.transport.coupling.DropSchedule`'s
    dropped fractions came from: per-(step, cause) loss rates plus the
    tier/phase context, so a trainer- or serve-side recovery metric can
    be attributed end-to-end.  ``rates`` are *unclipped* attribution
    (DropSchedule clips its own rates to MAX_DROP; the provenance keeps
    the physical split).  ``phase_rates`` resolves the wire/fault
    causes by schedule phase; window cuts are tier- but not
    phase-resolved (see :meth:`DesignRecord.phase_lost_pkts`).
    """
    axis: str                         # "flat" | "intra" | "cross"
    tiers: tuple                      # topology.TIERS subset feeding it
    causes: tuple                     # CAUSES order
    rates: np.ndarray                 # (R, n_causes) loss frac by cause
    phases: tuple = ()                # schedule phase names
    phase_rates: np.ndarray | None = None  # (R, n_phases) wire+fault frac
    source: str = "recorded"          # "recorded" | "heuristic"

    def total(self) -> np.ndarray:
        return self.rates.sum(axis=1)

    def mean_by_cause(self) -> Dict[str, float]:
        return {c: float(self.rates[:, i].mean())
                for i, c in enumerate(self.causes)}

    def dominant_cause(self) -> str:
        return self.causes[int(np.argmax(self.rates.sum(axis=0)))]

    def describe(self) -> str:
        """One line: 'cross[dci]: 0.031 window_cut + 0.004 fault (...)'."""
        by = self.mean_by_cause()
        parts = " + ".join(f"{v:.4f} {c}" for c, v in sorted(
            by.items(), key=lambda kv: -kv[1]) if v > 0) or "0 loss"
        return (f"{self.axis}[{','.join(self.tiers)}]: {parts} "
                f"({self.source})")


_AXIS_TIERS = {"flat": (0, 1, 2), "intra": (0, 1), "cross": (2,)}


def provenance_from_record(record: DesignRecord, axis: str
                           ) -> DropProvenance:
    """Exact per-cause provenance for one coupling axis from a
    :class:`DesignRecord` (requires an assembled record)."""
    ti = list(_AXIS_TIERS[axis])
    lost = record.loss_by_cause()[:, ti, :].sum(axis=1)       # (R, causes)
    offered = np.maximum(record.offered_round()[:, ti].sum(axis=1), 1.0)
    rates = lost / offered[:, None]
    ph_lost = record.phase_lost_pkts()[:, :, ti, :].sum(axis=(2, 3))
    return DropProvenance(
        axis=axis, tiers=tuple(topology.TIERS[k] for k in ti),
        causes=CAUSES, rates=rates, phases=record.phase_names,
        phase_rates=ph_lost / offered[:, None], source="recorded")


def provenance_heuristic(stats, axis: str) -> DropProvenance:
    """Cause attribution from :class:`RoundStats` alone (no recorder):
    loss in fault-exposed rounds is tagged "fault"; the remainder is
    "window_cut" for Celeris (the bounded window is what realizes its
    loss) and "wire_drop" otherwise.  Coarse by construction — run the
    engine with a :class:`TraceRecorder` for the exact split."""
    ti = list(_AXIS_TIERS[axis])
    if stats.tier_recv_frac is not None and stats.tier_pkts is not None:
        w = np.asarray(stats.tier_pkts, np.float64)[ti]
        if w.sum() > 0:
            loss = 1.0 - (np.asarray(stats.tier_recv_frac, np.float64)[:, ti]
                          * w).sum(axis=1) / w.sum()
        else:
            loss = np.zeros(np.asarray(stats.recv_frac).shape[0])
    else:
        loss = 1.0 - np.asarray(stats.recv_frac, np.float64)
    loss = np.maximum(loss, 0.0)
    rates = np.zeros((loss.size, N_CAUSES))
    faulted = stats.faulted
    resid = _CUT if stats.design == "celeris" else _WIRE
    rates[faulted, _FAULT] = loss[faulted]
    rates[~faulted, resid] = loss[~faulted]
    return DropProvenance(
        axis=axis, tiers=tuple(topology.TIERS[k] for k in ti),
        causes=CAUSES, rates=rates, source="heuristic")
