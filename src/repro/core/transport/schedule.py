"""Pluggable collective communication schedules.

The engine used to hardcode one communication pattern: a flat
``2(N-1)``-step ring whose every step moves ``message/N`` bytes between
ring neighbors — even across a multi-pod hierarchy, so the DCI
oversubscription penalty was charged to *every* hop instead of only the
cross-pod exchange.  This module extracts that choice into data: a
:class:`CollectiveSchedule` produces a :class:`SchedulePlan` — the
per-round sequence of steps, each step a set of concurrent flows with
``(src, dst, tier, payload_bytes)`` — that the engine's vectorized
trace loop consumes (``BatchedEngine._traces_shared`` times one phase
block at a time) and the coupling layer reads for its step→tier map.

Steps group into *phases*: contiguous step runs sharing one static flow
pattern and per-step payload, so each phase stays a dense
``(step, flow)`` tensor block and the engine loses none of its
vectorization.  Payload accounting follows the standard ring
reduce-scatter / all-gather arithmetic — an ``N``-peer ring RS (or AG)
of an ``M``-byte message takes ``N-1`` steps of ``M/N`` bytes per flow:

- :class:`RingSchedule` — the flat ring: one phase, ``2(N-1)`` steps of
  ``M/N`` bytes (RS immediately followed by AG over all ``N`` nodes).
  Selecting it reproduces the pre-schedule engine bit-exactly (pinned
  by ``tests/test_schedule.py`` against committed seed stats).
- :class:`HierarchicalSchedule` — the hierarchy-aware plan for
  ``n_pods`` pods of ``m = N / n_pods`` nodes:

  1. ``rs``  — reduce-scatter inside each pod: ``m-1`` steps of ``M/m``
     bytes on the intra-pod ring (tor/spine tiers only);
  2. ``dci`` — pod leaders all-reduce the pod-reduced message over the
     DCI: a ``2(n_pods-1)``-step ring of ``M/n_pods``-byte shards —
     the *only* steps that traverse the oversubscribed uplinks;
  3. ``ag``  — all-gather inside each pod: ``m-1`` steps of ``M/m``.

  Total ``2(m-1) + 2(n_pods-1)`` steps versus the flat ``2(N-1)``; the
  DCI penalty applies to ``2(n_pods-1)`` large-shard steps instead of
  all of them, which is what moves the cross-pod tail (Fig. 5).  At
  ``n_pods=1`` the plan degenerates to the flat ring exactly.

Select a schedule with ``SimParams.work.schedule`` (``"ring"`` |
``"hier"``), sweep it with ``BatchedSimParams.schedules``, and train
against it with ``CollectiveMode.HIERARCHICAL`` — the trainer's sync
order (exact intra-pod reduce → coded cross-pod exchange) mirrors
:attr:`HierarchicalSchedule.PHASE_ORDER`, asserted in
``train_step.make_train_step``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.transport import topology
from repro.core.transport.params import (NetworkParams, TopologyParams,
                                         WorkloadParams)


@dataclasses.dataclass(frozen=True, eq=False)
class SchedulePhase:
    """A contiguous run of steps sharing one static flow pattern.

    ``payload_bytes`` is per flow per step; a flow's sender column in
    the engine's ``(step, node)`` tensors is its ``src`` node (each
    node sends at most one flow per step in every schedule here).
    """
    name: str
    src: np.ndarray            # (n_flows,) sender node per flow
    dst: np.ndarray            # (n_flows,) receiver node per flow
    n_steps: int               # steps of this phase per round
    payload_bytes: int         # bytes per flow per step

    def n_pkts(self, net: NetworkParams) -> int:
        return max(1, self.payload_bytes // net.mtu_bytes)


@dataclasses.dataclass(frozen=True, eq=False)
class SchedulePlan:
    """One round of a collective schedule, resolved for a topology."""
    schedule: str
    phases: tuple              # of SchedulePhase, in execution order
    steps_per_round: int
    phase_of_step: np.ndarray  # (steps_per_round,) phase index per step

    @property
    def single_phase(self) -> bool:
        return len(self.phases) == 1

    def geometries(self, net: NetworkParams, topo: TopologyParams) -> tuple:
        """Per-phase :class:`topology.HierGeometry` (flow→tier maps)."""
        return tuple(topology.hier_geometry(net, topo, src=ph.src,
                                            dst=ph.dst)
                     for ph in self.phases)

    def step_table(self, net: NetworkParams, topo: TopologyParams) -> list:
        """The explicit per-step plan: ``(src, dst, tiers,
        payload_bytes)`` per step, tiers as indexes into
        ``topology.TIERS``.  The engine consumes the phase blocks; this
        flat view is for tests, docs, and the coupling layer's
        step→tier map."""
        rows = []
        for ph, hg in zip(self.phases, self.geometries(net, topo)):
            rows.extend([(ph.src, ph.dst, hg.tiers, ph.payload_bytes)]
                        * ph.n_steps)
        return rows

    def tier_counts(self, net: NetworkParams, topo: TopologyParams,
                    geometries: tuple | None = None) -> np.ndarray:
        """(n_tiers,) flows per tier, summed over phases.  Pass
        ``geometries`` when :meth:`geometries` is already in hand (the
        engine does) to skip recomputing it."""
        gs = geometries if geometries is not None else self.geometries(
            net, topo)
        out = np.zeros(topology.N_TIERS, dtype=int)
        for hg in gs:
            out += hg.tier_counts
        return out

    def tier_pkts_round(self, net: NetworkParams, topo: TopologyParams,
                        geometries: tuple | None = None) -> np.ndarray:
        """(n_tiers,) offered packets per round per tier — the
        schedule's actual per-tier exposure, which weights the
        axis-split drop schedules (``coupling``)."""
        gs = geometries if geometries is not None else self.geometries(
            net, topo)
        out = np.zeros(topology.N_TIERS)
        for ph, hg in zip(self.phases, gs):
            out += hg.tier_counts * (ph.n_pkts(net) * ph.n_steps)
        return out

    def bytes_per_round(self) -> int:
        """Total bytes offered to the fabric per round (all flows, all
        steps) — the payload-conservation invariant tests pin."""
        return sum(ph.src.size * ph.n_steps * ph.payload_bytes
                   for ph in self.phases)


def _mk_plan(name: str, phases) -> SchedulePlan:
    phases = tuple(ph for ph in phases if ph.n_steps > 0)
    steps = sum(ph.n_steps for ph in phases)
    phase_of_step = np.repeat(np.arange(len(phases)),
                              [ph.n_steps for ph in phases])
    return SchedulePlan(schedule=name, phases=phases, steps_per_round=steps,
                        phase_of_step=phase_of_step)


class CollectiveSchedule:
    """Produces the per-step flow plan the engine times."""

    name: str = "?"

    def plan(self, net: NetworkParams, topo: TopologyParams,
             work: WorkloadParams) -> SchedulePlan:
        raise NotImplementedError


class RingSchedule(CollectiveSchedule):
    """Flat ring RS+AG over all nodes: ``2(N-1)`` steps of ``M/N``
    bytes.  Bit-exact replica of the pre-schedule engine."""

    name = "ring"

    def plan(self, net, topo, work):
        n = net.n_nodes
        src = np.arange(n)
        ring = SchedulePhase(name="ring", src=src, dst=(src + 1) % n,
                             n_steps=2 * (n - 1),
                             payload_bytes=work.message_bytes // n)
        return _mk_plan(self.name, (ring,))


class HierarchicalSchedule(CollectiveSchedule):
    """Reduce-scatter within pod → leader DCI exchange → all-gather
    within pod (see module docstring for the step/payload accounting)."""

    name = "hier"
    # Execution order of the phases; the trainer's HIERARCHICAL sync
    # (exact intra-pod reduce first, coded cross-pod exchange second)
    # asserts against this so schedule and collective mode can't drift
    # apart silently.
    PHASE_ORDER = ("rs", "dci", "ag")

    def plan(self, net, topo, work):
        topology.validate(net, topo)
        n, n_pods = net.n_nodes, topo.n_pods
        if n_pods == 1:
            # degenerate hierarchy: the plan IS the flat ring (single
            # phase, so it stays bit-exact with RingSchedule too)
            return dataclasses.replace(RingSchedule().plan(net, topo, work),
                                       schedule=self.name)
        m = n // n_pods
        src = np.arange(n)
        pod = src // m
        nxt = pod * m + (src - pod * m + 1) % m     # intra-pod ring
        leaders = np.arange(n_pods) * m
        phases = (
            SchedulePhase(name="rs", src=src, dst=nxt, n_steps=m - 1,
                          payload_bytes=work.message_bytes // m),
            SchedulePhase(name="dci", src=leaders,
                          dst=((np.arange(n_pods) + 1) % n_pods) * m,
                          n_steps=2 * (n_pods - 1),
                          payload_bytes=work.message_bytes // n_pods),
            SchedulePhase(name="ag", src=src, dst=nxt, n_steps=m - 1,
                          payload_bytes=work.message_bytes // m),
        )
        assert tuple(ph.name for ph in phases) == self.PHASE_ORDER
        return _mk_plan(self.name, phases)


SCHEDULES = {cls.name: cls for cls in (RingSchedule, HierarchicalSchedule)}


def get_schedule(name: str) -> CollectiveSchedule:
    try:
        return SCHEDULES[name]()
    except KeyError:
        raise ValueError(f"unknown collective schedule {name!r}; choose "
                         f"from {sorted(SCHEDULES)}") from None


def make_plan(net: NetworkParams, topo: TopologyParams,
              work: WorkloadParams) -> SchedulePlan:
    """The plan for ``work.schedule`` on this topology."""
    return get_schedule(work.schedule).plan(net, topo, work)
