"""Point-to-point flow plans and the collective schedules built on them.

The engine used to hardcode one communication pattern: a flat
``2(N-1)``-step ring whose every step moves ``message/N`` bytes between
ring neighbors — even across a multi-pod hierarchy, so the DCI
oversubscription penalty was charged to *every* hop instead of only the
cross-pod exchange.  This module extracts that choice into data: a
:class:`FlowPlan` — the per-round sequence of steps, each step a set of
concurrent flows with ``(src, dst, tier, payload_bytes)`` — that the
engine's vectorized trace loop consumes
(``BatchedEngine._traces_shared`` times one phase block at a time) and
the coupling layer reads for its step→tier map.

A plan's flows are *arbitrary* static point-to-point sets, not just
collective rings: :func:`flow_plan` builds a validated plan from any
phase list (each node sends at most one flow per phase — the engine's
``(step, node)`` tensors scatter by sender column), and a
:class:`CollectiveSchedule` is simply a named factory producing the
degenerate case where every receiver has exactly one sender.  Plans
where several flows share a receiver (``SchedulePhase.fan_in() > 1``)
describe **incast** — e.g. the serve path's many-prefill→few-decode
KV-cache shipping (``serve/traffic.py``) — and the engine overlays
per-receiver contention on exactly those flows (occupancy floor
``1 - 1/fan`` at the receiver port plus ``fan``-way egress
serialization), leaving fan-in-1 plans bit-identical to the
pre-FlowPlan engine.

Steps group into *phases*: contiguous step runs sharing one static flow
pattern and per-step payload, so each phase stays a dense
``(step, flow)`` tensor block and the engine loses none of its
vectorization.  Payload accounting follows the standard ring
reduce-scatter / all-gather arithmetic — an ``N``-peer ring RS (or AG)
of an ``M``-byte message takes ``N-1`` steps of ``M/N`` bytes per flow:

- :class:`RingSchedule` — the flat ring: one phase, ``2(N-1)`` steps of
  ``M/N`` bytes (RS immediately followed by AG over all ``N`` nodes).
  Selecting it reproduces the pre-schedule engine bit-exactly (pinned
  by ``tests/test_schedule.py`` against committed seed stats).
- :class:`HierarchicalSchedule` — the hierarchy-aware plan for
  ``n_pods`` pods of ``m = N / n_pods`` nodes:

  1. ``rs``  — reduce-scatter inside each pod: ``m-1`` steps of ``M/m``
     bytes on the intra-pod ring (tor/spine tiers only);
  2. ``dci`` — pod leaders all-reduce the pod-reduced message over the
     DCI: a ``2(n_pods-1)``-step ring of ``M/n_pods``-byte shards —
     the *only* steps that traverse the oversubscribed uplinks;
  3. ``ag``  — all-gather inside each pod: ``m-1`` steps of ``M/m``.

  Total ``2(m-1) + 2(n_pods-1)`` steps versus the flat ``2(N-1)``; the
  DCI penalty applies to ``2(n_pods-1)`` large-shard steps instead of
  all of them, which is what moves the cross-pod tail (Fig. 5).  At
  ``n_pods=1`` the plan degenerates to the flat ring exactly.
- :class:`PerRailHierarchicalSchedule` — the per-rail variant of the
  hierarchical exchange: instead of funneling the DCI phase through one
  leader per pod, *every* node crosses pods.  Node ``(pod i, rank j)``
  rings over pods with its rank-``j`` peers (``rail j``), exchanging
  ``M/(m * n_pods)``-byte shards over ``2(n_pods-1)`` steps — the same
  DCI step count as the leader exchange, but the cross-pod payload is
  spread over ``m`` concurrent rails, so each DCI flow serializes
  ``m``-fold less per step.  Total bytes per round stay ``2(N-1) M``
  (the conservation invariant all three schedules share).

Per-phase window budgets: every phase carries a ``budget_frac`` weight
(defaulting to its nominal serialization share, ``n_steps x
payload_bytes``, with DCI phases additionally weighted by the mean
oversubscription ratio — the "wait longer where the fabric is slow"
policy).  :meth:`FlowPlan.budget_fracs` normalizes the weights into
the per-phase split of the Celeris round budget that the engine's
``window="phase"`` assembly applies (see ``params.WindowPolicy``).

Select a schedule with ``SimParams.work.schedule`` (``"ring"`` |
``"hier"`` | ``"perrail"``), sweep it with
``BatchedSimParams.schedules``, and train against it with
``CollectiveMode.HIERARCHICAL`` — the trainer's sync order (exact
intra-pod reduce → coded cross-pod exchange) mirrors
:attr:`HierarchicalSchedule.PHASE_ORDER`, asserted in
``train_step.make_train_step``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.transport import topology
from repro.core.transport.params import (NetworkParams, TopologyParams,
                                         WorkloadParams)


@dataclasses.dataclass(frozen=True, eq=False)
class SchedulePhase:
    """A contiguous run of steps sharing one static flow pattern.

    ``payload_bytes`` is per flow per step; a flow's sender column in
    the engine's ``(step, node)`` tensors is its ``src`` node (each
    node sends at most one flow per step in every schedule here).

    ``budget_frac`` is the phase's *un-normalized* weight in the
    per-phase window split (``window="phase"``): ``None`` defaults to
    the nominal serialization share ``n_steps * payload_bytes``;
    schedules set explicit weights where the fabric is slower than the
    payload suggests (the DCI phases weight by oversubscription).

    ``priority`` is the phase's semantic priority class (higher = more
    valuable bytes).  It never changes the physics — the engine times
    flows identically regardless of class — but the window policy's
    ``cut_order="priority"`` mode truncates the lowest class first when
    a budget binds, and the per-class delivered fractions feed the
    coupling layer (``RoundStats.prio_recv_frac``).  Class 0 is the
    default ("cut me first"); the hierarchical schedules put the
    Hadamard-coded DCI shards there and the exact intra-pod shards in
    class 1.
    """
    name: str
    src: np.ndarray            # (n_flows,) sender node per flow
    dst: np.ndarray            # (n_flows,) receiver node per flow
    n_steps: int               # steps of this phase per round
    payload_bytes: int         # bytes per flow per step
    budget_frac: float | None = None   # window-budget weight (un-normalized)
    priority: int = 0          # semantic class (higher = cut later)

    def n_pkts(self, net: NetworkParams) -> int:
        """Packets per flow per step (payload split at the MTU, >= 1)."""
        return max(1, self.payload_bytes // net.mtu_bytes)

    @property
    def budget_weight(self) -> float:
        """Un-normalized per-phase window-budget weight (see class doc)."""
        return (float(self.n_steps * self.payload_bytes)
                if self.budget_frac is None else float(self.budget_frac))

    def fan_in(self) -> np.ndarray:
        """(n_flows,) receivers' concurrent-sender count per flow.

        ``fan_in[i]`` is how many of this phase's flows share flow
        ``i``'s destination.  Every collective schedule here is a
        permutation (one sender per receiver) so the array is all ones;
        values > 1 mark incast flows, which the engine charges with
        per-receiver contention (see module docstring).
        """
        counts = np.bincount(self.dst, minlength=int(self.dst.max()) + 1)
        return counts[self.dst]


@dataclasses.dataclass(frozen=True, eq=False)
class FlowPlan:
    """One round of point-to-point flow phases, resolved for a topology.

    The engine's unit of work: ``phases`` are executed in order every
    round, each contributing ``n_steps`` rows to the round's
    ``(step, flow)`` tensor blocks.  Collective schedules produce
    fan-in-1 plans (``SchedulePlan`` is the historical alias); arbitrary
    plans — incast, parameter-server, all-to-all phase sets — come from
    :func:`flow_plan`.
    """
    schedule: str
    phases: tuple              # of SchedulePhase, in execution order
    steps_per_round: int
    phase_of_step: np.ndarray  # (steps_per_round,) phase index per step
    # Optional per-step priority override (serve plans bucket steps
    # inside one phase); None derives classes from the phases.
    priority_of_step: np.ndarray | None = None

    @property
    def single_phase(self) -> bool:
        return len(self.phases) == 1

    @property
    def phase_names(self) -> tuple:
        """Phase names in execution order — the telemetry layer's track
        labels (``telemetry.TraceRecorder`` / ``trace_export``)."""
        return tuple(ph.name for ph in self.phases)

    def max_fan_in(self) -> int:
        """Largest per-receiver concurrent-sender count over all phases
        (1 for every collective schedule; > 1 marks an incast plan)."""
        return max(int(ph.fan_in().max()) for ph in self.phases)

    def geometries(self, net: NetworkParams, topo: TopologyParams) -> tuple:
        """Per-phase :class:`topology.HierGeometry` (flow→tier maps)."""
        return tuple(topology.hier_geometry(net, topo, src=ph.src,
                                            dst=ph.dst)
                     for ph in self.phases)

    def step_table(self, net: NetworkParams, topo: TopologyParams) -> list:
        """The explicit per-step plan: ``(src, dst, tiers,
        payload_bytes)`` per step, tiers as indexes into
        ``topology.TIERS``.  The engine consumes the phase blocks; this
        flat view is for tests, docs, and the coupling layer's
        step→tier map."""
        rows = []
        for ph, hg in zip(self.phases, self.geometries(net, topo)):
            rows.extend([(ph.src, ph.dst, hg.tiers, ph.payload_bytes)]
                        * ph.n_steps)
        return rows

    def tier_counts(self, net: NetworkParams, topo: TopologyParams,
                    geometries: tuple | None = None) -> np.ndarray:
        """(n_tiers,) flows per tier, summed over phases.  Pass
        ``geometries`` when :meth:`geometries` is already in hand (the
        engine does) to skip recomputing it."""
        gs = geometries if geometries is not None else self.geometries(
            net, topo)
        out = np.zeros(topology.N_TIERS, dtype=int)
        for hg in gs:
            out += hg.tier_counts
        return out

    def tier_pkts_round(self, net: NetworkParams, topo: TopologyParams,
                        geometries: tuple | None = None) -> np.ndarray:
        """(n_tiers,) offered packets per round per tier — the
        schedule's actual per-tier exposure, which weights the
        axis-split drop schedules (``coupling``)."""
        gs = geometries if geometries is not None else self.geometries(
            net, topo)
        out = np.zeros(topology.N_TIERS)
        for ph, hg in zip(self.phases, gs):
            out += hg.tier_counts * (ph.n_pkts(net) * ph.n_steps)
        return out

    def pod_pkts_round(self, net: NetworkParams, topo: TopologyParams,
                       geometries: tuple | None = None) -> np.ndarray:
        """(n_pods,) offered *intra-pod* packets per round per pod —
        the weighting behind the per-pod axis-split drop schedules
        (``coupling.AxisSchedules.per_pod``).  DCI flows belong to the
        cross axis and are excluded."""
        gs = geometries if geometries is not None else self.geometries(
            net, topo)
        out = np.zeros(topo.n_pods)
        for ph, hg in zip(self.phases, gs):
            for p, cols in enumerate(hg.pod_cols):
                out[p] += cols.size * ph.n_pkts(net) * ph.n_steps
        return out

    def budget_fracs(self) -> np.ndarray:
        """(n_phases,) normalized per-phase split of the Celeris round
        budget (``window="phase"``).  Weights are each phase's
        ``budget_weight``; a single-phase plan yields exactly
        ``[1.0]``, so the phase window degenerates to the round window
        bit-for-bit there."""
        w = np.array([ph.budget_weight for ph in self.phases])
        return w / w.sum()

    def step_priority(self) -> np.ndarray:
        """(steps_per_round,) semantic priority class per step.

        Derived from the phases' ``priority`` fields unless the plan
        carries a per-step override (``priority_of_step`` — serve plans
        bucket steps inside a single phase).  Pure assembly-time
        metadata: the engine's ``cut_order="priority"`` window mode and
        the per-class delivered-fraction accounting read it; the
        physics never does.
        """
        if self.priority_of_step is not None:
            return np.asarray(self.priority_of_step, dtype=int)
        return np.repeat(np.array([ph.priority for ph in self.phases],
                                  dtype=int),
                         [ph.n_steps for ph in self.phases])

    def n_priority_classes(self) -> int:
        """Number of priority classes (``max class + 1``; >= 1)."""
        return int(self.step_priority().max()) + 1

    def prio_pkts_round(self, net: NetworkParams) -> np.ndarray:
        """(n_classes,) offered packets per round per priority class —
        the per-class analogue of :meth:`tier_pkts_round`, weighting the
        per-class drop schedules (``coupling``)."""
        cls = self.step_priority()
        out = np.zeros(self.n_priority_classes())
        for ph, rows in zip(self.phases,
                            np.split(np.arange(self.steps_per_round),
                                     np.cumsum([ph.n_steps for ph
                                                in self.phases])[:-1])):
            per_step = ph.src.size * ph.n_pkts(net)
            np.add.at(out, cls[rows], float(per_step))
        return out

    def bytes_per_round(self) -> int:
        """Total bytes offered to the fabric per round (all flows, all
        steps) — the payload-conservation invariant tests pin."""
        return sum(ph.src.size * ph.n_steps * ph.payload_bytes
                   for ph in self.phases)


# Historical alias: collective schedules predate arbitrary flow plans,
# and the engine/coupling layers grew up on this name.
SchedulePlan = FlowPlan


def _mk_plan(name: str, phases) -> FlowPlan:
    phases = tuple(ph for ph in phases if ph.n_steps > 0)
    steps = sum(ph.n_steps for ph in phases)
    phase_of_step = np.repeat(np.arange(len(phases)),
                              [ph.n_steps for ph in phases])
    return FlowPlan(schedule=name, phases=phases, steps_per_round=steps,
                    phase_of_step=phase_of_step)


def flow_plan(name: str, phases) -> FlowPlan:
    """Build a validated :class:`FlowPlan` from arbitrary static phases.

    The engine's contract per phase: ``src``/``dst`` same length, no
    self-flows, and **unique senders** — the ``(step, node)`` tensors
    have one column per node, so a node may drive at most one flow per
    step.  Receivers may repeat freely (that is what makes a plan an
    incast plan).  Empty phases (``n_steps == 0``) are dropped, matching
    the collective factories.
    """
    for ph in phases:
        if ph.n_steps == 0:
            continue                    # dropped by _mk_plan below
        src, dst = np.asarray(ph.src), np.asarray(ph.dst)
        if src.shape != dst.shape or src.ndim != 1 or src.size == 0:
            raise ValueError(
                f"phase {ph.name!r}: src/dst must be equal-length 1-D "
                f"non-empty arrays, got {src.shape} vs {dst.shape}")
        if np.unique(src).size != src.size:
            raise ValueError(
                f"phase {ph.name!r}: duplicate senders — each node "
                "drives at most one flow per step (the engine's "
                "(step, node) tensors scatter by sender column)")
        if (src == dst).any():
            raise ValueError(f"phase {ph.name!r}: self-flows (src == dst)")
        if ph.payload_bytes < 1:
            raise ValueError(
                f"phase {ph.name!r}: payload_bytes must be >= 1")
        if ph.priority < 0:
            raise ValueError(
                f"phase {ph.name!r}: priority class must be >= 0")
    plan = _mk_plan(name, phases)
    if not plan.phases:
        raise ValueError("flow plan has no non-empty phases")
    return plan


def with_step_priorities(plan: FlowPlan, priority_of_step) -> FlowPlan:
    """Return ``plan`` with a validated per-step priority override.

    Serve plans bucket steps *inside* one phase (e.g. head-of-cache KV
    blocks above tail blocks), which phase-level ``priority`` fields
    can't express.  The override is pure assembly-time metadata —
    engine timing and the plan's phases are untouched, so bit-pinned
    stats cannot move.
    """
    cls = np.asarray(priority_of_step, dtype=int)
    if cls.shape != (plan.steps_per_round,):
        raise ValueError(
            f"priority_of_step must have shape ({plan.steps_per_round},), "
            f"got {cls.shape}")
    if (cls < 0).any():
        raise ValueError("priority classes must be >= 0")
    return dataclasses.replace(plan, priority_of_step=cls)


def layer_priorities(plan: FlowPlan, top_frac: float = 0.5) -> np.ndarray:
    """Layer-depth priority classes for a hierarchical training plan.

    Training semantics on top of the phase classes: the trailing
    ``top_frac`` of the final all-gather phase carries the early-layer
    exact shards the *next* forward pass consumes first (the
    priority-based parameter-propagation observation), so those steps
    are promoted to a new top class above every phase priority.  The
    result is e.g. ``dci=0 < rs/early-ag=1 < late-ag=2``: the bounded
    window then cuts coded DCI bytes first, early-ag exact shards
    next, and the forward-critical shards last — the exact inverse of
    the arrival cut, which truncates the round from the end and kills
    the forward-critical shards *first*.  Plans without an all-gather
    phase (flat ring, serve) come back unchanged.  Feed the result to
    :func:`with_step_priorities`.
    """
    cls = plan.step_priority().copy()
    pos = np.asarray(plan.phase_of_step)
    is_ag = np.array([plan.phases[k].name.startswith("ag") for k in pos])
    ag_steps = np.where(is_ag)[0]
    n_top = int(round(ag_steps.size * top_frac))
    if n_top:
        cls[ag_steps[ag_steps.size - n_top:]] = cls.max() + 1
    return cls


class CollectiveSchedule:
    """Produces the per-step flow plan the engine times."""

    name: str = "?"

    def plan(self, net: NetworkParams, topo: TopologyParams,
             work: WorkloadParams) -> SchedulePlan:
        raise NotImplementedError


class RingSchedule(CollectiveSchedule):
    """Flat ring RS+AG over all nodes: ``2(N-1)`` steps of ``M/N``
    bytes.  Bit-exact replica of the pre-schedule engine."""

    name = "ring"

    def plan(self, net, topo, work):
        n = net.n_nodes
        src = np.arange(n)
        ring = SchedulePhase(name="ring", src=src, dst=(src + 1) % n,
                             n_steps=2 * (n - 1),
                             payload_bytes=work.message_bytes // n)
        return _mk_plan(self.name, (ring,))


def _mean_oversub(topo: TopologyParams) -> float:
    """Mean DCI oversubscription ratio (per-pod vectors average) — the
    nominal slowdown a DCI phase's window-budget weight carries."""
    return float(np.mean(topology.per_pod_array(
        topo.dci_oversubscription, topo.n_pods, "dci_oversubscription")))


def _nominal_us(net: NetworkParams, n_steps: int, payload_bytes: int,
                extra_rtt_us: float = 0.0, slowdown: float = 1.0) -> float:
    """Nominal unloaded phase time: per-step serialization (scaled by
    the tier's bandwidth slowdown) plus the half-RTT latency floor,
    summed over steps.  The hierarchical schedules use this as the
    per-phase window-budget weight — a latency-aware proxy, so a DCI
    phase whose cost is RTT- rather than payload-dominated (per-rail
    small shards) still gets a budget share matching its real floor."""
    return n_steps * (payload_bytes / net.link_bytes_per_us * slowdown
                      + net.base_rtt_us / 2 + extra_rtt_us)


class HierarchicalSchedule(CollectiveSchedule):
    """Reduce-scatter within pod → leader DCI exchange → all-gather
    within pod (see module docstring for the step/payload accounting)."""

    name = "hier"
    # Execution order of the phases; the trainer's HIERARCHICAL sync
    # (exact intra-pod reduce first, coded cross-pod exchange second)
    # asserts against this so schedule and collective mode can't drift
    # apart silently.
    PHASE_ORDER = ("rs", "dci", "ag")
    # Semantic priority classes: the DCI shards ride the Hadamard code
    # (losses are recoverable — "coded/low-value bytes"), the intra-pod
    # rs/ag shards are exact.  cut_order="priority" therefore truncates
    # DCI bytes first when a window budget binds; the trainer's
    # HIERARCHICAL sync asserts the coded phase is the lowest class
    # (train_step.make_train_step), mirroring that it masks only
    # cross-pod shards.
    PRIORITY = {"rs": 1, "dci": 0, "ag": 1}

    def _dci_phase(self, net, topo, work, m: int) -> SchedulePhase:
        """The leader exchange: one flow per pod, ``M/n_pods`` shards."""
        n_pods = topo.n_pods
        leaders = np.arange(n_pods) * m
        return SchedulePhase(
            name="dci", src=leaders,
            dst=((np.arange(n_pods) + 1) % n_pods) * m,
            n_steps=2 * (n_pods - 1),
            payload_bytes=work.message_bytes // n_pods)

    def plan(self, net, topo, work):
        topology.validate(net, topo)
        n, n_pods = net.n_nodes, topo.n_pods
        if n_pods == 1:
            # degenerate hierarchy: the plan IS the flat ring (single
            # phase, so it stays bit-exact with RingSchedule too)
            return dataclasses.replace(RingSchedule().plan(net, topo, work),
                                       schedule=self.name)
        m = n // n_pods
        src = np.arange(n)
        pod = src // m
        nxt = pod * m + (src - pod * m + 1) % m     # intra-pod ring
        dci = self._dci_phase(net, topo, work, m)
        # per-phase budget weights: nominal unloaded phase time, with
        # the DCI phase paying the oversubscription slowdown and the
        # extra DCI propagation — per-phase windows wait longer where
        # the fabric is slower (the Celeris tail policy, applied per
        # tier instead of per round)
        rs = SchedulePhase(name="rs", src=src, dst=nxt, n_steps=m - 1,
                           payload_bytes=work.message_bytes // m)
        intra_w = _nominal_us(net, rs.n_steps, rs.payload_bytes)
        dci_w = _nominal_us(net, dci.n_steps, dci.payload_bytes,
                            extra_rtt_us=topo.dci_rtt_us / 2,
                            slowdown=_mean_oversub(topo))
        phases = (
            dataclasses.replace(rs, budget_frac=intra_w,
                                priority=self.PRIORITY["rs"]),
            dataclasses.replace(dci, budget_frac=dci_w,
                                priority=self.PRIORITY["dci"]),
            dataclasses.replace(rs, name="ag", budget_frac=intra_w,
                                priority=self.PRIORITY["ag"]),
        )
        assert tuple(ph.name for ph in phases) == self.PHASE_ORDER
        return _mk_plan(self.name, phases)


class PerRailHierarchicalSchedule(HierarchicalSchedule):
    """Hierarchical exchange with *every* node crossing pods.

    The DCI phase replaces the ``n_pods`` leader flows with all
    ``N = m * n_pods`` nodes: node ``(pod i, rank j)`` rings over pods
    along its rail ``j`` (dst = same rank, next pod), moving
    ``M/(m * n_pods)``-byte shards for ``2(n_pods-1)`` steps.  Per-step
    DCI serialization drops ``m``-fold versus the leader exchange
    (same aggregate bytes spread over ``m`` concurrent rails), at the
    cost of ``m``-fold more flows contending for each pod's uplink.
    ``rs``/``ag`` phases, step count, and total bytes per round are
    identical to :class:`HierarchicalSchedule`.
    """

    name = "perrail"

    def _dci_phase(self, net, topo, work, m: int) -> SchedulePhase:
        n_pods = topo.n_pods
        src = np.arange(m * n_pods)
        pod, rank = src // m, src % m
        return SchedulePhase(
            name="dci", src=src,
            dst=((pod + 1) % n_pods) * m + rank,
            n_steps=2 * (n_pods - 1),
            payload_bytes=work.message_bytes // (m * n_pods))


SCHEDULES = {cls.name: cls for cls in (RingSchedule, HierarchicalSchedule,
                                       PerRailHierarchicalSchedule)}


def get_schedule(name: str) -> CollectiveSchedule:
    try:
        return SCHEDULES[name]()
    except KeyError:
        raise ValueError(f"unknown collective schedule {name!r}; choose "
                         f"from {sorted(SCHEDULES)}") from None


def make_plan(net: NetworkParams, topo: TopologyParams,
              work: WorkloadParams) -> SchedulePlan:
    """The plan for ``work.schedule`` on this topology."""
    return get_schedule(work.schedule).plan(net, topo, work)
