"""Transport→trainer coupling (closes the paper's loop).

The transport engine (:mod:`repro.core.transport.engine`) produces
per-round delivered fractions under bounded Celeris windows; the lossy
collectives (:mod:`repro.core.lossy_collectives`) and the trainer's
gradient sync consume a per-step ``drop_rate``.  Until now those ends
were hand-fed constants.  This module is the bridge:

- :class:`DropSchedule` — a per-step drop-probability trace with
  provenance, consumed one step at a time by the trainer;
- :func:`schedule_from_round_stats` — engine ``RoundStats`` → schedule
  (drop = 1 - delivered fraction per round; one AllReduce round maps to
  one train step);
- :func:`schedule_from_engine` — run the engine at a given scale /
  window tightness and return the resulting schedule (the paper-Fig.-1
  drop regimes are different ``timeout_scale`` settings of one knob);
- :func:`closed_form_schedule` / :class:`LatencyTail` — the closed-form
  lognormal-tail alternative, P(chunk latency > window), matching the
  trainer's standalone straggler model with bursts disabled;
- :class:`EngineStragglerModel` — adapts a schedule to the Trainer's
  ``straggler.drop_rate(timeout, rng)`` interface (duck-typed so core
  never imports train);
- :class:`CollectiveMode` — the exact | lossy | lossy+hadamard |
  hierarchical switch the train step dispatches on.

Hierarchical (multi-pod) coupling: the engine's per-tier delivered
fractions (:mod:`repro.core.transport.topology`) split into
:class:`AxisSchedules` — one :class:`DropSchedule` for the intra-pod
axis (ToR + spine tiers) and one for the cross-pod DCI axis — via
:func:`split_schedule_from_round_stats` / :func:`split_schedule_from_engine`;
:class:`HierStragglerModel` walks the pair and feeds the trainer a
``(2,)`` drop vector per step (``[intra, cross]``).

When the engine tracked *per-pod* delivered fractions
(``RoundStats.pod_recv_frac``, any multi-pod shared-fabric run), the
split refines into a per-pod vector: ``AxisSchedules.per_pod`` holds
one intra :class:`DropSchedule` per pod and ``rates(step)`` returns
``(n_pods + 1,)`` — ``[intra_pod0, ..., intra_podK, cross]`` — which
the hierarchical train step consumes per pod (each pod's DCI
contribution rides its own pod fabric first, so its arrival mask
combines its pod's intra rate with the shared cross rate).  The
2-element ``[intra, cross]`` form remains for flat aggregates and
older stats.
"""
from __future__ import annotations

import dataclasses
import enum
from math import erf, sqrt

import numpy as np

from repro.core.transport import telemetry, topology
from repro.core.transport.engine import BatchedEngine, RoundStats
from repro.core.transport.params import SimParams


class CollectiveMode(enum.Enum):
    """Gradient-sync collective flavor for the train step.

    - ``EXACT``: lossless all-reduce (RoCE-like semantics, the baseline);
    - ``LOSSY``: best-effort without coding — the receiver's bounded
      window truncates the payload, so a wire row that misses it is a
      hole in the raw gradient (lost from every peer at once, no
      rescaling; see ``train_step._mask_grads_plain``);
    - ``LOSSY_HADAMARD``: best-effort + randomized-Hadamard coding, the
      paper's §III-B recovery path — per-(peer, wire-row) arrival
      masks with count-unbiased decode, unbiased even through holes;
    - ``HIERARCHICAL``: topology-aware split on a multi-pod mesh —
      intra-pod gradient sync is exact (the fat in-pod fabric is
      effectively lossless), and only the cross-pod ('pod' axis)
      reduction takes the best-effort + Hadamard path at the DCI
      tier's drop rate (``drop_rate[-1]`` of the per-axis vector).
    """
    EXACT = "exact"
    LOSSY = "lossy"
    LOSSY_HADAMARD = "lossy_hadamard"
    HIERARCHICAL = "hierarchical"

    @classmethod
    def parse(cls, mode: "CollectiveMode | str") -> "CollectiveMode":
        if isinstance(mode, cls):
            return mode
        key = str(mode).lower().replace("+", "_").replace("-", "_")
        for m in cls:
            if m.value == key:
                return m
        raise ValueError(f"unknown collective mode {mode!r}; choose from "
                         f"{[m.value for m in cls]}")

    @property
    def lossy(self) -> bool:
        return self is not CollectiveMode.EXACT

    @property
    def coded(self) -> bool:
        return self in (CollectiveMode.LOSSY_HADAMARD,
                        CollectiveMode.HIERARCHICAL)

    @property
    def hierarchical(self) -> bool:
        return self is CollectiveMode.HIERARCHICAL


# ----------------------------------------------------------------------
# Drop schedules
# ----------------------------------------------------------------------

# The collectives emulate loss at wire-chunk granularity; a drop rate
# past ~0.5 means the window is mis-tuned, not a tail event, and the
# unbias factors blow up variance — clamp like the trainer's model does.
MAX_DROP = 0.5


@dataclasses.dataclass(frozen=True)
class DropSchedule:
    """Per-train-step drop probabilities with provenance.

    ``rates[i]`` is the drop probability for train step i; steps past
    the end wrap around (an engine trace is a stationary sample of the
    fabric, so tiling it is the natural extension).

    ``provenance`` (a :class:`telemetry.DropProvenance`, when the
    schedule came from engine stats) attributes each step's dropped
    fraction to its originating (tier, cause, phase): exact when the
    engine ran with a :class:`telemetry.TraceRecorder`, heuristic
    (fault-exposed rounds → "fault", remainder → the design's natural
    loss mode) otherwise.  Provenance keeps the *unclipped* physical
    split; ``rates`` stays clamped to ``MAX_DROP`` as before.
    """
    rates: np.ndarray
    source: str = "constant"
    provenance: "telemetry.DropProvenance | None" = None

    def __post_init__(self):
        object.__setattr__(
            self, "rates",
            np.clip(np.asarray(self.rates, dtype=np.float64).reshape(-1),
                    0.0, MAX_DROP))
        if self.rates.size == 0:
            raise ValueError("empty drop schedule")

    def rate(self, step: int) -> float:
        return float(self.rates[step % self.rates.size])

    @property
    def mean(self) -> float:
        return float(self.rates.mean())

    @property
    def p99(self) -> float:
        return float(np.percentile(self.rates, 99))

    @classmethod
    def constant(cls, p: float, n_steps: int = 1) -> "DropSchedule":
        return cls(rates=np.full(n_steps, p), source=f"constant({p})")


def schedule_from_round_stats(stats: RoundStats, *,
                              source: str | None = None,
                              record: "telemetry.DesignRecord | None" = None
                              ) -> DropSchedule:
    """Engine round statistics → per-step schedule (round i ≡ step i).

    Pass the matching :class:`telemetry.DesignRecord` (from the
    recorder the engine ran with) for exact per-cause provenance on the
    schedule; without it a coarse heuristic attribution is attached.
    """
    prov = (telemetry.provenance_from_record(record, "flat")
            if record is not None
            else telemetry.provenance_heuristic(stats, "flat"))
    return DropSchedule(
        rates=1.0 - np.asarray(stats.recv_frac, dtype=np.float64),
        source=source or f"engine:{stats.design}", provenance=prov)


def schedule_from_engine(n_rounds: int, seed: int = 0, *,
                         params: SimParams | None = None,
                         n_nodes: int | None = None,
                         message_mb: float | None = None,
                         design: str = "celeris",
                         timeout_scale: float = 1.0,
                         adaptive: bool = False,
                         window: str = "round",
                         legacy_streams: bool = False,
                         record: bool = False) -> DropSchedule:
    """Run the transport engine and derive the drop schedule it implies.

    The Celeris window follows the paper protocol — fixed at the RoCE
    baseline's median + 1 sigma on the *same* fabric trace — scaled by
    ``timeout_scale``: 1.0 is the paper's Fig.-1 operating point (~1%
    loss at 128 nodes), smaller values tighten the window into the
    heavier drop regimes, larger values relax it.  ``adaptive=True``
    runs the per-round timeout controller (EWMA + cluster median)
    instead of the fixed window.

    Lossless designs ("roce", "irn", "srnic") yield all-zero schedules —
    useful as the exact-collective control.

    ``record=True`` runs the engine with a ``telemetry.TraceRecorder``
    (shared-fabric mode required, the default here) so the returned
    schedule's ``provenance`` carries the exact per-(tier, cause,
    phase) attribution instead of the stats-level heuristic.
    """
    p = params or SimParams()
    if n_nodes is not None:
        p = dataclasses.replace(
            p, net=dataclasses.replace(p.net, n_nodes=n_nodes))
    if message_mb is not None:
        p = dataclasses.replace(
            p, work=dataclasses.replace(p.work,
                                        message_bytes=int(message_mb * 2**20)))
    rec = telemetry.TraceRecorder() if record else None
    eng = BatchedEngine(p, recorder=rec)
    designs_needed = [design] if design != "celeris" else ["roce", "celeris"]
    tr = eng.traces(designs_needed, n_rounds, seed,
                    legacy_streams=legacy_streams and not record)
    if design != "celeris":
        stats = eng.assemble(tr[design], seed)
    else:
        base = eng.assemble(tr["roce"], seed)
        to = float((np.percentile(base.times_us, 50) + base.times_us.std())
                   * timeout_scale)
        stats = eng.assemble(tr["celeris"], seed, celeris_timeout_us=to,
                             adaptive=adaptive, window=window)
    tag = (f"engine:{design} n={p.net.n_nodes} seed={seed} "
           f"scale={timeout_scale}" + (" adaptive" if adaptive else ""))
    return schedule_from_round_stats(
        stats, source=tag,
        record=rec.record(design) if rec is not None else None)


# ----------------------------------------------------------------------
# Axis-split schedules (hierarchical multi-pod topologies)
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AxisSchedules:
    """Per-mesh-axis drop schedules for a hierarchical topology.

    ``intra`` covers the in-pod fabric (ToR + spine tiers combined,
    weighted by the plan's per-tier packet exposure); ``cross`` covers
    the DCI tier.  When the engine tracked per-pod fractions,
    ``per_pod`` refines ``intra`` into one schedule per pod and
    :meth:`rates` returns the ``(n_pods + 1,)`` vector
    ``[intra_pod0, ..., intra_podK, cross]``; otherwise the ``(2,)``
    ``[intra, cross]`` form.  Either way the *cross* component is the
    last element — the convention the hierarchical train step and the
    MoE loss coin key on.  The trainer consumes the vector per step
    through :class:`HierStragglerModel`.
    """
    intra: DropSchedule
    cross: DropSchedule
    per_pod: tuple | None = None       # of DropSchedule, one per pod
    source: str = ""

    @property
    def n_pods(self) -> int | None:
        return None if self.per_pod is None else len(self.per_pod)

    def rates(self, step: int) -> np.ndarray:
        if self.per_pod is not None:
            return np.array([s.rate(step) for s in self.per_pod]
                            + [self.cross.rate(step)])
        return np.array([self.intra.rate(step), self.cross.rate(step)])

    # schedule-walk interface shared with DropSchedule, so the straggler
    # adapters can hold either flavor
    rate = rates

    @property
    def mean(self) -> tuple[float, float]:
        return (self.intra.mean, self.cross.mean)


def split_schedule_from_round_stats(stats: RoundStats, *,
                                    source: str | None = None,
                                    record: "telemetry.DesignRecord | None"
                                    = None) -> AxisSchedules:
    """Engine per-tier round statistics → axis-split schedules.

    Tier fractions (topology.TIERS order: tor, spine, dci) combine into
    the intra axis weighted by the collective schedule's actual
    per-tier exposure — ``stats.tier_pkts``, the offered packets per
    round per tier from the schedule plan's step→tier map (steps ×
    flows × packets), so e.g. a hierarchical plan's two all-node intra
    phases weigh tor/spine by what they really carried.  Older stats
    without ``tier_pkts`` fall back to the static flow-count heuristic.
    Empty tiers contribute nothing (their fraction is reported as 1).

    When the stats carry per-pod fractions (``pod_recv_frac``, any
    multi-pod engine assembly) the returned schedules also carry
    ``per_pod`` — one intra schedule per pod, whose
    ``pod_pkts``-weighted mean recombines to the aggregate intra rate
    exactly (same delivered packets, regrouped by pod instead of by
    tier).

    The intra and cross schedules carry :class:`telemetry
    .DropProvenance` — exact per-(tier, cause, phase) when ``record``
    (the engine run's :class:`telemetry.DesignRecord`) is given,
    heuristic otherwise.  Per-pod schedules share the intra axis's
    heuristic tag only (pod-resolved cause attribution is not tracked).
    """
    if stats.tier_recv_frac is None or stats.tier_counts is None:
        raise ValueError(
            "RoundStats lacks per-tier fractions — build it through "
            "BatchedEngine.assemble (stream-replay / reference paths "
            "don't track tiers)")
    f = np.asarray(stats.tier_recv_frac, dtype=np.float64)
    w = np.asarray(stats.tier_pkts if stats.tier_pkts is not None
                   else stats.tier_counts, dtype=np.float64)
    w_intra = w[:2].sum()
    if w_intra > 0:
        intra = 1.0 - (f[:, :2] * w[:2]).sum(axis=1) / w_intra
    else:
        intra = np.zeros(f.shape[0])
    cross = (1.0 - f[:, 2]) if w[2] > 0 else np.zeros(f.shape[0])
    tag = source or f"engine:{stats.design}"
    if record is not None:
        prov_i = telemetry.provenance_from_record(record, "intra")
        prov_c = telemetry.provenance_from_record(record, "cross")
    else:
        prov_i = telemetry.provenance_heuristic(stats, "intra")
        prov_c = telemetry.provenance_heuristic(stats, "cross")
    per_pod = None
    if stats.pod_recv_frac is not None:
        pf = np.asarray(stats.pod_recv_frac, dtype=np.float64)
        per_pod = tuple(
            DropSchedule(rates=1.0 - pf[:, p], source=f"{tag}:pod{p}")
            for p in range(pf.shape[1]))
    return AxisSchedules(
        intra=DropSchedule(rates=intra, source=tag + ":intra",
                           provenance=prov_i),
        cross=DropSchedule(rates=cross, source=tag + ":cross",
                           provenance=prov_c),
        per_pod=per_pod, source=tag)


def split_schedule_from_engine(n_rounds: int, seed: int = 0, *,
                               params: SimParams | None = None,
                               n_pods: int = 2,
                               n_nodes: int | None = None,
                               dci_oversubscription: "float | tuple | None"
                               = None,
                               schedule: str | None = None,
                               window: str = "round",
                               timeout_scale: float = 1.0,
                               fault=None,
                               record: bool = False) -> AxisSchedules:
    """Run the hierarchical engine and derive the axis-split schedule.

    Same window rule as :func:`schedule_from_engine` (RoCE baseline on
    the same fabric fixes the Celeris window at median + 1 sigma,
    scaled), but on the multi-pod fabric, so the returned pair reflects
    where in the hierarchy the loss actually happened.  ``schedule``
    selects the collective schedule riding that fabric ("ring" |
    "hier" | "perrail"): the hierarchical plans' cross axis reflects
    the DCI exchange's shards rather than per-hop ring slices.
    ``window`` selects the Celeris budget policy ("round" | "phase") —
    with "phase" the per-pod/per-tier loss reflects each phase block's
    own deadline.  The result always carries ``per_pod`` schedules
    (multi-pod engine runs track per-pod fractions).  ``fault`` takes an
    optional :class:`~repro.core.transport.params.FaultParams` (or its
    ``kind:rate`` string form): the faulted run's per-pod loss then
    charges the faulted pods' drop masks in hierarchical train steps —
    the end-to-end path of the fig7 resilience experiment.
    ``record=True`` attaches exact per-(tier, cause, phase)
    provenance from a ``telemetry.TraceRecorder`` run.
    """
    p = topology.hier_params(n_pods, base=params, n_nodes=n_nodes,
                             dci_oversubscription=dci_oversubscription,
                             schedule=schedule, fault=fault)
    rec = telemetry.TraceRecorder() if record else None
    stats = topology.hier_protocol(p, n_rounds, seed, window=window,
                                   timeout_scale=timeout_scale,
                                   recorder=rec)["celeris"]
    tag = (f"engine:celeris n={p.net.n_nodes} pods={n_pods} "
           f"sched={p.work.schedule} window={window} seed={seed} "
           f"scale={timeout_scale} fault={p.fault.tag}")
    return split_schedule_from_round_stats(
        stats, source=tag,
        record=rec.record("celeris") if rec is not None else None)



# ----------------------------------------------------------------------
# Priority-class split schedules (semantic-aware window cuts)
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PrioritySchedules:
    """Per-priority-class drop schedules from a prioritized engine run.

    ``classes[c]`` is the :class:`DropSchedule` for priority class
    ``c`` (class 0 = lowest = cut first; see
    ``schedule.SchedulePhase.priority``), ``pkts[c]`` its offered
    packets per round.  Under ``cut_order="priority"`` the low classes
    soak up the window cut, so the trainer masks only the low-priority
    shards: on the hierarchical plans class 0 *is* the Hadamard-coded
    DCI exchange (``HierarchicalSchedule.PRIORITY``), i.e. ``low``
    aligns with :class:`AxisSchedules`' ``cross`` axis and the exact
    intra-pod shards in ``high`` ride untouched — the int8-low /
    f32-high ``quantize_wire`` composition in the hierarchical train
    step.  ``rates(step)`` returns the ``(n_classes,)`` vector, low
    class first.
    """
    classes: tuple                      # of DropSchedule, index = class
    pkts: np.ndarray                    # (n_classes,) offered pkts/round
    source: str = ""

    @property
    def n_classes(self) -> int:
        return len(self.classes)

    @property
    def low(self) -> DropSchedule:
        """The cut-first class (coded / recoverable bytes)."""
        return self.classes[0]

    @property
    def high(self) -> DropSchedule:
        """The cut-last class (exact / high-value bytes)."""
        return self.classes[-1]

    def rates(self, step: int) -> np.ndarray:
        return np.array([s.rate(step) for s in self.classes])

    # schedule-walk interface shared with DropSchedule/AxisSchedules
    rate = rates

    @property
    def mean(self) -> tuple:
        return tuple(s.mean for s in self.classes)


def priority_schedules_from_round_stats(stats: RoundStats, *,
                                        source: str | None = None
                                        ) -> PrioritySchedules:
    """Engine per-class round statistics → per-priority-class schedules.

    Requires the stats to carry per-class fractions
    (``RoundStats.prio_recv_frac`` / ``prio_pkts`` — any
    ``BatchedEngine.assemble`` of a plan-built trace, either
    ``cut_order``); raises otherwise.  Classes with no offered packets
    get all-zero schedules (nothing to drop).  Unlike the tier/axis
    split, per-class drop is *semantic*: under ``cut_order="priority"``
    the class-0 schedule absorbs the budget pressure and the top class
    stays near zero, which is exactly what the trainer's masking
    consumes (mask coded shards, keep exact shards).
    """
    if stats.prio_recv_frac is None or stats.prio_pkts is None:
        raise ValueError(
            "RoundStats lacks per-priority-class fractions — build it "
            "through BatchedEngine.assemble on a plan-built trace "
            "(stream-replay / reference paths don't track priority "
            "classes)")
    f = np.asarray(stats.prio_recv_frac, dtype=np.float64)
    pk = np.asarray(stats.prio_pkts, dtype=np.float64)
    tag = source or f"engine:{stats.design}"
    classes = tuple(
        DropSchedule(rates=(1.0 - f[:, c]) if pk[c] > 0
                     else np.zeros(f.shape[0]),
                     source=f"{tag}:prio{c}")
        for c in range(f.shape[1]))
    return PrioritySchedules(classes=classes, pkts=pk, source=tag)


# ----------------------------------------------------------------------
# Closed-form alternative (no engine run needed)
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LatencyTail:
    """Lognormal per-chunk latency tail, the transport model's contention
    shape.  Identical math to the trainer's standalone
    ``StragglerModel`` with bursts disabled — the coupling test pins the
    two against each other."""
    median_latency: float = 1.0       # in units of clean step time
    sigma: float = 0.6

    def drop_rate(self, timeout: float) -> float:
        """P(latency > timeout) under lognormal(ln median, sigma)."""
        z = ((np.log(max(float(timeout), 1e-9))
              - np.log(self.median_latency)) / self.sigma)
        p_late = 0.5 * (1.0 - erf(z / sqrt(2.0)))
        return float(np.clip(p_late, 0.0, MAX_DROP))


def closed_form_schedule(timeouts, model: LatencyTail | None = None
                         ) -> DropSchedule:
    """Per-step drop from a timeout trace (e.g. the controller's
    adopted windows), without running the engine."""
    m = model or LatencyTail()
    rates = np.array([m.drop_rate(t) for t in np.atleast_1d(timeouts)])
    return DropSchedule(rates=rates, source="closed_form")


# ----------------------------------------------------------------------
# Trainer adapter
# ----------------------------------------------------------------------

class EngineStragglerModel:
    """Feed an engine-derived schedule into the Trainer.

    Duck-typed replacement for ``repro.train.trainer.StragglerModel``:
    the trainer calls ``drop_rate(timeout, rng)`` once per train step,
    which walks the schedule in order (wrapping).  ``timeout``/``rng``
    are accepted for interface parity but unused — the engine already
    resolved the window when the schedule was built.
    """

    def __init__(self, schedule: DropSchedule, median_latency: float = 1.0):
        self.schedule = schedule
        self.steps_taken = 0
        # the trainer's bounded-window emulation reads this to model the
        # clean per-step latency (units of clean step time)
        self.median_latency = median_latency

    def drop_rate(self, timeout: float, rng) -> "float | np.ndarray":
        p = self.schedule.rate(self.steps_taken)
        self.steps_taken += 1
        return p


class HierStragglerModel(EngineStragglerModel):
    """Feed an axis-split schedule pair into the Trainer.

    Same schedule walk as :class:`EngineStragglerModel` (the
    ``schedule.rate(step)`` interface is shared by
    :class:`DropSchedule` and :class:`AxisSchedules`), but holding an
    :class:`AxisSchedules`, so ``drop_rate`` returns the per-axis
    vector the hierarchical train step consumes: ``(n_pods + 1,)``
    ``[intra_pod0, ..., intra_podK, cross]`` when the stats tracked
    per-pod fractions, else the ``(2,)`` ``[intra, cross]`` aggregate.
    The cross (DCI) component is the last element in both forms.
    """

    @property
    def schedules(self) -> AxisSchedules:
        return self.schedule


# ----------------------------------------------------------------------
# Serve-path coupling: delivered KV fractions -> per-request hole masks
# ----------------------------------------------------------------------

def kv_hole_masks(kv_frac: np.ndarray, n_rot: int, seed: int = 0
                  ) -> np.ndarray:
    """Seeded per-request wire-row arrival masks for KV-cache shipping.

    The serve path's analogue of :func:`schedule_from_engine`: where
    training turns delivered fractions into per-step drop schedules,
    serving turns each request's delivered KV fraction (from
    ``serve.traffic.simulate_serving`` — the block-weighted mean of the
    engine's ``recv_frac`` over the rounds that shipped it) into a
    ``(n_req, n_rot)`` boolean mask over wire rows.  Row ``j`` arriving
    means coordinate ``j`` of every Hadamard rotation block survived
    the window (``core.coding``'s wire layout); losing it uncoded
    means a hole in every block at that coordinate, while the coded
    path unbiases over the surviving rows
    (``serve_step.degrade_caches`` applies both).

    Masks are Bernoulli(kv_frac) per row on the seeded
    ``serve.traffic.STREAM_KV_HOLES`` substream — independent rows, the
    same loss model the trainer's lossy modes assume per step.
    Requests with ``kv_frac == 1`` get all-true masks (bit-safe: the
    draw is still consumed, keeping masks per-request reproducible
    regardless of which other requests were cut).
    """
    from repro.serve import traffic as _traffic   # cycle-free late import
    kv_frac = np.asarray(kv_frac, dtype=float)
    rng = np.random.default_rng([seed, _traffic.STREAM_KV_HOLES])
    u = rng.random((kv_frac.size, n_rot))
    return u < kv_frac[:, None]
