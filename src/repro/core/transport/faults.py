"""Seeded fault injection for the batched transport engine (ISSUE 6).

The paper's third headline — Celeris "nearly doubles NIC resilience to
faults" — needs failures the contention model never produces: NICs that
stop delivering entirely, links that go dark, rails that drop out of the
cross-pod exchange.  This module materializes the
:class:`~repro.core.transport.params.FaultParams` processes as
per-``(step, node)`` / per-tier availability masks inside the engine's
whole-trace vectorized loop — no Python step loops, flat memory (masks
are built per round block and carried across block boundaries through
:class:`FaultState`, exactly like the fabric burst state).

Fault streams live in their own substream range (140+), disjoint from
the engine (101-120) and DCI (130-131) streams, and are **only drawn
when the corresponding rate is nonzero** — with ``FaultParams()``
(the default) no generator is even constructed, so every pre-fault
seeded trace stays bit-identical (pinned by ``tests/test_faults.py``).

Vectorized process algebra
--------------------------
- **Stall / crash-with-restart** (duration-``k`` outages): per-block
  Bernoulli start draws resolve to "steps since the last start" via a
  running-max scan (``np.maximum.accumulate`` over ``where(start,
  t, -inf)``), carried across blocks by keeping the last start index per
  node — a node is down while ``t - last_start < k``.
- **Permanent crash**: the same scan with infinite duration (down while
  ``last_start >= 0``).
- **Link flap**: a 2-state Markov on/off chain per ToR uplink (and per
  DCI uplink on multi-pod fabrics), resolved in closed form by the same
  last-constant-map + swap-parity composition the background burst
  process uses (:func:`network._markov_burst`).
- **Rail failure**: one Bernoulli draw per round; the affected flows
  are the cross-pod (dci-tier) flows whose sender rank equals the
  failed rail.  ``hier``'s leader exchange runs entirely on rank 0, so
  a rail-0 failure takes out the whole DCI phase; ``perrail`` loses
  1/m of its rails — the blast-radius asymmetry
  ``tests/test_faults.py`` pins.
- **Slow-NIC straggler**: a static seeded node subset whose effective
  send rate is scaled by ``1/straggler_slowdown`` — a rate degradation,
  not an availability event, so it shapes completion times for every
  design but is not counted in ``fault_flows``.

Design reactions (:func:`apply_to_result`)
------------------------------------------
A *blocked* flow (stall / flap / rail: nothing moves for the step, but
the data still exists) wedges the reliable designs: no packets arrive,
so no NACKs are generated and the outage is detected by timeout — RoCE
at the full RTO, IRN/SRNIC at the low RTO — after which the chunk is
resent from scratch (go-back-N and a fully-idle selective-repeat window
degenerate to the same thing when *everything* was lost).  Celeris never
waits: the bounded window simply cuts the flows the stall swallowed
(delivered = 0, time unchanged) and the Hadamard path recovers them at
the trainer.  A *dead* flow (crash) can never complete: reliable
designs burn the full retry budget (``rto x (1 + max_retries)``) and
still deliver nothing; Celeris just reports the data missing.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.transport import network
from repro.core.transport.params import FaultParams, SimParams

# Fault substreams (disjoint from engine 101-120 and DCI 130-131).
STREAM_STALL = 140
STREAM_CRASH = 141
STREAM_FLAP = 142
STREAM_FLAP_DCI = 143
STREAM_RAIL = 144
STREAM_STRAGGLER = 145

# "never started" sentinel for the running-max outage scans
_NEVER = np.iinfo(np.int64).min // 2


@dataclasses.dataclass
class FaultState:
    """Fault-process state carried across round blocks (the fault-side
    analogue of :class:`network.FabricState`)."""
    stall_last: np.ndarray | None = None    # (n,) last stall-start step
    crash_last: np.ndarray | None = None    # (n,) last crash-start step
    flap_down: np.ndarray | None = None     # (n_tors,) link down?
    flap_down_dci: np.ndarray | None = None  # (n_pods,) DCI link down?


@dataclasses.dataclass
class BlockFaults:
    """Availability masks for one round block (``tb`` steps)."""
    node_blocked: np.ndarray | None  # (tb, n) stalled (recoverable outage)
    node_dead: np.ndarray | None     # (tb, n) crashed (no data ever)
    tor_down: np.ndarray | None      # (tb, n_tors) uplink flapped down
    dci_down: np.ndarray | None      # (tb, n_pods) DCI uplink down
    rail_down: np.ndarray | None     # (tb,) failed-rail round?

    @property
    def any(self) -> bool:
        return any(m is not None for m in
                   (self.node_blocked, self.node_dead, self.tor_down,
                    self.dci_down, self.rail_down))


def _outage_scan(gen, rate, duration, t0, tb, n, last, targets):
    """(down, new_last): duration-``duration`` outages from per-step
    Bernoulli starts, resolved for the whole block at once.  ``last``
    carries the most recent start step per node across blocks;
    ``duration=None`` means permanent (crash without restart)."""
    t_idx = t0 + np.arange(tb)
    starts = gen.random((tb, n)) < rate
    if targets is not None:
        mask = np.zeros(n, dtype=bool)
        mask[list(targets)] = True
        starts &= mask[None, :]
    last_start = np.maximum.accumulate(
        np.where(starts, t_idx[:, None], _NEVER), axis=0)
    last_start = np.maximum(last_start, last[None, :])
    if duration is None:
        down = last_start > _NEVER
    else:
        down = (t_idx[:, None] - last_start) < duration
    return down, last_start[-1].copy()


class FaultModel:
    """Materializes one seed's failure scenario block by block.

    Construct once per :meth:`BatchedEngine._traces_shared` call (only
    when ``params.fault.active``); call :meth:`advance` once per round
    block, in step order, then :meth:`phase_masks` per schedule phase.
    Generators are created once and consumed sequentially, so block
    boundaries never change the draws (same contract as the fabric
    stream).
    """

    def __init__(self, p: SimParams, seed: int, n: int, n_tors: int,
                 steps_per_round: int):
        self.fp: FaultParams = p.fault
        self.n = n
        self.steps = steps_per_round
        self.n_pods = p.topo.n_pods if p.topo.hierarchical else 0
        fp = self.fp
        self._stall_gen = (np.random.default_rng([seed, STREAM_STALL])
                           if fp.stall_rate > 0 else None)
        self._crash_gen = (np.random.default_rng([seed, STREAM_CRASH])
                           if fp.crash_rate > 0 else None)
        self._flap_gen = (np.random.default_rng([seed, STREAM_FLAP])
                          if fp.flap_rate > 0 else None)
        self._flap_dci_gen = (np.random.default_rng([seed, STREAM_FLAP_DCI])
                              if fp.flap_rate > 0 and self.n_pods else None)
        self._rail_gen = (np.random.default_rng([seed, STREAM_RAIL])
                          if fp.rail_fail_rate > 0 else None)
        self.state = FaultState(
            stall_last=(np.full(n, _NEVER) if self._stall_gen is not None
                        else None),
            crash_last=(np.full(n, _NEVER) if self._crash_gen is not None
                        else None),
            flap_down=(np.zeros(n_tors, dtype=bool)
                       if self._flap_gen is not None else None),
            flap_down_dci=(np.zeros(self.n_pods, dtype=bool)
                           if self._flap_dci_gen is not None else None))
        self.n_tors = n_tors
        # static slow-NIC subset: rate scale per node, drawn once
        self.rate_scale = None
        if fp.straggler_frac > 0:
            gen = np.random.default_rng([seed, STREAM_STRAGGLER])
            pool = (np.asarray(fp.target_nodes)
                    if fp.target_nodes is not None else np.arange(n))
            k = max(1, int(round(fp.straggler_frac * pool.size)))
            slow = gen.choice(pool, size=min(k, pool.size), replace=False)
            self.rate_scale = np.ones(n, dtype=np.float32)
            self.rate_scale[slow] = 1.0 / fp.straggler_slowdown

    # ------------------------------------------------------------------
    def advance(self, t0: int, tb: int) -> BlockFaults:
        """Availability masks for steps ``[t0, t0 + tb)``."""
        fp, st = self.fp, self.state
        blocked = dead = tor_down = dci_down = rail_down = None
        if self._stall_gen is not None:
            blocked, st.stall_last = _outage_scan(
                self._stall_gen, fp.stall_rate, fp.stall_steps, t0, tb,
                self.n, st.stall_last, fp.target_nodes)
        if self._crash_gen is not None:
            dur = fp.crash_restart_steps or None
            dead, st.crash_last = _outage_scan(
                self._crash_gen, fp.crash_rate, dur, t0, tb, self.n,
                st.crash_last, fp.target_nodes)
        if self._flap_gen is not None:
            u = self._flap_gen.random((tb, 2, self.n_tors))
            tor_down = network._markov_burst(
                st.flap_down, u[:, 0] < fp.flap_rate,
                u[:, 1] < fp.flap_recover_prob)
            st.flap_down = tor_down[-1].copy()
        if self._flap_dci_gen is not None:
            u = self._flap_dci_gen.random((tb, 2, self.n_pods))
            dci_down = network._markov_burst(
                st.flap_down_dci, u[:, 0] < fp.flap_rate,
                u[:, 1] < fp.flap_recover_prob)
            st.flap_down_dci = dci_down[-1].copy()
        if self._rail_gen is not None:
            n_rounds = tb // self.steps
            fails = self._rail_gen.random(n_rounds) < fp.rail_fail_rate
            rail_down = np.repeat(fails, self.steps)
        return BlockFaults(node_blocked=blocked, node_dead=dead,
                           tor_down=tor_down, dci_down=dci_down,
                           rail_down=rail_down)

    # ------------------------------------------------------------------
    def phase_masks(self, blk: BlockFaults, rows: np.ndarray, ph, hg,
                    nodes_per_tor: int):
        """(blocked, dead) ``(n_rows, n_flows)`` masks for one phase.

        A flow is affected when either endpoint's NIC, either
        endpoint's ToR uplink, or (cross-pod flows) either endpoint
        pod's DCI uplink is unavailable; rail failures hit the
        cross-tier flows of the failed rail.  ``dead`` (crash) wins
        over ``blocked`` where both apply — the data is gone, not late.
        """
        if not blk.any:
            return None, None
        src, dst = ph.src, ph.dst
        n_rows = rows.size
        blocked = np.zeros((n_rows, src.size), dtype=bool)
        dead = np.zeros((n_rows, src.size), dtype=bool)
        if blk.node_blocked is not None:
            nb = blk.node_blocked[rows]
            blocked |= nb[:, src] | nb[:, dst]
        if blk.node_dead is not None:
            nd = blk.node_dead[rows]
            dead |= nd[:, src] | nd[:, dst]
        if blk.tor_down is not None:
            td = blk.tor_down[rows]
            blocked |= (td[:, src // nodes_per_tor]
                        | td[:, dst // nodes_per_tor])
        if blk.dci_down is not None and hg.cross.size:
            dd = blk.dci_down[rows]
            x = hg.cross
            blocked[:, x] |= (dd[:, hg.src_pod[x]] | dd[:, hg.dst_pod[x]])
        if blk.rail_down is not None and self.n_pods and hg.cross.size:
            m = self.n // self.n_pods
            x = hg.cross
            on_rail = x[(src[x] % m) == (self.fp.rail % m)]
            if on_rail.size:
                blocked[:, on_rail] |= blk.rail_down[rows, None]
        blocked &= ~dead
        if not blocked.any():
            blocked = None
        if not dead.any():
            dead = None
        return blocked, dead


def apply_to_result(design: str, res, blocked, dead, rel,
                    parts: dict | None = None) -> None:
    """Overlay one phase's fault masks onto a ``TransferResult``
    in place (mutates ``res`` before the engine's reduction, so tier /
    pod / coupling accounting all inherit the fault for free).

    See the module docstring for the per-design semantics.  ``blocked``
    / ``dead`` may be None (nothing of that class in this block).

    ``parts`` is the telemetry scratchpad: when passed, the fault-added
    completion time (``"fault"``) and fault-swallowed packets
    (``"fault_lost"``) are recorded as the exact deltas this overlay
    applies — pure reads of the pre-mutation state, never a changed
    draw or value.
    """
    if blocked is None and dead is None:
        return
    detect = {"roce": rel.rto_us, "irn": rel.rto_low_us,
              "srnic": rel.rto_low_us + rel.host_slowpath_us}.get(design)
    if parts is not None:
        shape = res.time_us.shape
        parts["fault"] = f_add = np.zeros(shape)
        parts["fault_lost"] = f_lost = np.zeros(shape)
    if dead is not None or design == "celeris":
        # reliable designs return broadcast (read-only) delivered views;
        # materialize before punching fault holes into them
        if not res.delivered_pkts.flags.writeable:
            res.delivered_pkts = np.array(res.delivered_pkts)
    if blocked is not None:
        if design == "celeris":
            if parts is not None:
                f_lost[blocked] = res.delivered_pkts[blocked]
            res.delivered_pkts[blocked] = 0.0
        else:
            # timeout-detect the silent outage, then resend the chunk
            t = res.time_us
            if parts is not None:
                f_add[blocked] = (np.asarray(t[blocked], np.float64)
                                  + detect)
            t[blocked] = 2.0 * t[blocked] + t.dtype.type(detect)
    if dead is not None:
        if parts is not None:
            # += not =: a flow both blocked and dead already had its
            # packets attributed by the blocked branch (delivered is 0
            # by now) — overwriting would silently drop that loss
            f_lost[dead] += res.delivered_pkts[dead]
        res.delivered_pkts[dead] = 0.0
        if design != "celeris":
            res.time_us[dead] += res.time_us.dtype.type(
                detect * (1 + rel.max_retries))
            if parts is not None:
                f_add[dead] += detect * (1 + rel.max_retries)
