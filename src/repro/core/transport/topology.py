"""Hierarchical multi-pod topology subsystem (node → ToR → spine → DCI).

The flat engine models a single 2-tier Clos: every ring hop sees the
same ToR-uplink contention process, so the trainer is fed one scalar
drop rate per step.  Cluster-scale ML lives on a *hierarchy*: pods of a
few hundred nodes with a fat intra-pod fabric, stitched by oversubscribed
DCI (data-center interconnect) links whose contention, loss, and RTT
dominate the cross-pod tail.  This module layers that hierarchy over the
existing vectorized machinery:

- :func:`hier_geometry` — static flow→tier assignment for the ring
  (``tor`` same-ToR, ``spine`` cross-ToR intra-pod, ``dci`` cross-pod);
- :func:`dci_net_params` — the DCI tier's burst process expressed as a
  :class:`~repro.core.transport.params.NetworkParams` clone, so the DCI
  occupancy trace reuses :func:`network.occupancy_trace` verbatim (same
  closed-form Markov/EWMA math, its own random substream);
- :func:`overlay_curves` / :func:`overlay_rates` — the per-block DCI
  overlay the batched engine applies to cross-pod flow columns: ECN and
  drop evaluated at the *effective* occupancy (max over traversed tiers),
  available bandwidth divided by the oversubscription ratio, queueing
  delay multiplied by it (the shared egress serializes pod traffic), and
  the extra DCI propagation added to completion times;
- :func:`hier_protocol` — the Fig.-4 protocol: RoCE baseline fixes the
  Celeris window (paper rule) on the *same hierarchical fabric*, and
  every design reports per-tier delivered fractions.

Everything is gated on ``SimParams.topo.n_pods > 1``: at ``n_pods=1``
the engine never calls into the overlay and never draws from the DCI
streams, so flat seeded traces stay bit-identical to the pre-topology
engine (pinned by ``tests/test_topology.py``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.transport import network
from repro.core.transport.params import NetworkParams, SimParams, TopologyParams

# Tier axis order used everywhere a per-tier quantity appears.
TIERS = ("tor", "spine", "dci")
N_TIERS = len(TIERS)

# Engine-native random substreams for the DCI tier (disjoint from the
# flat engine's 101-120 range so flat streams are never perturbed).
STREAM_DCI_FABRIC = 130
STREAM_DCI_CNP = 131


def per_pod_array(value, n_pods: int, name: str = "parameter") -> np.ndarray:
    """(n_pods,) f64 view of a scalar-or-per-pod topology parameter."""
    a = np.asarray(value, dtype=np.float64).reshape(-1)
    if a.size == 1:
        return np.full(n_pods, a[0])
    if a.size != n_pods:
        raise ValueError(f"per-pod {name} has length {a.size}; expected a "
                         f"scalar or one value per pod (n_pods={n_pods})")
    return a


def validate(net: NetworkParams, topo: TopologyParams) -> None:
    if topo.n_pods < 1:
        raise ValueError(f"n_pods={topo.n_pods} must be >= 1")
    if net.n_nodes % topo.n_pods:
        raise ValueError(f"n_nodes={net.n_nodes} must be a multiple of "
                         f"n_pods={topo.n_pods}")
    per_pod = net.n_nodes // topo.n_pods
    if per_pod % net.nodes_per_tor:
        raise ValueError(
            f"nodes per pod ({per_pod}) must be a multiple of "
            f"nodes_per_tor={net.nodes_per_tor} (pods align to ToRs)")
    if (per_pod_array(topo.dci_oversubscription, topo.n_pods,
                      "dci_oversubscription") < 1.0).any():
        raise ValueError("dci_oversubscription must be >= 1")
    bp = per_pod_array(topo.dci_burst_on_prob, topo.n_pods,
                       "dci_burst_on_prob")
    if ((bp < 0.0) | (bp > 1.0)).any():
        raise ValueError("dci_burst_on_prob must lie in [0, 1]")


@dataclasses.dataclass(frozen=True)
class HierGeometry:
    """Static per-flow topology facts for one collective flow pattern."""
    n_pods: int
    pod_of: np.ndarray         # (n,) pod index per node
    src: np.ndarray            # (n_flows,) source node per flow
    dst: np.ndarray            # (n_flows,) destination node per flow
    src_pod: np.ndarray        # (n_flows,) pod of each flow's source
    dst_pod: np.ndarray        # (n_flows,) pod of each flow's destination
    tiers: np.ndarray          # (n_flows,) tier index per flow (into TIERS)
    tier_cols: tuple           # per tier: flow-column index array
    cross: np.ndarray          # alias of tier_cols[2] (dci flows)

    @property
    def tier_counts(self) -> np.ndarray:
        return np.array([c.size for c in self.tier_cols])

    @property
    def pod_cols(self) -> tuple:
        """Per pod: the *intra-pod* flow columns whose source lives in
        that pod (tor + spine tiers; DCI flows belong to the cross axis
        and are excluded).  This is the grouping behind the per-pod
        delivered fractions (``RoundStats.pod_recv_frac``) that drive
        ``coupling.AxisSchedules.per_pod``."""
        intra = self.tiers != 2
        return tuple(np.flatnonzero(intra & (self.src_pod == p))
                     for p in range(self.n_pods))


def hier_geometry(net: NetworkParams, topo: TopologyParams,
                  src: np.ndarray | None = None,
                  dst: np.ndarray | None = None) -> HierGeometry:
    """Tier assignment per flow (default: ring, src=i, dst=(i+1) mod n)."""
    validate(net, topo)
    n = net.n_nodes
    if src is None:
        src = np.arange(n)
    if dst is None:
        dst = (np.arange(n) + 1) % n
    per_pod = n // topo.n_pods
    pod_of = np.arange(n) // per_pod
    ts, td = src // net.nodes_per_tor, dst // net.nodes_per_tor
    sp, dp = pod_of[src], pod_of[dst]
    tiers = np.where(sp != dp, 2, np.where(ts != td, 1, 0))
    tier_cols = tuple(np.flatnonzero(tiers == k) for k in range(N_TIERS))
    return HierGeometry(n_pods=topo.n_pods, pod_of=pod_of, src=src, dst=dst,
                        src_pod=sp, dst_pod=dp, tiers=tiers,
                        tier_cols=tier_cols, cross=tier_cols[2])


def dci_net_params(net: NetworkParams, topo: TopologyParams) -> NetworkParams:
    """The DCI burst process as a NetworkParams clone, so
    :func:`network.occupancy_trace` drives it unchanged (one "ToR" per
    DCI uplink).  A per-pod ``dci_burst_on_prob`` vector broadcasts
    through the burst draws (hot pods burst more often); scalars stay
    scalars so the flat path is untouched."""
    on = topo.dci_burst_on_prob
    if np.ndim(on):
        on = per_pod_array(on, topo.n_pods, "dci_burst_on_prob")
    return dataclasses.replace(
        net,
        burst_on_prob=on,
        burst_off_prob=topo.dci_burst_off_prob,
        burst_occupancy_lo=topo.dci_burst_occupancy_lo,
        burst_occupancy_hi=topo.dci_burst_occupancy_hi,
        idle_occupancy=topo.dci_idle_occupancy)


def init_dci_state(net: NetworkParams, topo: TopologyParams
                   ) -> network.FabricState:
    return network.FabricState(
        bursting=np.zeros(topo.n_pods, dtype=bool),
        occupancy=np.full(topo.n_pods, topo.dci_idle_occupancy))


def overlay_curves(net: NetworkParams, topo: TopologyParams,
                   hg: HierGeometry, occ_tor: np.ndarray,
                   occ_dci: np.ndarray, ecn_p: np.ndarray,
                   drop_p: np.ndarray) -> np.ndarray:
    """Re-evaluate ECN/drop on cross-pod columns at the effective path
    occupancy (max over ToR uplinks *and* the two DCI uplinks traversed).

    Mutates ``ecn_p``/``drop_p`` in place (cross columns only) and
    returns the effective f64 occupancy ``(T, n_cross)`` for the rate
    overlay.  Intra-pod columns are untouched, so the flat curves (and
    with them the flat random-stream positions) are preserved exactly.
    """
    x = hg.cross
    if x.size == 0:
        return np.empty((occ_tor.shape[0], 0))
    occ_path = network.path_occupancy_trace(net, occ_tor, hg.src[x],
                                            hg.dst[x])
    occ_pair = np.maximum(occ_dci[:, hg.src_pod[x]], occ_dci[:, hg.dst_pod[x]])
    occ_eff = np.maximum(occ_path, occ_pair)
    ecn_p[:, x] = network.ecn_mark_prob(net, occ_eff)
    drop_p[:, x] = network.drop_prob(net, occ_eff)
    return occ_eff


def dci_oversub_factor(topo: TopologyParams, hg: HierGeometry) -> np.ndarray:
    """The f32 oversubscription factor charged to each cross-pod column
    (``np.float32`` scalar, or ``(n_cross,)`` f32 for per-pod vectors —
    each flow pays the max of its two endpoint pods' ratios).  Shared
    by :func:`overlay_rates` and the jax backend's static column
    multipliers, so both backends charge the identical factor."""
    x = hg.cross
    o = topo.dci_oversubscription
    if np.ndim(o) == 0:
        return np.float32(o)
    ov = per_pod_array(o, topo.n_pods, "dci_oversubscription")
    return np.maximum(ov[hg.src_pod[x]],
                      ov[hg.dst_pod[x]]).astype(np.float32)


def overlay_rates(net: NetworkParams, topo: TopologyParams,
                  hg: HierGeometry, occ_eff: np.ndarray, rate: np.ndarray,
                  occ32: np.ndarray, qd: np.ndarray,
                  eff_rate: np.ndarray) -> None:
    """Apply the oversubscription penalty to cross-pod columns in place.

    - available bandwidth: evaluated at the effective occupancy, then
      divided by the oversubscription ratio (pod egress is shared);
    - queueing delay: evaluated at the effective occupancy, multiplied
      by the ratio (the shared egress serializes pod traffic);
    - ``occ32`` is refreshed on cross columns so RoCE's PFC pause trace
      sees DCI congestion too.

    A per-pod oversubscription vector charges each cross flow the max
    of its two endpoint pods' ratios (the flow rides both uplinks); the
    scalar form keeps the exact pre-vector arithmetic.
    """
    x = hg.cross
    if x.size == 0:
        return
    o32 = dci_oversub_factor(topo, hg)
    eff32 = occ_eff.astype(np.float32)
    occ32[:, x] = eff32
    qd[:, x] = network.queue_delay_us(net, eff32) * o32
    eff_rate[:, x] = (rate[:, x] * network.avail_bandwidth(net, eff32)
                      / o32)


def dci_cnp_draws(hg: HierGeometry, ecn_p: np.ndarray, cnp: np.ndarray,
                  gen: np.random.Generator) -> None:
    """Extra CNP draws for cross-pod columns (DCI marking is active even
    when every ToR is calm, so the flat hot-row prescreen misses it).
    Draws come from the dedicated DCI stream; the flat CNP stream's
    consumption is untouched."""
    x = hg.cross
    if x.size == 0:
        return
    rows = np.flatnonzero(ecn_p[:, x].any(axis=1))
    if rows.size:
        cnp[np.ix_(rows, x)] = (gen.random((rows.size, x.size))
                                < ecn_p[np.ix_(rows, x)])


def add_dci_latency(topo: TopologyParams, hg: HierGeometry,
                    time_us: np.ndarray, parts: dict | None = None) -> None:
    """Extra DCI propagation (one-way) on cross-pod completion times.

    ``parts`` is the telemetry scratchpad: the DCI propagation is RTT
    (speed of light between pods), so it lands in the "rtt" component —
    which must be promoted from the scalar ``designs.transfer`` wrote
    to a per-flow array before the cross columns diverge.
    """
    if hg.cross.size:
        time_us[..., hg.cross] += np.asarray(topo.dci_rtt_us / 2.0,
                                             dtype=time_us.dtype)
        if parts is not None:
            rtt = np.full(time_us.shape,
                          float(parts.get("rtt", 0.0)))
            rtt[..., hg.cross] += topo.dci_rtt_us / 2.0
            parts["rtt"] = rtt


# ----------------------------------------------------------------------
# Protocol front-end (what fig4 and the axis-split coupling consume)
# ----------------------------------------------------------------------

def hier_params(n_pods: int, *, base: SimParams | None = None,
                n_nodes: int | None = None,
                dci_oversubscription: "float | tuple | None" = None,
                schedule: str | None = None,
                fault=None,
                **topo_kw) -> SimParams:
    """A SimParams with the topology tier configured (convenience).
    ``schedule`` selects the collective schedule ("ring" | "hier",
    see :mod:`repro.core.transport.schedule`); ``fault`` an optional
    :class:`~repro.core.transport.params.FaultParams` (or its
    ``kind:rate`` string form) enabling seeded fault injection on the
    hierarchical fabric."""
    p = base or SimParams()
    if n_nodes is not None:
        p = dataclasses.replace(p, net=dataclasses.replace(
            p.net, n_nodes=n_nodes))
    if schedule is not None:
        p = dataclasses.replace(p, work=dataclasses.replace(
            p.work, schedule=schedule))
    if fault is not None:
        from repro.core.transport.params import FaultParams
        p = dataclasses.replace(p, fault=FaultParams.parse(fault))
    kw = dict(n_pods=n_pods, **topo_kw)
    if dci_oversubscription is not None:
        kw["dci_oversubscription"] = dci_oversubscription
    return dataclasses.replace(p, topo=dataclasses.replace(p.topo, **kw))


def hier_protocol(params: SimParams, n_rounds: int = 200, seed: int = 0, *,
                  timeout_scale: float = 1.0, window: str = "round",
                  cut_order: str = "arrival", recorder=None):
    """Fig.-4 protocol on the hierarchical fabric.

    Same window rule as the flat paper protocol — the RoCE baseline on
    the *same* fabric trace fixes the Celeris window at median + 1 sigma
    (scaled) — but run with the DCI overlay active, so the returned
    :class:`RoundStats` carry per-tier delivered fractions.  ``window``
    selects the Celeris budget policy ("round" | "phase", see
    ``params.WindowPolicy``) — "phase" splits the same budget across
    the collective schedule's phase blocks by their ``budget_frac``.
    ``cut_order`` selects what a binding budget truncates ("arrival" |
    "priority" — the latter cuts the schedule's lowest semantic class
    first; times are identical either way, see
    ``BatchedEngine.assemble``).  Returns ``{design: RoundStats}`` for
    roce + celeris.  Pass a ``telemetry.TraceRecorder`` as ``recorder``
    to capture the tail / loss attribution of both designs (a pure
    overlay; stats unchanged).
    """
    from repro.core.transport.engine import BatchedEngine

    eng = BatchedEngine(params, recorder=recorder)
    tr = eng.traces(["roce", "celeris"], n_rounds, seed,
                    legacy_streams=False)
    base = eng.assemble(tr["roce"], seed)
    to = float((np.percentile(base.times_us, 50) + base.times_us.std())
               * timeout_scale)
    cel = eng.assemble(tr["celeris"], seed, celeris_timeout_us=to,
                       adaptive=False, window=window,
                       cut_order=cut_order)
    return {"roce": base, "celeris": cel}
