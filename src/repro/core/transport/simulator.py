"""Cluster-scale collective simulator (paper §IV setup: 128-node Clos,
25 MB AllReduce rounds, randomized bursty background contention).

Ring AllReduce = 2(N-1) synchronized steps; each step, node i sends one
chunk (M/N bytes) to its ring successor.  The *step* completes when the
slowest transfer completes (global synchronization — the tail-at-scale
amplifier), so

    round_time = sum_s  max_i  t[s, i]        (reliable designs)
    round_time = sum_s  min(max_i t[s, i], step_timeout)   (Celeris)

Celeris receivers finalize each step at the bounded window and discard
late packets; the per-round timeout adapts via
:class:`repro.core.timeout.TimeoutController` with cluster-median
coordination, exactly as §III-B describes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core import timeout as timeout_mod
from repro.core.transport import dcqcn, designs
from repro.core.transport.network import ClosFabric
from repro.core.transport.params import SimParams


@dataclasses.dataclass
class RoundStats:
    times_us: np.ndarray          # (rounds,)
    recv_frac: np.ndarray         # (rounds,) delivered fraction of payload
    design: str

    @property
    def p50(self) -> float:
        return float(np.percentile(self.times_us, 50))

    @property
    def p99(self) -> float:
        return float(np.percentile(self.times_us, 99))

    @property
    def p999(self) -> float:
        return float(np.percentile(self.times_us, 99.9))

    @property
    def mean_loss(self) -> float:
        return float(1.0 - self.recv_frac.mean())

    def summary(self) -> Dict[str, float]:
        return dict(p50_us=self.p50, p99_us=self.p99, p999_us=self.p999,
                    mean_us=float(self.times_us.mean()),
                    data_loss=self.mean_loss)


class CollectiveSimulator:
    def __init__(self, params: SimParams | None = None):
        self.p = params or SimParams()

    # ------------------------------------------------------------------
    def run(self, design: str, n_rounds: int = 400, *,
            celeris_timeout_us: float | None = None,
            adaptive: bool = True, window: str = "round",
            seed: int | None = None) -> RoundStats:
        """Simulate ``n_rounds`` AllReduce rounds for one NIC design.

        ``celeris_timeout_us``: initial round timeout (the paper's Fig.-2
        protocol fixes it to median+1sigma of the baseline with
        ``adaptive=False``); when ``adaptive`` the
        :class:`~repro.core.timeout.TimeoutController` refines it per
        round from (duration, received fraction) with cluster-median
        coordination.

        ``window``: ``"round"`` bounds the whole collective operation
        (paper semantics — data not delivered when the window closes is
        discarded); ``"step"`` subdivides the budget across ring steps
        (beyond-paper variant: bounds even intra-round stragglers,
        trading slightly more loss for a much flatter tail).
        """
        p = self.p
        net, rel = p.net, p.rel
        rng = np.random.default_rng(p.seed if seed is None else seed)
        fabric = ClosFabric(net, seed=int(rng.integers(2**31)))

        n = net.n_nodes
        steps = 2 * (n - 1)
        chunk_bytes = p.work.message_bytes // n
        n_pkts = max(1, chunk_bytes // net.mtu_bytes)
        src = np.arange(n)
        dst = (src + 1) % n

        cc = dcqcn.DcqcnState.init(n)

        # --- Celeris bounded-window controllers (one per node) --------
        controllers = None
        if design == "celeris":
            init_to = (celeris_timeout_us or 50_000.0) / 1e6
            cfg = timeout_mod.TimeoutConfig(
                init_timeout=init_to, min_timeout=init_to * 0.25,
                max_timeout=init_to * 8.0, alpha=0.25)
            controllers = [timeout_mod.TimeoutController(cfg) for _ in range(n)]

        times = np.zeros(n_rounds)
        fracs = np.ones(n_rounds)

        for r in range(n_rounds):
            if controllers is not None:
                round_budget_us = controllers[0].timeout * 1e6
                step_timeout_us = round_budget_us / steps

            step_nat = np.zeros(steps)            # natural per-step time
            step_deliv = np.zeros(steps)          # pkts that physically arrived
            step_total = np.zeros(steps)

            for s in range(steps):
                fabric.advance()
                occ = fabric.path_occupancy(src, dst)
                drop_p = fabric.drop_prob(occ)
                qd = fabric.queue_delay_us(occ)
                pfc = fabric.pfc_pause_us(occ) if design == "roce" else np.zeros(n)

                # effective send rate: DCQCN decision x bandwidth left by
                # the background burst on the bottleneck hop
                eff_rate = cc.rate * fabric.avail_bandwidth(occ)
                res = designs.transfer(design, n_pkts, occ, eff_rate, drop_p,
                                       pfc, qd, rel, net, rng)

                if design == "celeris" and window == "step":
                    # bounded window per ring step: late data discarded
                    t_nat = float(res.time_us.max())
                    step_nat[s] = min(t_nat, step_timeout_us)
                    late_frac = np.clip(
                        (res.time_us - step_timeout_us)
                        / np.maximum(res.time_us, 1e-9), 0, 1)
                    step_deliv[s] = float(
                        (res.delivered_pkts * (1 - late_frac)).sum())
                else:
                    step_nat[s] = float(res.time_us.max())
                    step_deliv[s] = float(res.delivered_pkts.sum())
                step_total[s] = float(res.total_pkts.sum())

                # DCQCN control interval per step
                cnp = rng.random(n) < fabric.ecn_mark_prob(occ)
                cc = dcqcn.step(cc, cnp, p.dcqcn)

            if design == "celeris" and window == "round":
                # paper semantics: one bounded window per collective
                # operation; at the deadline receivers finalize with the
                # data that made it and discard the rest.
                cum = np.cumsum(step_nat)
                total_t = float(cum[-1])
                if total_t <= round_budget_us:
                    times[r] = total_t
                    fracs[r] = step_deliv.sum() / max(step_total.sum(), 1.0)
                else:
                    times[r] = round_budget_us
                    done = cum <= round_budget_us
                    # boundary step delivers its in-flight fraction
                    bidx = int(np.argmax(~done))
                    prev = float(cum[bidx - 1]) if bidx > 0 else 0.0
                    part = (round_budget_us - prev) / max(step_nat[bidx], 1e-9)
                    got = step_deliv[done].sum() + step_deliv[bidx] * part
                    fracs[r] = got / max(step_total.sum(), 1.0)
            else:
                times[r] = step_nat.sum()
                fracs[r] = step_deliv.sum() / max(step_total.sum(), 1.0)

            if controllers is not None and adaptive:
                # each node updates from its local observation, then the
                # cluster adopts the median (paper's coordination step)
                node_frac = np.clip(
                    fracs[r] + rng.normal(0, 0.002, n), 0.0, 1.0)
                local = [c.update(times[r] / 1e6, node_frac[i])
                         for i, c in enumerate(controllers)]
                agreed = timeout_mod.coordinate(local)
                for c in controllers:
                    c.adopt(agreed)

        return RoundStats(times_us=times, recv_frac=fracs, design=design)

    # ------------------------------------------------------------------
    def paper_protocol(self, n_rounds: int = 400, seed: int = 0
                       ) -> Dict[str, RoundStats]:
        """The paper's Fig.-2 protocol: run the RoCE baseline, set the
        Celeris window to baseline median + 1 sigma, run everything."""
        base = self.run("roce", n_rounds, seed=seed)
        to = float(np.percentile(base.times_us, 50) + base.times_us.std())
        out = {"roce": base}
        for d in ("irn", "srnic"):
            out[d] = self.run(d, n_rounds, seed=seed)
        out["celeris"] = self.run("celeris", n_rounds, celeris_timeout_us=to,
                                  adaptive=False, window="round", seed=seed)
        return out
