"""Cluster-scale collective simulator (paper §IV setup: 128-node Clos,
25 MB AllReduce rounds, randomized bursty background contention).

Ring AllReduce = 2(N-1) synchronized steps; each step, node i sends one
chunk (M/N bytes) to its ring successor.  The *step* completes when the
slowest transfer completes (global synchronization — the tail-at-scale
amplifier), so

    round_time = sum_s  max_i  t[s, i]        (reliable designs)
    round_time = sum_s  min(max_i t[s, i], step_timeout)   (Celeris)

Celeris receivers finalize each step at the bounded window and discard
late packets; the per-round timeout adapts via
:class:`repro.core.timeout.TimeoutController` with cluster-median
coordination, exactly as §III-B describes.

This class is now a thin compatibility facade over
:class:`repro.core.transport.engine.BatchedEngine`, which evaluates the
same model as whole-trace tensor operations instead of a Python
``rounds x steps`` loop (>10x faster at the Fig.-2 protocol scale, and
the only practical path to 512-1024-node sweeps).  Seeded runs
reproduce pre-refactor statistics: the fabric contention trace is
replayed bit-exactly (including RoCE's PFC-polluted stream), leaving
only per-transfer draw noise (a few percent on p99).  Use the engine
directly — or :func:`repro.core.transport.engine.sweep` — for batched
multi-design / multi-seed / multi-scale studies.
"""
from __future__ import annotations

from typing import Dict

from repro.core.transport.engine import BatchedEngine, RoundStats
from repro.core.transport.params import SimParams

__all__ = ["CollectiveSimulator", "RoundStats"]


class CollectiveSimulator:
    def __init__(self, params: SimParams | None = None):
        self.p = params or SimParams()
        self._engine = BatchedEngine(self.p)

    # ------------------------------------------------------------------
    def run(self, design: str, n_rounds: int = 400, *,
            celeris_timeout_us: float | None = None,
            adaptive: bool = True, window: str = "round",
            seed: int | None = None) -> RoundStats:
        """Simulate ``n_rounds`` AllReduce rounds for one NIC design.

        ``celeris_timeout_us``: initial round timeout (the paper's Fig.-2
        protocol fixes it to median+1sigma of the baseline with
        ``adaptive=False``); when ``adaptive`` the
        :class:`~repro.core.timeout.TimeoutController` refines it per
        round from (duration, received fraction) with cluster-median
        coordination.

        ``window``: ``"round"`` bounds the whole collective operation
        (paper semantics — data not delivered when the window closes is
        discarded); ``"step"`` subdivides the budget across ring steps
        (beyond-paper variant: bounds even intra-round stragglers,
        trading slightly more loss for a much flatter tail).
        """
        return self._engine.run(design, n_rounds,
                                celeris_timeout_us=celeris_timeout_us,
                                adaptive=adaptive, window=window, seed=seed)

    # ------------------------------------------------------------------
    def paper_protocol(self, n_rounds: int = 400, seed: int = 0
                       ) -> Dict[str, RoundStats]:
        """The paper's Fig.-2 protocol: run the RoCE baseline, set the
        Celeris window to baseline median + 1 sigma, run everything."""
        return self._engine.paper_protocol(n_rounds, seed)
