"""Clos fabric + background-contention model.

Foreground collective packets are simulated per chunk; background load
is a Markov-modulated burst process per ToR uplink (on/off with
occupancy drawn per burst).  Occupancy determines queueing delay, ECN
marking probability, drop probability, and (for RoCE) PFC pause events.
All state is numpy-vectorized over nodes.

Two ways to drive the burst process:

- :meth:`ClosFabric.advance` — one step at a time (the original
  per-step API, kept for interactive use and as the reference for the
  vectorized path);
- :func:`occupancy_trace` — the whole ``(step, tor)`` trace in one
  vectorized shot, consuming *the same random stream in the same
  order* as sequential ``advance()`` calls, so seeded traces are
  bit-identical.  The burst on/off Markov chain is resolved in closed
  form (function composition: each step's transition is constant /
  identity / swap, so the state at t is the last constant's value XOR
  the parity of later swaps) and the occupancy EWMA by a truncated
  geometric filter whose tail error (0.5**64) is below f64 resolution.

:func:`roce_fabric_trace` replays the *RoCE-polluted* stream: a seed
RoCE run interleaves PFC-cascade draws (>= 1 per step, data-dependent
count) into the fabric stream, so its occupancy trace diverges from the
clean one.  The replay speculates vectorized windows assuming the
common one-draw case and re-anchors the stream position (PCG64
``advance``) at every step where a cascade survives its first draw.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.transport.params import NetworkParams


@dataclasses.dataclass
class FabricState:
    """Per-node path-congestion state (node i's send path this step)."""
    bursting: np.ndarray      # (n_tors,) bool
    occupancy: np.ndarray     # (n_tors,) current uplink occupancy


class ClosFabric:
    """2-tier Clos: nodes -> ToR -> spine.  Ring neighbors that share a
    ToR traverse one hop; cross-ToR hops traverse the (contended) uplink.
    """

    def __init__(self, p: NetworkParams, rng: np.ndarray | None = None,
                 seed: int = 0):
        self.p = p
        self.n_tors = p.n_nodes // p.nodes_per_tor
        self.rng = np.random.default_rng(seed)
        self.state = FabricState(
            bursting=np.zeros(self.n_tors, dtype=bool),
            occupancy=np.full(self.n_tors, p.idle_occupancy),
        )

    def tor_of(self, node: np.ndarray) -> np.ndarray:
        return node // self.p.nodes_per_tor

    def advance(self) -> None:
        """One collective-step tick of the background burst process."""
        p, st, rng = self.p, self.state, self.rng
        start = rng.random(self.n_tors) < p.burst_on_prob
        stop = rng.random(self.n_tors) < p.burst_off_prob
        st.bursting = (st.bursting & ~stop) | (~st.bursting & start)
        burst_occ = rng.uniform(p.burst_occupancy_lo, p.burst_occupancy_hi,
                                self.n_tors)
        target = np.where(st.bursting, burst_occ, p.idle_occupancy)
        # occupancy relaxes toward target (queues drain/fill gradually)
        st.occupancy = 0.5 * st.occupancy + 0.5 * target

    def path_occupancy(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        """Occupancy seen by each (src,dst) transfer: max over traversed
        uplinks; same-ToR transfers see only local (near-idle) queues."""
        p = self.p
        ts, td = self.tor_of(src), self.tor_of(dst)
        up = self.state.occupancy[ts]
        down = self.state.occupancy[td]
        cross = np.maximum(up, down)
        same = np.full_like(cross, p.idle_occupancy)
        return np.where(ts == td, same, cross)

    # --- derived per-transfer quantities (module functions below, so
    # the batched engine shares the exact same formulas) ---------------

    def queue_delay_us(self, occ: np.ndarray) -> np.ndarray:
        return queue_delay_us(self.p, occ)

    def avail_bandwidth(self, occ: np.ndarray) -> np.ndarray:
        """Fraction of line rate available to the foreground transfer."""
        return avail_bandwidth(self.p, occ)

    def ecn_mark_prob(self, occ: np.ndarray) -> np.ndarray:
        return ecn_mark_prob(self.p, occ)

    def drop_prob(self, occ: np.ndarray) -> np.ndarray:
        return drop_prob(self.p, occ)

    def pfc_pause_us(self, occ: np.ndarray) -> np.ndarray:
        """RoCE only: PAUSE stalls when ingress exceeds the PFC threshold.
        A pause on a ToR uplink head-of-line-blocks *every* flow through
        that ToR; each pause propagates a further hop with probability
        ``pfc_cascade_prob`` (geometric storm, capped)."""
        p = self.p
        paused = occ > p.pfc_threshold
        total = np.where(paused, p.pfc_pause_us, 0.0)
        alive = paused.copy()
        for _ in range(p.pfc_max_cascade):
            alive = alive & (self.rng.random(occ.shape) < p.pfc_cascade_prob)
            if not alive.any():
                break
            total = total + np.where(alive, p.pfc_pause_us, 0.0)
        return total


# ----------------------------------------------------------------------
# Fabric response curves — single source of truth for the per-step
# ClosFabric methods, the batched engine's whole-trace math, *and* the
# jax backend (engine_jax traces these same functions).  The bit-exact
# stream replay depends on every consumer agreeing on where the drop
# probability is exactly zero, so never fork these formulas.  They are
# written array-polymorphically (operator / method syntax only — the
# ``.clip`` method is what both numpy arrays and jax tracers share) so
# one formula body serves both backends; for numpy inputs each is
# bit-identical to its historical ``np.clip`` form.
# ----------------------------------------------------------------------

def queue_delay_us(p: NetworkParams, occ) -> np.ndarray:
    return p.queue_capacity_us * occ ** 3


def avail_bandwidth(p: NetworkParams, occ) -> np.ndarray:
    return (1.0 - p.bg_bandwidth_weight * occ).clip(p.min_avail_frac, 1.0)


def ecn_mark_prob(p: NetworkParams, occ) -> np.ndarray:
    return ((occ - p.ecn_threshold) / (1 - p.ecn_threshold)).clip(0, 1)


def drop_prob(p: NetworkParams, occ) -> np.ndarray:
    x = ((occ - p.loss_knee) / (1 - p.loss_knee)).clip(0, 1)
    return p.loss_max_prob * x ** 2


def congestion_counters(p: NetworkParams, occ: np.ndarray,
                        drop_p: np.ndarray | None = None) -> dict:
    """Per-step fabric congestion summary for the telemetry counter
    tracks (``telemetry.TraceRecorder.record_fabric``): mean / max path
    occupancy, the fraction of flows past the ECN knee, and (when the
    drop curve is at hand) mean drop probability.  Pure reads over the
    per-phase ``(step, flow)`` blocks — reductions only, no new draws.
    """
    out = {"occ_mean": occ.mean(axis=-1).astype(np.float64),
           "occ_max": occ.max(axis=-1).astype(np.float64),
           "ecn_frac": (occ > p.ecn_threshold).mean(axis=-1)}
    if drop_p is not None:
        out["drop_p_mean"] = drop_p.mean(axis=-1).astype(np.float64)
    return out


# ----------------------------------------------------------------------
# Vectorized traces (the batched engine's fabric front-end)
# ----------------------------------------------------------------------

# Doubles consumed by one advance(): start + stop + burst_occ draws.
_ADVANCE_DRAWS = 3


def _markov_burst(b0: np.ndarray, start: np.ndarray,
                  stop: np.ndarray) -> np.ndarray:
    """Closed-form burst state for all steps at once.

    Per step the transition  b' = (b & ~stop) | (~b & start)  is one of
    four maps on {0,1}: const-0 (stop only), const-1 (start only),
    identity (neither), swap (both).  Composing over steps: the state at
    t is the value of the last constant map at or before t, XOR'd with
    the parity of swaps after it (or b0 if no constant map yet).
    """
    T = start.shape[0]
    const = start ^ stop                   # exactly one of start/stop
    swap = start & stop
    t_idx = np.arange(T)[(slice(None),) + (None,) * (start.ndim - 1)]
    last_const = np.maximum.accumulate(np.where(const, t_idx, -1), axis=0)
    cs = np.cumsum(swap, axis=0)           # swaps in [0, t], inclusive
    gather = np.clip(last_const, 0, None)
    val_at = np.take_along_axis(np.where(const, start, False), gather, axis=0)
    cs_at = np.take_along_axis(cs, gather, axis=0)
    has_const = last_const >= 0
    base = np.where(has_const, val_at, np.broadcast_to(b0, start.shape))
    n_swaps = np.where(has_const, cs - cs_at, cs)
    return base ^ (n_swaps % 2 == 1)


def _ewma_half(target: np.ndarray, occ0: np.ndarray,
               seg: int = 512) -> np.ndarray:
    """occ[t] = 0.5*occ[t-1] + 0.5*target[t], all t at once — bitwise
    identical to the sequential recurrence.

    Closed form via exponentially scaled prefix sums::

        occ[t] = 0.5**(t+1) * cumsum([occ0, 2**0*target[0],
                                      2**1*target[1], ...])[t+1]

    Power-of-two scaling is exact in IEEE-754 and commutes with
    round-to-nearest, and the cumsum folds ``occ0`` first — the same
    association order as the recurrence — so every step rounds exactly
    as the sequential loop does.  Bit-exactness matters: the stream
    replay positions draws off threshold tests on these occupancies,
    and a 1-ulp difference at a threshold would silently
    desynchronize it.  Evaluated in ``seg``-step segments so the 2**s
    scale stays far from the f64 exponent limit.
    """
    T = target.shape[0]
    out = np.empty_like(target)
    trail = (None,) * (target.ndim - 1)
    prev = occ0
    for a in range(0, T, seg):
        b = min(a + seg, T)
        s = np.arange(b - a)
        up = np.exp2(s)[(slice(None),) + trail]
        down = np.exp2(-(s + 1.0))[(slice(None),) + trail]
        ext = np.concatenate(
            [np.broadcast_to(prev, (1,) + target.shape[1:]),
             target[a:b] * up], axis=0)
        out[a:b] = down * np.cumsum(ext, axis=0)[1:]
        prev = out[b - 1]
    return out


def occupancy_trace(p: NetworkParams, u: np.ndarray, state: FabricState
                    ) -> tuple[np.ndarray, np.ndarray, FabricState]:
    """Vectorized ``T`` steps of the burst process.

    ``u``: (T, 3, n_tors) uniforms laid out exactly as ``T`` sequential
    ``advance()`` calls consume them (start, stop, burst_occ per step),
    so ``rng.random((T, 3, n_tors))`` reproduces seeded traces
    bit-identically.  Returns (bursting, occupancy, final_state).
    """
    start = u[:, 0] < p.burst_on_prob
    stop = u[:, 1] < p.burst_off_prob
    burst_occ = (p.burst_occupancy_lo
                 + (p.burst_occupancy_hi - p.burst_occupancy_lo) * u[:, 2])
    b = _markov_burst(state.bursting, start, stop)
    target = np.where(b, burst_occ, p.idle_occupancy)
    occ = _ewma_half(target, state.occupancy)
    final = FabricState(bursting=b[-1].copy(), occupancy=occ[-1].copy())
    return b, occ, final


def path_occupancy_trace(p: NetworkParams, occ: np.ndarray, src: np.ndarray,
                         dst: np.ndarray) -> np.ndarray:
    """Per-transfer path occupancy for a whole trace: ``occ`` (..., T,
    n_tors) -> (..., T, n_flows)."""
    ts = src // p.nodes_per_tor
    td = dst // p.nodes_per_tor
    cross = np.maximum(occ[..., ts], occ[..., td])
    return np.where(ts == td, p.idle_occupancy, cross)


def pfc_pause_trace(p: NetworkParams, occ: np.ndarray,
                    rng: np.random.Generator) -> np.ndarray:
    """Vectorized PFC pause totals over a (..., n_flows) occupancy block.

    Distributionally identical to :meth:`ClosFabric.pfc_pause_us` per
    step; draws only for still-alive entries (pauses are rare), so the
    stream differs from the sequential path but the cascade law is the
    same.
    """
    paused = occ > p.pfc_threshold
    total = np.where(paused, p.pfc_pause_us, 0.0)
    alive = paused.copy()
    for _ in range(p.pfc_max_cascade):
        idx = np.flatnonzero(alive)
        if idx.size == 0:
            break
        survive = rng.random(idx.size) < p.pfc_cascade_prob
        # .flat, not .ravel(): ravel() copies on non-contiguous blocks
        # and the write would be lost (see designs.transfer)
        alive.flat[idx] = survive
        total.flat[idx] += np.where(survive, p.pfc_pause_us, 0.0)
    return total


def roce_fabric_trace(p: NetworkParams, fabric_seed: int, src: np.ndarray,
                      dst: np.ndarray, n_steps: int, *, window: int = 512,
                      window_max: int = 16384) -> tuple[np.ndarray, np.ndarray]:
    """Bit-exact replay of the fabric stream as a seed RoCE run consumes it.

    Per step the sequential simulator draws 3*n_tors doubles in
    ``advance()`` and then >= 1 cascade block of ``n_flows`` doubles in
    ``pfc_pause_us`` — further blocks only while some cascade survives,
    which is rare (strong bursts only).  We therefore speculate
    ``window`` steps at a time assuming the one-block common case, find
    the first step whose cascade survives its first draw, finish that
    step's cascade sequentially, and re-anchor the stream position with
    ``PCG64.advance``.

    Returns (occupancy (T, n_tors), pfc_pause_us (T, n_flows)).
    """
    n_tors = p.n_nodes // p.nodes_per_tor
    n = src.shape[0]
    step_draws = _ADVANCE_DRAWS * n_tors + n
    state = FabricState(bursting=np.zeros(n_tors, dtype=bool),
                        occupancy=np.full(n_tors, p.idle_occupancy))
    out_occ = np.empty((n_steps, n_tors))
    out_pfc = np.empty((n_steps, n))
    t = 0
    offset = 0                                # doubles consumed so far
    win = window                              # adaptive: grow while calm,
    while t < n_steps:                        # shrink on cascade breaks
        L = min(win, n_steps - t)
        bg = np.random.PCG64(fabric_seed)
        bg.advance(offset)
        gen = np.random.Generator(bg)
        u = gen.random((L, step_draws))
        b, occ, spec_state = occupancy_trace(
            p, u[:, : _ADVANCE_DRAWS * n_tors].reshape(L, _ADVANCE_DRAWS,
                                                       n_tors), state)
        # ToR-level prescreen: a path can only pause when some ToR
        # exceeds the threshold (same-ToR paths sit at idle occupancy),
        # which is rare — skip the per-flow work for cold steps.
        hot = (occ > p.pfc_threshold).any(axis=1)
        hidx = np.flatnonzero(hot)
        paused_h = np.zeros((hidx.size, n), dtype=bool)
        if hidx.size:
            occ_path_h = path_occupancy_trace(p, occ[hidx], src, dst)
            paused_h = occ_path_h > p.pfc_threshold
        alive1_h = paused_h & (
            u[hidx, _ADVANCE_DRAWS * n_tors:] < p.pfc_cascade_prob)
        cont_h = alive1_h.any(axis=1)
        j = int(hidx[np.argmax(cont_h)]) if cont_h.any() else L
        upto = min(j + 1, L)
        out_occ[t: t + upto] = occ[:upto]
        out_pfc[t: t + upto] = 0.0
        keep_h = hidx[hidx < upto]
        if keep_h.size:
            out_pfc[t + keep_h] = np.where(paused_h[: keep_h.size],
                                           p.pfc_pause_us, 0.0)
        if j < L:
            # step t+j: cascade survived its first draw — replay the
            # remaining iterations sequentially at the exact position.
            bg2 = np.random.PCG64(fabric_seed)
            extra_offset = offset + (j + 1) * step_draws
            bg2.advance(extra_offset)
            gen2 = np.random.Generator(bg2)
            alive = alive1_h[int(np.argmax(cont_h))].copy()
            total = out_pfc[t + j]
            total += np.where(alive, p.pfc_pause_us, 0.0)
            draws = 1
            while draws < p.pfc_max_cascade:
                alive = alive & (gen2.random(n) < p.pfc_cascade_prob)
                draws += 1
                if not alive.any():
                    break
                total += np.where(alive, p.pfc_pause_us, 0.0)
            offset = extra_offset + (draws - 1) * n
            # resume from the state *after* step t+j
            state = FabricState(bursting=b[j].copy(), occupancy=occ[j].copy())
            t += j + 1
            win = window
        else:
            state = spec_state
            offset += L * step_draws
            t += L
            win = min(win * 2, window_max)
    return out_occ, out_pfc
