"""Clos fabric + background-contention model.

Foreground collective packets are simulated per chunk; background load
is a Markov-modulated burst process per ToR uplink (on/off with
occupancy drawn per burst).  Occupancy determines queueing delay, ECN
marking probability, drop probability, and (for RoCE) PFC pause events.
All state is numpy-vectorized over nodes.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.transport.params import NetworkParams


@dataclasses.dataclass
class FabricState:
    """Per-node path-congestion state (node i's send path this step)."""
    bursting: np.ndarray      # (n_tors,) bool
    occupancy: np.ndarray     # (n_tors,) current uplink occupancy


class ClosFabric:
    """2-tier Clos: nodes -> ToR -> spine.  Ring neighbors that share a
    ToR traverse one hop; cross-ToR hops traverse the (contended) uplink.
    """

    def __init__(self, p: NetworkParams, rng: np.ndarray | None = None,
                 seed: int = 0):
        self.p = p
        self.n_tors = p.n_nodes // p.nodes_per_tor
        self.rng = np.random.default_rng(seed)
        self.state = FabricState(
            bursting=np.zeros(self.n_tors, dtype=bool),
            occupancy=np.full(self.n_tors, p.idle_occupancy),
        )

    def tor_of(self, node: np.ndarray) -> np.ndarray:
        return node // self.p.nodes_per_tor

    def advance(self) -> None:
        """One collective-step tick of the background burst process."""
        p, st, rng = self.p, self.state, self.rng
        start = rng.random(self.n_tors) < p.burst_on_prob
        stop = rng.random(self.n_tors) < p.burst_off_prob
        st.bursting = (st.bursting & ~stop) | (~st.bursting & start)
        burst_occ = rng.uniform(p.burst_occupancy_lo, p.burst_occupancy_hi,
                                self.n_tors)
        target = np.where(st.bursting, burst_occ, p.idle_occupancy)
        # occupancy relaxes toward target (queues drain/fill gradually)
        st.occupancy = 0.5 * st.occupancy + 0.5 * target

    def path_occupancy(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        """Occupancy seen by each (src,dst) transfer: max over traversed
        uplinks; same-ToR transfers see only local (near-idle) queues."""
        p = self.p
        ts, td = self.tor_of(src), self.tor_of(dst)
        up = self.state.occupancy[ts]
        down = self.state.occupancy[td]
        cross = np.maximum(up, down)
        same = np.full_like(cross, p.idle_occupancy)
        return np.where(ts == td, same, cross)

    # --- derived per-transfer quantities -----------------------------

    def queue_delay_us(self, occ: np.ndarray) -> np.ndarray:
        return self.p.queue_capacity_us * occ ** 3

    def avail_bandwidth(self, occ: np.ndarray) -> np.ndarray:
        """Fraction of line rate available to the foreground transfer."""
        p = self.p
        return np.clip(1.0 - p.bg_bandwidth_weight * occ, p.min_avail_frac, 1.0)

    def ecn_mark_prob(self, occ: np.ndarray) -> np.ndarray:
        p = self.p
        x = np.clip((occ - p.ecn_threshold) / (1 - p.ecn_threshold), 0, 1)
        return x

    def drop_prob(self, occ: np.ndarray) -> np.ndarray:
        p = self.p
        x = np.clip((occ - p.loss_knee) / (1 - p.loss_knee), 0, 1)
        return p.loss_max_prob * x ** 2

    def pfc_pause_us(self, occ: np.ndarray) -> np.ndarray:
        """RoCE only: PAUSE stalls when ingress exceeds the PFC threshold.
        A pause on a ToR uplink head-of-line-blocks *every* flow through
        that ToR; each pause propagates a further hop with probability
        ``pfc_cascade_prob`` (geometric storm, capped)."""
        p = self.p
        paused = occ > p.pfc_threshold
        total = np.where(paused, p.pfc_pause_us, 0.0)
        alive = paused.copy()
        for _ in range(p.pfc_max_cascade):
            alive = alive & (self.rng.random(occ.shape) < p.pfc_cascade_prob)
            if not alive.any():
                break
            total = total + np.where(alive, p.pfc_pause_us, 0.0)
        return total
