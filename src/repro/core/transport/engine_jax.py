"""Accelerator-native transport engine: a jitted ``lax.scan`` backend.

The numpy :class:`~repro.core.transport.engine.BatchedEngine` is the
bit-pinning source of truth — every seeded statistic in tests/data is
defined by its exact draw order and float op sequence.  This module is
the throughput backend: the same physics as
``BatchedEngine._traces_shared``, restructured so the rate-dependent
hot loop runs as pure ``jax.numpy`` ops under ``jit`` with the seed
axis vmapped.

Hybrid split (the replay contract decides what goes where)
----------------------------------------------------------
Everything that *consumes a random substream* stays host-side numpy,
block for block in the numpy engine's exact order — the burst chains,
the hot-row ECN/drop curves that gate CNP and loss draws, the PFC
cascade, and the per-design loss draws (via the shared helpers in
:mod:`designs`).  Loss draws depend only on the drop curve, never on
the DCQCN rate, so each design's recovery machinery reduces to two
dense rate-independent fields::

    excess_time = A + B * pkt_time        (reliable designs)
    delivered   = n_pkts - wire_losses    (celeris)

Everything *rate-dependent* runs jitted and vmapped over seeds: the
DCQCN recurrence as one ``lax.scan`` over steps (CNP steps apply
:func:`dcqcn.step_math`, calm gaps advance closed-form via
:func:`dcqcn.calm_ramp` inside the scan body — the same dual f32/f64
emission as ``rate_trace``), the queue/bandwidth response curves
(shared formula source: :mod:`network`), per-design completion times,
fault availability overlays, and the per-step reductions.  The fixed
round/phase window assembly has a jitted twin used by
``BatchedEngine.assemble`` under ``backend="jax"``.

Tolerance contract
------------------
The host pass replays the numpy engine's streams bit-exactly, so the
two backends see identical draws; the jitted arithmetic regroups a few
float accumulations (the A/B split above, XLA ``pow``/sum orderings),
leaving relative differences at the 1e-7 level on step traces.  The
A/B harness (``tests/test_engine_jax.py``) pins agreement on p99,
delivered fractions, per-tier loss and per-pod recombination to
``rtol=1e-5``.  Anything tighter than that is not part of the
contract — bit-level questions are always settled by the numpy
backend.
"""
from __future__ import annotations

import numpy as np

from repro.core.transport import dcqcn, designs, faults, network, topology
from repro.core.transport import engine as engine_mod
from repro.core.transport.params import SimParams

try:  # the repo runs on a CPU jax build; keep the module importable without
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64
    HAVE_JAX = True
    _JAX_ERR: Exception | None = None
except Exception as e:  # pragma: no cover - exercised only without jax
    HAVE_JAX = False
    _JAX_ERR = e

# Trace-time counter: incremented once per jit compilation of a core
# (the function body only runs while tracing).  The jit-cache-reuse
# test asserts a second identical call leaves it untouched.
TRACE_COUNT = [0]

# Compiled cores / window kernels per static configuration.  jit itself
# caches per input shape on top (one full block + at most one partial
# tail block per trace length).
_CORE_CACHE: dict = {}
_WINDOW_CACHE: dict = {}


def _require_jax():
    if not HAVE_JAX:  # pragma: no cover
        raise RuntimeError(
            f"backend='jax' needs a working jax install ({_JAX_ERR!r}); "
            "use backend='numpy'")


# ----------------------------------------------------------------------
# DCQCN recurrence as a scan (mirror of dcqcn.rate_trace)
# ----------------------------------------------------------------------

def _dcqcn_scan(cnp, cc, dq):
    """(tb, n) bool CNP block -> (tb, n) f32 rates + final f64 state.

    The carry holds the last *materialized* state (the anchor) plus the
    calm-gap length ``L`` since it.  Calm steps only bump ``L``; the
    emitted rate is the f32 closed-form ramp from the anchor — exactly
    ``rate_trace``'s gap fill.  A CNP step advances the anchor in f64
    closed form, applies :func:`dcqcn.step_math`, emits the advanced
    (pre-step) rate, and resets ``L`` — exactly the sequential
    ``use rate; step()`` order.  The block end materializes the
    trailing gap, matching ``rate_trace``'s final ``_advance_calm``.
    """
    decay = np.float64(1.0 - dq.alpha_g)

    def body(carry, cnp_t):
        r, t, a, g, L = carry
        any_t = cnp_t.any()
        # dual emission, as in rate_trace: calm steps fill the trace
        # from the f32-cast anchor; CNP steps emit the f64-advanced
        # state cast to f32
        calm32 = dcqcn.calm_ramp(r.astype(jnp.float32),
                                 t.astype(jnp.float32), g, L, dq,
                                 dtype=np.float32, xp=jnp)
        r64 = dcqcn.calm_ramp(r, t, g, L, dq, dtype=np.float64, xp=jnp)
        emit = jnp.where(any_t, r64.astype(jnp.float32), calm32)
        a_adv = a * jnp.power(decay, L.astype(jnp.float64))
        g_adv = g + L
        r_s, t_s, a_s, g_s = dcqcn.step_math(r64, t, a_adv, g_adv,
                                             cnp_t, dq, xp=jnp)
        new = (jnp.where(any_t, r_s, r), jnp.where(any_t, t_s, t),
               jnp.where(any_t, a_s, a), jnp.where(any_t, g_s, g),
               jnp.where(any_t, jnp.int32(0), L + 1))
        return new, emit

    carry0 = (cc["rate"], cc["target"], cc["alpha"], cc["good"],
              jnp.int32(0))
    (r, t, a, g, L), rates = lax.scan(body, carry0, cnp)
    cc_out = {
        "rate": dcqcn.calm_ramp(r, t, g, L, dq, dtype=np.float64, xp=jnp),
        "target": t,
        "alpha": a * jnp.power(decay, L.astype(jnp.float64)),
        "good": g + L}
    return rates, cc_out


# ----------------------------------------------------------------------
# The jitted per-block core (vmapped over the seed axis)
# ----------------------------------------------------------------------

def _phase_statics(p: SimParams, plan, hgs, ph_pkts, ph_fan, ph_inc):
    """Static per-phase column vectors the rate assembly multiplies by.

    The DCI oversubscription and incast fan divisors are data-independent
    per column, so they fold into ``(n_flows,)`` constants applied to
    every step of the phase — multiplying/dividing the untouched
    columns by exactly 1.0 keeps them bit-identical to the numpy
    engine's sliced in-place mutations.
    """
    hier = p.topo.hierarchical
    out = []
    for k, ph in enumerate(plan.phases):
        f = ph.src.size
        s = dict(src=ph.src, n_pkts=ph_pkts[k],
                 tier_cols=hgs[k].tier_cols,
                 pod_cols=hgs[k].pod_cols if hier else None,
                 qd_mult=None, o_div=None, dci_add=None, fan_div=None)
        x = hgs[k].cross
        if hier and x.size:
            o32 = topology.dci_oversub_factor(p.topo, hgs[k])
            qm = np.ones(f, np.float32)
            qm[x] = o32
            od = np.ones(f, np.float32)
            od[x] = o32
            da = np.zeros(f, np.float32)
            da[x] = np.float32(p.topo.dci_rtt_us / 2.0)
            s.update(qd_mult=qm, o_div=od, dci_add=da)
        inc = ph_inc[k]
        if inc.size:
            # numpy does eff_rate[:, inc] /= fan (an f64 divide cast
            # back to f32 by the in-place ufunc); the f64 round trip
            # below reproduces that bit-for-bit, and is the exact
            # identity on the fan-1 columns
            fd = np.ones(f, np.float64)
            fd[inc] = ph_fan[k][inc]
            s["fan_div"] = fd
        out.append(s)
    return out


def _make_core(p: SimParams, plan, hgs, design_list, n, steps,
               ph_pkts, ph_steps, ph_fan, ph_inc, identity_plan):
    net, rel, dq = p.net, p.rel, p.dcqcn
    has_faults = p.fault.active
    use_rate_scale = p.fault.straggler_frac > 0
    single = plan.single_phase
    stat = _phase_statics(p, plan, hgs, ph_pkts, ph_fan, ph_inc)
    detect_for = {"roce": rel.rto_us, "irn": rel.rto_low_us,
                  "srnic": rel.rto_low_us + rel.host_slowpath_us}

    def core_one(inp):
        TRACE_COUNT[0] += 1
        cnp = inp["cnp"]
        tb = cnp.shape[0]                       # static under jit
        round0 = np.arange(0, tb, steps)
        rates, cc_out = _dcqcn_scan(cnp, inp["cc"], dq)
        out_phases = []
        for k, s in enumerate(stat):
            ph_in = inp["phases"][k]
            occ32 = ph_in["occ32"]
            if identity_plan:
                rate_ph = rates
            elif single:
                rate_ph = rates[:, s["src"]]
            else:
                rows = (round0[:, None] + ph_steps[k][None, :]).ravel()
                rate_ph = rates[rows[:, None], s["src"][None, :]]
            # response curves: the same formula source as the numpy
            # engine (network.py), evaluated on the final mutated
            # occupancies, with the DCI overlay folded into static
            # column multipliers
            qd = network.queue_delay_us(net, occ32)
            if s["qd_mult"] is not None:
                qd = qd * s["qd_mult"]
            eff = rate_ph * network.avail_bandwidth(net, occ32)
            if s["o_div"] is not None:
                eff = eff / s["o_div"]
            if s["fan_div"] is not None:
                eff = (eff.astype(jnp.float64)
                       / s["fan_div"]).astype(jnp.float32)
            if use_rate_scale:
                eff = eff * inp["rate_scale"][s["src"]]
            pkt_time = net.pkt_time_us / jnp.maximum(eff, 1e-3)
            ptf64 = pkt_time.astype(jnp.float64)
            serialize = s["n_pkts"] * pkt_time
            blocked = ph_in["blocked"] if has_faults else None
            dead = ph_in["dead"] if has_faults else None
            alive = (~dead).astype(jnp.float64) if has_faults else None
            per_design = {}
            for d in design_list:
                dd = ph_in["designs"][d]
                if d == "celeris":
                    t = (serialize + designs.CELERIS_QUEUE_OVERLAP * qd
                         + net.base_rtt_us / 2)
                    deliv = dd["deliv"]
                else:
                    t = serialize + qd + net.base_rtt_us / 2
                    if d == "roce":
                        t = t + ph_in["pfc"]
                    ex = (dd["A"].astype(jnp.float64)
                          + dd["B"].astype(jnp.float64) * ptf64)
                    t = t + ex.astype(jnp.float32)
                if s["dci_add"] is not None:
                    t = t + s["dci_add"]
                if has_faults:
                    # faults.apply_to_result, as where-ops
                    if d == "celeris":
                        deliv = jnp.where(blocked, 0.0, deliv)
                        deliv = jnp.where(dead, 0.0, deliv)
                    else:
                        t = jnp.where(blocked,
                                      2.0 * t + np.float32(detect_for[d]),
                                      t)
                        t = jnp.where(
                            dead,
                            t + np.float32(detect_for[d]
                                           * (1 + rel.max_retries)),
                            t)
                nat = t.max(axis=-1)
                if d == "celeris":
                    dsum = deliv.sum(axis=-1)
                    tier = jnp.stack([deliv[:, c].sum(axis=-1)
                                      for c in s["tier_cols"]], axis=-1)
                    pod = (jnp.stack([deliv[:, c].sum(axis=-1)
                                      for c in s["pod_cols"]], axis=-1)
                           if s["pod_cols"] is not None else None)
                elif has_faults:
                    # reliable designs deliver everything a live flow
                    # offers; only dead flows zero out
                    npk = np.float64(s["n_pkts"])
                    dsum = npk * alive.sum(axis=-1)
                    tier = jnp.stack([npk * alive[:, c].sum(axis=-1)
                                      for c in s["tier_cols"]], axis=-1)
                    pod = (jnp.stack([npk * alive[:, c].sum(axis=-1)
                                      for c in s["pod_cols"]], axis=-1)
                           if s["pod_cols"] is not None else None)
                else:
                    # constant offered=delivered sums; the host fills
                    # them without a device round trip
                    dsum = tier = pod = None
                per_design[d] = dict(nat=nat, deliv=dsum, tier=tier,
                                     pod=pod)
            out_phases.append(per_design)
        return {"cc": cc_out, "phases": out_phases}

    return jax.jit(jax.vmap(core_one))


def _core_for(p: SimParams, plan, hgs, design_list, n, steps,
              ph_pkts, ph_steps, ph_fan, ph_inc, identity_plan):
    key = (repr(p), tuple(design_list), n, steps,
           tuple((ph.src.tobytes(), ph.dst.tobytes(), int(ph.n_steps),
                  int(ph.payload_bytes)) for ph in plan.phases))
    core = _CORE_CACHE.get(key)
    if core is None:
        core = _make_core(p, plan, hgs, design_list, n, steps, ph_pkts,
                          ph_steps, ph_fan, ph_inc, identity_plan)
        _CORE_CACHE[key] = core
    return core


# ----------------------------------------------------------------------
# Host-side stream replay (the draw pass)
# ----------------------------------------------------------------------

class _SeedStreams:
    """One seed's generators + carried chain states, consumed block by
    block in ``_traces_shared``'s exact order (the replay contract)."""

    def __init__(self, eng, seed: int, design_list, hier: bool,
                 incast: bool):
        p = eng.p
        g = eng._geometry(seed)
        self.g = g
        net = p.net
        n, n_tors, steps = g["n"], g["n_tors"], g["steps"]
        self.fabric_gen = np.random.default_rng(g["fabric_seed"])
        self.cnp_gen = np.random.default_rng([seed, engine_mod._STREAM_CNP])
        self.pfc_gen = np.random.default_rng([seed, engine_mod._STREAM_PFC])
        self.transfer_gens = {
            d: np.random.default_rng(
                [seed, engine_mod._STREAM_TRANSFER[d]])
            for d in design_list}
        self.fab_state = network.FabricState(
            bursting=np.zeros(n_tors, dtype=bool),
            occupancy=np.full(n_tors, net.idle_occupancy))
        if hier:
            self.dci_state = topology.init_dci_state(net, p.topo)
            self.dci_fab_gen = np.random.default_rng(
                [g["fabric_seed"], topology.STREAM_DCI_FABRIC])
            self.dci_cnp_gen = np.random.default_rng(
                [seed, topology.STREAM_DCI_CNP])
        if incast:
            self.inc_cnp_gen = np.random.default_rng(
                [seed, engine_mod._STREAM_INCAST_CNP])
        self.fmodel = (faults.FaultModel(p, seed, n, n_tors, steps)
                       if p.fault.active else None)
        self.rate_scale = np.ones(n, dtype=np.float32)
        if self.fmodel is not None and self.fmodel.rate_scale is not None:
            self.rate_scale = self.fmodel.rate_scale


def _design_draws(d, n_pkts, drop_p, rel, net, rng, shape):
    """One design-phase's loss draws, reduced to dense rate-independent
    fields: ``A + B * pkt_time`` excess for the reliable designs,
    delivered packets for celeris.  Draw order and the drop-capable
    subset are exactly ``designs.transfer``'s (shared helpers)."""
    if d == "celeris":
        deliv = np.full(shape, n_pkts, dtype=np.float32)
        idx = np.flatnonzero(drop_p > 0)
        if idx.size:
            pf = np.ascontiguousarray(drop_p).ravel()[idx]
            deliv.flat[idx] -= designs.celeris_loss_draws(n_pkts, pf, rng)
        return {"deliv": deliv}
    A = np.zeros(shape, dtype=np.float32)
    B = np.zeros(shape, dtype=np.float32)
    if d == "roce":
        p_eff = drop_p * designs.PFC_DROP_SUPPRESSION
        idx = np.flatnonzero(p_eff > 0)
        if idx.size:
            pf = np.ascontiguousarray(p_eff).ravel()[idx]
            a = np.zeros(idx.size)
            b = np.zeros(idx.size)
            for has_loss, n_resend, detect in designs.roce_loss_episodes(
                    n_pkts, pf, rel, net, rng):
                a += np.where(has_loss, detect, 0.0)
                b += np.where(has_loss, n_resend, 0.0)
            A.flat[idx] = a
            B.flat[idx] = b
    else:  # irn / srnic
        idx = np.flatnonzero(drop_p > 0)
        if idx.size:
            pf = np.ascontiguousarray(drop_p).ravel()[idx]
            k, tail_lost, k2 = designs.sr_loss_draws(n_pkts, pf, rng)
            detect = np.where(tail_lost, rel.rto_low_us,
                              rel.nack_delay_us + net.base_rtt_us)
            a = (np.where(k > 0, detect, 0.0)
                 + np.where(k2 > 0, rel.rto_low_us, 0.0))
            if d == "srnic":
                a += k * rel.host_slowpath_us
            b = np.where(k > 0, k, 0.0) + np.where(k2 > 0, k2, 0.0)
            A.flat[idx] = a
            B.flat[idx] = b
    return {"A": A, "B": B}


def _stack_seeds(host_inputs):
    """Stack a list of per-seed input pytrees along a new leading axis."""
    return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *host_inputs)


# ----------------------------------------------------------------------
# Entry point
# ----------------------------------------------------------------------

def traces_batched(eng, design_list, n_rounds: int, seeds, *,
                   round_block: int | None = None):
    """Physics traces for every seed in ``seeds``, one jitted pass per
    round block with the seed axis vmapped.  Returns one
    ``{design: StepTrace}`` dict per seed, interchangeable (within the
    tolerance contract) with ``BatchedEngine.traces(...,
    legacy_streams=False)`` per seed.
    """
    _require_jax()
    p = eng.p
    net, rel = p.net, p.rel
    unknown = [d for d in design_list if d not in designs.DESIGNS]
    if unknown:
        raise ValueError(f"unknown design(s) {unknown}; "
                         f"choose from {designs.DESIGNS}")
    if net.n_nodes < net.nodes_per_tor or net.n_nodes % net.nodes_per_tor:
        raise ValueError(
            f"n_nodes={net.n_nodes} must be a positive multiple of "
            f"nodes_per_tor={net.nodes_per_tor}")
    if net.ecn_threshold > net.loss_knee:
        raise ValueError(
            f"ecn_threshold={net.ecn_threshold} must not exceed "
            f"loss_knee={net.loss_knee}")
    if eng.recorder is not None:
        raise ValueError("a TraceRecorder requires backend='numpy' "
                         "(the recorder hooks ride the numpy per-phase "
                         "pass)")
    design_list = list(design_list)
    seeds = [int(s) for s in seeds]
    S = len(seeds)
    if S == 0:
        return []

    g0 = eng._geometry(seeds[0])
    n, steps, n_tors = g0["n"], g0["steps"], g0["n_tors"]
    plan = g0["plan"]
    T = n_rounds * steps
    if round_block is None:
        # the numpy default, and not negotiable: the host pass must
        # consume the PFC/transfer streams in the numpy engine's exact
        # block partition or the draws land on different cells
        round_block = max(1, engine_mod._BLOCK_ELEMENTS // (steps * n))
    block_steps = round_block * steps

    hier = p.topo.hierarchical
    dci_net = topology.dci_net_params(net, p.topo) if hier else None
    hgs = plan.geometries(net, p.topo)
    ph_pkts = [ph.n_pkts(net) for ph in plan.phases]
    ph_steps = [np.flatnonzero(plan.phase_of_step == k)
                for k in range(len(plan.phases))]
    ph_fan = [ph.fan_in() for ph in plan.phases]
    ph_inc = [np.flatnonzero(f > 1) for f in ph_fan]
    identity_plan = plan.single_phase and np.array_equal(
        plan.phases[0].src, np.arange(n))
    incast = any(inc.size for inc in ph_inc)
    has_faults = p.fault.active
    has_roce = "roce" in design_list
    single = plan.single_phase
    ph_pod_cols = [hg.pod_cols for hg in hgs] if hier else None

    core = _core_for(p, plan, hgs, tuple(design_list), n, steps,
                     ph_pkts, ph_steps, ph_fan, ph_inc, identity_plan)

    streams = [_SeedStreams(eng, s, design_list, hier, incast)
               for s in seeds]
    outs = [eng._new_traces(
        design_list, T, steps, n, (),
        tier_cols=hgs[0].tier_cols if single else None,
        tier_counts=plan.tier_counts(net, p.topo, hgs),
        tier_pkts_round=plan.tier_pkts_round(net, p.topo, hgs),
        phase_of_step=plan.phase_of_step,
        phase_budget_frac=plan.budget_fracs(),
        phase_src=tuple(ph.src for ph in plan.phases),
        phase_tier_cols=tuple(hg.tier_cols for hg in hgs),
        phase_pod_cols=tuple(ph_pod_cols) if hier else None,
        n_pods=p.topo.n_pods if hier else 0,
        pod_pkts_round=(plan.pod_pkts_round(net, p.topo, hgs)
                        if hier else None),
        step_priority=plan.step_priority()) for _ in seeds]
    fault_flows = ([np.zeros(T) for _ in seeds] if has_faults else None)

    def host_block(st: _SeedStreams, t0: int, tb: int, si: int):
        """One seed's draw pass for steps [t0, t0+tb): exactly
        ``_traces_shared``'s stream consumption, minus the
        rate-dependent math the core does."""
        u = st.fabric_gen.random((tb, network._ADVANCE_DRAWS, n_tors))
        _, occ_tor, st.fab_state = network.occupancy_trace(
            net, u, st.fab_state)
        occ_dci = None
        if hier:
            u_dci = st.dci_fab_gen.random(
                (tb, network._ADVANCE_DRAWS, p.topo.n_pods))
            _, occ_dci, st.dci_state = network.occupancy_trace(
                dci_net, u_dci, st.dci_state)
        cnp = np.zeros((tb, n), dtype=bool)
        round0 = np.arange(0, tb, steps)
        ph_host = []
        # phase pass 1: curves + CNP draws (numpy engine order)
        for k, ph in enumerate(plan.phases):
            rows = (round0[:, None] + ph_steps[k][None, :]).ravel()
            occ_ph = occ_tor[rows] if not single else occ_tor
            ecn_p, drop_p, hot = engine_mod._sparse_path_curves(
                net, occ_ph, ph.src, ph.dst)
            occ32 = network.path_occupancy_trace(
                net, occ_ph.astype(np.float32), ph.src, ph.dst)
            occ_eff = None
            if hier:
                occ_eff = topology.overlay_curves(
                    net, p.topo, hgs[k], occ_ph,
                    occ_dci[rows] if not single else occ_dci,
                    ecn_p, drop_p)
            cnp_ph = np.zeros((rows.size, ph.src.size), dtype=bool)
            cnp_ph[hot] = (st.cnp_gen.random((hot.size, ph.src.size))
                           < ecn_p[hot])
            if hier:
                topology.dci_cnp_draws(hgs[k], ecn_p, cnp_ph,
                                       st.dci_cnp_gen)
            inc = ph_inc[k]
            if inc.size:
                occ_inc = np.maximum(occ32[:, inc],
                                     (1.0 - 1.0 / ph_fan[k][inc]
                                      ).astype(occ32.dtype))
                occ32[:, inc] = occ_inc
                ecn_inc = network.ecn_mark_prob(net, occ_inc)
                drop_p[:, inc] = network.drop_prob(net, occ_inc)
                cnp_ph[:, inc] = (st.inc_cnp_gen.random(occ_inc.shape)
                                  < ecn_inc)
            cnp[np.ix_(rows, ph.src)] = cnp_ph
            ph_host.append([rows, occ32, drop_p, occ_eff])

        blk = st.fmodel.advance(t0, tb) if st.fmodel is not None else None

        # phase pass 2: final occupancy mutation + fault masks
        for k, ph in enumerate(plan.phases):
            rows, occ32, drop_p, occ_eff = ph_host[k]
            if hier and hgs[k].cross.size:
                occ32[:, hgs[k].cross] = occ_eff.astype(np.float32)
            blocked = dead = None
            if st.fmodel is not None:
                blocked, dead = st.fmodel.phase_masks(
                    blk, rows, ph, hgs[k], net.nodes_per_tor)
                nf = ((blocked.sum(axis=1) if blocked is not None else 0)
                      + (dead.sum(axis=1) if dead is not None else 0))
                fault_flows[si][t0 + rows] = nf
            ph_host[k] = [rows, occ32, drop_p, blocked, dead, None, {}]

        # design loop: PFC + loss draws (numpy engine order — the PFC
        # stream is consumed only on the roce iterations, per phase)
        for d in design_list:
            for k in range(len(plan.phases)):
                rows, occ32, drop_p, blocked, dead, pfc, dd = ph_host[k]
                if d == "roce":
                    pfc = network.pfc_pause_trace(net, occ32, st.pfc_gen)
                    ph_host[k][5] = pfc
                dd[d] = _design_draws(d, ph_pkts[k], drop_p, rel, net,
                                      st.transfer_gens[d], occ32.shape)

        phases_in = []
        for k in range(len(plan.phases)):
            rows, occ32, drop_p, blocked, dead, pfc, dd = ph_host[k]
            ph_in = {"occ32": occ32, "designs": dd}
            if has_roce:
                ph_in["pfc"] = pfc
            if has_faults:
                shape = occ32.shape
                ph_in["blocked"] = (blocked if blocked is not None
                                    else np.zeros(shape, dtype=bool))
                ph_in["dead"] = (dead if dead is not None
                                 else np.zeros(shape, dtype=bool))
            phases_in.append(ph_in)
        return {"cnp": cnp, "phases": phases_in}

    cc = {"rate": np.ones((S, n)), "target": np.ones((S, n)),
          "alpha": np.ones((S, n)),
          "good": np.zeros((S, n), dtype=np.int32)}
    rate_scales = np.stack([st.rate_scale for st in streams])

    with enable_x64():
        for t0 in range(0, T, block_steps):
            tb = min(block_steps, T - t0)
            host = [host_block(st, t0, tb, si)
                    for si, st in enumerate(streams)]
            inp = _stack_seeds(host)
            inp["cc"] = cc
            inp["rate_scale"] = rate_scales
            res = jax.device_get(core(inp))
            cc = res["cc"]
            for si in range(S):
                _scatter_block(outs[si], res, si, t0, plan, ph_steps,
                               ph_pkts, hgs, ph_pod_cols, tb, steps,
                               has_faults)

    if has_faults:
        for si in range(S):
            for tr in outs[si].values():
                tr.fault_flows = fault_flows[si]
    return outs


def _scatter_block(out, res, si, t0, plan, ph_steps, ph_pkts, hgs,
                   ph_pod_cols, tb, steps, has_faults):
    """Write one seed's block of core outputs into its StepTraces; the
    offered totals are schedule constants filled host-side."""
    round0 = np.arange(0, tb, steps)
    for k, ph in enumerate(plan.phases):
        rows = t0 + (round0[:, None] + ph_steps[k][None, :]).ravel()
        f = ph.src.size
        n_pkts = ph_pkts[k]
        for d, tr in out.items():
            o = res["phases"][k][d]
            tr.nat_us[rows] = o["nat"][si]
            tr.total[rows] = float(n_pkts * f)
            if o["deliv"] is not None:
                tr.deliv[rows] = o["deliv"][si]
            else:
                tr.deliv[rows] = float(n_pkts * f)
            if tr.tier_deliv is not None:
                for kt, cols in enumerate(hgs[k].tier_cols):
                    tr.tier_total[rows, kt] = float(n_pkts * cols.size)
                    if o["tier"] is not None:
                        tr.tier_deliv[rows, kt] = o["tier"][si][:, kt]
                    else:
                        tr.tier_deliv[rows, kt] = float(n_pkts * cols.size)
            if tr.pod_deliv is not None and ph_pod_cols is not None:
                for kp, cols in enumerate(ph_pod_cols[k]):
                    tr.pod_total[rows, kp] = float(n_pkts * cols.size)
                    if o["pod"] is not None:
                        tr.pod_deliv[rows, kp] = o["pod"][si][:, kp]
                    else:
                        tr.pod_deliv[rows, kp] = float(n_pkts * cols.size)


# ----------------------------------------------------------------------
# Jitted fixed bounded-window assembly
# ----------------------------------------------------------------------

def _make_window(ph_rows, ph_frac, n_groups, perms=None):
    """Jitted twin of ``BatchedEngine._assemble_phase_window_fixed``
    (which the round window is the single-phase case of).

    ``perms`` (``cut_order="priority"``; one static permutation per
    phase block) mirrors ``engine._priority_survive``: each over-budget
    block's cut is reallocated across steps in the static priority
    order, leaving times and total delivered packets untouched."""
    invs = ([np.argsort(p) for p in perms] if perms is not None else None)

    def fn(nat, deliv, budget_us, group_delivs):
        R = nat.shape[0]
        times = jnp.zeros(R)
        got = jnp.zeros(R)
        got_g = [jnp.zeros((R, g.shape[2])) for g in group_delivs]
        for k, rows in enumerate(ph_rows):
            b_k = budget_us * ph_frac[k]
            nat_k = nat[:, rows]
            cum = jnp.cumsum(nat_k, axis=1)
            total_t = cum[:, -1]
            over = total_t > b_k
            times = times + jnp.where(over, b_k, total_t)
            done = cum <= b_k
            bidx = jnp.argmax(~done, axis=1)
            prev = jnp.where(
                bidx > 0,
                jnp.take_along_axis(cum, jnp.maximum(bidx - 1, 0)[:, None],
                                    axis=1)[:, 0],
                0.0)
            d_k = deliv[:, rows]
            part = (b_k - prev) / jnp.maximum(
                jnp.take_along_axis(nat_k, bidx[:, None], axis=1)[:, 0],
                1e-9)
            got_k = ((d_k * done).sum(axis=1)
                     + jnp.take_along_axis(d_k, bidx[:, None],
                                           axis=1)[:, 0] * part)
            got = got + jnp.where(over, got_k, d_k.sum(axis=1))
            survive = None
            if perms is not None:
                K = jnp.where(over, d_k.sum(axis=1) - got_k, 0.0)
                d_perm = d_k[:, perms[k]]
                cum_d = jnp.cumsum(d_perm, axis=1)
                cutfrac = jnp.clip(
                    (K[:, None] - (cum_d - d_perm))
                    / jnp.maximum(d_perm, 1e-30), 0.0, 1.0)
                survive = (1.0 - cutfrac)[:, invs[k]]
            for i in range(n_groups):
                gd_k = group_delivs[i][:, rows]
                if survive is not None:
                    cut = (gd_k * survive[:, :, None]).sum(axis=1)
                else:
                    cut = ((gd_k * done[:, :, None]).sum(axis=1)
                           + gd_k[jnp.arange(R), bidx] * part[:, None])
                got_g[i] = got_g[i] + jnp.where(over[:, None], cut,
                                                gd_k.sum(axis=1))
        return times, got, got_g

    return jax.jit(fn)


def assemble_window_fixed(nat, deliv, tot_sum, budget_us, groups,
                          ph_rows, ph_frac, perms=None):
    """Fixed round/phase bounded window on (R, steps) arrays, jitted.

    Same signature contract as the numpy fixed-window helpers: returns
    ``(times, fracs, group_fracs)``.  Pass a single phase covering the
    round for the round window; ``perms`` selects the priority cut
    order (one static permutation per phase block, None = arrival).
    """
    _require_jax()
    ph_rows = [np.asarray(r) for r in ph_rows]
    ph_frac = np.asarray(ph_frac, dtype=np.float64)
    if perms is not None:
        perms = [np.asarray(p) for p in perms]
    key = (tuple(r.tobytes() for r in ph_rows), ph_frac.tobytes(),
           len(groups), nat.shape[1],
           None if perms is None else tuple(p.tobytes() for p in perms))
    fn = _WINDOW_CACHE.get(key)
    if fn is None:
        fn = _make_window(ph_rows, ph_frac, len(groups), perms=perms)
        _WINDOW_CACHE[key] = fn
    with enable_x64():
        times, got, got_g = jax.device_get(
            fn(nat, deliv, np.float64(budget_us),
               [gd for gd, _ in groups]))
    fracs = np.asarray(got) / tot_sum
    g_fracs = [engine_mod._tier_frac(np.asarray(gg), gt.sum(axis=1))
               for gg, (_, gt) in zip(got_g, groups)]
    return np.asarray(times), fracs, g_fracs
