"""Bit-exact replay of the sequential simulator's per-design draw streams.

The pre-refactor :class:`CollectiveSimulator` consumed one
``default_rng(seed)`` stream per design run, interleaving every draw
(loss binomials, tail-loss uniforms, CNP uniforms) step by step.  A
vectorized engine cannot call the generator in that order — but for the
designs whose consumption pattern is *deterministic given the fabric
trace* it can reproduce the stream exactly:

- numpy's ``Generator.binomial`` consumes exactly one uniform per
  element when ``0 < p`` and ``n > 0`` (inversion sampling holds
  whenever ``n*p <= 30`` — always true for the paper's loss model),
  and **zero** uniforms when ``p == 0`` or ``n == 0``;
- ``random(n)`` consumes ``n`` uniforms;
- the drop probability is 0 exactly whenever path occupancy is below
  the loss knee, which is known from the (bit-exact) fabric trace.

So the whole stream is one flat uniform buffer indexed by closed-form
offsets: **celeris** (per step ``[binomial(m) | cnp n]``) is fully
static; **irn/srnic** (per step ``[binomial(m1) | tail n |
binomial(m2) | cnp n]``) needs one cheap sequential pass to resolve
``m2`` (the count of first-pass losses, itself a threshold test on the
already-positioned uniforms) before the batched gathers.  The
binomials are sampled with an exact vectorized replica of numpy's
``random_binomial_inversion`` arithmetic.

**RoCE cannot be replayed this way**: its retry loop calls
``integers``, whose masked-rejection sampling consumes a
data-dependent number of raw words.  The engine keeps engine-native
draws for RoCE transfers (a few percent of p99 noise, bounded by the
bit-exact fabric replay in :func:`network.roce_fabric_trace`).

The adaptive bounded-window controller's per-round ``normal`` draws are
likewise not replayed (ziggurat consumption is data-dependent); replay
therefore covers ``adaptive=False`` protocols — which is exactly the
paper's Fig.-2 configuration — and the engine falls back to
engine-native streams elsewhere.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def binomial_inversion(U: np.ndarray, n, p: np.ndarray) -> np.ndarray:
    """Exact replica of numpy's ``random_binomial_inversion`` arithmetic.

    Valid for ``n * p <= 30`` (the caller's regime); the bound-restart
    branch (probability ~1e-11 per draw) is asserted absent — hitting it
    would mean numpy consumed an extra uniform and the replay must not
    silently desynchronize.
    """
    p = np.asarray(p, dtype=np.float64)
    U = np.array(U, dtype=np.float64)
    n = np.broadcast_to(np.asarray(n), p.shape).astype(np.int64)
    q = 1.0 - p
    qn = np.exp(n * np.log(q))
    np_ = n * p
    bound = np.minimum(n, (np_ + 10.0 * np.sqrt(np_ * q + 1))).astype(np.int64)
    X = np.zeros(p.shape, dtype=np.int64)
    px = qn.copy()
    act = U > px
    while act.any():
        X[act] += 1
        if (X[act] > bound[act]).any():
            raise RuntimeError("binomial inversion bound restart — "
                               "stream replay would desynchronize")
        U[act] -= px[act]
        Xa = X[act]
        px[act] = ((n[act] - Xa + 1) * p[act] * px[act]) / (Xa * q[act])
        act = U > px
    return X


@dataclasses.dataclass
class SelectiveRepeatDraws:
    """Replayed irn/srnic draws (identical streams in the seed impl)."""
    k: np.ndarray          # (T, n) first-pass losses
    tail_lost: np.ndarray  # (T, n) bool
    k2: np.ndarray         # (T, n) second-pass losses
    cnp: np.ndarray        # (T, n) bool


@dataclasses.dataclass
class CelerisDraws:
    k: np.ndarray          # (T, n) dropped packets
    cnp: np.ndarray        # (T, n) bool


def _uniform_buffer(seed: int, total: int) -> np.ndarray:
    """The run's sim stream: one fabric-seed ``integers`` word, then
    ``total`` uniforms."""
    gen = np.random.default_rng(seed)
    gen.integers(2**31)
    return gen.random(total)


def _flat_mask_positions(mask: np.ndarray, starts: np.ndarray) -> np.ndarray:
    """Absolute buffer position of each True entry of ``mask`` (row-major
    — numpy's elementwise order), given each row's segment start."""
    rows, cols = np.nonzero(mask)
    rank = np.arange(rows.size) - np.repeat(
        np.concatenate([[0], np.cumsum(mask.sum(axis=1))[:-1]]), mask.sum(axis=1))
    return starts[rows] + rank


def replay_selective_repeat(seed: int, n_pkts: int, drop_p: np.ndarray,
                            ecn_prob: np.ndarray) -> SelectiveRepeatDraws:
    """Replay an irn/srnic run's stream: per step
    ``[binomial(m1) | tail n | binomial(count k>0) | cnp n]``."""
    T, n = drop_p.shape
    mask = drop_p > 0
    m1 = mask.sum(axis=1)
    total_m1 = int(m1.sum())
    U = _uniform_buffer(seed, T * 2 * n + 2 * total_m1)

    msteps = np.flatnonzero(m1 > 0)
    # qn per masked entry (row-major): k > 0  <=>  U > (1-p)^n_pkts
    p_flat = drop_p[mask]
    qn_flat = np.exp(n_pkts * np.log(1.0 - p_flat))
    m1s = m1[msteps]
    ends = np.cumsum(m1s)
    qn_start = np.concatenate([[0], ends[:-1]])

    # sequential offset walk: only m2 (count of first-pass losses) makes
    # the layout data-dependent, and it is a threshold test on uniforms
    # whose positions are already known at that point.
    starts = np.empty(msteps.size, dtype=np.int64)     # k-draw block start
    m2s = np.empty(msteps.size, dtype=np.int64)
    extra = 0
    for i in range(msteps.size):
        t = msteps[i]
        ofs = 2 * n * t + extra
        starts[i] = ofs
        mi = m1s[i]
        m2 = int((U[ofs: ofs + mi]
                  > qn_flat[qn_start[i]: qn_start[i] + mi]).sum())
        m2s[i] = m2
        extra += mi + m2

    # batched gathers + one inversion call per binomial family
    k = np.zeros((T, n), dtype=np.int16)
    abs_start = np.zeros(T, dtype=np.int64)
    abs_start[msteps] = starts
    k_pos = _flat_mask_positions(mask, abs_start)
    k[mask] = binomial_inversion(U[k_pos], n_pkts, p_flat)

    tail = np.zeros((T, n), dtype=bool)
    tail_starts = starts + m1s
    tail[msteps] = (U[tail_starts[:, None] + np.arange(n)]
                    < drop_p[msteps])

    mask2 = k > 0
    k2 = np.zeros((T, n), dtype=np.int16)
    if mask2.any():
        abs2 = np.zeros(T, dtype=np.int64)
        abs2[msteps] = tail_starts + n
        k2_pos = _flat_mask_positions(mask2, abs2)
        k2[mask2] = binomial_inversion(U[k2_pos], k[mask2], drop_p[mask2])

    # CNP block: calm steps advance uniformly (2n per step) — slice
    # contiguous runs; masked steps gathered individually.
    cnp = np.zeros((T, n), dtype=bool)
    cnp_start_m = tail_starts + n + m2s
    cnp[msteps] = (U[cnp_start_m[:, None] + np.arange(n)]
                   < ecn_prob[msteps])
    _calm_cnp_runs(U, ecn_prob, cnp, msteps, T, n, stride=2 * n,
                   extra_after=np.cumsum(m1s + m2s))
    return SelectiveRepeatDraws(k=k, tail_lost=tail, k2=k2, cnp=cnp)


def _calm_cnp_runs(U, ecn_prob, cnp, msteps, T, n, stride, extra_after):
    """Fill CNPs for the calm runs between masked steps.

    A calm step consumes ``stride`` uniforms ([tail n | cnp n] for
    irn/srnic, [cnp n] for celeris) with the CNP block last, so a run of
    L calm steps is one contiguous ``(L, stride)`` slice.
    """
    bounds = np.concatenate([[-1], msteps, [T]])
    cum_extra = np.concatenate([[0], extra_after])
    for i in range(bounds.size - 1):
        a, b = int(bounds[i]) + 1, int(bounds[i + 1])
        if a >= b:
            continue
        ofs = stride * a + int(cum_extra[i])
        u = U[ofs: ofs + (b - a) * stride].reshape(b - a, stride)
        cnp[a:b] = u[:, stride - n:] < ecn_prob[a:b]


def replay_celeris(seed: int, n_pkts: int, drop_p: np.ndarray,
                   ecn_prob: np.ndarray) -> CelerisDraws:
    """Replay a celeris (adaptive=False) run: per step
    ``[binomial(m1) | cnp n]`` — the layout is fully static."""
    T, n = drop_p.shape
    mask = drop_p > 0
    m1 = mask.sum(axis=1)
    total_m1 = int(m1.sum())
    U = _uniform_buffer(seed, T * n + total_m1)

    cum_before = np.concatenate([[0], np.cumsum(m1)[:-1]])
    abs_start = n * np.arange(T) + cum_before       # k block start per step
    k = np.zeros((T, n), dtype=np.int16)
    if total_m1:
        k_pos = _flat_mask_positions(mask, abs_start)
        k[mask] = binomial_inversion(U[k_pos], n_pkts, drop_p[mask])

    cnp = np.zeros((T, n), dtype=bool)
    msteps = np.flatnonzero(m1 > 0)
    cnp_start_m = abs_start[msteps] + m1[msteps]
    if msteps.size:
        cnp[msteps] = (U[cnp_start_m[:, None] + np.arange(n)]
                       < ecn_prob[msteps])
    _calm_cnp_runs(U, ecn_prob, cnp, msteps, T, n, stride=n,
                   extra_after=np.cumsum(m1[msteps]))
    return CelerisDraws(k=k, cnp=cnp)
