from repro.core.transport.params import (
    SimParams, NetworkParams, DcqcnParams, ReliabilityParams, WorkloadParams,
    TopologyParams, WindowPolicy, FaultParams)
from repro.core.transport.faults import FaultModel
from repro.core.transport.engine import (
    BatchedEngine, BatchedSimParams, RoundStats, SweepResult, sweep)
from repro.core.transport.simulator import CollectiveSimulator
from repro.core.transport.designs import DESIGNS
from repro.core.transport.topology import (
    TIERS, hier_params, hier_protocol)
from repro.core.transport.schedule import (
    SCHEDULES, CollectiveSchedule, HierarchicalSchedule,
    PerRailHierarchicalSchedule, RingSchedule, SchedulePhase, SchedulePlan,
    get_schedule, layer_priorities, make_plan, with_step_priorities)
from repro.core.transport.coupling import (
    AxisSchedules, CollectiveMode, DropSchedule, EngineStragglerModel,
    HierStragglerModel, LatencyTail, PrioritySchedules,
    closed_form_schedule, priority_schedules_from_round_stats,
    schedule_from_engine, schedule_from_round_stats,
    split_schedule_from_engine, split_schedule_from_round_stats)
from repro.core.transport.telemetry import (
    CAUSES, COMPONENTS, ConservationError, DesignRecord, DropProvenance,
    TraceRecorder, audit_round, provenance_from_record, provenance_heuristic)
from repro.core.transport.trace_export import (
    iter_trace_events, to_trace_events, validate_events, validate_trace,
    write_trace)

__all__ = [
    "SimParams", "NetworkParams", "DcqcnParams", "ReliabilityParams",
    "WorkloadParams", "TopologyParams", "WindowPolicy", "FaultParams",
    "FaultModel", "CollectiveSimulator", "RoundStats",
    "DESIGNS", "TIERS", "BatchedEngine", "BatchedSimParams", "SweepResult",
    "sweep", "hier_params", "hier_protocol",
    "SCHEDULES", "CollectiveSchedule", "HierarchicalSchedule",
    "PerRailHierarchicalSchedule", "RingSchedule", "SchedulePhase",
    "SchedulePlan", "get_schedule", "layer_priorities", "make_plan",
    "with_step_priorities",
    "AxisSchedules", "CollectiveMode", "DropSchedule", "EngineStragglerModel",
    "HierStragglerModel", "LatencyTail", "PrioritySchedules",
    "closed_form_schedule", "priority_schedules_from_round_stats",
    "schedule_from_engine", "schedule_from_round_stats",
    "split_schedule_from_engine", "split_schedule_from_round_stats",
    "CAUSES", "COMPONENTS", "ConservationError", "DesignRecord",
    "DropProvenance", "TraceRecorder", "audit_round",
    "provenance_from_record", "provenance_heuristic",
    "iter_trace_events", "to_trace_events", "validate_events",
    "validate_trace", "write_trace",
]
