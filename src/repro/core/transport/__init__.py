from repro.core.transport.params import (
    SimParams, NetworkParams, DcqcnParams, ReliabilityParams, WorkloadParams)
from repro.core.transport.simulator import CollectiveSimulator, RoundStats
from repro.core.transport.designs import DESIGNS

__all__ = [
    "SimParams", "NetworkParams", "DcqcnParams", "ReliabilityParams",
    "WorkloadParams", "CollectiveSimulator", "RoundStats", "DESIGNS",
]
