from repro.core.transport.params import (
    SimParams, NetworkParams, DcqcnParams, ReliabilityParams, WorkloadParams)
from repro.core.transport.engine import (
    BatchedEngine, BatchedSimParams, RoundStats, SweepResult, sweep)
from repro.core.transport.simulator import CollectiveSimulator
from repro.core.transport.designs import DESIGNS
from repro.core.transport.coupling import (
    CollectiveMode, DropSchedule, EngineStragglerModel, LatencyTail,
    closed_form_schedule, schedule_from_engine, schedule_from_round_stats)

__all__ = [
    "SimParams", "NetworkParams", "DcqcnParams", "ReliabilityParams",
    "WorkloadParams", "CollectiveSimulator", "RoundStats", "DESIGNS",
    "BatchedEngine", "BatchedSimParams", "SweepResult", "sweep",
    "CollectiveMode", "DropSchedule", "EngineStragglerModel", "LatencyTail",
    "closed_form_schedule", "schedule_from_engine",
    "schedule_from_round_stats",
]
