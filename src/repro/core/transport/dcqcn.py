"""DCQCN congestion control (vectorized over flows).

All four simulated NIC designs keep DCQCN in hardware (paper Table I,
"Congestion Control: Hardware").  Standard behavior: ECN-marked packets
trigger CNPs; the sender cuts rate multiplicatively by alpha/2 and
tracks a congestion estimate alpha; absent CNPs the rate recovers via
additive then hyper increase stages.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.transport.params import DcqcnParams


@dataclasses.dataclass
class DcqcnState:
    rate: np.ndarray          # (n_flows,) fraction of line rate
    target: np.ndarray
    alpha: np.ndarray
    good_stages: np.ndarray   # consecutive no-CNP stages

    @classmethod
    def init(cls, n_flows: int) -> "DcqcnState":
        return cls(rate=np.ones(n_flows), target=np.ones(n_flows),
                   alpha=np.ones(n_flows), good_stages=np.zeros(n_flows, int))


def step(state: DcqcnState, cnp_received: np.ndarray, p: DcqcnParams) -> DcqcnState:
    """One control interval: apply CNP cuts / increases per flow."""
    r, t, a, g = state.rate, state.target, state.alpha, state.good_stages

    # --- congestion: multiplicative decrease, alpha <- EWMA toward 1
    a_new = np.where(cnp_received, (1 - p.alpha_g) * a + p.alpha_g, (1 - p.alpha_g) * a)
    t_new = np.where(cnp_received, r, t)
    r_cut = np.maximum(r * (1 - a_new / 2), p.rate_decrease_floor)

    # --- recovery: additive toward target, hyper after sustained calm
    g_new = np.where(cnp_received, 0, g + 1)
    add = np.minimum(t_new, r + p.additive_increase)
    hyper = np.minimum(1.0, r + p.hyper_increase)
    r_up = np.where(g_new > p.hyper_after, hyper, add)

    rate = np.clip(np.where(cnp_received, r_cut, r_up), p.min_rate, 1.0)
    return DcqcnState(rate=rate, target=np.clip(t_new, p.min_rate, 1.0),
                      alpha=a_new, good_stages=g_new)
