"""DCQCN congestion control (vectorized over flows).

All four simulated NIC designs keep DCQCN in hardware (paper Table I,
"Congestion Control: Hardware").  Standard behavior: ECN-marked packets
trigger CNPs; the sender cuts rate multiplicatively by alpha/2 and
tracks a congestion estimate alpha; absent CNPs the rate recovers via
additive then hyper increase stages.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.transport.params import DcqcnParams


@dataclasses.dataclass
class DcqcnState:
    rate: np.ndarray          # (..., n_flows) fraction of line rate
    target: np.ndarray
    alpha: np.ndarray
    good_stages: np.ndarray   # consecutive no-CNP stages

    @classmethod
    def init(cls, shape: int | tuple) -> "DcqcnState":
        return cls(rate=np.ones(shape), target=np.ones(shape),
                   alpha=np.ones(shape), good_stages=np.zeros(shape, int))


def step_math(r, t, a, g, cnp_received, p: DcqcnParams, xp=np):
    """One control interval's update rule on raw state arrays.

    The single formula source for both backends: ``step()`` applies it
    with ``xp=np`` (bit-identical to the historical inline form), and
    the jax backend's ``lax.scan`` body applies it to f64 tracers with
    ``xp=jax.numpy``.  Returns ``(rate, target, alpha, good_stages)``.
    """
    # --- congestion: multiplicative decrease, alpha <- EWMA toward 1
    a_new = xp.where(cnp_received, (1 - p.alpha_g) * a + p.alpha_g,
                     (1 - p.alpha_g) * a)
    t_new = xp.where(cnp_received, r, t)
    r_cut = xp.maximum(r * (1 - a_new / 2), p.rate_decrease_floor)

    # --- recovery: additive toward target, hyper after sustained calm
    g_new = xp.where(cnp_received, 0, g + 1)
    add = xp.minimum(t_new, r + p.additive_increase)
    hyper = xp.minimum(1.0, r + p.hyper_increase)
    r_up = xp.where(g_new > p.hyper_after, hyper, add)

    rate = xp.clip(xp.where(cnp_received, r_cut, r_up), p.min_rate, 1.0)
    return rate, xp.clip(t_new, p.min_rate, 1.0), a_new, g_new


def step(state: DcqcnState, cnp_received: np.ndarray, p: DcqcnParams) -> DcqcnState:
    """One control interval: apply CNP cuts / increases per flow."""
    rate, target, alpha, good = step_math(
        state.rate, state.target, state.alpha, state.good_stages,
        cnp_received, p)
    return DcqcnState(rate=rate, target=target, alpha=alpha,
                      good_stages=good)


# ----------------------------------------------------------------------
# Whole-trace evaluation (the batched engine's congestion-control pass)
# ----------------------------------------------------------------------
#
# DCQCN is the one true *per-step* sequential dependency in the
# transport model.  But the recurrence is only data-dependent at steps
# where some flow receives a CNP; between CNPs the update is the
# deterministic recovery ramp, which has a closed form:
#
#   - alpha decays geometrically:  a_L = (1-g)^L * a_0
#   - good_stages counts up:       g_L = g_0 + L
#   - rate ramps additively toward ``target`` for the first
#     k = clip(hyper_after - g_0, 0, L) steps, then hyper-increases
#     toward 1.0 (the two phases are each elementwise-monotone
#     saturating ramps, so min()s give the exact per-step value).
#
# ``rate_trace`` therefore touches Python only at CNP steps (a few
# percent of steps under the paper's burst process) and fills the calm
# gaps in closed form — exactly matching the step()-by-step recurrence.


def calm_ramp(r, t, g, i, p: DcqcnParams, dtype=np.float64, xp=np):
    """Recovery-ramp rate after ``i`` consecutive no-CNP updates, on raw
    state arrays (``r``/``t`` already in ``dtype``, ``g`` int32).

    The single ramp-formula source for both backends: ``_calm_rates``
    wraps it for the numpy engine (bit-identical to the historical
    inline form) and the jax scan body evaluates it twice per step —
    once in f32 for the emitted trace, once in f64 for state advance —
    with ``xp=jax.numpy``.
    """
    k = xp.clip(np.int32(p.hyper_after) - g, 0, i)  # additive steps among i
    kf = k.astype(dtype)
    # invariant: k > 0 implies r <= t (hyper is the only way past target,
    # and it requires good_stages > hyper_after, i.e. k == 0)
    r_add = xp.where(k > 0,
                     xp.minimum(t, r + dtype(p.additive_increase) * kf), r)
    r_i = xp.where(i > k,
                   xp.minimum(dtype(1.0),
                              r_add + dtype(p.hyper_increase)
                              * (i - k).astype(dtype)),
                   r_add)
    # no clip needed: both ramps start at r >= min_rate and saturate at
    # min(target, 1) / 1.0, matching step()'s clip exactly
    return r_i


def _calm_rates(state: DcqcnState, i: np.ndarray, p: DcqcnParams,
                dtype=np.float64) -> np.ndarray:
    """Rate after ``i`` consecutive no-CNP updates of ``state`` (exact).

    ``i``: integer array broadcastable against ``state.rate`` with a
    leading axis (one entry per gap position); ``i == 0`` returns the
    current rate.  ``dtype`` controls only the *emitted* ramp values
    (the engine fills float32 traces); state math stays float64.
    """
    r = state.rate.astype(dtype, copy=False)
    t = state.target.astype(dtype, copy=False)
    g = state.good_stages.astype(np.int32, copy=False)
    return calm_ramp(r, t, g, i, p, dtype)


def _advance_calm(state: DcqcnState, L: int, p: DcqcnParams) -> DcqcnState:
    """State after ``L`` consecutive no-CNP updates (exact, O(1) in L)."""
    return DcqcnState(
        rate=_calm_rates(state, np.asarray(L), p),
        target=state.target,
        alpha=state.alpha * (1.0 - p.alpha_g) ** L,
        good_stages=state.good_stages + L)


def rate_trace(cnp: np.ndarray, p: DcqcnParams, state: DcqcnState | None = None,
               dtype=np.float64) -> tuple[np.ndarray, DcqcnState]:
    """Sending rate *used at* each step for a whole CNP trace.

    ``cnp``: (T, ..., n_flows) bool.  Returns (rates (T, ..., n_flows),
    final_state) where ``rates[t]`` is the state rate before step t's
    update — the rate the transfer at step t sees, matching the
    sequential  ``use rate; draw cnp; step()``  order of the original
    simulator loop.  State evolution is float64 regardless of ``dtype``
    (which only sets the emitted trace precision).
    """
    T = cnp.shape[0]
    if state is None:
        state = DcqcnState.init(cnp.shape[1:])
    out = np.empty(cnp.shape, dtype=dtype)
    active = np.flatnonzero(cnp.reshape(T, -1).any(axis=1))
    expand = (slice(None),) + (None,) * state.rate.ndim
    prev = 0
    for a in active:
        if a > prev:
            gap = np.arange(a - prev, dtype=np.int32)[expand]
            out[prev:a] = _calm_rates(state, gap, p, dtype)
            state = _advance_calm(state, a - prev, p)
        out[a] = state.rate
        state = step(state, cnp[a], p)
        prev = a + 1
    if prev < T:
        gap = np.arange(T - prev, dtype=np.int32)[expand]
        out[prev:T] = _calm_rates(state, gap, p, dtype)
        state = _advance_calm(state, T - prev, p)
    return out, state
