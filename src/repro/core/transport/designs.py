"""Per-design chunk-transfer completion models (vectorized over flows).

Given the fabric conditions for one ring step (occupancy, DCQCN rate,
packet drop draws), each NIC design turns losses into time (or, for
Celeris, into missing data):

- **RoCE** — go-back-N: the first lost packet forces retransmission of
  everything after it.  Loss in the *tail* of the chunk is detected only
  by the retransmission timeout (RTO, ~1 ms) because no later packet
  generates a NACK — this is the dominant p99 contributor.  PFC pauses
  (head-of-line blocking) add correlated stalls; in exchange, PFC
  suppresses most overflow drops.
- **IRN** — selective repeat: each lost packet is NACK'd/SACK'd and
  resent individually (no PFC, full drop exposure); tail losses use the
  low RTO (~100 us).
- **SRNIC** — selective repeat in host software: as IRN plus a host
  slow-path penalty per loss event.
- **Celeris** — no recovery: lost packets are simply absent; the chunk
  "completes" when the wire finishes pushing it.  Late/lost data is
  bounded by the receiver's step timeout at the simulator level.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.transport.params import ReliabilityParams, NetworkParams

DESIGNS = ("roce", "irn", "srnic", "celeris")

# RoCE runs PFC: overflow drops are largely prevented (residual drops
# from corruption / buffer carving remain).
PFC_DROP_SUPPRESSION = 0.15

# Celeris's push engine streams with no ACK/window clocking, so queueing
# *latency* (not bandwidth) overlaps across in-flight chunks; only this
# residual fraction shows up in completion time.  Reliable designs pay
# the full per-chunk queue delay: ordering + ACK windows serialize it
# (go-back-N stalls the pipe; IRN's BDP-bounded window stalls on loss).
CELERIS_QUEUE_OVERLAP = 0.15


@dataclasses.dataclass
class TransferResult:
    time_us: np.ndarray       # completion time per flow
    delivered_pkts: np.ndarray
    total_pkts: np.ndarray


def transfer(design: str, n_pkts: int, occ: np.ndarray, rate: np.ndarray,
             drop_p: np.ndarray, pfc_pause: np.ndarray, queue_delay: np.ndarray,
             rel: ReliabilityParams, net: NetworkParams,
             rng: np.random.Generator) -> TransferResult:
    """Completion time of an n_pkts chunk per concurrent flow."""
    n_flows = occ.shape[0]
    pkt_time = net.pkt_time_us / np.maximum(rate, 1e-3)
    serialize = n_pkts * pkt_time
    base = serialize + queue_delay + net.base_rtt_us / 2

    if design == "roce":
        p = drop_p * PFC_DROP_SUPPRESSION
        k = rng.binomial(n_pkts, p)
        tail_lost = rng.random(n_flows) < p          # last pkt's own fate
        extra = np.zeros(n_flows)
        resend = np.zeros(n_flows, int)
        # go-back-N episodes (up to max_retries)
        remaining = k.copy()
        for _ in range(rel.max_retries):
            has_loss = remaining > 0
            pos = rng.integers(0, n_pkts, n_flows)      # first-loss position
            n_resend = np.where(has_loss, n_pkts - pos, 0)
            detect = np.where(tail_lost, rel.rto_us,
                              rel.nack_delay_us + net.base_rtt_us)
            extra += np.where(has_loss, detect + n_resend * pkt_time, 0.0)
            resend += n_resend
            # losses within the retransmitted burst
            remaining = rng.binomial(np.maximum(n_resend, 0), p)
            tail_lost = tail_lost & (rng.random(n_flows) < p)
        t = base + extra + pfc_pause
        return TransferResult(t, np.full(n_flows, n_pkts), np.full(n_flows, n_pkts))

    if design in ("irn", "srnic"):
        k = rng.binomial(n_pkts, drop_p)
        tail_lost = rng.random(n_flows) < drop_p
        detect = np.where(tail_lost, rel.rto_low_us,
                          rel.nack_delay_us + net.base_rtt_us)
        extra = np.where(k > 0, detect + k * pkt_time, 0.0)
        if design == "srnic":
            extra += k * rel.host_slowpath_us       # host slow-path per loss
        # selective-repeat second round for re-lost packets
        k2 = rng.binomial(k, drop_p)
        extra += np.where(k2 > 0, rel.rto_low_us + k2 * pkt_time, 0.0)
        t = base + extra
        return TransferResult(t, np.full(n_flows, n_pkts), np.full(n_flows, n_pkts))

    if design == "celeris":
        k = rng.binomial(n_pkts, drop_p)
        # no recovery: wire time only; lost packets never arrive.
        # Streaming push -> queue latency mostly hidden (see above).
        t = (serialize + CELERIS_QUEUE_OVERLAP * queue_delay
             + net.base_rtt_us / 2)
        return TransferResult(t, n_pkts - k, np.full(n_flows, n_pkts))

    raise ValueError(design)
