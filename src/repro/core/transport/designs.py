"""Per-design chunk-transfer completion models (vectorized over flows).

Given the fabric conditions for one ring step (occupancy, DCQCN rate,
packet drop draws), each NIC design turns losses into time (or, for
Celeris, into missing data):

- **RoCE** — go-back-N: the first lost packet forces retransmission of
  everything after it.  Loss in the *tail* of the chunk is detected only
  by the retransmission timeout (RTO, ~1 ms) because no later packet
  generates a NACK — this is the dominant p99 contributor.  PFC pauses
  (head-of-line blocking) add correlated stalls; in exchange, PFC
  suppresses most overflow drops.
- **IRN** — selective repeat: each lost packet is NACK'd/SACK'd and
  resent individually (no PFC, full drop exposure); tail losses use the
  low RTO (~100 us).
- **SRNIC** — selective repeat in host software: as IRN plus a host
  slow-path penalty per loss event.
- **Celeris** — no recovery: lost packets are simply absent; the chunk
  "completes" when the wire finishes pushing it.  Late/lost data is
  bounded by the receiver's step timeout at the simulator level.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.transport.params import ReliabilityParams, NetworkParams

DESIGNS = ("roce", "irn", "srnic", "celeris")

# RoCE runs PFC: overflow drops are largely prevented (residual drops
# from corruption / buffer carving remain).
PFC_DROP_SUPPRESSION = 0.15

# Celeris's push engine streams with no ACK/window clocking, so queueing
# *latency* (not bandwidth) overlaps across in-flight chunks; only this
# residual fraction shows up in completion time.  Reliable designs pay
# the full per-chunk queue delay: ordering + ACK windows serialize it
# (go-back-N stalls the pipe; IRN's BDP-bounded window stalls on loss).
CELERIS_QUEUE_OVERLAP = 0.15


@dataclasses.dataclass
class TransferResult:
    time_us: np.ndarray       # completion time per flow
    delivered_pkts: np.ndarray
    total_pkts: np.ndarray


# ----------------------------------------------------------------------
# Loss-machinery draw sequences, shared verbatim by ``transfer()`` and
# the jax backend's host draw pass (engine_jax).  The draws depend only
# on the drop curve — never on the DCQCN rate — which is exactly what
# lets the jax backend split each design into a host-side draw pass
# (these helpers) and a jitted rate-dependent time assembly.  Draw
# *order* here is the replay contract: reordering a single call shifts
# every later value in the design's transfer substream.
# ----------------------------------------------------------------------

def roce_loss_episodes(n_pkts: int, pf: np.ndarray,
                       rel: ReliabilityParams, net: NetworkParams,
                       rng: np.random.Generator) -> list:
    """The go-back-N recovery draws over a drop-capable subset.

    Returns ``max_retries`` episodes of ``(has_loss, n_resend,
    detect_us)``; completion-time excess is ``sum(where(has_loss,
    detect + n_resend * pkt_time, 0))`` over the episodes.  No draw
    depends on an accumulated time, so hoisting them out of the
    accumulation loop consumes the stream identically.
    """
    k = rng.binomial(n_pkts, pf)
    tail_lost = rng.random(pf.size) < pf    # last pkt's own fate
    episodes = []
    remaining = k
    for _ in range(rel.max_retries):
        has_loss = remaining > 0
        pos = rng.integers(0, n_pkts, pf.size)  # first-loss position
        n_resend = np.where(has_loss, n_pkts - pos, 0)
        detect = np.where(tail_lost, rel.rto_us,
                          rel.nack_delay_us + net.base_rtt_us)
        episodes.append((has_loss, n_resend, detect))
        # losses within the retransmitted burst
        remaining = rng.binomial(n_resend, pf)
        tail_lost = tail_lost & (rng.random(pf.size) < pf)
    return episodes


def sr_loss_draws(n_pkts: int, pf: np.ndarray, rng: np.random.Generator
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Selective-repeat (irn / srnic) loss draws over a drop-capable
    subset: ``(k, tail_lost, k2)`` — first-round losses, the last
    packet's own fate, and the re-lost second round."""
    k = rng.binomial(n_pkts, pf)
    tail_lost = rng.random(pf.size) < pf
    k2 = rng.binomial(k, pf)
    return k, tail_lost, k2


def celeris_loss_draws(n_pkts: int, pf: np.ndarray,
                       rng: np.random.Generator) -> np.ndarray:
    """Celeris drop draws over a drop-capable subset: packets that
    simply never arrive (no recovery)."""
    return rng.binomial(n_pkts, pf)


def transfer(design: str, n_pkts: int, occ: np.ndarray, rate: np.ndarray,
             drop_p: np.ndarray, pfc_pause: np.ndarray, queue_delay: np.ndarray,
             rel: ReliabilityParams, net: NetworkParams,
             rng: np.random.Generator,
             parts: dict | None = None) -> TransferResult:
    """Completion time of an n_pkts chunk per concurrent flow.

    Shape-polymorphic: every per-flow array may carry arbitrary leading
    batch axes — ``(n_flows,)`` for the step-at-a-time simulator,
    ``(step, n_flows)`` (or ``(batch, step, n_flows)``) for the batched
    engine.  Loss machinery runs on the drop-capable subset only (the
    paper's drop probability is exactly 0 below the loss knee, >90% of
    entries under the burst process); the distribution per entry is
    unchanged, only the draw order differs from a dense sweep.

    ``parts`` is the telemetry scratchpad (``telemetry.TraceRecorder``):
    when a dict is passed, the component arrays this function already
    computes — serialization, queueing, RTT, PFC pause, retransmit
    time, wire-lost packets — are recorded into it, scalar or
    full-shape, *without touching the arithmetic or the draw streams*:
    recording must never change the seeded physics.
    """
    shape = occ.shape
    pkt_time = net.pkt_time_us / np.maximum(rate, 1e-3)
    serialize = n_pkts * pkt_time
    full = np.broadcast_to(np.float64(n_pkts), shape)
    if parts is not None:
        parts["serialize"] = serialize
        parts["rtt"] = net.base_rtt_us / 2

    if design == "roce":
        p = drop_p * PFC_DROP_SUPPRESSION
        idx = np.flatnonzero(p > 0)
        t = serialize + queue_delay + net.base_rtt_us / 2
        t += pfc_pause
        if parts is not None:
            parts["queue"] = queue_delay
            parts["pfc"] = pfc_pause
        if idx.size:
            pf = np.ascontiguousarray(p).ravel()[idx]
            ptf = np.ascontiguousarray(pkt_time).ravel()[idx]
            ex = np.zeros(idx.size)
            # go-back-N episodes (up to max_retries); the draw sequence
            # is the shared helper's — episode accumulation order is
            # unchanged, so the sum rounds exactly as it always did
            for has_loss, n_resend, detect in roce_loss_episodes(
                    n_pkts, pf, rel, net, rng):
                ex += np.where(has_loss, detect + n_resend * ptf, 0.0)
            # .flat, not .ravel(): the batched engine can hand in
            # non-C-contiguous blocks (large advanced-indexed phase
            # views), where ravel() silently returns a copy and the
            # in-place update would be lost
            t.flat[idx] += ex.astype(t.dtype)
            if parts is not None:
                rx = np.zeros(shape)
                rx.flat[idx] = ex
                parts["retransmit"] = rx
        return TransferResult(t, full, full)

    if design in ("irn", "srnic"):
        idx = np.flatnonzero(drop_p > 0)
        t = serialize + queue_delay + net.base_rtt_us / 2
        if parts is not None:
            parts["queue"] = queue_delay
        if idx.size:
            pf = np.ascontiguousarray(drop_p).ravel()[idx]
            ptf = np.ascontiguousarray(pkt_time).ravel()[idx]
            k, tail_lost, k2 = sr_loss_draws(n_pkts, pf, rng)
            detect = np.where(tail_lost, rel.rto_low_us,
                              rel.nack_delay_us + net.base_rtt_us)
            ex = np.where(k > 0, detect + k * ptf, 0.0)
            if design == "srnic":
                ex += k * rel.host_slowpath_us      # host slow-path per loss
            # selective-repeat second round for re-lost packets
            ex += np.where(k2 > 0, rel.rto_low_us + k2 * ptf, 0.0)
            t.flat[idx] += ex.astype(t.dtype)
            if parts is not None:
                rx = np.zeros(shape)
                rx.flat[idx] = ex
                parts["retransmit"] = rx
        return TransferResult(t, full, full)

    if design == "celeris":
        idx = np.flatnonzero(drop_p > 0)
        delivered = np.full(shape, n_pkts, dtype=serialize.dtype)
        if idx.size:
            pf = np.ascontiguousarray(drop_p).ravel()[idx]
            delivered.flat[idx] -= celeris_loss_draws(n_pkts, pf, rng)
        # no recovery: wire time only; lost packets never arrive.
        # Streaming push -> queue latency mostly hidden (see above).
        t = (serialize + CELERIS_QUEUE_OVERLAP * queue_delay
             + net.base_rtt_us / 2)
        if parts is not None:
            parts["queue"] = CELERIS_QUEUE_OVERLAP * queue_delay
            parts["wire_lost"] = np.asarray(full - delivered, np.float64)
        return TransferResult(t, delivered, full)

    raise ValueError(design)
