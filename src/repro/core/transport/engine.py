"""Batched, vectorized transport-simulation engine.

The original :class:`~repro.core.transport.simulator.CollectiveSimulator`
walked a pure-Python ``rounds x 2(N-1)`` double loop, issuing dozens of
small per-node numpy calls per ring step — a 128-node/300-round Fig.-2
protocol took >70 s, and the scales where tail-at-scale effects actually
bite (512-1024 nodes, multi-seed sweeps) were unaffordable.  This module
replaces the loop with whole-trace tensor operations.

Data layout
-----------
The engine materializes the simulation as ``(step, node)`` blocks —
``step`` is the flattened ``round * ring_step`` time axis (rounds are
contiguous runs of ``2(N-1)`` steps), ``node`` the concurrent ring
flows.  Work proceeds in *round blocks* (a bounded number of rounds per
chunk, sized to a fixed element budget) so peak memory stays flat at any
cluster size; every per-(step, node) quantity — path occupancy, drop /
ECN / queue curves, DCQCN send rate, per-design transfer times and
delivered packets — is computed for the whole block at once.  Designs
and seeds batch naturally: all NIC designs of one seed share the same
fabric contention trace and DCQCN rate trace, and sweeps loop seeds ×
(cluster size, message size) configurations around the same core.

What stays sequential, and why
------------------------------
Only true control dependencies remain step-by-step; everything else is
closed-form or embarrassingly parallel over the trace:

- **Background burst Markov chain** (per ToR): resolved in closed form
  (last-constant-map + swap-parity composition) — bit-identical to
  sequential ``advance()`` calls on the same stream
  (:func:`repro.core.transport.network.occupancy_trace`).
- **Occupancy EWMA**: a truncated geometric filter (error 0.5**64,
  below f64 resolution).
- **DCQCN** is genuinely sequential *across steps* (each step's rate
  depends on the previous state), but the recurrence is only
  data-dependent at CNP steps; calm gaps advance in closed form
  (:func:`repro.core.transport.dcqcn.rate_trace`), so Python touches a
  few percent of steps.
- **Adaptive bounded-window coordination** is genuinely sequential
  *across rounds* (the cluster adopts the median timeout each round),
  but it never feeds back into the physics — transfer times don't
  depend on the window — so it runs as a cheap per-round assembly pass
  over precomputed step traces, vectorized over nodes.
- **RoCE's PFC-cascade draws** pollute the fabric random stream with a
  data-dependent number of draws per step.  ``legacy_streams=True``
  (the compatibility default) replays that stream bit-exactly via
  speculative windows (:func:`repro.core.transport.network.
  roce_fabric_trace`), so seeded pre-refactor statistics are
  reproduced up to transfer-draw noise (a few percent on p99);
  ``legacy_streams=False`` (the sweep default) shares one clean fabric
  trace across all designs.

Entry points
------------
- :meth:`BatchedEngine.run` — one design, returns :class:`RoundStats`
  (what ``CollectiveSimulator.run`` now wraps);
- :meth:`BatchedEngine.traces` /:meth:`BatchedEngine.assemble` — the
  two-phase core (all designs share one physics pass; windows applied
  afterwards);
- :meth:`BatchedEngine.paper_protocol` — the Fig.-2 protocol;
- :func:`sweep` + :class:`BatchedSimParams` — multi-(scale, message,
  seed) sweeps, e.g. ``sweep(BatchedSimParams(n_nodes=(128, 256, 512,
  1024), seeds=range(4)))``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Sequence

import numpy as np

from repro.core import timeout as timeout_mod
from repro.core.transport import dcqcn, designs, faults, network, replay, topology
from repro.core.transport import schedule as schedule_mod
from repro.core.transport.params import SimParams, WindowPolicy, parse_backend

# Engine-native random sub-streams, all derived from the user seed.
# (The per-step simulator interleaved every draw into one stream; the
# batched engine draws whole traces per purpose, so each purpose gets
# its own child stream.  irn and srnic intentionally share one: in the
# sequential simulator both designs consumed identical draw sequences,
# making srnic exactly irn + host slow-path on the same loss draws.)
_STREAM_CNP = 101          # clean-trace CNP draws (shared by designs)
_STREAM_CNP_ROCE = 102     # CNP draws on the RoCE legacy trace
_STREAM_PFC = 103          # PFC cascade draws (shared-fabric mode only)
_STREAM_TRANSFER = {"roce": 110, "irn": 111, "srnic": 111, "celeris": 112}
_STREAM_WINDOW = 120       # bounded-window controller observation noise
_STREAM_INCAST_CNP = 150   # CNP draws on incast (fan_in > 1) flow columns

# Round-block sizing: bound the (step, node) chunk to this many elements
# so peak memory is flat in cluster size (~12 live f64 temporaries).
_BLOCK_ELEMENTS = 4 << 20


def _tier_frac(got: np.ndarray, tot: np.ndarray) -> np.ndarray:
    """Delivered fraction per tier; empty tiers report 1 (nothing to
    lose).  The one tier-accounting rule every window assembly shares —
    full rounds, truncated rounds, and the vectorized fixed window all
    reduce to it with different ``got``."""
    return np.where(tot > 0, got / np.maximum(tot, 1.0), 1.0)


def _cut_block(nat_b, deliv_b, budget_us, groups, perm=None):
    """Apply one deadline to a contiguous run of steps.

    The one truncation rule every window policy shares: elapsed time is
    ``min(sum(nat), budget)``; packets delivered strictly inside the
    deadline count in full and the boundary step earns linear partial
    credit.  ``groups`` are (steps, G) per-group delivered arrays
    (tiers, pods, priority classes) that take the same cut.  Returns
    ``(elapsed, got, group_gots)``.  The round window is this applied
    to the whole round; the phase window applies it per phase block
    with the plan's ``budget_frac`` split.

    ``perm`` (``cut_order="priority"``) reallocates the *same* total
    cut across steps in a static order — lowest priority class first,
    late arrivals first within a class — so elapsed time and total
    delivered packets are unchanged ("matched p99" by construction)
    while the per-group accounting concentrates the loss in the
    low-priority steps.  ``perm=None`` is arrival order, bit-pinned.
    """
    cum = np.cumsum(nat_b)
    total_t = cum[-1]
    if total_t <= budget_us:
        return total_t, deliv_b.sum(), [g.sum(axis=0) for g in groups]
    done = cum <= budget_us
    bidx = int(np.argmax(~done))
    prev = float(cum[bidx - 1]) if bidx > 0 else 0.0
    part = (budget_us - prev) / max(nat_b[bidx], 1e-9)
    got = deliv_b[done].sum() + deliv_b[bidx] * part
    if perm is not None:
        survive = _priority_survive(deliv_b[None, :],
                                    np.array([deliv_b.sum() - got]),
                                    perm)[0]
        return budget_us, got, [(g * survive[:, None]).sum(0)
                                for g in groups]
    return budget_us, got, [(g * done[:, None]).sum(0) + g[bidx] * part
                            for g in groups]


def _priority_survive(d, K, perm):
    """(R, steps) per-step survive fraction cutting ``K`` packets in
    ``perm`` order.

    The priority cut's allocation rule: walk the steps in the static
    ``perm`` order (lowest class first, late arrivals first within a
    class) and remove delivered packets until the arrival cut's total
    ``K[r]`` is gone; the boundary step takes a linear partial cut.
    One clipped expression covers full / partial / no cut per step.
    With uniform priorities ``perm`` is plain reverse-arrival order and
    the allocation coincides with the arrival cut's.
    """
    d_perm = d[:, perm]
    cum = np.cumsum(d_perm, axis=1)
    prev = cum - d_perm
    cutfrac = np.clip((K[:, None] - prev) / np.maximum(d_perm, 1e-30),
                      0.0, 1.0)
    survive = np.empty_like(d)
    survive[:, perm] = 1.0 - cutfrac
    return survive


def _priority_perm(step_priority: np.ndarray) -> np.ndarray:
    """Static cut order over a step block: priority class ascending
    (lowest cut first), step index *descending* within a class (the
    latest arrivals of a class go first, so uniform-priority blocks
    reproduce the arrival cut's allocation exactly)."""
    idx = np.arange(step_priority.size)
    return np.lexsort((-idx, step_priority))


@dataclasses.dataclass
class RoundStats:
    times_us: np.ndarray          # (rounds,)
    recv_frac: np.ndarray         # (rounds,) delivered fraction of payload
    design: str
    # per-tier delivered fractions over the topology hierarchy
    # (topology.TIERS order: tor, spine, dci); None on paths that don't
    # track tiers (stream replay, the retained sequential reference)
    tier_recv_frac: np.ndarray | None = None    # (rounds, n_tiers)
    tier_counts: np.ndarray | None = None       # (n_tiers,) flows per tier
    # (n_tiers,) offered packets per round per tier — the collective
    # schedule's actual per-tier exposure (steps x flows x pkts), which
    # the axis-split coupling uses as its weighting
    tier_pkts: np.ndarray | None = None
    # per-pod intra fractions over the hierarchy (rounds, n_pods) plus
    # the (n_pods,) offered intra packets per round per pod — the
    # per-pod axis-split coupling's inputs (None on flat topologies)
    pod_recv_frac: np.ndarray | None = None
    pod_pkts: np.ndarray | None = None
    # per-priority-class delivered fractions (rounds, n_classes) plus
    # the (n_classes,) offered packets per round per class — the
    # semantic-priority accounting (schedule.SchedulePhase.priority)
    # that the per-class coupling splits and fig10 read; None on traces
    # without priority metadata
    prio_recv_frac: np.ndarray | None = None
    prio_pkts: np.ndarray | None = None
    # fault-injection accounting (None when the trace ran fault-free):
    # per round, the number of steps with >= 1 faulted flow and the
    # total faulted (flow, step) cells (params.FaultParams, faults.py)
    fault_steps: np.ndarray | None = None       # (rounds,)
    affected_flows: np.ndarray | None = None    # (rounds,)

    @property
    def p50(self) -> float:
        return float(np.percentile(self.times_us, 50))

    @property
    def p99(self) -> float:
        return float(np.percentile(self.times_us, 99))

    @property
    def p999(self) -> float:
        return float(np.percentile(self.times_us, 99.9))

    @property
    def mean_loss(self) -> float:
        return float(1.0 - self.recv_frac.mean())

    def tier_loss(self, tier: str) -> float:
        """Mean loss on one topology tier ('tor' | 'spine' | 'dci');
        0 when the tier is empty or untracked."""
        if self.tier_recv_frac is None:
            return 0.0
        k = topology.TIERS.index(tier)
        if self.tier_counts is not None and self.tier_counts[k] == 0:
            return 0.0
        return float(1.0 - self.tier_recv_frac[:, k].mean())

    def prio_loss(self, cls: int) -> float:
        """Mean loss in one semantic priority class (0 = lowest, cut
        first under ``cut_order="priority"``); 0 when the class is
        empty or the trace carried no priority metadata."""
        if self.prio_recv_frac is None or cls >= self.prio_recv_frac.shape[1]:
            return 0.0
        if self.prio_pkts is not None and self.prio_pkts[cls] == 0:
            return 0.0
        return float(1.0 - self.prio_recv_frac[:, cls].mean())

    # -- fault-resilience metrics (fig7) -------------------------------
    @property
    def faulted(self) -> np.ndarray:
        """(rounds,) bool — rounds with at least one faulted flow."""
        if self.fault_steps is None:
            return np.zeros(self.times_us.shape[0], dtype=bool)
        return np.asarray(self.fault_steps) > 0

    def goodput_trace(self) -> np.ndarray:
        """(rounds,) delivered payload per unit time, normalized so the
        mean *fault-free* round is 1.0 (per-round offered payload is
        constant, so goodput ∝ recv_frac / time).  Falls back to the
        all-round mean when every round was faulted."""
        g = self.recv_frac / np.maximum(self.times_us, 1e-9)
        clean = ~self.faulted
        ref = g[clean].mean() if clean.any() else g.mean()
        return g / max(float(ref), 1e-30)

    @property
    def goodput_under_failure(self) -> float:
        """Goodput retained in the faulted rounds, as the ratio of the
        faulted rounds' mean goodput to the clean rounds' — the
        "goodput under failure" axis of the fig7 grid (1.0 when the
        trace was fault-free).  A ratio of means, so a single lucky
        (idle-fabric) faulted round cannot dominate the statistic the
        way a mean of per-round ratios would let it."""
        f = self.faulted
        if not f.any():
            return 1.0
        g = self.recv_frac / np.maximum(self.times_us, 1e-9)
        ref = g[~f].mean() if (~f).any() else g.mean()
        return float(g[f].mean() / max(float(ref), 1e-30))

    def recovery_rounds(self, frac: float = 0.9) -> float:
        """Mean rounds from the end of each fault episode until
        normalized goodput first returns to ``frac`` — the
        recovery-time-to-90%-goodput metric.  0.0 when recovery is
        immediate (or nothing ever faulted); a still-faulted trace tail
        is censored (no completed episode to measure), and an episode
        unrecovered by end of trace counts its remaining length."""
        f = self.faulted
        if not f.any():
            return 0.0
        g = self.goodput_trace()
        ends = np.flatnonzero(f[:-1] & ~f[1:]) + 1   # first clean round
        waits = []
        for e in ends:
            ok = np.flatnonzero(g[e:] >= frac)
            waits.append(float(ok[0]) if ok.size else float(f.size - e))
        return float(np.mean(waits)) if waits else 0.0

    def summary(self) -> Dict[str, float]:
        return dict(p50_us=self.p50, p99_us=self.p99, p999_us=self.p999,
                    mean_us=float(self.times_us.mean()),
                    data_loss=self.mean_loss)


@dataclasses.dataclass
class StepTrace:
    """Reduced per-step physics for one design (full trace length T).

    ``nat_us``/``deliv``/``total`` are (T,) reductions over nodes; the
    optional per-node arrays (T, n) are retained only when a per-step
    bounded window (``window="step"``) must re-inspect individual flows.
    """
    design: str
    steps_per_round: int
    nat_us: np.ndarray            # max over nodes of completion time
    deliv: np.ndarray             # delivered packets summed over nodes
    total: np.ndarray             # offered packets summed over nodes
    node_time_us: np.ndarray | None = None
    node_deliv: np.ndarray | None = None
    # per-tier reductions over the topology hierarchy (T, n_tiers) in
    # topology.TIERS order.  ``tier_cols`` holds the static flow-column
    # index arrays of a *single-phase* (ring) schedule; multi-phase
    # plans have a per-step flow→tier map instead, so it is None there
    # and the per-tier sums are filled per phase.
    tier_deliv: np.ndarray | None = None
    tier_total: np.ndarray | None = None
    tier_cols: tuple | None = None
    tier_counts: np.ndarray | None = None       # (n_tiers,) flows per tier
    tier_pkts_round: np.ndarray | None = None   # (n_tiers,) offered/round
    # static plan facts for the window policies: in-round phase index
    # per step, normalized per-phase budget split, and per-phase sender
    # nodes / flow→tier columns (the multi-phase step window's scatter
    # map).  Single-phase plans carry the degenerate one-phase versions.
    phase_of_step: np.ndarray | None = None     # (steps_per_round,)
    phase_budget_frac: np.ndarray | None = None # (n_phases,) sums to 1
    phase_src: tuple | None = None              # per phase: sender nodes
    phase_tier_cols: tuple | None = None        # per phase: per-tier cols
    phase_pod_cols: tuple | None = None         # per phase: per-pod cols
    # (steps_per_round,) semantic priority class per step
    # (schedule.FlowPlan.step_priority) — assembly-time metadata only:
    # cut_order="priority" reorders the window cut by it and the
    # per-class delivered fractions scatter by it; the physics above
    # never reads it
    step_priority: np.ndarray | None = None
    # per-pod intra reductions (T, n_pods), multi-pod topologies only;
    # ``pod_pkts_round`` is (n_pods,) offered intra packets per round
    pod_deliv: np.ndarray | None = None
    pod_total: np.ndarray | None = None
    pod_pkts_round: np.ndarray | None = None
    # (T,) faulted-flow count per step (design-independent availability
    # masks, shared by every design of the physics pass); None on
    # fault-free traces (params.FaultParams inactive)
    fault_flows: np.ndarray | None = None


class BatchedEngine:
    """Vectorized flow-plan simulator over ``(step, node)`` tensors.

    Times one :class:`~repro.core.transport.schedule.FlowPlan` per
    round — by default the collective plan named by
    ``params.work.schedule``, or an arbitrary point-to-point plan
    passed as ``plan=`` (e.g. the serve path's incast KV-transfer
    plans from ``serve.traffic``, which require
    ``legacy_streams=False`` like every non-ring plan).
    """

    def __init__(self, params: SimParams | None = None, *,
                 plan: "schedule_mod.FlowPlan | None" = None,
                 recorder: "telemetry_mod.TraceRecorder | None" = None,
                 backend: str = "numpy"):
        self.p = params or SimParams()
        self.plan_override = plan
        # opt-in flight recorder (telemetry.TraceRecorder): a pure
        # overlay on the shared-fabric pass — it reads the component
        # arrays the physics already computes and draws nothing, so
        # seeded stats are bit-identical with or without it
        self.recorder = recorder
        # compute backend: "numpy" is the bit-pinning reference;
        # "jax" routes the shared-fabric hot loop through the jitted
        # engine_jax core (tolerance contract: rtol 1e-5 vs numpy)
        self.backend = parse_backend(backend)

    # ------------------------------------------------------------------
    def _geometry(self, seed: int):
        p = self.p
        net = p.net
        n = net.n_nodes
        plan = (self.plan_override if self.plan_override is not None
                else schedule_mod.make_plan(net, p.topo, p.work))
        geo = dict(
            n=n, steps=plan.steps_per_round, plan=plan,
            n_pkts=max(1, (p.work.message_bytes // n) // net.mtu_bytes),
            src=np.arange(n), dst=(np.arange(n) + 1) % n,
            n_tors=n // net.nodes_per_tor,
            hier=topology.hier_geometry(net, p.topo))
        master = np.random.default_rng(seed)
        geo["fabric_seed"] = int(master.integers(2**31))
        return geo

    def _new_traces(self, design_list, T, steps, n, per_node_for,
                    tier_cols=None, tier_counts=None, tier_pkts_round=None,
                    phase_of_step=None, phase_budget_frac=None,
                    phase_src=None, phase_tier_cols=None,
                    phase_pod_cols=None, n_pods=0, pod_pkts_round=None,
                    step_priority=None):
        track = tier_counts is not None
        pods = n_pods > 1
        out: Dict[str, StepTrace] = {}
        for d in design_list:
            keep = d in per_node_for
            out[d] = StepTrace(
                design=d, steps_per_round=steps,
                nat_us=np.empty(T), deliv=np.empty(T), total=np.empty(T),
                # per-node arrays start zeroed: multi-phase plans leave
                # inactive (node, step) cells untouched
                node_time_us=np.zeros((T, n)) if keep else None,
                node_deliv=np.zeros((T, n)) if keep else None,
                tier_deliv=np.empty((T, topology.N_TIERS)) if track else None,
                tier_total=np.empty((T, topology.N_TIERS)) if track else None,
                tier_cols=tier_cols, tier_counts=tier_counts,
                tier_pkts_round=tier_pkts_round,
                phase_of_step=phase_of_step,
                phase_budget_frac=phase_budget_frac,
                phase_src=phase_src, phase_tier_cols=phase_tier_cols,
                phase_pod_cols=phase_pod_cols,
                step_priority=step_priority,
                pod_deliv=np.zeros((T, n_pods)) if pods else None,
                pod_total=np.zeros((T, n_pods)) if pods else None,
                pod_pkts_round=pod_pkts_round if pods else None)
        return out

    @staticmethod
    def _reduce_into(tr: StepTrace, sl: slice, time_us, delivered, total):
        tr.nat_us[sl] = time_us.max(axis=-1)
        tr.deliv[sl] = delivered.sum(axis=-1)
        tr.total[sl] = total.sum(axis=-1)
        if tr.tier_cols is not None:
            for k, cols in enumerate(tr.tier_cols):
                tr.tier_deliv[sl, k] = delivered[..., cols].sum(axis=-1)
                tr.tier_total[sl, k] = total[..., cols].sum(axis=-1)
        if tr.node_time_us is not None:
            tr.node_time_us[sl] = time_us
            tr.node_deliv[sl] = delivered

    @staticmethod
    def _phase_reduce_into(tr: StepTrace, rows: np.ndarray, src: np.ndarray,
                           tier_cols: tuple, res,
                           pod_cols: tuple | None = None) -> None:
        """Scatter one schedule phase's transfer results into the trace.

        ``rows`` are the phase's absolute step indices, ``src`` its
        sender nodes (the per-node scatter columns), ``tier_cols`` its
        flow→tier column sets, and ``pod_cols`` (multi-pod only) its
        per-pod intra-flow column sets.  On a single-phase (ring) plan
        this reduces to exactly :meth:`_reduce_into` over the block
        slice.
        """
        tr.nat_us[rows] = res.time_us.max(axis=-1)
        tr.deliv[rows] = res.delivered_pkts.sum(axis=-1)
        tr.total[rows] = res.total_pkts.sum(axis=-1)
        if tr.tier_deliv is not None:
            for k, cols in enumerate(tier_cols):
                tr.tier_deliv[rows, k] = (
                    res.delivered_pkts[..., cols].sum(axis=-1))
                tr.tier_total[rows, k] = res.total_pkts[..., cols].sum(axis=-1)
        if tr.pod_deliv is not None and pod_cols is not None:
            for p, cols in enumerate(pod_cols):
                tr.pod_deliv[rows, p] = (
                    res.delivered_pkts[..., cols].sum(axis=-1))
                tr.pod_total[rows, p] = res.total_pkts[..., cols].sum(axis=-1)
        if tr.node_time_us is not None:
            tr.node_time_us[np.ix_(rows, src)] = res.time_us
            tr.node_deliv[np.ix_(rows, src)] = res.delivered_pkts

    def traces(self, design_list: Sequence[str], n_rounds: int, seed: int, *,
               legacy_streams: bool = True,
               per_node_for: Sequence[str] = (),
               round_block: int | None = None) -> Dict[str, StepTrace]:
        """One physics pass for every design in ``design_list``.

        ``legacy_streams=True`` reproduces the sequential simulator's
        seeded statistics: the fabric trace is replayed bit-exactly per
        design-stream class (clean for irn/srnic/celeris, PFC-polluted
        for RoCE), and the irn/srnic/celeris transfer + CNP draws are
        replayed bit-exactly too (RoCE transfer draws are engine-native
        — its ``integers`` consumption is irreproducible — leaving a
        few percent of p99 noise).  Memory is O(T * n); intended for
        the compatibility scales (<= 256 nodes).

        ``legacy_streams=False`` is the sweep fast path: all designs
        share one clean fabric trace and one DCQCN rate trace,
        engine-native streams, processed in bounded round blocks
        (flat memory at any cluster size).
        """
        unknown = [d for d in design_list if d not in designs.DESIGNS]
        if unknown:
            raise ValueError(f"unknown design(s) {unknown}; "
                             f"choose from {designs.DESIGNS}")
        net = self.p.net
        if net.n_nodes < net.nodes_per_tor or net.n_nodes % net.nodes_per_tor:
            raise ValueError(
                f"n_nodes={net.n_nodes} must be a positive multiple of "
                f"nodes_per_tor={net.nodes_per_tor}")
        if net.ecn_threshold > net.loss_knee:
            # the hot-row prescreen in _sparse_path_curves keys on the
            # ECN threshold being the lower of the two curves
            raise ValueError(
                f"ecn_threshold={net.ecn_threshold} must not exceed "
                f"loss_knee={net.loss_knee}")
        if self.backend == "jax":
            if legacy_streams:
                raise ValueError(
                    "backend='jax' computes engine-native "
                    "(shared-fabric) traces only: pass "
                    "legacy_streams=False (run() and sweep() flip it "
                    "automatically)")
            if per_node_for:
                raise ValueError(
                    "backend='jax' does not materialize per-flow "
                    "(T, n) arrays; use backend='numpy' for "
                    "per_node_for traces")
            if self.recorder is not None:
                raise ValueError(
                    "a TraceRecorder requires backend='numpy' (its "
                    "hooks ride the numpy per-phase pass)")
            from repro.core.transport import engine_jax
            return engine_jax.traces_batched(
                self, list(design_list), n_rounds, [seed],
                round_block=round_block)[0]
        if self.p.topo.hierarchical and legacy_streams:
            # legacy mode replays the flat sequential simulator's random
            # streams; there is no pre-topology stream to replay for a
            # multi-pod fabric
            raise ValueError(
                "hierarchical topologies (n_pods > 1) require "
                "legacy_streams=False (shared-fabric mode)")
        if self.p.work.schedule != "ring" and legacy_streams:
            # same contract for non-ring collective schedules: the
            # sequential simulator only ever ran the flat ring, so there
            # is no stream to replay for any other plan
            raise ValueError(
                f"schedule={self.p.work.schedule!r} requires "
                "legacy_streams=False (shared-fabric mode)")
        if self.plan_override is not None and legacy_streams:
            # arbitrary flow plans are engine-native by definition
            raise ValueError(
                "a FlowPlan override requires legacy_streams=False "
                "(shared-fabric mode)")
        if self.p.fault.active and legacy_streams:
            # faults are engine-native processes with their own
            # substreams; the replayed sequential streams predate them
            raise ValueError(
                "fault injection (FaultParams) requires "
                "legacy_streams=False (shared-fabric mode)")
        if self.recorder is not None and legacy_streams:
            # the recorder hooks ride the shared-fabric per-phase pass;
            # the replayed sequential path has no component arrays to
            # attribute from
            raise ValueError(
                "a TraceRecorder requires legacy_streams=False "
                "(shared-fabric mode)")
        if legacy_streams:
            return self._traces_legacy(design_list, n_rounds, seed,
                                       per_node_for)
        return self._traces_shared(design_list, n_rounds, seed,
                                   per_node_for, round_block)

    # -- legacy mode ---------------------------------------------------
    def _traces_legacy(self, design_list, n_rounds, seed, per_node_for
                       ) -> Dict[str, StepTrace]:
        p = self.p
        net, rel = p.net, p.rel
        g = self._geometry(seed)
        n, steps, n_pkts = g["n"], g["steps"], g["n_pkts"]
        T = n_rounds * steps
        src, dst, n_tors = g["src"], g["dst"], g["n_tors"]

        need_clean = any(d != "roce" for d in design_list)
        if need_clean:
            # clean fabric trace (shared by irn/srnic/celeris streams)
            u = np.random.default_rng(g["fabric_seed"]).random(
                (T, network._ADVANCE_DRAWS, n_tors))
            state0 = network.FabricState(
                bursting=np.zeros(n_tors, dtype=bool),
                occupancy=np.full(n_tors, net.idle_occupancy))
            _, occ_tor, _ = network.occupancy_trace(net, u, state0)
            del u
            ecn_clean, drop_clean, _ = _sparse_path_curves(net, occ_tor,
                                                           src, dst)
            occ_clean32 = network.path_occupancy_trace(
                net, occ_tor.astype(np.float32), src, dst)

        need_roce = "roce" in design_list
        if need_roce:
            occ_tor_roce, pfc_roce = network.roce_fabric_trace(
                net, g["fabric_seed"], src, dst, T)
            ecn_roce, drop_roce, hot_roce = _sparse_path_curves(
                net, occ_tor_roce, src, dst)
            occ_roce32 = network.path_occupancy_trace(
                net, occ_tor_roce.astype(np.float32), src, dst)

        # replayed draw streams (bit-exact vs the sequential simulator)
        sr = cel = None
        if "irn" in design_list or "srnic" in design_list:
            sr = replay.replay_selective_repeat(seed, n_pkts, drop_clean,
                                                ecn_clean)
        if "celeris" in design_list:
            cel = replay.replay_celeris(seed, n_pkts, drop_clean, ecn_clean)

        # one batched DCQCN pass over all distinct CNP channels
        channels = []
        chan_idx = {}
        if need_roce:
            # engine-native stream: ECN is zero off the hot rows, so only
            # those need uniforms
            cnp_roce = np.zeros((T, n), dtype=bool)
            cnp_roce[hot_roce] = (
                np.random.default_rng([seed, _STREAM_CNP_ROCE])
                .random((hot_roce.size, n)) < ecn_roce[hot_roce])
            chan_idx["roce"] = len(channels)
            channels.append(cnp_roce)
        if sr is not None:
            chan_idx["sr"] = len(channels)
            channels.append(sr.cnp)
        if cel is not None:
            chan_idx["celeris"] = len(channels)
            channels.append(cel.cnp)
        # float32 for the time chain: times feed only max/sum/percentile
        # reductions, so f32 noise (~1e-7 relative) is immaterial, and
        # the arrays are memory-bandwidth-bound.  Everything feeding the
        # *replay* (occupancies, drop/ECN curves) stays f64 — a flipped
        # comparison there would desynchronize the stream.
        rates, _ = dcqcn.rate_trace(np.stack(channels, axis=1), p.dcqcn,
                                    dtype=np.float32)

        tier_counts = g["hier"].tier_counts
        plan: schedule_mod.SchedulePlan = g["plan"]   # single-phase ring
        out = self._new_traces(design_list, T, steps, n, per_node_for,
                               tier_cols=g["hier"].tier_cols,
                               tier_counts=tier_counts,
                               tier_pkts_round=tier_counts
                               * float(n_pkts * steps),
                               phase_of_step=plan.phase_of_step,
                               phase_budget_frac=plan.budget_fracs(),
                               phase_src=(plan.phases[0].src,),
                               phase_tier_cols=(g["hier"].tier_cols,),
                               step_priority=plan.step_priority())
        if need_clean:
            qd_clean = network.queue_delay_us(net, occ_clean32)
            avail_clean = network.avail_bandwidth(net, occ_clean32)
        full_total = np.full(T, float(n_pkts * n))

        if need_roce:
            rate_d = np.ascontiguousarray(rates[:, chan_idx["roce"]])
            eff = rate_d * network.avail_bandwidth(net, occ_roce32)
            res = designs.transfer(
                "roce", n_pkts, occ_roce32, eff, drop_roce,
                pfc_roce.astype(np.float32),
                network.queue_delay_us(net, occ_roce32), rel, net,
                np.random.default_rng([seed, _STREAM_TRANSFER["roce"]]))
            self._reduce_into(out["roce"], slice(0, T), res.time_us,
                              res.delivered_pkts, res.total_pkts)

        if sr is not None:
            rate_d = np.ascontiguousarray(rates[:, chan_idx["sr"]])
            pkt_time = net.pkt_time_us / np.maximum(rate_d * avail_clean,
                                                    1e-3)
            base = n_pkts * pkt_time + qd_clean + net.base_rtt_us / 2
            # loss penalties exist only where packets dropped — scatter
            idx = np.nonzero(sr.k)
            kk = sr.k[idx].astype(np.float64)
            ptf = pkt_time[idx].astype(np.float64)
            detect = np.where(sr.tail_lost[idx], rel.rto_low_us,
                              rel.nack_delay_us + net.base_rtt_us)
            extra = np.zeros((T, n), dtype=np.float32)
            extra[idx] = detect + kk * ptf
            idx2 = np.nonzero(sr.k2)
            extra[idx2] += (rel.rto_low_us
                            + sr.k2[idx2] * pkt_time[idx2].astype(np.float64))
            for d in ("irn", "srnic"):
                if d not in out:
                    continue
                t = base + extra
                if d == "srnic":
                    t[idx] += (kk * rel.host_slowpath_us).astype(np.float32)
                tr = out[d]
                tr.nat_us[:] = t.max(axis=-1)
                tr.deliv[:] = full_total
                tr.total[:] = full_total
                tr.tier_deliv[:] = n_pkts * tier_counts
                tr.tier_total[:] = n_pkts * tier_counts
                if tr.node_time_us is not None:
                    tr.node_time_us[:] = t
                    tr.node_deliv[:] = float(n_pkts)

        if cel is not None:
            rate_d = np.ascontiguousarray(rates[:, chan_idx["celeris"]])
            serialize = n_pkts * (net.pkt_time_us
                                  / np.maximum(rate_d * avail_clean, 1e-3))
            t = (serialize + designs.CELERIS_QUEUE_OVERLAP * qd_clean
                 + net.base_rtt_us / 2)
            tr = out["celeris"]
            tr.nat_us[:] = t.max(axis=-1)
            tr.deliv[:] = full_total - cel.k.sum(axis=-1)
            tr.total[:] = full_total
            for k_t, cols in enumerate(tr.tier_cols):
                tr.tier_deliv[:, k_t] = (n_pkts * cols.size
                                         - cel.k[:, cols].sum(axis=-1))
                tr.tier_total[:, k_t] = n_pkts * cols.size
            if tr.node_time_us is not None:
                tr.node_time_us[:] = t
                tr.node_deliv[:] = n_pkts - cel.k
        return out

    # -- shared (sweep) mode -------------------------------------------
    def _traces_shared(self, design_list, n_rounds, seed, per_node_for,
                       round_block) -> Dict[str, StepTrace]:
        """One physics pass driven by the collective schedule's plan.

        The plan's phases partition each round's steps; every phase is
        a dense ``(step, flow)`` block with a static flow pattern, so
        the whole-trace vectorization survives arbitrary schedules.
        On the single-phase ring plan each per-phase pass covers every
        row of the block, making this bit-identical to the
        pre-schedule engine (the per-phase loop touches the same
        arrays with the same draws in the same order).
        """
        p = self.p
        net, rel = p.net, p.rel
        g = self._geometry(seed)
        n, steps = g["n"], g["steps"]
        plan: schedule_mod.SchedulePlan = g["plan"]
        T = n_rounds * steps
        n_tors = g["n_tors"]

        if round_block is None:
            round_block = max(1, _BLOCK_ELEMENTS // (steps * n))
        block_steps = round_block * steps

        fabric_gen = np.random.default_rng(g["fabric_seed"])
        cnp_gen = np.random.default_rng([seed, _STREAM_CNP])
        pfc_gen = np.random.default_rng([seed, _STREAM_PFC])
        transfer_gens = {d: np.random.default_rng([seed, _STREAM_TRANSFER[d]])
                         for d in design_list}

        fab_state = network.FabricState(
            bursting=np.zeros(n_tors, dtype=bool),
            occupancy=np.full(n_tors, net.idle_occupancy))
        cc_state = dcqcn.DcqcnState.init(n)

        # DCI tier (multi-pod only): its own burst process and random
        # substreams, so the flat (n_pods=1) trace consumes exactly the
        # streams it always did
        hier = p.topo.hierarchical
        if hier:
            dci_net = topology.dci_net_params(net, p.topo)
            dci_state = topology.init_dci_state(net, p.topo)
            dci_fab_gen = np.random.default_rng(
                [g["fabric_seed"], topology.STREAM_DCI_FABRIC])
            dci_cnp_gen = np.random.default_rng(
                [seed, topology.STREAM_DCI_CNP])

        # static per-phase facts: flow→tier geometry, packet budget,
        # in-round step offsets
        hgs = plan.geometries(net, p.topo)
        ph_pkts = [ph.n_pkts(net) for ph in plan.phases]
        ph_steps = [np.flatnonzero(plan.phase_of_step == k)
                    for k in range(len(plan.phases))]

        # incast columns (flows whose receiver takes > 1 concurrent
        # sender): every collective schedule is a permutation, so these
        # are empty there — the overlay below constructs nothing, draws
        # nothing, and the trace stays bit-identical to the fan-in-1
        # engine.  Point-to-point plans (serve KV shipping) populate
        # them, and their receiver ports get an occupancy floor of
        # 1 - 1/fan (fan senders sharing one egress link) plus
        # fan-way egress serialization in phase pass 2.
        ph_fan = [ph.fan_in() for ph in plan.phases]
        ph_inc = [np.flatnonzero(f > 1) for f in ph_fan]
        # single-phase fast paths (no row/column re-indexing) apply only
        # when the phase's senders are exactly the identity over all n
        # nodes — true for the flat ring, not necessarily for a
        # point-to-point plan with idle nodes
        identity_plan = plan.single_phase and np.array_equal(
            plan.phases[0].src, np.arange(n))
        incast = any(inc.size for inc in ph_inc)
        if incast:
            inc_cnp_gen = np.random.default_rng([seed, _STREAM_INCAST_CNP])

        # seeded fault processes (params.FaultParams): generators are
        # created once and consumed per block, like the fabric stream;
        # inactive configs construct nothing and draw nothing, keeping
        # fault-free traces bit-identical to the pre-fault engine
        fmodel = (faults.FaultModel(p, seed, n, n_tors, steps)
                  if p.fault.active else None)
        fault_flows = np.zeros(T) if fmodel is not None else None

        rec = self.recorder
        if rec is not None:
            rec.begin(design_list, plan=plan, n_rounds=n_rounds,
                      steps=steps)

        ph_pod_cols = ([hg.pod_cols for hg in hgs] if hier else None)
        out = self._new_traces(
            design_list, T, steps, n, per_node_for,
            tier_cols=hgs[0].tier_cols if plan.single_phase else None,
            tier_counts=plan.tier_counts(net, p.topo, hgs),
            tier_pkts_round=plan.tier_pkts_round(net, p.topo, hgs),
            phase_of_step=plan.phase_of_step,
            phase_budget_frac=plan.budget_fracs(),
            phase_src=tuple(ph.src for ph in plan.phases),
            phase_tier_cols=tuple(hg.tier_cols for hg in hgs),
            phase_pod_cols=tuple(ph_pod_cols) if hier else None,
            n_pods=p.topo.n_pods if hier else 0,
            pod_pkts_round=(plan.pod_pkts_round(net, p.topo, hgs)
                            if hier else None),
            step_priority=plan.step_priority())
        for t0 in range(0, T, block_steps):
            tb = min(block_steps, T - t0)   # whole rounds: steps | tb
            u = fabric_gen.random((tb, network._ADVANCE_DRAWS, n_tors))
            _, occ_tor, fab_state = network.occupancy_trace(net, u, fab_state)

            if hier:
                u_dci = dci_fab_gen.random(
                    (tb, network._ADVANCE_DRAWS, p.topo.n_pods))
                _, occ_dci, dci_state = network.occupancy_trace(
                    dci_net, u_dci, dci_state)

            # phase pass 1: path curves + CNP draws per phase block
            # (phase rows of the block share the phase's flow pattern)
            cnp = np.zeros((tb, n), dtype=bool)
            round0 = np.arange(0, tb, steps)
            ph_data = []
            for k, ph in enumerate(plan.phases):
                rows = (round0[:, None] + ph_steps[k][None, :]).ravel()
                occ_ph = occ_tor[rows] if not plan.single_phase else occ_tor
                ecn_p, drop_p, hot = _sparse_path_curves(net, occ_ph,
                                                         ph.src, ph.dst)
                occ32 = network.path_occupancy_trace(
                    net, occ_ph.astype(np.float32), ph.src, ph.dst)
                occ_eff = None
                if hier:
                    occ_eff = topology.overlay_curves(
                        net, p.topo, hgs[k], occ_ph,
                        occ_dci[rows] if not plan.single_phase else occ_dci,
                        ecn_p, drop_p)
                cnp_ph = np.zeros((rows.size, ph.src.size), dtype=bool)
                cnp_ph[hot] = (cnp_gen.random((hot.size, ph.src.size))
                               < ecn_p[hot])
                if hier:
                    topology.dci_cnp_draws(hgs[k], ecn_p, cnp_ph, dci_cnp_gen)
                inc = ph_inc[k]
                if inc.size:
                    # incast overlay, pass 1: the receiver's egress port
                    # runs at >= 1 - 1/fan occupancy whenever its fan
                    # senders offer load, regardless of background
                    # bursts — curves and CNP marking on those columns
                    # follow the raised occupancy (own substream: the
                    # shared CNP stream's consumption must not shift)
                    occ_inc = np.maximum(occ32[:, inc],
                                         (1.0 - 1.0 / ph_fan[k][inc]
                                          ).astype(occ32.dtype))
                    occ32[:, inc] = occ_inc
                    ecn_inc = network.ecn_mark_prob(net, occ_inc)
                    drop_p[:, inc] = network.drop_prob(net, occ_inc)
                    cnp_ph[:, inc] = inc_cnp_gen.random(occ_inc.shape) < ecn_inc
                cnp[np.ix_(rows, ph.src)] = cnp_ph
                ph_data.append([rows, occ32, drop_p, occ_eff])

            # the DCQCN recurrence runs over the full block — per
            # *sender NIC*, whose rate evolves across phase boundaries
            # (recovering through steps it does not send in)
            rate, cc_state = dcqcn.rate_trace(cnp, p.dcqcn, cc_state,
                                              dtype=np.float32)

            # fault masks for this block: availability is physics, not
            # design behavior, so one set of masks serves every design
            blk = fmodel.advance(t0, tb) if fmodel is not None else None

            # phase pass 2: queueing + effective send rate (+ DCI
            # overlay, + fault availability masks) per phase block
            for k, ph in enumerate(plan.phases):
                rows, occ32, drop_p, occ_eff = ph_data[k]
                qd = network.queue_delay_us(net, occ32)
                rate_ph = (rate if identity_plan
                           else rate[np.ix_(rows, ph.src)])
                eff_rate = rate_ph * network.avail_bandwidth(net, occ32)
                if hier:
                    topology.overlay_rates(net, p.topo, hgs[k], occ_eff,
                                           rate_ph, occ32, qd, eff_rate)
                inc = ph_inc[k]
                if inc.size:
                    # incast overlay, pass 2: queueing and bandwidth
                    # already follow the raised occ32 from pass 1; on
                    # top, fan senders share the receiver's one egress
                    # link, so each flow serializes at 1/fan of it
                    eff_rate[:, inc] /= ph_fan[k][inc]
                blocked = dead = None
                if fmodel is not None:
                    if fmodel.rate_scale is not None:
                        # slow-NIC stragglers: scaled DCQCN-granted rate
                        eff_rate *= fmodel.rate_scale[ph.src]
                    blocked, dead = fmodel.phase_masks(
                        blk, rows, ph, hgs[k], net.nodes_per_tor)
                    nf = ((blocked.sum(axis=1) if blocked is not None else 0)
                          + (dead.sum(axis=1) if dead is not None else 0))
                    fault_flows[t0 + rows] = nf
                ph_data[k] = (rows, occ32, drop_p, qd, eff_rate,
                              blocked, dead)
                if rec is not None:
                    # design-independent fabric counters for the export
                    # counter tracks (pure reductions, no draws)
                    rec.record_fabric(
                        t0 + rows,
                        network.congestion_counters(net, occ32, drop_p), T)

            for d in design_list:
                for k, ph in enumerate(plan.phases):
                    (rows, occ32, drop_p, qd, eff_rate,
                     blocked, dead) = ph_data[k]
                    pfc = (network.pfc_pause_trace(net, occ32, pfc_gen)
                           if d == "roce"
                           else np.zeros(occ32.shape, np.float32))
                    parts = rec.new_parts() if rec is not None else None
                    res = designs.transfer(d, ph_pkts[k], occ32, eff_rate,
                                           drop_p, pfc, qd, rel, net,
                                           transfer_gens[d], parts=parts)
                    if hier:
                        topology.add_dci_latency(p.topo, hgs[k], res.time_us,
                                                 parts=parts)
                    faults.apply_to_result(d, res, blocked, dead, rel,
                                           parts=parts)
                    self._phase_reduce_into(
                        out[d], t0 + rows, ph.src, hgs[k].tier_cols, res,
                        pod_cols=ph_pod_cols[k] if hier else None)
                    if rec is not None:
                        rec.record_phase(d, t0 + rows, ph, hgs[k],
                                         ph_fan[k], res, parts)
        if fault_flows is not None:
            for tr in out.values():
                tr.fault_flows = fault_flows
        return out

    # ------------------------------------------------------------------
    def assemble(self, trace: StepTrace, seed: int, *,
                 celeris_timeout_us: float | None = None,
                 adaptive: bool = True,
                 window: "str | WindowPolicy" = "round",
                 cut_order: str = "arrival") -> RoundStats:
        """Apply round structure (and, for Celeris, bounded windows) to a
        step trace.  Sequential only across rounds, and only when the
        adaptive controller is on.

        ``window`` is a :class:`~repro.core.transport.params
        .WindowPolicy` (or its kind string): ``"round"`` is one
        deadline per round (bit-exact with the pre-policy engine),
        ``"phase"`` splits the same budget across the collective
        schedule's phase blocks by their ``budget_frac`` weights, and
        ``"step"`` divides each phase's share uniformly over its steps
        (per-flow data required).  On a single-phase (ring) plan all
        three policies see the identical ``[1.0]`` split, so "phase"
        degenerates to "round" and "step" to the pre-policy per-step
        window, bit-for-bit.

        ``cut_order`` decides *which* packets a binding budget cuts:
        ``"arrival"`` (bit-pinned default) truncates the trailing
        steps; ``"priority"`` reallocates the same total cut by
        semantic class (``schedule.SchedulePhase.priority``) — lowest
        class first, high classes only after the low ones are
        exhausted.  Elapsed times and total delivered fractions are
        identical between the two orders (matched p99 by
        construction); only the per-tier / per-pod / per-class
        accounting moves, which is what the coupling layer and fig10
        read.
        """
        window = WindowPolicy.parse(window).kind
        if cut_order not in ("arrival", "priority"):
            raise ValueError(f"cut_order must be 'arrival' or "
                             f"'priority', got {cut_order!r}")
        if cut_order == "priority":
            if trace.step_priority is None:
                raise ValueError(
                    "cut_order='priority' needs a trace with "
                    "step_priority metadata (engine-built traces carry "
                    "it; traces assembled from raw arrays do not)")
            if window == "step":
                raise ValueError(
                    "cut_order='priority' applies to round/phase "
                    "budgets; the step window binds per step, leaving "
                    "no cut to reorder")
        steps = trace.steps_per_round
        R = trace.nat_us.shape[0] // steps
        nat = trace.nat_us.reshape(R, steps)
        deliv = trace.deliv.reshape(R, steps)
        total = trace.total.reshape(R, steps)
        tot_sum = np.maximum(total.sum(axis=1), 1.0)

        # accounting groups riding the window cut: tiers, then pods,
        # then priority classes
        t_deliv = t_total = p_deliv = p_total = pr_deliv = None
        groups = []             # (R, steps, G) delivered/total pairs
        if trace.tier_deliv is not None:
            t_deliv = trace.tier_deliv.reshape(R, steps, -1)
            t_total = trace.tier_total.reshape(R, steps, -1)
            groups.append((t_deliv, t_total))
        if trace.pod_deliv is not None:
            p_deliv = trace.pod_deliv.reshape(R, steps, -1)
            p_total = trace.pod_total.reshape(R, steps, -1)
            groups.append((p_deliv, p_total))
        prio_pkts = None
        if trace.step_priority is not None:
            # per-class accounting: scatter the scalar per-step sums by
            # the static step→class map (no physics involved — the
            # class split of a step's delivered packets is the step's
            # own split, like the tier columns above)
            cls = np.asarray(trace.step_priority, dtype=int)
            onehot = cls[:, None] == np.arange(cls.max() + 1)[None, :]
            pr_deliv = deliv[:, :, None] * onehot
            pr_total = total[:, :, None] * onehot
            groups.append((pr_deliv, pr_total))
            prio_pkts = pr_total.sum(axis=1)[0]
        tier_kw = dict(tier_counts=trace.tier_counts,
                       tier_pkts=trace.tier_pkts_round,
                       pod_pkts=trace.pod_pkts_round,
                       prio_pkts=prio_pkts)
        if trace.fault_flows is not None:
            # fault exposure per round: steps with >= 1 faulted flow,
            # and total faulted (flow, step) cells — design-independent,
            # so every design's stats carry the same availability story
            ff = trace.fault_flows.reshape(R, steps)
            tier_kw.update(fault_steps=(ff > 0).sum(axis=1),
                           affected_flows=ff.sum(axis=1))

        def _pack(times, fracs, group_fracs, design=trace.design):
            gf = list(group_fracs)
            tf = gf.pop(0) if t_deliv is not None else None
            pf = gf.pop(0) if p_deliv is not None else None
            prf = gf.pop(0) if pr_deliv is not None else None
            st = RoundStats(times_us=times, recv_frac=fracs,
                            design=design, tier_recv_frac=tf,
                            pod_recv_frac=pf, prio_recv_frac=prf,
                            **tier_kw)
            if self.recorder is not None:
                # window-cut attribution: the gap between the trace's
                # post-fault delivery and what survived the window
                self.recorder.record_assemble(trace, st)
            return st

        if trace.design != "celeris":
            return _pack(nat.sum(axis=1), deliv.sum(axis=1) / tot_sum,
                         [_tier_frac(gd.sum(axis=1), gt.sum(axis=1))
                          for gd, gt in groups])

        if window == "step" and trace.node_time_us is None:
            raise ValueError(
                "window='step' needs per-flow data: build the trace with "
                "traces(..., per_node_for=('celeris',)) or use "
                "BatchedEngine.run(), which sets it up")

        # per-phase structure: in-round step rows, sender nodes, and
        # column maps per phase.  Traces without plan metadata (built
        # outside the engine) degenerate to one phase covering the
        # round — exactly the old single-phase behavior.
        if trace.phase_of_step is not None:
            ph_rows = [np.flatnonzero(trace.phase_of_step == k)
                       for k in range(trace.phase_budget_frac.size)]
            ph_frac = trace.phase_budget_frac
            ph_src = trace.phase_src
            ph_tier_cols = trace.phase_tier_cols
            ph_pod_cols = trace.phase_pod_cols
        else:
            ph_rows = [np.arange(steps)]
            ph_frac = np.ones(1)
            ph_src = None
            ph_tier_cols = ((trace.tier_cols,)
                            if trace.tier_cols is not None else None)
            ph_pod_cols = None
        multi_phase = len(ph_rows) > 1
        if window == "step" and multi_phase and ph_src is None:
            raise ValueError(
                "window='step' on a multi-phase plan needs the trace's "
                "per-phase sender maps (engine-built traces carry them)")
        if window == "step":
            # per-group column maps, aligned one-to-one with ``groups``
            # (the cut accounting below indexes them in lockstep): a
            # tracked group whose flow→column map is missing cannot be
            # attributed per step — fail with intent, not an IndexError
            step_col_maps = []
            for present, ph_cols, what in (
                    (t_deliv is not None, ph_tier_cols, "flow→tier"),
                    (p_deliv is not None, ph_pod_cols, "flow→pod")):
                if not present:
                    continue
                if ph_cols is None:
                    raise ValueError(
                        f"window='step' {what} accounting needs the "
                        "plan's per-phase flow maps (engine-built "
                        "traces carry them)")
                step_col_maps.append(ph_cols)

        def _node_cols(k, cols):
            # a phase's flow columns → node columns in the (T, n) arrays
            return cols if ph_src is None else ph_src[k][cols]

        def _step_window_round(r, budget_us):
            """Per-step deadlines for round ``r``: each phase's budget
            share divided uniformly over its steps."""
            step_to = np.empty(steps)
            for k, rows in enumerate(ph_rows):
                step_to[rows] = budget_us * ph_frac[k] / rows.size
            t_node = trace.node_time_us[r * steps: (r + 1) * steps]
            d_node = trace.node_deliv[r * steps: (r + 1) * steps]
            late = np.clip((t_node - step_to[:, None])
                           / np.maximum(t_node, 1e-9), 0, 1)
            time_r = np.minimum(nat[r], step_to).sum()
            got_node = d_node * (1 - late)
            gots = []
            for ph_cols in step_col_maps:
                got_g = np.zeros(len(ph_cols[0]))
                for k, rows in enumerate(ph_rows):
                    for j, cols in enumerate(ph_cols[k]):
                        if cols.size:
                            got_g[j] += got_node[
                                np.ix_(rows, _node_cols(k, cols))].sum()
                gots.append(got_g)
            if pr_deliv is not None:
                # per-class split of the per-step cut (the step window
                # binds per step, so each step's survivors land whole
                # in that step's class)
                got_pr = np.zeros(pr_deliv.shape[2])
                np.add.at(got_pr, np.asarray(trace.step_priority, int),
                          got_node.sum(axis=1))
                gots.append(got_pr)
            return time_r, got_node.sum(), gots

        init_to = (celeris_timeout_us or 50_000.0) / 1e6
        cfg = timeout_mod.TimeoutConfig(
            init_timeout=init_to, min_timeout=init_to * 0.25,
            max_timeout=init_to * 8.0, alpha=0.25)

        # static cut-order permutations (cut_order="priority"): one per
        # budget block — the whole round for the round window, each
        # phase block for the phase window (a phase of uniform class
        # degenerates to arrival order there)
        round_perm = ph_perms = None
        if cut_order == "priority":
            sp = np.asarray(trace.step_priority, dtype=int)
            round_perm = _priority_perm(sp)
            ph_perms = [_priority_perm(sp[rows]) for rows in ph_rows]

        if not adaptive and window in ("round", "phase"):
            if self.backend == "jax":
                # jitted twin of the fixed windows below; the round
                # window is the single-phase case of the phase window
                # (value-identical, see engine_jax)
                from repro.core.transport import engine_jax
                jax_rows, jax_frac = (
                    (ph_rows, ph_frac) if window == "phase"
                    else ([np.arange(steps)], np.ones(1)))
                jax_perms = None
                if cut_order == "priority":
                    jax_perms = (ph_perms if window == "phase"
                                 else [round_perm])
                return _pack(*engine_jax.assemble_window_fixed(
                    nat, deliv, tot_sum, init_to * 1e6, groups,
                    jax_rows, jax_frac, perms=jax_perms),
                    design="celeris")
            if window == "round":
                return _pack(*self._assemble_round_window_fixed(
                    nat, deliv, tot_sum, init_to * 1e6, groups,
                    perm=round_perm), design="celeris")
            return _pack(*self._assemble_phase_window_fixed(
                nat, deliv, tot_sum, init_to * 1e6, groups, ph_rows,
                ph_frac, perms=ph_perms), design="celeris")

        rng = np.random.default_rng([seed, _STREAM_WINDOW])
        n = self.p.net.n_nodes
        timeout = cfg.init_timeout
        smoothed = np.full(n, cfg.init_timeout)
        times = np.zeros(R)
        fracs = np.ones(R)
        g_fracs = [np.ones((R, gd.shape[2])) for gd, _ in groups]
        g_tot = [gt.sum(axis=1) for _, gt in groups]

        for r in range(R):
            budget_us = timeout * 1e6
            if window == "step":
                times[r], got, gots = _step_window_round(r, budget_us)
                fracs[r] = got / tot_sum[r]
            elif window == "phase" and multi_phase:
                t_sum, got = 0.0, 0.0
                gots = [np.zeros(gd.shape[2]) for gd, _ in groups]
                for k, rows in enumerate(ph_rows):
                    t_k, got_k, gots_k = _cut_block(
                        nat[r, rows], deliv[r, rows],
                        budget_us * ph_frac[k],
                        [gd[r, rows] for gd, _ in groups],
                        perm=None if ph_perms is None else ph_perms[k])
                    t_sum += t_k
                    got += got_k
                    for gg, gk in zip(gots, gots_k):
                        gg += gk
                times[r] = t_sum
                fracs[r] = got / tot_sum[r]
            else:   # "round" (and "phase" on a single-phase plan,
                    # where the one phase block is the whole round and
                    # the perms coincide)
                times[r], got, gots = _cut_block(
                    nat[r], deliv[r], budget_us,
                    [gd[r] for gd, _ in groups], perm=round_perm)
                fracs[r] = got / tot_sum[r]
            for i, gg in enumerate(gots):
                g_fracs[i][r] = _tier_frac(gg, g_tot[i][r])
            if adaptive:
                node_frac = np.clip(
                    fracs[r] + rng.normal(0, 0.002, n), 0.0, 1.0)
                local, smoothed = timeout_mod.update_array(
                    smoothed, times[r] / 1e6, node_frac, cfg)
                timeout = timeout_mod.adopt_scalar(
                    timeout_mod.coordinate(local), cfg)
        return _pack(times, fracs, g_fracs, design="celeris")

    @staticmethod
    def _assemble_round_window_fixed(nat, deliv, tot_sum, budget_us,
                                     groups=(), perm=None):
        """Fixed bounded round window, all rounds at once (paper
        protocol).  Returns ``(times, fracs, group_fracs)``.

        ``perm`` (``cut_order="priority"``) reallocates each over-budget
        round's cut across steps in the static priority order — times
        and total delivered fractions are untouched, only the group
        accounting moves (see :func:`_priority_survive`)."""
        cum = np.cumsum(nat, axis=1)
        total_t = cum[:, -1]
        over = total_t > budget_us
        times = np.where(over, budget_us, total_t)
        done = cum <= budget_us
        bidx = np.argmax(~done, axis=1)
        prev = np.where(
            bidx > 0,
            np.take_along_axis(cum, np.maximum(bidx - 1, 0)[:, None],
                               axis=1)[:, 0],
            0.0)
        part = (budget_us - prev) / np.maximum(
            np.take_along_axis(nat, bidx[:, None], axis=1)[:, 0], 1e-9)
        got = ((deliv * done).sum(axis=1)
               + np.take_along_axis(deliv, bidx[:, None], axis=1)[:, 0] * part)
        fracs = np.where(over, got / tot_sum, deliv.sum(axis=1) / tot_sum)
        if perm is not None:
            K = np.where(over, deliv.sum(axis=1) - got, 0.0)
            survive = _priority_survive(deliv, K, perm)
        g_fracs = []
        for g_deliv, g_total in groups:
            # same window cut, applied per group column (the truncated
            # step's partial credit splits in proportion to each
            # column's share of that step's delivered packets —
            # identical math to the scalar path)
            R = g_deliv.shape[0]
            if perm is not None:
                got_g = (g_deliv * survive[:, :, None]).sum(axis=1)
            else:
                got_g = ((g_deliv * done[:, :, None]).sum(axis=1)
                         + g_deliv[np.arange(R), bidx] * part[:, None])
            full_g = g_deliv.sum(axis=1)
            g_fracs.append(_tier_frac(
                np.where(over[:, None], got_g, full_g),
                g_total.sum(axis=1)))
        return times, fracs, g_fracs

    @staticmethod
    def _assemble_phase_window_fixed(nat, deliv, tot_sum, budget_us,
                                     groups, ph_rows, ph_frac,
                                     perms=None):
        """Fixed per-phase windows, all rounds at once: every phase
        block takes its ``budget_frac`` share of the round budget and
        is truncated at its own deadline (the Celeris adaptive-timeout
        idea applied per fabric tier — DCI blocks may run long without
        eating the intra-pod phases' slack, and an intra-pod straggler
        cannot push the DCI deadline out).  Single-phase plans reduce
        to the round window exactly (``ph_frac == [1.0]``).

        ``perms`` (``cut_order="priority"``; one static permutation per
        phase block) reallocates each block's cut in priority order —
        within a phase the classes are usually uniform, making the
        per-phase priority cut coincide with arrival there."""
        R = nat.shape[0]
        times = np.zeros(R)
        got = np.zeros(R)
        got_g = [np.zeros((R, gd.shape[2])) for gd, _ in groups]
        for k, rows in enumerate(ph_rows):
            b_k = budget_us * ph_frac[k]
            cum = np.cumsum(nat[:, rows], axis=1)
            total_t = cum[:, -1]
            over = total_t > b_k
            times += np.where(over, b_k, total_t)
            done = cum <= b_k
            bidx = np.argmax(~done, axis=1)
            prev = np.where(
                bidx > 0,
                np.take_along_axis(cum, np.maximum(bidx - 1, 0)[:, None],
                                   axis=1)[:, 0],
                0.0)
            d_k = deliv[:, rows]
            part = (b_k - prev) / np.maximum(
                np.take_along_axis(nat[:, rows], bidx[:, None],
                                   axis=1)[:, 0], 1e-9)
            got_k = ((d_k * done).sum(axis=1)
                     + np.take_along_axis(d_k, bidx[:, None],
                                          axis=1)[:, 0] * part)
            got += np.where(over, got_k, d_k.sum(axis=1))
            survive = None
            if perms is not None:
                K = np.where(over, d_k.sum(axis=1) - got_k, 0.0)
                survive = _priority_survive(d_k, K, perms[k])
            for i, (gd, _) in enumerate(groups):
                gd_k = gd[:, rows]
                if survive is not None:
                    cut = (gd_k * survive[:, :, None]).sum(axis=1)
                else:
                    cut = ((gd_k * done[:, :, None]).sum(axis=1)
                           + gd_k[np.arange(R), bidx] * part[:, None])
                got_g[i] += np.where(over[:, None], cut,
                                     gd_k.sum(axis=1))
        fracs = got / tot_sum
        g_fracs = [_tier_frac(gg, gt.sum(axis=1))
                   for gg, (_, gt) in zip(got_g, groups)]
        return times, fracs, g_fracs

    # ------------------------------------------------------------------
    def run(self, design: str, n_rounds: int = 400, *,
            celeris_timeout_us: float | None = None,
            adaptive: bool = True, window: "str | WindowPolicy" = "round",
            cut_order: str = "arrival",
            seed: int | None = None, legacy_streams: bool = True
            ) -> RoundStats:
        """Simulate ``n_rounds`` AllReduce rounds for one NIC design."""
        seed = self.p.seed if seed is None else seed
        window = WindowPolicy.parse(window).kind
        keep = (design,) if design == "celeris" and window == "step" else ()
        if design == "celeris" and adaptive:
            # the adaptive controller's per-round normal() draws make the
            # sequential stream irreproducible — engine-native draws (the
            # fabric trace is identical either way)
            legacy_streams = False
        if self.p.work.schedule != "ring":
            # non-ring schedules exist only in shared-fabric mode (no
            # sequential stream to replay)
            legacy_streams = False
        if self.p.fault.active:
            # fault processes are engine-native (their substreams have
            # no sequential-simulator counterpart to replay)
            legacy_streams = False
        if self.plan_override is not None:
            # arbitrary flow plans exist only in shared-fabric mode
            legacy_streams = False
        if self.recorder is not None:
            # telemetry hooks ride the shared-fabric per-phase pass
            legacy_streams = False
        if self.backend == "jax":
            # the jax backend is engine-native by construction
            legacy_streams = False
        tr = self.traces([design], n_rounds, seed,
                         legacy_streams=legacy_streams, per_node_for=keep)
        return self.assemble(tr[design], seed,
                             celeris_timeout_us=celeris_timeout_us,
                             adaptive=adaptive, window=window,
                             cut_order=cut_order)

    # ------------------------------------------------------------------
    def paper_protocol(self, n_rounds: int = 400, seed: int = 0, *,
                       legacy_streams: bool = True) -> Dict[str, RoundStats]:
        """The paper's Fig.-2 protocol: RoCE baseline fixes the Celeris
        window at median + 1 sigma; every design shares one physics
        pass."""
        tr = self.traces(designs.DESIGNS, n_rounds, seed,
                         legacy_streams=legacy_streams)
        out = {d: self.assemble(tr[d], seed)
               for d in ("roce", "irn", "srnic")}
        base = out["roce"]
        to = float(np.percentile(base.times_us, 50) + base.times_us.std())
        out["celeris"] = self.assemble(tr["celeris"], seed,
                                       celeris_timeout_us=to,
                                       adaptive=False, window="round")
        return out


# ----------------------------------------------------------------------
# Parameter-sweep API
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatchedSimParams:
    """A sweep grid over the batched engine.

    Celeris windows follow the paper protocol per (config, seed): fixed
    at that seed's RoCE median + 1 sigma unless ``celeris_timeout_us``
    pins them explicitly; ``timeout_scale`` multiplies the derived
    window (same knob as ``coupling.schedule_from_engine`` — < 1
    tightens the budget into the truncating tail regimes where window
    *policies* actually differ).  ``n_pods`` adds the
    hierarchical-topology
    dimension: pod counts > 1 run with the DCI overlay
    (:mod:`repro.core.transport.topology`) configured from
    ``base.topo``.  ``schedules`` adds the collective-schedule
    dimension ("ring" | "hier" | "perrail",
    :mod:`repro.core.transport.schedule`), and ``windows`` the Celeris
    window-policy dimension ("round" | "phase",
    :class:`~repro.core.transport.params.WindowPolicy`) — window
    policies share one physics trace per cell, only the budget
    assembly differs, so the window axis is nearly free.  ``faults``
    adds the failure-scenario dimension (``params.FaultParams``
    instances, ``"kind:rate"`` specs, or ``None`` for the fault-free
    baseline cell) — a fault changes the physics, so each fault cell
    runs its own trace.
    """
    n_nodes: Sequence[int] = (128,)
    message_mb: Sequence[float] = (25.0,)
    seeds: Sequence[int] = (0,)
    n_pods: Sequence[int] = (1,)
    schedules: Sequence[str] = ("ring",)
    windows: Sequence[str] = ("round",)
    faults: Sequence = (None,)
    designs: Sequence[str] = designs.DESIGNS
    n_rounds: int = 200
    celeris_timeout_us: float | None = None
    timeout_scale: float = 1.0
    legacy_streams: bool = False      # sweeps share one fabric trace
    # "numpy" (bit-pinned reference) | "jax" (jitted core; batches the
    # whole seed axis of each cell in one vmapped pass)
    backend: str = "numpy"
    base: SimParams = SimParams()

    def fault_params(self) -> tuple:
        """``faults`` normalized to FaultParams (None => inactive)."""
        from repro.core.transport.params import FaultParams
        return tuple(FaultParams() if f is None else FaultParams.parse(f)
                     for f in self.faults)


@dataclasses.dataclass
class SweepResult:
    """``stats[(design, n_nodes, message_mb, seed)] -> RoundStats``.

    When the grid sweeps pods (``n_pods != (1,)``) keys grow a trailing
    pod-count element, when it sweeps schedules (``schedules !=
    ("ring",)``) a trailing schedule name after that, and when it
    sweeps window policies (``windows != ("round",)``) a trailing
    window kind last, and when it sweeps fault scenarios (``faults !=
    (None,)``) a trailing ``FaultParams.tag`` string after everything:
    ``(design, n_nodes, message_mb, seed[, n_pods][, schedule][,
    window][, fault])``.
    """
    params: BatchedSimParams
    stats: Dict[tuple, RoundStats]

    def fault_tags(self) -> tuple:
        return tuple(fp.tag for fp in self.params.fault_params())

    def _key(self, d, nn, mb, s, npods, sched="ring", window="round",
             fault="none"):
        key = (d, nn, mb, s)
        if tuple(self.params.n_pods) != (1,):
            key += (npods,)
        if tuple(self.params.schedules) != ("ring",):
            key += (sched,)
        if tuple(self.params.windows) != ("round",):
            key += (window,)
        if self.fault_tags() != ("none",):
            key += (fault,)
        return key

    def _defaults(self, *, message_mb=None, n_pods=None, schedule=None,
                  n_nodes=None, window=None):
        p = self.params
        return (p.n_nodes[0] if n_nodes is None else n_nodes,
                p.message_mb[0] if message_mb is None else message_mb,
                p.n_pods[0] if n_pods is None else n_pods,
                p.schedules[0] if schedule is None else schedule,
                p.windows[0] if window is None else window)

    def p99_vs_scale(self, design: str, message_mb: float | None = None,
                     n_pods: int | None = None,
                     schedule: str | None = None,
                     window: str | None = None
                     ) -> Dict[int, tuple[float, float]]:
        """{n_nodes: (mean p99 over seeds, std over seeds)}."""
        _, mb, npods, sched, win = self._defaults(message_mb=message_mb,
                                                  n_pods=n_pods,
                                                  schedule=schedule,
                                                  window=window)
        out = {}
        for nn in self.params.n_nodes:
            v = [self.stats[self._key(design, nn, mb, s, npods, sched,
                                      win)].p99
                 for s in self.params.seeds]
            out[nn] = (float(np.mean(v)), float(np.std(v)))
        return out

    def p99_vs_pods(self, design: str, n_nodes: int | None = None,
                    message_mb: float | None = None,
                    schedule: str | None = None,
                    window: str | None = None
                    ) -> Dict[int, tuple[float, float]]:
        """{n_pods: (mean p99 over seeds, std over seeds)}."""
        nn, mb, _, sched, win = self._defaults(message_mb=message_mb,
                                               schedule=schedule,
                                               n_nodes=n_nodes,
                                               window=window)
        out = {}
        for npods in self.params.n_pods:
            v = [self.stats[self._key(design, nn, mb, s, npods, sched,
                                      win)].p99
                 for s in self.params.seeds]
            out[npods] = (float(np.mean(v)), float(np.std(v)))
        return out

    def p99_vs_schedule(self, design: str, n_nodes: int | None = None,
                        message_mb: float | None = None,
                        n_pods: int | None = None,
                        window: str | None = None
                        ) -> Dict[str, tuple[float, float]]:
        """{schedule: (mean p99 over seeds, std over seeds)} — the
        ring-vs-hierarchical comparison on one fabric configuration."""
        nn, mb, npods, _, win = self._defaults(message_mb=message_mb,
                                               n_pods=n_pods,
                                               n_nodes=n_nodes,
                                               window=window)
        out = {}
        for sched in self.params.schedules:
            v = [self.stats[self._key(design, nn, mb, s, npods, sched,
                                      win)].p99
                 for s in self.params.seeds]
            out[sched] = (float(np.mean(v)), float(np.std(v)))
        return out

    def p99_vs_window(self, design: str, n_nodes: int | None = None,
                      message_mb: float | None = None,
                      n_pods: int | None = None,
                      schedule: str | None = None
                      ) -> Dict[str, tuple[float, float]]:
        """{window: (mean p99 over seeds, std over seeds)} — the
        round-vs-phase budget comparison on one fabric configuration
        (same physics trace, different budget assembly)."""
        nn, mb, npods, sched, _ = self._defaults(message_mb=message_mb,
                                                 n_pods=n_pods,
                                                 n_nodes=n_nodes,
                                                 schedule=schedule)
        out = {}
        for win in self.params.windows:
            v = [self.stats[self._key(design, nn, mb, s, npods, sched,
                                      win)].p99
                 for s in self.params.seeds]
            out[win] = (float(np.mean(v)), float(np.std(v)))
        return out

    def summary_rows(self):
        """Flat (design, n_nodes, message_mb, seed[, n_pods][, schedule]
        [, window][, fault], p50, p99, loss) rows."""
        rows = []
        for key, st in sorted(self.stats.items()):
            rows.append(key + (st.p50, st.p99, st.mean_loss))
        return rows


def sweep(params: BatchedSimParams | None = None, *, progress=None
          ) -> SweepResult:
    """Run the grid in :class:`BatchedSimParams`; one engine pass per
    ``(n_nodes, message_mb, n_pods, schedule, fault, seed)`` cell, with
    every design and window policy assembled from that cell's shared
    physics trace (designs differ in loss reaction, windows only in
    budget assembly — both axes are nearly free).  Result keys follow
    the :class:`SweepResult` ordering convention: ``(design, n_nodes,
    message_mb, seed)`` plus trailing ``[n_pods][, schedule][, window]
    [, fault]`` elements appended *only* for axes the grid actually
    sweeps (see docs/ARCHITECTURE.md).  ``progress``: optional
    ``callable(str)`` for liveness logging on long grids."""
    bp = params or BatchedSimParams()
    if bp.legacy_streams and any(np_ > 1 for np_ in bp.n_pods):
        # same contract as BatchedEngine.traces: there is no flat
        # sequential stream to replay for a multi-pod fabric, and
        # silently mixing stream modes inside one SweepResult would
        # turn pod comparisons into stream-methodology artifacts
        raise ValueError("legacy_streams=True is incompatible with "
                         "n_pods > 1 sweep cells")
    if bp.legacy_streams and any(sc != "ring" for sc in bp.schedules):
        raise ValueError("legacy_streams=True is incompatible with "
                         "non-ring schedule sweep cells")
    fault_grid = bp.fault_params()
    if bp.legacy_streams and any(fp.active for fp in fault_grid):
        raise ValueError("legacy_streams=True is incompatible with "
                         "fault-injection sweep cells")
    for win in bp.windows:
        if WindowPolicy.parse(win).kind == "step":
            # the per-step window needs per-flow (T, n) arrays the sweep
            # deliberately never materializes (memory flat in cluster
            # size); round/phase assemble from the reduced traces
            raise ValueError("sweep windows must be 'round' or 'phase' "
                             "(window='step' needs per-flow traces; use "
                             "BatchedEngine.run)")
    backend = parse_backend(bp.backend)
    if backend == "jax" and bp.legacy_streams:
        raise ValueError("backend='jax' is incompatible with "
                         "legacy_streams=True (engine-native only)")
    res = SweepResult(params=bp, stats={})
    # liveness accounting: one "cell" = one (config, seed) physics pass
    total_cells = (len(bp.n_nodes) * len(bp.message_mb) * len(bp.n_pods)
                   * len(bp.schedules) * len(fault_grid) * len(bp.seeds))
    done_cells = 0
    sweep_t0 = time.perf_counter()
    for nn in bp.n_nodes:
        for mb in bp.message_mb:
            for npods in bp.n_pods:
                for sched in bp.schedules:
                  for fp in fault_grid:
                    # faults are a physics dimension: each scenario gets
                    # its own whole-trace pass (masks live inside
                    # _traces_shared), unlike window policies which
                    # re-assemble one shared trace
                    p = dataclasses.replace(
                        bp.base,
                        net=dataclasses.replace(bp.base.net, n_nodes=nn),
                        work=dataclasses.replace(
                            bp.base.work, message_bytes=int(mb * 2**20),
                            schedule=sched),
                        topo=dataclasses.replace(bp.base.topo,
                                                 n_pods=npods),
                        fault=fp)
                    eng = BatchedEngine(p, backend=backend)
                    trs = None
                    if backend == "jax":
                        # the jax core batches the whole seed axis of
                        # this config in one vmapped pass
                        from repro.core.transport import engine_jax
                        trs = engine_jax.traces_batched(
                            eng, list(bp.designs), bp.n_rounds,
                            list(bp.seeds))
                    for si, s in enumerate(bp.seeds):
                        if progress is not None:
                            el = time.perf_counter() - sweep_t0
                            rate = done_cells / el if el > 0 else 0.0
                            progress(f"[{backend}] n_nodes={nn} "
                                     f"message_mb={mb} n_pods={npods} "
                                     f"schedule={sched} fault={fp.tag} "
                                     f"seed={s} ({done_cells}/"
                                     f"{total_cells} cells, "
                                     f"{rate:.2f} cells/s)")
                        if trs is not None:
                            tr = trs[si]
                        else:
                            tr = eng.traces(list(bp.designs), bp.n_rounds,
                                            s,
                                            legacy_streams=bp.legacy_streams)
                        to = bp.celeris_timeout_us
                        if "celeris" in bp.designs and to is None:
                            if "roce" in bp.designs:
                                base = eng.assemble(tr["roce"], s)
                                to = float((np.percentile(base.times_us, 50)
                                            + base.times_us.std())
                                           * bp.timeout_scale)
                            else:
                                to = 50_000.0 * bp.timeout_scale
                        for d in bp.designs:
                            # window policies share the physics trace:
                            # only the celeris budget assembly differs
                            for win in bp.windows:
                                key = res._key(d, nn, mb, s, npods, sched,
                                               win, fp.tag)
                                if d == "celeris":
                                    res.stats[key] = eng.assemble(
                                        tr[d], s, celeris_timeout_us=to,
                                        adaptive=False, window=win)
                                elif win == bp.windows[0]:
                                    st = eng.assemble(tr[d], s)
                                    for w2 in bp.windows:
                                        res.stats[res._key(
                                            d, nn, mb, s, npods, sched,
                                            w2, fp.tag)] = st
                        done_cells += 1
    return res


# ----------------------------------------------------------------------
# Fabric response curves (scalar-parameter forms of ClosFabric methods,
# applied to whole traces)
# ----------------------------------------------------------------------

def _sparse_path_curves(net, occ_tor: np.ndarray, src: np.ndarray,
                        dst: np.ndarray
                        ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Exact f64 (ecn, drop, hot_rows) per (step, node), touching only
    hot steps.

    Both curves (shared with :class:`ClosFabric` via the module-level
    functions in :mod:`network`) are exactly 0 below their occupancy
    thresholds, and a path's occupancy is the max of its two ToR
    occupancies (or idle), so steps where no ToR crosses the ECN
    threshold (the lower of the two) contribute exact zeros — the
    common case under rare bursts.  ``hot_rows`` are the step indices
    that were actually evaluated (everything else is zero).
    """
    T = occ_tor.shape[0]
    n = src.shape[0]
    ecn = np.zeros((T, n))
    drop = np.zeros((T, n))
    rows = np.flatnonzero((occ_tor > net.ecn_threshold).any(axis=1))
    if rows.size:
        op = network.path_occupancy_trace(net, occ_tor[rows], src, dst)
        ecn[rows] = network.ecn_mark_prob(net, op)
        drop[rows] = network.drop_prob(net, op)
    return ecn, drop, rows
