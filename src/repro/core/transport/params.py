"""Transport-simulation parameters (paper §IV evaluation setup).

128-node 2-tier Clos, 100G host links, 25 MB AllReduce rounds, bursty
randomized background traffic injected to create contention.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class NetworkParams:
    n_nodes: int = 128
    nodes_per_tor: int = 16
    link_gbps: float = 100.0
    mtu_bytes: int = 4096
    base_rtt_us: float = 8.0            # propagation + switching, intra-fabric

    # background traffic: Markov-modulated bursts per ToR uplink.
    # Bursts are rare but long (mean ~1/off_prob steps), so some rounds
    # sail through an idle fabric while others ride out a storm — the
    # bimodality that produces realistic p99/p50 ratios.
    burst_on_prob: float = 0.00012      # P(burst starts) per ToR-step
    burst_off_prob: float = 0.02        # P(burst ends) per step -> ~50-step bursts
    burst_occupancy_lo: float = 0.55    # link share taken while bursting
    burst_occupancy_hi: float = 0.95
    idle_occupancy: float = 0.05

    # share of line rate left for the foreground flow under contention
    bg_bandwidth_weight: float = 0.80
    min_avail_frac: float = 0.30

    # queueing / loss model (switch buffer ~ 2 ms drain at 100G)
    queue_capacity_us: float = 100.0    # max queueing delay at full buffer
    ecn_threshold: float = 0.45         # occupancy that starts ECN marking
    loss_knee: float = 0.55             # occupancy where drops begin
    loss_max_prob: float = 0.025        # per-packet drop prob at occupancy 1

    # PFC (RoCE only): pauses can cascade hop-by-hop into storms
    pfc_threshold: float = 0.80         # occupancy triggering PAUSE upstream
    pfc_pause_us: float = 120.0         # quanta-scale pause duration
    pfc_cascade_prob: float = 0.30      # chance each pause propagates further
    pfc_max_cascade: int = 6

    @property
    def link_bytes_per_us(self) -> float:
        return self.link_gbps * 1e9 / 8 / 1e6

    @property
    def pkt_time_us(self) -> float:
        return self.mtu_bytes / self.link_bytes_per_us


@dataclasses.dataclass(frozen=True)
class TopologyParams:
    """Hierarchical multi-pod extension of the flat 2-tier Clos.

    ``n_pods=1`` (the default) is the flat fabric — every code path is
    bit-identical to the pre-topology engine.  With ``n_pods > 1`` the
    cluster splits into contiguous pods of ``n_nodes / n_pods`` nodes;
    ring hops that cross a pod boundary traverse a DCI (data-center
    interconnect) uplink with its own burst process, an
    oversubscription penalty, and extra propagation delay.  The DCI
    tier is where the paper's best-effort transport matters most: it is
    the contended, lossy, high-RTT hop that dominates cross-pod tails.

    ``dci_oversubscription`` and ``dci_burst_on_prob`` also accept a
    per-pod tuple (length ``n_pods``) so asymmetric "hot pod"
    scenarios are expressible — one pod's DCI uplink oversubscribed or
    bursting harder than the others.  A cross-pod flow pays the worse
    of its two endpoint pods' oversubscription (it traverses both
    uplinks).  Scalars keep the exact pre-vector code paths, so scalar
    configs stay bit-identical with the flat per-pod model.
    """
    n_pods: int = 1
    # pod egress bandwidth divisor: a 4:1 oversubscribed DCI gives each
    # cross-pod flow 1/4 of the per-link line rate under contention
    dci_oversubscription: "float | tuple" = 4.0
    dci_rtt_us: float = 12.0            # extra one-way propagation, inter-pod

    # DCI burst process: inter-pod links aggregate many jobs, so bursts
    # are far more frequent, hotter, and the idle floor is higher than
    # the ToR uplinks'.
    dci_burst_on_prob: "float | tuple" = 0.003
    dci_burst_off_prob: float = 0.01
    dci_burst_occupancy_lo: float = 0.60
    dci_burst_occupancy_hi: float = 0.97
    dci_idle_occupancy: float = 0.10

    @property
    def hierarchical(self) -> bool:
        return self.n_pods > 1


@dataclasses.dataclass(frozen=True)
class DcqcnParams:
    """DCQCN rate control (kept in hardware on all four designs)."""
    alpha_g: float = 0.00390625         # 1/256 alpha EWMA gain
    rate_decrease_floor: float = 0.30   # min rate fraction after cuts
    additive_increase: float = 0.05     # RAI per increase event (fraction)
    hyper_increase: float = 0.05        # HAI after sustained no-congestion
    hyper_after: int = 5                # stages before hyper increase
    min_rate: float = 0.30


@dataclasses.dataclass(frozen=True)
class ReliabilityParams:
    """Per-design recovery behavior knobs."""
    nack_delay_us: float = 4.0          # NACK generation + return latency
    rto_us: float = 1000.0              # RoCE retransmission timeout
    rto_low_us: float = 100.0           # IRN/SRNIC low RTO (tail-loss probe)
    host_slowpath_us: float = 25.0      # SRNIC SW retransmission handling
    max_retries: int = 3


@dataclasses.dataclass(frozen=True)
class WindowPolicy:
    """How the Celeris bounded budget binds one AllReduce round.

    - ``"round"`` — one deadline for the whole round (the paper's
      adaptive-timeout policy; bit-exact with the pre-policy engine);
    - ``"phase"`` — the same budget split across the collective
      schedule's phase blocks by their ``budget_frac`` weights, each
      block truncated at its own deadline.  Expensive (DCI) phases get
      a proportionally larger share — "wait longer where the fabric is
      slow, cut losses where it's cheap".  On a single-phase (ring)
      plan the split is ``[1.0]`` and the policy degenerates to
      ``"round"`` bit-for-bit.
    - ``"step"`` — per-step deadlines: each phase's budget share is
      divided uniformly over its steps (the beyond-paper fig2 policy).
      On a single-phase plan this is the pre-policy per-step window
      unchanged; multi-phase plans split per ``budget_frac`` first.
    """
    kind: str = "round"

    KINDS = ("round", "phase", "step")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown window policy {self.kind!r}; "
                             f"choose from {self.KINDS}")

    @classmethod
    def parse(cls, v: "WindowPolicy | str") -> "WindowPolicy":
        return v if isinstance(v, cls) else cls(kind=str(v))


@dataclasses.dataclass(frozen=True)
class WorkloadParams:
    message_bytes: int = 25 * 1024 * 1024   # 25 MB per node per round
    # collective schedule riding the fabric (core/transport/schedule.py):
    # "ring" — flat 2(N-1)-step ring RS+AG, every step message/N bytes;
    # "hier" — reduce-scatter within pod -> pod-leader DCI exchange with
    # 1/n_pods-sized shards -> all-gather within pod;
    # "perrail" — hier with every node crossing pods (rank-aligned
    # rails moving 1/(m*n_pods)-sized shards).
    schedule: str = "ring"


@dataclasses.dataclass(frozen=True)
class SimParams:
    net: NetworkParams = NetworkParams()
    dcqcn: DcqcnParams = DcqcnParams()
    rel: ReliabilityParams = ReliabilityParams()
    work: WorkloadParams = WorkloadParams()
    topo: TopologyParams = TopologyParams()
    seed: int = 0
