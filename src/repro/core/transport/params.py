"""Transport-simulation parameters (paper §IV evaluation setup).

128-node 2-tier Clos, 100G host links, 25 MB AllReduce rounds, bursty
randomized background traffic injected to create contention.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class NetworkParams:
    n_nodes: int = 128
    nodes_per_tor: int = 16
    link_gbps: float = 100.0
    mtu_bytes: int = 4096
    base_rtt_us: float = 8.0            # propagation + switching, intra-fabric

    # background traffic: Markov-modulated bursts per ToR uplink.
    # Bursts are rare but long (mean ~1/off_prob steps), so some rounds
    # sail through an idle fabric while others ride out a storm — the
    # bimodality that produces realistic p99/p50 ratios.
    burst_on_prob: float = 0.00012      # P(burst starts) per ToR-step
    burst_off_prob: float = 0.02        # P(burst ends) per step -> ~50-step bursts
    burst_occupancy_lo: float = 0.55    # link share taken while bursting
    burst_occupancy_hi: float = 0.95
    idle_occupancy: float = 0.05

    # share of line rate left for the foreground flow under contention
    bg_bandwidth_weight: float = 0.80
    min_avail_frac: float = 0.30

    # queueing / loss model (switch buffer ~ 2 ms drain at 100G)
    queue_capacity_us: float = 100.0    # max queueing delay at full buffer
    ecn_threshold: float = 0.45         # occupancy that starts ECN marking
    loss_knee: float = 0.55             # occupancy where drops begin
    loss_max_prob: float = 0.025        # per-packet drop prob at occupancy 1

    # PFC (RoCE only): pauses can cascade hop-by-hop into storms
    pfc_threshold: float = 0.80         # occupancy triggering PAUSE upstream
    pfc_pause_us: float = 120.0         # quanta-scale pause duration
    pfc_cascade_prob: float = 0.30      # chance each pause propagates further
    pfc_max_cascade: int = 6

    @property
    def link_bytes_per_us(self) -> float:
        return self.link_gbps * 1e9 / 8 / 1e6

    @property
    def pkt_time_us(self) -> float:
        return self.mtu_bytes / self.link_bytes_per_us


@dataclasses.dataclass(frozen=True)
class TopologyParams:
    """Hierarchical multi-pod extension of the flat 2-tier Clos.

    ``n_pods=1`` (the default) is the flat fabric — every code path is
    bit-identical to the pre-topology engine.  With ``n_pods > 1`` the
    cluster splits into contiguous pods of ``n_nodes / n_pods`` nodes;
    ring hops that cross a pod boundary traverse a DCI (data-center
    interconnect) uplink with its own burst process, an
    oversubscription penalty, and extra propagation delay.  The DCI
    tier is where the paper's best-effort transport matters most: it is
    the contended, lossy, high-RTT hop that dominates cross-pod tails.

    ``dci_oversubscription`` and ``dci_burst_on_prob`` also accept a
    per-pod tuple (length ``n_pods``) so asymmetric "hot pod"
    scenarios are expressible — one pod's DCI uplink oversubscribed or
    bursting harder than the others.  A cross-pod flow pays the worse
    of its two endpoint pods' oversubscription (it traverses both
    uplinks).  Scalars keep the exact pre-vector code paths, so scalar
    configs stay bit-identical with the flat per-pod model.
    """
    n_pods: int = 1
    # pod egress bandwidth divisor: a 4:1 oversubscribed DCI gives each
    # cross-pod flow 1/4 of the per-link line rate under contention
    dci_oversubscription: "float | tuple" = 4.0
    dci_rtt_us: float = 12.0            # extra one-way propagation, inter-pod

    # DCI burst process: inter-pod links aggregate many jobs, so bursts
    # are far more frequent, hotter, and the idle floor is higher than
    # the ToR uplinks'.
    dci_burst_on_prob: "float | tuple" = 0.003
    dci_burst_off_prob: float = 0.01
    dci_burst_occupancy_lo: float = 0.60
    dci_burst_occupancy_hi: float = 0.97
    dci_idle_occupancy: float = 0.10

    @property
    def hierarchical(self) -> bool:
        return self.n_pods > 1


@dataclasses.dataclass(frozen=True)
class DcqcnParams:
    """DCQCN rate control (kept in hardware on all four designs)."""
    alpha_g: float = 0.00390625         # 1/256 alpha EWMA gain
    rate_decrease_floor: float = 0.30   # min rate fraction after cuts
    additive_increase: float = 0.05     # RAI per increase event (fraction)
    hyper_increase: float = 0.05        # HAI after sustained no-congestion
    hyper_after: int = 5                # stages before hyper increase
    min_rate: float = 0.30


@dataclasses.dataclass(frozen=True)
class ReliabilityParams:
    """Per-design recovery behavior knobs."""
    nack_delay_us: float = 4.0          # NACK generation + return latency
    rto_us: float = 1000.0              # RoCE retransmission timeout
    rto_low_us: float = 100.0           # IRN/SRNIC low RTO (tail-loss probe)
    host_slowpath_us: float = 25.0      # SRNIC SW retransmission handling
    max_retries: int = 3


@dataclasses.dataclass(frozen=True)
class WindowPolicy:
    """How the Celeris bounded budget binds one engine round.

    A "round" is one pass over the active :class:`FlowPlan` — a
    collective AllReduce for the ring/hier/perrail schedules, or an
    arbitrary point-to-point plan (e.g. the serve path's KV-transfer
    incast).  The policy decides where inside the round the budget
    truncates:

    - ``"round"`` — one deadline for the whole round (the paper's
      adaptive-timeout policy; bit-exact with the pre-policy engine);
    - ``"phase"`` — the same budget split across the collective
      schedule's phase blocks by their ``budget_frac`` weights, each
      block truncated at its own deadline.  Expensive (DCI) phases get
      a proportionally larger share — "wait longer where the fabric is
      slow, cut losses where it's cheap".  On a single-phase (ring)
      plan the split is ``[1.0]`` and the policy degenerates to
      ``"round"`` bit-for-bit.
    - ``"step"`` — per-step deadlines: each phase's budget share is
      divided uniformly over its steps (the beyond-paper fig2 policy).
      On a single-phase plan this is the pre-policy per-step window
      unchanged; multi-phase plans split per ``budget_frac`` first.
    """
    kind: str = "round"

    KINDS = ("round", "phase", "step")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown window policy {self.kind!r}; "
                             f"choose from {self.KINDS}")

    @classmethod
    def parse(cls, v: "WindowPolicy | str") -> "WindowPolicy":
        return v if isinstance(v, cls) else cls(kind=str(v))


@dataclasses.dataclass(frozen=True)
class FaultParams:
    """Seeded NIC / link failure processes (ISSUE 6, OptiNIC taxonomy).

    All rates default to 0: the default config draws from no fault
    stream and perturbs no existing seeded trace (pinned bit-exactly by
    ``tests/test_faults.py``).  Faults are engine-native (shared-stream
    mode only): each process has its own random substream derived from
    the user seed (``faults.py``), so the same seed reproduces the same
    failure scenario across designs and schedules.

    - **NIC stall** (``stall_rate`` per node-step): delivery through the
      node pauses for ``stall_steps`` steps — a firmware hiccup or PCIe
      backpressure event, the paper's "NIC resilience" headline case;
    - **NIC crash** (``crash_rate`` per node-step): the node goes dead
      mid-round — permanently (``crash_restart_steps=0``) or until a
      restart after that many steps;
    - **link flap** (``flap_rate`` per edge-step): a ToR uplink (and,
      on multi-pod fabrics, a DCI uplink) goes down/up as a Markov
      on/off chain with recovery probability ``flap_recover_prob``;
    - **rail failure** (``rail_fail_rate`` per round): the cross-pod
      exchange loses rail ``rail`` for the round — under the ``hier``
      leader exchange (leaders are rank 0) a rail-0 failure kills the
      whole DCI phase, under ``perrail`` it kills 1/m of the rails (the
      blast-radius experiment of PR 5's per-rail schedule);
    - **slow-NIC straggler** (``straggler_frac`` of nodes): a static
      seeded subset of NICs runs at ``1/straggler_slowdown`` of the
      DCQCN-granted rate for the whole trace.

    ``target_nodes`` restricts the node-level processes (stall, crash,
    straggler) to a node subset — e.g. one pod, for the faulted-pod
    end-to-end training experiment.
    """
    stall_rate: float = 0.0
    stall_steps: int = 8
    crash_rate: float = 0.0
    crash_restart_steps: int = 0        # 0 => dead for the whole trace
    flap_rate: float = 0.0
    flap_recover_prob: float = 0.25
    rail_fail_rate: float = 0.0
    rail: int = 0
    straggler_frac: float = 0.0
    straggler_slowdown: float = 4.0
    target_nodes: "tuple | None" = None

    KINDS = ("stall", "crash", "flap", "rail", "straggler")

    def __post_init__(self):
        for name in ("stall_rate", "crash_rate", "flap_rate",
                     "rail_fail_rate", "flap_recover_prob",
                     "straggler_frac"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name}={v} must lie in [0, 1]")
        if self.stall_steps < 1:
            raise ValueError(f"stall_steps={self.stall_steps} must be >= 1")
        if self.straggler_slowdown < 1.0:
            raise ValueError("straggler_slowdown must be >= 1")
        if self.target_nodes is not None:
            object.__setattr__(self, "target_nodes",
                               tuple(int(i) for i in self.target_nodes))

    @property
    def active(self) -> bool:
        return (self.stall_rate > 0 or self.crash_rate > 0
                or self.flap_rate > 0 or self.rail_fail_rate > 0
                or self.straggler_frac > 0)

    @property
    def tag(self) -> str:
        """Compact label for sweep keys / benchmark rows."""
        if not self.active:
            return "none"
        parts = []
        for kind, rate in (("stall", self.stall_rate),
                           ("crash", self.crash_rate),
                           ("flap", self.flap_rate),
                           ("rail", self.rail_fail_rate),
                           ("straggler", self.straggler_frac)):
            if rate > 0:
                parts.append(f"{kind}:{rate:g}")
        return "+".join(parts)

    @classmethod
    def of_kind(cls, kind: str, rate: float, **kw) -> "FaultParams":
        """One fault process by name at the given rate."""
        field = {"stall": "stall_rate", "crash": "crash_rate",
                 "flap": "flap_rate", "rail": "rail_fail_rate",
                 "straggler": "straggler_frac"}.get(kind)
        if field is None:
            raise ValueError(f"unknown fault kind {kind!r}; choose from "
                             f"{cls.KINDS}")
        return cls(**{field: rate}, **kw)

    @classmethod
    def parse(cls, spec: "FaultParams | str") -> "FaultParams":
        """CLI form ``kind:rate`` (e.g. ``stall:0.001``), ``+``-joined
        for compound scenarios (``stall:0.001+flap:0.0005``)."""
        if isinstance(spec, cls):
            return spec
        kw = {}
        for part in str(spec).split("+"):
            kind, _, rate = part.partition(":")
            probe = cls.of_kind(kind.strip(), float(rate or 0.0))
            kw.update({f.name: getattr(probe, f.name)
                       for f in dataclasses.fields(cls)
                       if getattr(probe, f.name) != f.default})
        return cls(**kw)


@dataclasses.dataclass(frozen=True)
class WorkloadParams:
    message_bytes: int = 25 * 1024 * 1024   # 25 MB per node per round
    # collective schedule riding the fabric (core/transport/schedule.py):
    # "ring" — flat 2(N-1)-step ring RS+AG, every step message/N bytes;
    # "hier" — reduce-scatter within pod -> pod-leader DCI exchange with
    # 1/n_pods-sized shards -> all-gather within pod;
    # "perrail" — hier with every node crossing pods (rank-aligned
    # rails moving 1/(m*n_pods)-sized shards).
    schedule: str = "ring"


# Engine compute backends (engine.BatchedEngine / engine_jax).  The
# numpy engine is the bit-pinning reference; the jax backend matches it
# within rtol 1e-5 (see engine_jax's tolerance contract) and batches
# seeds on the accelerator.
BACKENDS = ("numpy", "jax")


def parse_backend(v: str) -> str:
    v = str(v)
    if v not in BACKENDS:
        raise ValueError(f"unknown backend {v!r}; choose from {BACKENDS}")
    return v


@dataclasses.dataclass(frozen=True)
class SimParams:
    net: NetworkParams = NetworkParams()
    dcqcn: DcqcnParams = DcqcnParams()
    rel: ReliabilityParams = ReliabilityParams()
    work: WorkloadParams = WorkloadParams()
    topo: TopologyParams = TopologyParams()
    fault: FaultParams = FaultParams()
    seed: int = 0
