"""Chrome/Perfetto ``trace_event`` export for recorded engine traces.

Turns a :class:`~repro.core.transport.telemetry.TraceRecorder` into the
JSON Trace Event Format that https://ui.perfetto.dev (and Chrome's
``about:tracing``) render natively:

- one **process** per NIC design (``pid`` = design index, named via
  ``M``/``process_name`` metadata),
- one **thread** per schedule phase (``tid`` = phase index + 1), whose
  ``X`` complete-events are the per-step critical-path slices — ``ts``
  is the cumulative natural time, ``dur`` the step's natural duration,
  and ``args`` the critical flow's component decomposition (telemetry
  .COMPONENTS), its sender node and tier — so a p99 round's timeline
  shows *where* the microseconds went,
- a **round marker thread** (``tid`` 0) with one slice per round
  carrying the per-cause loss attribution and, for Celeris, the window
  cut,
- **counter tracks** (``C``) per design for delivered fraction, plus
  design-independent fabric occupancy counters when the recorder
  captured them.

The exporter is read-only over the recorder and pure stdlib; the
schema validator (:func:`validate_trace`) is the round-trip gate the
tests and the ``--trace`` CLI flag share.  See docs/OBSERVABILITY.md.
"""
from __future__ import annotations

import json
from typing import Dict, List

import numpy as np

from repro.core.transport import telemetry, topology

_EVENT_TYPES = ("X", "C", "M", "i")
_ROUND_TID = 0


def _slices(rec, pid: int, max_rounds: int | None) -> List[dict]:
    R = rec.n_rounds if max_rounds is None else min(rec.n_rounds, max_rounds)
    steps = rec.steps
    cc = rec.comp_crit
    step_dur = cc.reshape(rec.n_rounds, steps, -1).sum(axis=2)
    nat = (rec.natural_us if rec.natural_us is not None
           else step_dur.sum(axis=1))
    events: List[dict] = []
    ts = 0.0
    for r in range(R):
        t0 = ts
        events.append({
            "name": f"round {r}", "ph": "X", "pid": pid, "tid": _ROUND_TID,
            "ts": round(t0, 3), "dur": round(float(nat[r]), 3),
            "cat": "round", "args": _round_args(rec, r)})
        for s in range(steps):
            i = r * steps + s
            k = int(rec.phase_of_step[s])
            comp = {name: round(float(cc[i, ci]), 3)
                    for ci, name in enumerate(telemetry.COMPONENTS)
                    if cc[i, ci] > 0}
            tier = int(rec.crit_tier[i])
            events.append({
                "name": rec.phase_names[k], "ph": "X", "pid": pid,
                "tid": k + 1, "ts": round(ts, 3),
                "dur": round(float(step_dur[r, s]), 3), "cat": "step",
                "args": {"components_us": comp,
                         "critical_src": int(rec.crit_src[i]),
                         "critical_tier": (topology.TIERS[tier]
                                           if tier >= 0 else "?")}})
            ts += float(step_dur[r, s])
        ts = t0 + float(nat[r])
        if rec.stats is not None:
            events.append({
                "name": "delivered_frac", "ph": "C", "pid": pid,
                "tid": _ROUND_TID, "ts": round(t0, 3),
                "args": {"frac": round(
                    float(np.asarray(rec.stats.recv_frac)[r]), 6)}})
    return events


def _round_args(rec, r: int) -> dict:
    args: dict = {}
    lost = rec.loss_by_cause()[r].sum(axis=0)
    offered = max(float(rec.offered_round()[r].sum()), 1.0)
    args["loss_by_cause"] = {
        c: round(float(lost[i]) / offered, 6)
        for i, c in enumerate(telemetry.CAUSES) if lost[i] > 0}
    if rec.elapsed_us is not None:
        args["elapsed_us"] = round(float(rec.elapsed_us[r]), 3)
    if rec.window_cut_pkts is not None:
        cut = float(rec.window_cut_pkts[r].sum())
        if cut > 0:
            args["window_cut_pkts"] = round(cut, 3)
    return args


def to_trace_events(recorder: telemetry.TraceRecorder, *,
                    max_rounds: int | None = None,
                    meta: dict | None = None) -> dict:
    """Build the trace_event JSON object for every recorded design.

    ``max_rounds`` caps the exported rounds per design (None = all);
    the cap is recorded in ``otherData`` so a truncated export never
    masquerades as full coverage.
    """
    if not recorder.records:
        raise ValueError("recorder holds no records: run "
                         "BatchedEngine(params, recorder=rec).traces(...) "
                         "first")
    events: List[dict] = []
    designs = sorted(recorder.records)
    for pid, d in enumerate(designs):
        rec = recorder.records[d]
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "args": {"name": f"design:{d}"}})
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": _ROUND_TID, "args": {"name": "rounds"}})
        for k, pn in enumerate(rec.phase_names):
            events.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": k + 1, "args": {"name": f"phase:{pn}"}})
        events.extend(_slices(rec, pid, max_rounds))
    other = {"generator": "repro.core.transport.trace_export",
             "components": list(telemetry.COMPONENTS),
             "causes": list(telemetry.CAUSES),
             "designs": designs,
             "max_rounds": max_rounds}
    if meta:
        other.update(meta)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": other}


def write_trace(recorder: telemetry.TraceRecorder, path: str, *,
                max_rounds: int | None = None,
                meta: dict | None = None) -> dict:
    """Export, validate, and write the trace JSON; returns the object."""
    obj = to_trace_events(recorder, max_rounds=max_rounds, meta=meta)
    validate_trace(obj)
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


def validate_trace(obj) -> Dict[str, int]:
    """Schema validator for the export (and anything claiming to be a
    trace_event JSON we produced).  Raises ``ValueError`` with the
    first violation; returns per-event-type counts on success.  Checks:
    top-level shape, per-event required fields by phase type, numeric
    non-negative ``ts``/``dur``, step slices carrying a component
    decomposition limited to the published schema."""
    if not isinstance(obj, dict):
        raise ValueError("trace must be a JSON object")
    for key in ("traceEvents", "otherData"):
        if key not in obj:
            raise ValueError(f"trace missing top-level {key!r}")
    events = obj["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("traceEvents must be a non-empty list")
    comps = set(obj["otherData"].get("components", telemetry.COMPONENTS))
    counts: Dict[str, int] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i}: not an object")
        ph = ev.get("ph")
        if ph not in _EVENT_TYPES:
            raise ValueError(f"event {i}: unknown ph {ph!r}")
        counts[ph] = counts.get(ph, 0) + 1
        for field in ("name", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"event {i} ({ph}): missing {field!r}")
        if ph in ("X", "C", "i"):
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                raise ValueError(f"event {i} ({ph}): bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i} (X): bad dur {dur!r}")
            args = ev.get("args", {})
            bad = set(args.get("components_us", {})) - comps
            if bad:
                raise ValueError(
                    f"event {i} (X): unknown components {sorted(bad)}")
        if ph == "C" and not isinstance(ev.get("args"), dict):
            raise ValueError(f"event {i} (C): counter needs args object")
    if counts.get("M", 0) == 0:
        raise ValueError("no metadata (M) events: process/thread names "
                         "are required for a readable Perfetto view")
    if counts.get("X", 0) == 0:
        raise ValueError("no slice (X) events")
    return counts
