"""Chrome/Perfetto ``trace_event`` export for recorded engine traces.

Turns a :class:`~repro.core.transport.telemetry.TraceRecorder` into the
JSON Trace Event Format that https://ui.perfetto.dev (and Chrome's
``about:tracing``) render natively:

- one **process** per NIC design (``pid`` = design index, named via
  ``M``/``process_name`` metadata),
- one **thread** per schedule phase (``tid`` = phase index + 1), whose
  ``X`` complete-events are the per-step critical-path slices — ``ts``
  is the cumulative natural time, ``dur`` the step's natural duration,
  and ``args`` the critical flow's component decomposition (telemetry
  .COMPONENTS), its sender node and tier — so a p99 round's timeline
  shows *where* the microseconds went,
- a **round marker thread** (``tid`` 0) with one slice per round
  carrying the per-cause loss attribution and, for Celeris, the window
  cut,
- **counter tracks** (``C``) per design for delivered fraction, plus
  design-independent fabric occupancy counters when the recorder
  captured them.

The export is **streamed**: :func:`iter_trace_events` is a generator
over round-chunks (``chunk_rounds`` rounds per design at a time), so a
multi-thousand-round recording never holds its full event list — let
alone the serialized JSON — in memory.  :func:`write_trace` consumes
it chunk-by-chunk, runs the schema gate (:func:`validate_events`) on
every chunk *before* that chunk hits the file, and deletes the partial
file if any chunk fails.  :func:`to_trace_events` still materializes
the whole object for small recordings and tests; :func:`validate_trace`
is the whole-file round-trip gate the tests and the ``--trace`` CLI
flag share.  The exporter is read-only over the recorder and pure
stdlib.  See docs/OBSERVABILITY.md.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Iterator, List

import numpy as np

from repro.core.transport import telemetry, topology

_EVENT_TYPES = ("X", "C", "M", "i")
_ROUND_TID = 0
_CHUNK_ROUNDS = 64


def _meta_events(recorder: telemetry.TraceRecorder,
                 designs: List[str]) -> List[dict]:
    events: List[dict] = []
    for pid, d in enumerate(designs):
        rec = recorder.records[d]
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "args": {"name": f"design:{d}"}})
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": _ROUND_TID, "args": {"name": "rounds"}})
        for k, pn in enumerate(rec.phase_names):
            events.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": k + 1, "args": {"name": f"phase:{pn}"}})
    return events


def _round_events(rec, pid: int, r: int, ts: float, step_dur, nat,
                  lost, offered) -> tuple[List[dict], float]:
    """Events for one round starting at cumulative time ``ts``; returns
    (events, ts after the round)."""
    cc = rec.comp_crit
    steps = rec.steps
    t0 = ts
    events: List[dict] = [{
        "name": f"round {r}", "ph": "X", "pid": pid, "tid": _ROUND_TID,
        "ts": round(t0, 3), "dur": round(float(nat[r]), 3),
        "cat": "round", "args": _round_args(rec, r, lost, offered)}]
    for s in range(steps):
        i = r * steps + s
        k = int(rec.phase_of_step[s])
        comp = {name: round(float(cc[i, ci]), 3)
                for ci, name in enumerate(telemetry.COMPONENTS)
                if cc[i, ci] > 0}
        tier = int(rec.crit_tier[i])
        events.append({
            "name": rec.phase_names[k], "ph": "X", "pid": pid,
            "tid": k + 1, "ts": round(ts, 3),
            "dur": round(float(step_dur[r, s]), 3), "cat": "step",
            "args": {"components_us": comp,
                     "critical_src": int(rec.crit_src[i]),
                     "critical_tier": (topology.TIERS[tier]
                                       if tier >= 0 else "?")}})
        ts += float(step_dur[r, s])
    ts = t0 + float(nat[r])
    if rec.stats is not None:
        events.append({
            "name": "delivered_frac", "ph": "C", "pid": pid,
            "tid": _ROUND_TID, "ts": round(t0, 3),
            "args": {"frac": round(
                float(np.asarray(rec.stats.recv_frac)[r]), 6)}})
    return events, ts


def _round_args(rec, r: int, lost, offered) -> dict:
    args: dict = {}
    lost_r = lost[r].sum(axis=0)
    off = max(float(offered[r].sum()), 1.0)
    args["loss_by_cause"] = {
        c: round(float(lost_r[i]) / off, 6)
        for i, c in enumerate(telemetry.CAUSES) if lost_r[i] > 0}
    if rec.elapsed_us is not None:
        args["elapsed_us"] = round(float(rec.elapsed_us[r]), 3)
    if rec.window_cut_pkts is not None:
        cut = float(rec.window_cut_pkts[r].sum())
        if cut > 0:
            args["window_cut_pkts"] = round(cut, 3)
    return args


def iter_trace_events(recorder: telemetry.TraceRecorder, *,
                      max_rounds: int | None = None,
                      chunk_rounds: int = _CHUNK_ROUNDS
                      ) -> Iterator[List[dict]]:
    """Generator over the export: first a metadata chunk (process/thread
    names for every design), then one chunk per ``chunk_rounds`` rounds
    per design.  Peak memory is one chunk's events, independent of the
    recording length; ``max_rounds`` caps the exported rounds per design
    (None = all)."""
    if not recorder.records:
        raise ValueError("recorder holds no records: run "
                         "BatchedEngine(params, recorder=rec).traces(...) "
                         "first")
    if chunk_rounds < 1:
        raise ValueError(f"chunk_rounds must be >= 1, got {chunk_rounds}")
    designs = sorted(recorder.records)
    yield _meta_events(recorder, designs)
    for pid, d in enumerate(designs):
        rec = recorder.records[d]
        R = rec.n_rounds if max_rounds is None else min(rec.n_rounds,
                                                        max_rounds)
        steps = rec.steps
        step_dur = rec.comp_crit.reshape(rec.n_rounds, steps, -1).sum(axis=2)
        nat = (rec.natural_us if rec.natural_us is not None
               else step_dur.sum(axis=1))
        lost = rec.loss_by_cause()
        offered = rec.offered_round()
        ts = 0.0
        for r0 in range(0, R, chunk_rounds):
            chunk: List[dict] = []
            for r in range(r0, min(r0 + chunk_rounds, R)):
                events, ts = _round_events(rec, pid, r, ts, step_dur, nat,
                                           lost, offered)
                chunk.extend(events)
            yield chunk


def _other_data(recorder: telemetry.TraceRecorder,
                max_rounds: int | None, meta: dict | None) -> dict:
    other = {"generator": "repro.core.transport.trace_export",
             "components": list(telemetry.COMPONENTS),
             "causes": list(telemetry.CAUSES),
             "designs": sorted(recorder.records),
             "max_rounds": max_rounds}
    if meta:
        other.update(meta)
    return other


def to_trace_events(recorder: telemetry.TraceRecorder, *,
                    max_rounds: int | None = None,
                    meta: dict | None = None) -> dict:
    """Build the full trace_event JSON object for every recorded design.

    Materializes every chunk of :func:`iter_trace_events` — fine for
    short recordings and tests; long recordings should stream through
    :func:`write_trace` instead.  ``max_rounds`` caps the exported
    rounds per design (None = all); the cap is recorded in
    ``otherData`` so a truncated export never masquerades as full
    coverage.
    """
    events = [ev
              for chunk in iter_trace_events(recorder, max_rounds=max_rounds)
              for ev in chunk]
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": _other_data(recorder, max_rounds, meta)}


def write_trace(recorder: telemetry.TraceRecorder, path: str, *,
                max_rounds: int | None = None,
                meta: dict | None = None,
                chunk_rounds: int = _CHUNK_ROUNDS) -> Dict[str, int]:
    """Stream the export to ``path`` chunk-by-chunk.

    Each chunk of :func:`iter_trace_events` passes the per-event schema
    gate (:func:`validate_events`) before it is serialized, so peak
    memory is one chunk regardless of the recording length and nothing
    schema-invalid ever reaches the file; a failed chunk (or failed
    aggregate check) deletes the partial file and re-raises.  Returns
    the per-event-type counts — the same shape :func:`validate_trace`
    returns for the whole file.
    """
    other = _other_data(recorder, max_rounds, meta)
    counts: Dict[str, int] = {}
    try:
        with open(path, "w") as f:
            f.write('{"traceEvents": [')
            sep = ""
            for chunk in iter_trace_events(recorder, max_rounds=max_rounds,
                                           chunk_rounds=chunk_rounds):
                validate_events(chunk, counts=counts)
                for ev in chunk:
                    f.write(sep)
                    json.dump(ev, f)
                    sep = ", "
            if counts.get("M", 0) == 0:
                raise ValueError("no metadata (M) events: process/thread "
                                 "names are required for a readable "
                                 "Perfetto view")
            if counts.get("X", 0) == 0:
                raise ValueError("no slice (X) events")
            f.write('], "displayTimeUnit": "ms", "otherData": ')
            json.dump(other, f)
            f.write("}")
    except BaseException:
        try:
            os.remove(path)
        except OSError:
            pass
        raise
    return counts


def _validate_event(i: int, ev, comps: set,
                    counts: Dict[str, int]) -> None:
    if not isinstance(ev, dict):
        raise ValueError(f"event {i}: not an object")
    ph = ev.get("ph")
    if ph not in _EVENT_TYPES:
        raise ValueError(f"event {i}: unknown ph {ph!r}")
    counts[ph] = counts.get(ph, 0) + 1
    for field in ("name", "pid", "tid"):
        if field not in ev:
            raise ValueError(f"event {i} ({ph}): missing {field!r}")
    if ph in ("X", "C", "i"):
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"event {i} ({ph}): bad ts {ts!r}")
    if ph == "X":
        dur = ev.get("dur")
        if not isinstance(dur, (int, float)) or dur < 0:
            raise ValueError(f"event {i} (X): bad dur {dur!r}")
        args = ev.get("args", {})
        bad = set(args.get("components_us", {})) - comps
        if bad:
            raise ValueError(
                f"event {i} (X): unknown components {sorted(bad)}")
    if ph == "C" and not isinstance(ev.get("args"), dict):
        raise ValueError(f"event {i} (C): counter needs args object")


def validate_events(events, *, components=None,
                    counts: Dict[str, int] | None = None) -> Dict[str, int]:
    """Per-chunk schema gate: validate a list of events (required fields
    by phase type, numeric non-negative ``ts``/``dur``, component args
    limited to the published schema).  Raises ``ValueError`` with the
    first violation; accumulates into and returns ``counts`` so a
    streaming writer can fold per-chunk results into whole-file totals.
    Aggregate checks (at least one M and one X event) are the caller's
    job — a single chunk legitimately carries only one event type."""
    comps = set(telemetry.COMPONENTS if components is None else components)
    if counts is None:
        counts = {}
    if not isinstance(events, list):
        raise ValueError("event chunk must be a list")
    for i, ev in enumerate(events):
        _validate_event(i, ev, comps, counts)
    return counts


def validate_trace(obj) -> Dict[str, int]:
    """Schema validator for a complete export (and anything claiming to
    be a trace_event JSON we produced).  Raises ``ValueError`` with the
    first violation; returns per-event-type counts on success.  Checks:
    top-level shape, the per-event gate of :func:`validate_events`, and
    the aggregate requirements (metadata and slice events present)."""
    if not isinstance(obj, dict):
        raise ValueError("trace must be a JSON object")
    for key in ("traceEvents", "otherData"):
        if key not in obj:
            raise ValueError(f"trace missing top-level {key!r}")
    events = obj["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("traceEvents must be a non-empty list")
    comps = obj["otherData"].get("components", telemetry.COMPONENTS)
    counts = validate_events(events, components=comps)
    if counts.get("M", 0) == 0:
        raise ValueError("no metadata (M) events: process/thread names "
                         "are required for a readable Perfetto view")
    if counts.get("X", 0) == 0:
        raise ValueError("no slice (X) events")
    return counts
