"""Request-driven serving traffic over the lossy transport.

The "millions of users" workload: a disaggregated serving mesh where
``n_prefill`` prefill nodes compute KV caches and ship them, block by
block, into a small pod of ``n_decode`` decode nodes.  Many senders per
receiver is an **incast** pattern — the transport engine charges it
with per-receiver contention (see ``core/transport/schedule.py``), and
each NIC design reacts in character: RoCE/IRN retransmit into the
congested decode ports, Celeris's bounded window cuts late KV blocks
and the Hadamard-coded KV path (``core/coding.py``) recovers them.

Three layers, all seeded and engine-compatible:

1. :func:`kv_flow_plan` — the static per-round transfer plan: every
   prefill node drives one flow into its (round-robin) decode target,
   ``steps_per_round`` blocks of ``kv_block_bytes`` per round.  Static
   flows are what keeps the engine's ``(step, flow)`` vectorization —
   the *request* dynamics live in the queue simulation, not the plan.
2. :func:`request_trace` — an open-loop Poisson request process:
   exponential inter-arrivals at a rate set by ``load`` (offered KV
   bytes as a fraction of the plan's shipping capacity), log-normal
   prefill lengths, geometric decode lengths.  Open-loop means the
   arrival *times* are design-independent: a slow transport design
   does not throttle users, it accumulates backlog.
3. :func:`simulate_serving` — FIFO block shipping over the engine's
   per-round times: each round moves up to ``capacity_blocks_per_round``
   blocks, a request's KV is complete when its last block's round
   ends, and its delivered KV fraction is the shipped-block-weighted
   mean of the rounds' ``recv_frac`` (Celeris window cuts surface
   here; ``coupling.kv_hole_masks`` turns the fraction into per-wire-row
   hole masks that ``serve_step.degrade_caches`` applies to real
   decoders).

Token latency is time-to-first-decode-token: queueing + KV transfer
(+ a constant prefill-compute term), the serving-SLO quantity fig8
sweeps against load and design.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.transport import schedule as schedule_mod
from repro.core.transport.params import NetworkParams

# seeded substreams (engine streams live in 100-150; serve traffic gets
# its own block so plans and request processes never share draws)
STREAM_ARRIVALS = 160
STREAM_LENGTHS = 161
STREAM_KV_HOLES = 162      # consumed by coupling.kv_hole_masks


@dataclasses.dataclass(frozen=True)
class ServeTrafficParams:
    """The disaggregated serving scenario's static knobs.

    ``load`` is the offered-load fraction: mean KV bytes arriving per
    microsecond over the plan's shipping capacity at the reference
    round time (see :func:`arrival_rate_per_us`).  Open-loop, so
    load > ~1 is allowed and means unbounded backlog growth.
    """
    n_prefill: int = 28
    n_decode: int = 4
    steps_per_round: int = 8          # KV blocks per prefill flow per round
    kv_block_bytes: int = 1 << 20
    kv_bytes_per_token: int = 32 << 10
    prefill_tokens_mean: float = 512.0
    prefill_tokens_sigma: float = 0.5   # log-space sigma of the lognormal
    decode_tokens_mean: float = 128.0   # geometric mean decode length
    prefill_us_per_token: float = 0.3   # prefill compute before shipping
    load: float = 0.7

    @property
    def n_nodes(self) -> int:
        return self.n_prefill + self.n_decode

    @property
    def fan_in(self) -> int:
        """Concurrent senders per decode node (ceil of the ratio)."""
        return -(-self.n_prefill // self.n_decode)

    @property
    def capacity_blocks_per_round(self) -> int:
        """KV blocks the plan can ship per round (all flows, all steps)."""
        return self.n_prefill * self.steps_per_round

    @property
    def mean_request_blocks(self) -> float:
        """Mean KV blocks per request (lognormal mean x bytes/token)."""
        mean_bytes = self.prefill_tokens_mean * self.kv_bytes_per_token
        return mean_bytes / self.kv_block_bytes


def kv_flow_plan(tp: ServeTrafficParams) -> schedule_mod.FlowPlan:
    """The static prefill→decode incast plan the engine times.

    One phase: prefill node ``i`` drives decode node ``i % n_decode``
    (nodes ``n_prefill ..`` in the fabric), ``steps_per_round`` steps of
    one ``kv_block_bytes`` block each.  With ``n_prefill > n_decode``
    every decode port takes ``~fan_in`` concurrent senders — the incast
    case of :func:`repro.core.transport.schedule.flow_plan`.

    Steps carry per-step priorities (pure assemble-time metadata — the
    physics trace and the default ``cut_order="arrival"`` stats are
    unchanged): the head half of each request's KV blocks is class 1
    (the prompt prefix decode needs first — losing it stalls the first
    token) and the tail half class 0 (late-context blocks the coded KV
    path recovers most cheaply).  Under ``cut_order="priority"`` the
    bounded window then cuts tail blocks first.
    """
    src = np.arange(tp.n_prefill)
    dst = tp.n_prefill + (src % tp.n_decode)
    kv = schedule_mod.SchedulePhase(
        name="kv", src=src, dst=dst, n_steps=tp.steps_per_round,
        payload_bytes=tp.kv_block_bytes)
    plan = schedule_mod.flow_plan("kv_incast", (kv,))
    head = (np.arange(tp.steps_per_round)
            < (tp.steps_per_round + 1) // 2).astype(int)
    return schedule_mod.with_step_priorities(plan, head)


def serve_net_params(tp: ServeTrafficParams, base: NetworkParams | None = None
                     ) -> NetworkParams:
    """Fabric sized for the serving mesh (prefill + decode nodes)."""
    base = base or NetworkParams()
    npt = base.nodes_per_tor
    if tp.n_nodes % npt:
        # shrink the ToR to the largest divisor of the mesh size
        npt = max(d for d in range(1, npt + 1) if tp.n_nodes % d == 0)
    return dataclasses.replace(base, n_nodes=tp.n_nodes, nodes_per_tor=npt)


@dataclasses.dataclass(frozen=True)
class RequestTrace:
    """One seeded open-loop request process (design-independent)."""
    arrival_us: np.ndarray      # (n_req,) sorted arrival times
    ready_us: np.ndarray        # (n_req,) arrival + prefill compute
    kv_blocks: np.ndarray       # (n_req,) int, KV blocks to ship
    decode_tokens: np.ndarray   # (n_req,) int, response length

    @property
    def n_requests(self) -> int:
        return self.arrival_us.size


def arrival_rate_per_us(tp: ServeTrafficParams, ref_round_us: float) -> float:
    """Requests per microsecond hitting ``load``.

    Capacity is ``capacity_blocks_per_round`` per ``ref_round_us``
    (the *reference* round time — fig8 uses the unloaded nominal, so
    every design faces the same arrival process and the slow ones eat
    the backlog).
    """
    cap_blocks_per_us = tp.capacity_blocks_per_round / ref_round_us
    return tp.load * cap_blocks_per_us / tp.mean_request_blocks


def request_trace(tp: ServeTrafficParams, horizon_us: float,
                  ref_round_us: float, seed: int) -> RequestTrace:
    """Draw the request process covering ``[0, horizon_us)``."""
    rate = arrival_rate_per_us(tp, ref_round_us)
    rng_a = np.random.default_rng([seed, STREAM_ARRIVALS])
    rng_l = np.random.default_rng([seed, STREAM_LENGTHS])
    # exponential gaps until past the horizon (draw in chunks)
    gaps, t = [], 0.0
    while t < horizon_us:
        chunk = rng_a.exponential(1.0 / rate, size=256)
        gaps.append(chunk)
        t += float(chunk.sum())
    arrival = np.cumsum(np.concatenate(gaps))
    arrival = arrival[arrival < horizon_us]
    n = arrival.size
    mu = np.log(tp.prefill_tokens_mean) - tp.prefill_tokens_sigma ** 2 / 2
    prefill_tokens = np.maximum(
        1, rng_l.lognormal(mu, tp.prefill_tokens_sigma, n)).astype(int)
    kv_blocks = np.maximum(1, np.ceil(
        prefill_tokens * tp.kv_bytes_per_token / tp.kv_block_bytes)).astype(int)
    decode_tokens = 1 + rng_l.geometric(
        1.0 / max(tp.decode_tokens_mean, 1.0), n)
    return RequestTrace(
        arrival_us=arrival,
        ready_us=arrival + prefill_tokens * tp.prefill_us_per_token,
        kv_blocks=kv_blocks, decode_tokens=decode_tokens)


@dataclasses.dataclass(frozen=True)
class ServingResult:
    """Per-request outcomes of one design's rounds serving one trace.

    ``kv_loss_by_cause`` (present when ``simulate_serving`` was given
    the engine's per-cause loss rates — ``telemetry.DesignRecord
    .loss_rates()``) splits each request's missing KV fraction by
    originating cause (``telemetry.CAUSES`` order: wire_drop, fault,
    window_cut), shipped-block-weighted exactly like ``kv_frac`` — the
    serve side of the end-to-end drop-provenance chain.
    """
    latency_us: np.ndarray      # (n_req,) time-to-first-decode-token
    completed: np.ndarray       # (n_req,) bool — KV fully shipped in horizon
    kv_frac: np.ndarray         # (n_req,) delivered KV fraction (<= 1)
    blocks_shipped: int         # total blocks moved (conservation checks)
    kv_loss_by_cause: np.ndarray | None = None   # (n_req, n_causes)

    @property
    def p99_latency_us(self) -> float:
        return float(np.percentile(self.latency_us, 99))

    @property
    def completion_frac(self) -> float:
        return float(self.completed.mean()) if self.completed.size else 1.0

    @property
    def mean_kv_frac(self) -> float:
        done = self.kv_frac[self.completed]
        return float(done.mean()) if done.size else 1.0

    def loss_attribution(self) -> dict:
        """Mean lost-KV fraction by cause over completed requests
        (empty dict when causes were not supplied)."""
        if self.kv_loss_by_cause is None:
            return {}
        from repro.core.transport import telemetry
        rows = self.kv_loss_by_cause[self.completed]
        if not rows.size:
            return {c: 0.0 for c in telemetry.CAUSES}
        return {c: float(rows[:, i].mean())
                for i, c in enumerate(telemetry.CAUSES)}


def simulate_serving(tp: ServeTrafficParams, times_us: np.ndarray,
                     recv_frac: np.ndarray, trace: RequestTrace,
                     loss_rates: np.ndarray | None = None
                     ) -> ServingResult:
    """FIFO KV shipping over one design's engine rounds.

    Round ``r`` (ending at ``T[r] = cumsum(times_us)[r]``) ships up to
    ``capacity_blocks_per_round`` blocks from requests whose prefill
    finished before the round started, oldest-ready first; a request's
    first decode token fires at the end of the round carrying its last
    block.  ``recv_frac[r]`` is the fraction of round ``r``'s packets
    that beat the window (1.0 for the reliable designs) — a request's
    delivered KV fraction is the block-weighted mean over its rounds.

    Requests whose KV is still queued when the horizon ends are
    *censored*: ``completed=False`` and their latency is the (lower
    bound) horizon remainder — report completion_frac next to any
    latency percentile at loads near 1.

    ``loss_rates`` (optional, ``(R, n_causes)`` — per-round lost
    payload fraction by cause, ``telemetry.DesignRecord.loss_rates()``
    from the engine run that produced ``times_us``/``recv_frac``)
    additionally attributes every request's missing KV to its
    originating cause with the same shipped-block weighting, so a
    degraded cache can be traced back to a DCI fault stall or a window
    cut.  Rounds beyond the rates' length wrap, like DropSchedule.
    """
    T_end = np.cumsum(times_us)
    R = times_us.size
    n = trace.n_requests
    order = np.argsort(trace.ready_us, kind="stable")
    latency = np.zeros(n)
    kv_got = np.zeros(n)
    lr = None
    if loss_rates is not None:
        lr = np.asarray(loss_rates, np.float64)
        kv_lost = np.zeros((n, lr.shape[1]))
    done = np.zeros(n, dtype=bool)
    cap = tp.capacity_blocks_per_round
    shipped_total = 0
    head = 0                       # next request not yet fully shipped
    remaining = trace.kv_blocks.astype(np.int64).copy()
    for r in range(R):
        t_start = T_end[r - 1] if r else 0.0
        budget = cap
        i = head
        while budget > 0 and i < n:
            j = order[i]
            if trace.ready_us[j] > t_start:
                break              # FIFO by ready time: later ones wait
            ship = min(budget, int(remaining[j]))
            if ship > 0:
                remaining[j] -= ship
                budget -= ship
                shipped_total += ship
                kv_got[j] += ship * recv_frac[r]
                if lr is not None:
                    kv_lost[j] += ship * lr[r % lr.shape[0]]
                if remaining[j] == 0:
                    done[j] = True
                    latency[j] = T_end[r] - trace.arrival_us[j]
            if remaining[j] == 0:
                if i == head:
                    head += 1
                i += 1
            else:
                break              # this round's capacity is exhausted
    horizon = T_end[-1] if R else 0.0
    censored = ~done
    latency[censored] = np.maximum(
        horizon - trace.arrival_us[censored], 0.0)
    kv_frac = np.where(trace.kv_blocks > 0,
                       kv_got / np.maximum(trace.kv_blocks, 1), 1.0)
    by_cause = None
    if lr is not None:
        by_cause = np.clip(
            kv_lost / np.maximum(trace.kv_blocks, 1)[:, None], 0.0, 1.0)
    return ServingResult(latency_us=latency, completed=done,
                         kv_frac=np.clip(kv_frac, 0.0, 1.0),
                         blocks_shipped=shipped_total,
                         kv_loss_by_cause=by_cause)


def nominal_round_us(tp: ServeTrafficParams, net: NetworkParams) -> float:
    """Unloaded reference round time for the KV plan.

    Per step, a block serializes behind ``fan_in - 1`` other senders on
    the decode port (the incast overlay's egress share), plus the
    half-RTT floor.  This is the load-normalization reference and the
    scale the Celeris serving SLO budget is set from — *not* a
    prediction of loaded round times.
    """
    per_step = (tp.kv_block_bytes / net.link_bytes_per_us * tp.fan_in
                + net.base_rtt_us / 2)
    return tp.steps_per_round * per_step
