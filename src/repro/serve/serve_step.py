"""Serving: prefill + decode step factories.

- ``make_prefill``: (params, batch) -> (last-position logits, caches).
- ``make_decode``: (params, caches, tokens (B,1), index) -> (logits,
  caches) — one new token against a KV cache / recurrent state of
  ``s_max``; this is what the ``decode_32k`` / ``long_500k`` dry-run
  cells lower.

Sharding: batch over dp axes, params TP over 'model' (GSPMD).  KV-cache
heads are *not* forced onto the model axis (kv counts like 2 or 8 don't
divide 16); caches shard over batch, which is where decode parallelism
lives (the attention einsum for one token is bandwidth-bound on the
cache read, linear in B).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import coding
from repro.models import layers as L
from repro.models import model as M


def make_prefill(cfg: ModelConfig, s_max: int):
    def prefill(params, batch):
        tokens = batch["tokens"]
        b = tokens.shape[0]
        caches = M.init_caches(cfg, b, s_max)
        n_front = (cfg.n_frontend_tokens
                   if cfg.frontend == "vision_stub" else 0)
        positions = jnp.arange(tokens.shape[1] + n_front,
                               dtype=jnp.int32)[None, :]
        logits, caches, _ = M.forward(params, cfg, batch, caches=caches,
                                      positions=positions, remat=False,
                                      last_only=True)
        return logits[:, -1], caches
    return jax.jit(prefill)


def make_decode(cfg: ModelConfig):
    def decode(params, caches, batch, index):
        """index: scalar int32 — the position being generated."""
        positions = jnp.full((batch["tokens"].shape[0], 1), index,
                             dtype=jnp.int32)
        memory = batch.get("memory")       # enc-dec cross-attention
        logits, caches, _ = M.forward(
            params, cfg, {"tokens": batch["tokens"]}, caches=caches,
            cache_index=index, positions=positions, memory=memory,
            remat=False)
        return logits[:, -1], caches
    return jax.jit(decode, donate_argnums=(1,))


def greedy_decode(cfg: ModelConfig, params, caches, first_token: jax.Array,
                  start_idx: int, n_steps: int):
    """Greedy host-loop decode from an existing (possibly degraded) KV
    cache: ``first_token`` (B, 1) seeds the loop, ``start_idx`` is the
    cache position of the first generated token.  Returns
    (B, n_steps) tokens including ``first_token``."""
    decode = make_decode(cfg)
    out = [first_token]
    idx = start_idx
    for _ in range(n_steps - 1):
        logits, caches = decode(params, caches,
                                {"tokens": out[-1]}, jnp.int32(idx))
        out.append(jnp.argmax(logits, -1)[:, None])
        idx += 1
    return jnp.concatenate(out, axis=1)


def greedy_generate(cfg: ModelConfig, params, prompt: jax.Array,
                    n_steps: int, s_max: Optional[int] = None,
                    extra: Optional[Dict[str, Any]] = None):
    """Small host-loop generator for examples/tests (greedy)."""
    s_max = s_max or (prompt.shape[1] + n_steps)
    batch = {"tokens": prompt, **(extra or {})}
    prefill = make_prefill(cfg, s_max)
    logits, caches = prefill(params, batch)
    first = jnp.argmax(logits, -1)[:, None]
    return greedy_decode(cfg, params, caches, first, prompt.shape[1], n_steps)


# ----------------------------------------------------------------------
# Degraded-KV decode: ship caches through the lossy transport's wire
# layout (serve/traffic.py -> coupling.kv_hole_masks -> here)
# ----------------------------------------------------------------------

def kv_wire_roundtrip(flat: jax.Array, mask: jax.Array, signs: jax.Array,
                      code: coding.HadamardCode, *, coded: bool = True
                      ) -> jax.Array:
    """One flat KV payload through the wire: encode (or just block),
    drop the wire rows where ``mask`` is 0, decode.

    ``mask`` (n_rot,) is one request's transport-block arrival mask
    (``coupling.kv_hole_masks`` row) — the payload ships as ``n_rot``
    transport blocks either way, and the same block indices are lost
    either way; the two layouts differ in what a block *carries*:

    - ``coded=True``: block ``j`` is wire row ``j`` of the Hadamard
      layout — coordinate ``j`` of every rotation block.  Lost rows
      are unbiased over by ``core.coding.decode``, so the damage is
      small dense noise spread across the entire payload.
    - ``coded=False``: block ``j`` is the ``j``-th *contiguous chunk*
      of the raw payload (how an uncoded sender packs KV).  Lost
      chunks are holes: whole spans of cache positions zeroed —
      exactly the trainer's plain-lossy ablation, applied to serving.
    """
    mask = mask.astype(flat.dtype)
    if coded:
        wire = coding.encode(flat, signs, code, use_pallas=False)
        wire = wire * mask[:, None]
        return coding.decode(wire, mask, signs, code, total_peers=1,
                             use_pallas=False)
    x = jnp.pad(flat.reshape(-1), (0, code.padded_len - code.orig_len))
    chunks = x.reshape(code.n_rot, code.n_blocks) * mask[:, None]
    return chunks.reshape(-1)[: code.orig_len]


def degrade_caches(caches, mask: jax.Array, key: jax.Array, *,
                   coded: bool = True):
    """Apply one request's KV-transfer loss to its decode caches.

    Every attention layer's K and V tensors are flattened, shipped
    through :func:`kv_wire_roundtrip` under the same wire-row mask
    (all of a request's KV blocks ride the same cut rounds), and
    restored in place; recurrent state and cache positions are
    metadata the transport does not code, and pass through untouched.
    ``key`` seeds the shared rotation signs — prefill and decode sides
    must agree on it, exactly like the trainer's coded all-reduce.
    """
    def _ship(leaf):
        code = coding.plan(int(leaf.size), n_rot=int(mask.shape[0]))
        if code.n_rot != int(mask.shape[0]):
            raise ValueError(
                f"KV leaf of {leaf.size} elements cannot carry a "
                f"{mask.shape[0]}-row wire mask (plan chose {code.n_rot})")
        signs = coding.rademacher(key, code)
        out = kv_wire_roundtrip(leaf.reshape(-1).astype(jnp.float32),
                                mask, signs, code, coded=coded)
        return out.reshape(leaf.shape).astype(leaf.dtype)

    def _one(node):
        if not isinstance(node, L.AttnCache):
            return node
        return dataclasses.replace(node, k=_ship(node.k), v=_ship(node.v))

    return jax.tree_util.tree_map(
        _one, caches, is_leaf=lambda x: isinstance(x, L.AttnCache))


def kv_position_error(clean, degraded, n_ctx: int):
    """(n_ctx,) per-position relative KV error after lossy transfer.

    For each cache position ``s < n_ctx`` (the prefilled context), the
    relative L2 error of its K/V vectors aggregated over every
    attention layer — the serving counterpart of the trainer's
    gradient-error metric.  An uncoded lost chunk drives whole
    positions to error ~1 (their context is simply gone at the decode
    node); the coded path spreads the same loss as uniform small noise
    across all positions.  ``usable fraction`` (positions under an
    error threshold) is fig8's recovery metric.
    """
    def _leaves(tree):
        nodes = jax.tree_util.tree_leaves(
            tree, is_leaf=lambda x: isinstance(x, L.AttnCache))
        return [n for n in nodes if isinstance(n, L.AttnCache)]

    err2 = jnp.zeros(n_ctx)
    ref2 = jnp.zeros(n_ctx)
    for c0, c1 in zip(_leaves(clean), _leaves(degraded)):
        for a0, a1 in ((c0.k, c1.k), (c0.v, c1.v)):
            # (..., S, kv, hd): fold everything but the position axis
            s_ax = a0.ndim - 3
            d = jnp.moveaxis((a1 - a0) ** 2, s_ax, 0)
            r = jnp.moveaxis(a0.astype(jnp.float32) ** 2, s_ax, 0)
            err2 = err2 + d[:n_ctx].reshape(n_ctx, -1).sum(1)
            ref2 = ref2 + r[:n_ctx].reshape(n_ctx, -1).sum(1)
    return jnp.sqrt(err2 / jnp.maximum(ref2, 1e-12))
