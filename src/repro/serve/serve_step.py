"""Serving: prefill + decode step factories.

- ``make_prefill``: (params, batch) -> (last-position logits, caches).
- ``make_decode``: (params, caches, tokens (B,1), index) -> (logits,
  caches) — one new token against a KV cache / recurrent state of
  ``s_max``; this is what the ``decode_32k`` / ``long_500k`` dry-run
  cells lower.

Sharding: batch over dp axes, params TP over 'model' (GSPMD).  KV-cache
heads are *not* forced onto the model axis (kv counts like 2 or 8 don't
divide 16); caches shard over batch, which is where decode parallelism
lives (the attention einsum for one token is bandwidth-bound on the
cache read, linear in B).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M


def make_prefill(cfg: ModelConfig, s_max: int):
    def prefill(params, batch):
        tokens = batch["tokens"]
        b = tokens.shape[0]
        caches = M.init_caches(cfg, b, s_max)
        n_front = (cfg.n_frontend_tokens
                   if cfg.frontend == "vision_stub" else 0)
        positions = jnp.arange(tokens.shape[1] + n_front,
                               dtype=jnp.int32)[None, :]
        logits, caches, _ = M.forward(params, cfg, batch, caches=caches,
                                      positions=positions, remat=False,
                                      last_only=True)
        return logits[:, -1], caches
    return jax.jit(prefill)


def make_decode(cfg: ModelConfig):
    def decode(params, caches, batch, index):
        """index: scalar int32 — the position being generated."""
        positions = jnp.full((batch["tokens"].shape[0], 1), index,
                             dtype=jnp.int32)
        memory = batch.get("memory")       # enc-dec cross-attention
        logits, caches, _ = M.forward(
            params, cfg, {"tokens": batch["tokens"]}, caches=caches,
            cache_index=index, positions=positions, memory=memory,
            remat=False)
        return logits[:, -1], caches
    return jax.jit(decode, donate_argnums=(1,))


def greedy_generate(cfg: ModelConfig, params, prompt: jax.Array,
                    n_steps: int, s_max: Optional[int] = None,
                    extra: Optional[Dict[str, Any]] = None):
    """Small host-loop generator for examples/tests (greedy)."""
    s_max = s_max or (prompt.shape[1] + n_steps)
    batch = {"tokens": prompt, **(extra or {})}
    prefill = make_prefill(cfg, s_max)
    decode = make_decode(cfg)
    logits, caches = prefill(params, batch)
    out = [jnp.argmax(logits, -1)[:, None]]
    idx = prompt.shape[1]
    for t in range(n_steps - 1):
        logits, caches = decode(params, caches,
                                {"tokens": out[-1]}, jnp.int32(idx))
        out.append(jnp.argmax(logits, -1)[:, None])
        idx += 1
    return jnp.concatenate(out, axis=1)
