"""Mesh / shard_map compatibility shims (JAX 0.4.x through 0.8.x).

The repo targets the jax 0.8 surface (``jax.shard_map`` with
``axis_names``/``check_vma``, ``jax.sharding.AxisType``,
``jax.lax.axis_size``); containers pinned to 0.4.x lack all three.
Every mesh / shard_map / axis-size use in the tree goes through this
module so the version split lives in exactly one place.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax

_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
_HAS_LAX_AXIS_SIZE = hasattr(jax.lax, "axis_size")

try:  # jax >= 0.8: top-level shard_map (axis_names / check_vma API)
    _shard_map_new = jax.shard_map
    _shard_map_old = None
except AttributeError:  # jax 0.4.x: experimental (auto / check_rep API)
    _shard_map_new = None
    from jax.experimental.shard_map import shard_map as _shard_map_old


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """jax-0.8-style shard_map on either jax.

    ``axis_names``: the *manual* axes (None = all mesh axes manual).  On
    0.4.x this is translated to the complementary ``auto`` frozenset and
    ``check_vma`` to ``check_rep``.  Note 0.4.x partial-auto shard_map
    only traces under ``jit`` — every call site in this repo is jitted.
    """
    if _shard_map_new is not None:
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return _shard_map_new(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=check_vma, **kw)
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma,
                          auto=auto)


def make_mesh(shape: Sequence[int], axis_names: Sequence[str]
              ) -> jax.sharding.Mesh:
    """jax.make_mesh with the pre-0.9 Auto axis-type behavior pinned and
    the device list sliced explicitly (0.4.x requires an exact count)."""
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise ValueError(f"mesh {tuple(shape)} needs {n} devices, "
                         f"only {len(devices)} available")
    kw = {}
    if _HAS_AXIS_TYPE:
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(tuple(shape))
    return jax.make_mesh(tuple(shape), tuple(axis_names),
                         devices=devices[:n], **kw)


def axis_size(axis_name) -> int:
    """Static size of a (possibly composite) mesh axis inside shard_map."""
    names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    size = 1
    for a in names:
        if _HAS_LAX_AXIS_SIZE:
            size *= jax.lax.axis_size(a)
        else:  # 0.4.x: core.axis_frame(name) is the bound size
            from jax._src import core as _core
            size *= int(_core.axis_frame(a))
    return size


# ----------------------------------------------------------------------
# Ambient mesh registry: model code (e.g. the MoE expert-parallel island)
# needs the mesh to open shard_map regions inside a jitted step.  When no
# mesh is set (single-device smoke tests), layers fall back to local-only
# implementations.
# ----------------------------------------------------------------------

_GLOBAL_MESH: jax.sharding.Mesh | None = None


def set_global_mesh(mesh: jax.sharding.Mesh | None) -> None:
    global _GLOBAL_MESH
    _GLOBAL_MESH = mesh


def get_global_mesh() -> jax.sharding.Mesh | None:
    return _GLOBAL_MESH


def dp_axes(mesh: jax.sharding.Mesh | None = None) -> tuple[str, ...]:
    """Data-parallel axes of the production meshes ('pod' composes)."""
    mesh = mesh or _GLOBAL_MESH
    if mesh is None:
        return ()
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


MODEL_AXIS = "model"
POD_AXIS = "pod"


# ----------------------------------------------------------------------
# Partitioner capability gates.  The jax 0.4.x CPU SPMD partitioner
# CHECK-crashes (hlo_sharding_util IsManualSubgroup) when raw/uncoded AD
# gradients cross a partial-auto shard_map boundary — on 0.4.x only the
# Hadamard-coded psum island lowers, so plain-lossy applies its receiver
# window to the GSPMD-synced gradient (receiver granularity).  jax 0.8's
# partitioner handles the general island, unlocking per-(peer, wire-row)
# loss granularity for the uncoded mode too.  train_step dispatches on
# this gate; keep every version split in this module.
# ----------------------------------------------------------------------

def plain_lossy_island_supported() -> bool:
    """True when per-(peer,row) plain-lossy can run as a partial-auto
    shard_map island (jax >= 0.8 partitioner)."""
    return _shard_map_new is not None
