"""Mesh / shard_map compatibility shims (JAX 0.8.x)."""
from __future__ import annotations

from typing import Sequence

import jax

try:  # jax >= 0.8: top-level shard_map
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map  # type: ignore


def make_mesh(shape: Sequence[int], axis_names: Sequence[str]) -> jax.sharding.Mesh:
    """jax.make_mesh with the pre-0.9 Auto axis-type behavior pinned."""
    return jax.make_mesh(
        tuple(shape), tuple(axis_names),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))


# ----------------------------------------------------------------------
# Ambient mesh registry: model code (e.g. the MoE expert-parallel island)
# needs the mesh to open shard_map regions inside a jitted step.  When no
# mesh is set (single-device smoke tests), layers fall back to local-only
# implementations.
# ----------------------------------------------------------------------

_GLOBAL_MESH: jax.sharding.Mesh | None = None


def set_global_mesh(mesh: jax.sharding.Mesh | None) -> None:
    global _GLOBAL_MESH
    _GLOBAL_MESH = mesh


def get_global_mesh() -> jax.sharding.Mesh | None:
    return _GLOBAL_MESH


def dp_axes(mesh: jax.sharding.Mesh | None = None) -> tuple[str, ...]:
    """Data-parallel axes of the production meshes ('pod' composes)."""
    mesh = mesh or _GLOBAL_MESH
    if mesh is None:
        return ()
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


MODEL_AXIS = "model"
