"""AdamW with mixed precision + global-norm clipping (pure JAX).

Mixed-precision layout: model params live in bf16 (compute dtype); the
optimizer keeps an fp32 master copy plus fp32 m/v moments.  Under the
production mesh the moments/master are additionally sharded over the
``data`` axis (ZeRO-1) via the sharding rules in
``repro.train.sharding_rules``.

Schedule: linear warmup -> cosine decay.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Params) -> Dict[str, Any]:
    # copy=True: astype(f32) on an f32 leaf would alias the param buffer
    # and break donation (same buffer donated twice in the train step).
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    return {
        "master": jax.tree.map(f32, params),
        "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def apply_updates(params: Params, grads: Params, state: Dict[str, Any],
                  cfg: OptConfig):
    """Returns (new_params (compute dtype), new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, count)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        step = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        w = w - lr * (step + cfg.weight_decay * w)
        return m, v, w

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(state["mu"])
    flat_v = tdef.flatten_up_to(state["nu"])
    flat_w = tdef.flatten_up_to(state["master"])
    out = [upd(g, m, v, w) for g, m, v, w in
           zip(flat_g, flat_m, flat_v, flat_w)]
    mu = tdef.unflatten([o[0] for o in out])
    nu = tdef.unflatten([o[1] for o in out])
    master = tdef.unflatten([o[2] for o in out])

    flat_p = tdef.flatten_up_to(params)
    new_params = tdef.unflatten([
        w.astype(p.dtype) for w, p in
        zip([o[2] for o in out], flat_p)])
    new_state = {"master": master, "mu": mu, "nu": nu, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
