"""Sharded, mesh-agnostic, atomic checkpointing.

Layout::

    <dir>/step_<N>/
        manifest.json      # treedef paths, shapes, dtypes, step, extra
        <idx>_<path>.npy   # one file per leaf (host layout, unsharded)
    <dir>/LATEST           # text file with the last durable step

Guarantees needed for fault tolerance at scale:

- **atomic**: written to ``step_<N>.tmp`` then renamed; LATEST updated
  last.  A crash mid-save never corrupts the previous checkpoint.
- **mesh-agnostic**: leaves are stored in host layout; restore
  device_puts them with whatever shardings the *current* mesh dictates,
  so jobs can restart elastically on a different topology.
- **async**: ``save_async`` snapshots to host memory synchronously
  (cheap) and writes on a background thread, overlapping I/O with the
  next training steps — double-buffered via a single worker.
"""
from __future__ import annotations

import concurrent.futures as cf
import json
import os
import re
import shutil
from typing import Any, Dict, Optional

import jax
import numpy as np

_EXEC = cf.ThreadPoolExecutor(max_workers=1)


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = re.sub(r"[^A-Za-z0-9_.-]", "_", jax.tree_util.keystr(path))
        out.append((name, leaf))
    return out


def save(directory: str, step: int, tree: Any,
         extra: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _leaf_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (name, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if dtype == "bfloat16":      # numpy can't round-trip bf16
            arr = arr.astype(np.float32)   # lossless upcast
        fn = f"{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append(
            {"file": fn, "path": name, "shape": list(arr.shape),
             "dtype": dtype})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    return final


def save_async(directory: str, step: int, tree: Any,
               extra: Optional[Dict[str, Any]] = None) -> cf.Future:
    """Snapshot to host now; write on the background thread."""
    host = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)
    return _EXEC.submit(save, directory, step, host, extra)


def latest_step(directory: str) -> Optional[int]:
    try:
        with open(os.path.join(directory, "LATEST")) as f:
            return int(f.read().strip())
    except (FileNotFoundError, ValueError):
        return None


def restore(directory: str, tree_like: Any, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, int, Dict[str, Any]]:
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional matching pytree of NamedSharding (current
    mesh) — leaves are device_put with them (elastic restart).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    flat, treedef = jax.tree_util.tree_flatten(tree_like)
    assert len(flat) == len(manifest["leaves"]), \
        (len(flat), len(manifest["leaves"]))
    loaded = []
    for m in manifest["leaves"]:
        arr = np.load(os.path.join(d, m["file"]))
        if m["dtype"] == "bfloat16":
            arr = jax.numpy.asarray(arr).astype(jax.numpy.bfloat16)
        loaded.append(arr)
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(shardings)
        loaded = [jax.device_put(a, s) for a, s in zip(loaded, flat_sh)]
    tree = jax.tree_util.tree_unflatten(treedef, loaded)
    return tree, manifest["step"], manifest["extra"]
