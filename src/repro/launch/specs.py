"""Abstract input specs (ShapeDtypeStruct) per (arch x shape) cell.

No device allocation happens here: everything is shape/dtype/sharding
metadata that ``jax.jit(...).lower()`` consumes directly.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as shd
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M


def _sds(shape, dtype, mesh=None, spec=None):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec or P()))


def _batch_spec(mesh, batch_size: int) -> P:
    """Shard batch over dp axes only when divisible (long_500k B=1)."""
    if mesh is None:
        return P()
    dp = shd.dp_axes(mesh)
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    return P(dp) if batch_size % n == 0 and batch_size >= n else P()


def token_extras(cfg: ModelConfig, b: int, s: int, mesh=None) -> Dict[str, Any]:
    """Frontend stub inputs (precomputed embeddings), per DESIGN.md."""
    bspec = _batch_spec(mesh, b)
    extras: Dict[str, Any] = {}
    if cfg.frontend == "vision_stub":
        extras["image_embeds"] = _sds(
            (b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.bfloat16,
            mesh, P(*bspec, None, None))
    if cfg.frontend == "audio_stub":
        extras["frame_embeds"] = _sds(
            (b, s, cfg.frontend_dim), jnp.bfloat16,
            mesh, P(*bspec, None, None))
    return extras


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh=None
                      ) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    s_text = s - (cfg.n_frontend_tokens if cfg.frontend == "vision_stub" else 0)
    bspec = _batch_spec(mesh, b)
    batch = {
        "tokens": _sds((b, s_text), jnp.int32, mesh, P(*bspec, None)),
        "labels": _sds((b, s_text), jnp.int32, mesh, P(*bspec, None)),
    }
    batch.update(token_extras(cfg, b, s, mesh))
    return batch


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh=None
                        ) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    s_text = s - (cfg.n_frontend_tokens if cfg.frontend == "vision_stub" else 0)
    bspec = _batch_spec(mesh, b)
    batch = {"tokens": _sds((b, s_text), jnp.int32, mesh, P(*bspec, None))}
    batch.update(token_extras(cfg, b, s, mesh))
    return batch


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh=None):
    """(batch, caches, index) abstract inputs for one decode step."""
    b, s_max = shape.global_batch, shape.seq_len
    bspec = _batch_spec(mesh, b)
    batch: Dict[str, Any] = {
        "tokens": _sds((b, 1), jnp.int32, mesh, P(*bspec, None))}
    if cfg.is_encdec:
        batch["memory"] = _sds((b, 1024, cfg.d_model), jnp.bfloat16,
                               mesh, P(*bspec, None, None))

    caches = jax.eval_shape(lambda: M.init_caches(cfg, b, s_max))

    def shard_cache(leaf):
        if mesh is None:
            return leaf
        if leaf.ndim <= 2:
            spec = P()
        elif leaf.shape[1] == b and leaf.ndim >= 3:   # stacked (G,B,...)
            spec = P(None, *bspec, *([None] * (leaf.ndim - 2)))
        elif leaf.shape[0] == b:                       # tail (B, ...)
            spec = P(*bspec, *([None] * (leaf.ndim - 1)))
        else:
            spec = P()
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))

    caches = jax.tree.map(shard_cache, caches)
    index = _sds((), jnp.int32, mesh, P())
    return batch, caches, index


def abstract_state(cfg: ModelConfig, mesh=None):
    """Abstract train state with ZeRO-1 shardings attached."""
    from repro.train import train_step as ts
    from repro.train import sharding_rules as rules

    state = jax.eval_shape(
        lambda: ts.init_state(jax.random.PRNGKey(0), cfg))
    if mesh is None:
        return state
    sh = ts.state_shardings(state, mesh)
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        state, sh)


def abstract_params(cfg: ModelConfig, mesh=None):
    from repro.train import sharding_rules as rules
    params = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    if mesh is None:
        return params
    sh = rules.param_shardings(params, mesh)
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        params, sh)
