"""Static cost analysis for the roofline (§Roofline of EXPERIMENTS.md).

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**
(verified in this container: an 8-iteration scan reports 1/8th of the
unrolled FLOPs), and all our models scan over layer groups — so raw
cost_analysis would undercount 20-40x.  Two complementary analyzers fix
this:

1. :func:`jaxpr_costs` — walks the traced jaxpr, multiplying ``scan``
   bodies by their trip count and ``shard_map`` bodies by the manual
   mesh factor.  Gives exact *global logical* matmul FLOPs and an HBM
   traffic estimate (dot operands+outputs, elementwise outputs — i.e.
   fusion-optimistic).
2. :func:`hlo_collective_bytes` — parses the *compiled post-SPMD* HLO,
   builds the computation call graph, extracts while trip counts from
   loop-condition constants, and sums per-device collective buffer
   bytes with the correct loop multipliers (GSPMD-inserted TP
   collectives live inside the scanned layer body).
"""
from __future__ import annotations

import re
from typing import Any, Dict

import jax
import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
}


# ======================================================================
# 1. jaxpr walker
# ======================================================================

def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:   # noqa: BLE001 - abstract tokens etc.
        return 0


def jaxpr_costs(jaxpr, mult: float = 1.0, acc: Dict[str, float] | None = None
                ) -> Dict[str, float]:
    """Accumulate {flops, hbm_bytes, coll_bytes} over a (closed) jaxpr."""
    acc = acc if acc is not None else {"flops": 0.0, "hbm_bytes": 0.0,
                                       "coll_bytes": 0.0}
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        params = eqn.params

        if prim == "dot_general":
            dn = params["dimension_numbers"]
            (lc, _), (lb, _) = dn
            lhs = eqn.invars[0].aval
            out = eqn.outvars[0].aval
            k = 1
            for d in lc:
                k *= lhs.shape[d]
            flops = 2.0 * float(np.prod(out.shape)) * k
            acc["flops"] += mult * flops
            io = sum(_aval_bytes(v.aval) for v in eqn.invars) \
                + _aval_bytes(out)
            acc["hbm_bytes"] += mult * io

        elif prim == "scan":
            length = params["length"]
            body = params["jaxpr"]
            jaxpr_costs(body, mult * length, acc)

        elif prim == "while":
            # we never emit raw while from python; safe fallback x1
            jaxpr_costs(params["body_jaxpr"], mult, acc)

        elif prim in ("jit", "pjit", "core_call", "closed_call",
                      "remat_call", "checkpoint", "remat2",
                      "custom_vjp_call", "custom_jvp_call",
                      "custom_vjp_call_jaxpr", "custom_lin"):
            inner = params.get("jaxpr") or params.get("call_jaxpr") \
                or params.get("fun_jaxpr")
            if inner is not None:
                jaxpr_costs(inner, mult, acc)

        elif prim == "shard_map":
            inner = params.get("jaxpr")
            mesh = params.get("mesh")
            manual = params.get("manual_axes") or ()
            sizes = dict(zip(mesh.axis_names, mesh.axis_sizes)) \
                if mesh is not None else {}
            factor = 1
            for a in manual:
                factor *= sizes.get(a, 1)
            if inner is not None:
                jaxpr_costs(inner, mult * factor, acc)

        elif prim == "cond":
            branches = params.get("branches", ())
            sub = [jaxpr_costs(b, mult, dict(acc)) for b in branches]
            if sub:
                best = max(sub, key=lambda d: d["flops"])
                for k2 in acc:
                    acc[k2] = best[k2]

        elif prim in ("psum", "all_gather", "all_to_all", "ppermute",
                      "psum_scatter", "pmax", "pmin"):
            nbytes = sum(_aval_bytes(v.aval) for v in eqn.invars)
            acc["coll_bytes"] += mult * nbytes
            acc["hbm_bytes"] += mult * nbytes

        else:
            # elementwise / reduction / layout: count output traffic
            out_b = sum(_aval_bytes(v.aval) for v in eqn.outvars)
            acc["hbm_bytes"] += mult * out_b * 0.5   # fusion discount
            if prim in ("exp", "tanh", "log", "logistic", "erf",
                        "rsqrt", "sin", "cos", "pow"):
                acc["flops"] += mult * float(np.prod(
                    eqn.outvars[0].aval.shape))
    return acc


def trace_costs(fn, *abstract_args) -> Dict[str, float]:
    jaxpr = jax.make_jaxpr(fn)(*abstract_args)
    return jaxpr_costs(jaxpr)


# ======================================================================
# 2. compiled-HLO collective parse (while-aware)
# ======================================================================


_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_COLL_RE = re.compile(
    r"=\s*(.+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo: str) -> Dict[str, str]:
    """Computation-name -> body text.  Headers look like
    ``[ENTRY] %name (params...) -> result {`` and can contain nested
    parens in tuple-typed params, so we split on tokens, not a regex."""
    comps: Dict[str, str] = {}
    name, buf = None, []
    for ln in hlo.splitlines():
        stripped = ln.rstrip()
        if (name is None and stripped.endswith("{")
                and " -> " in stripped
                and not stripped.startswith("HloModule")):
            parts = stripped.split()
            if parts[0] == "ENTRY":
                cname = parts[1]
                comps["__entry__"] = cname.lstrip("%")
            else:
                cname = parts[0]
            name = cname.lstrip("%")
            buf = [ln]
        elif name is not None:
            buf.append(ln)
            if stripped == "}" or stripped.startswith("} "):
                comps[name] = "\n".join(buf)
                name = None
    return comps


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def hlo_collective_bytes(hlo: str) -> Dict[str, Any]:
    """Per-device collective bytes with while-loop trip multipliers."""
    comps = _split_computations(hlo)
    entry = comps.pop("__entry__", None)

    def trip_count(cond_name: str) -> int:
        text = comps.get(cond_name, "")
        consts = [int(c) for c in _CONST_RE.findall(text)]
        return max(consts) if consts else 1

    totals: Dict[str, Dict[str, float]] = {}
    visited_mult: Dict[str, float] = {}

    def visit(name: str, mult: float):
        text = comps.get(name)
        if text is None:
            return
        # collectives directly in this computation
        for m in _COLL_RE.finditer(text):
            kind = m.group(2).lower()
            nbytes = _shape_bytes(m.group(1))
            rec = totals.setdefault(kind, {"count": 0.0, "bytes": 0.0})
            rec["count"] += mult
            rec["bytes"] += mult * nbytes
        # recurse into whiles with trip multiplier.  Collectives only
        # live in loop bodies / the entry computation: fusions and
        # reducers are collective-free, so no generic call recursion
        # (which would double-count shared computations).
        for wm in _WHILE_RE.finditer(text):
            cond, body = wm.group(1), wm.group(2)
            t = trip_count(cond)
            visit(body, mult * t)

    if entry:
        visit(entry, 1.0)
    out = {k: {"count": round(v["count"], 1), "bytes": v["bytes"]}
           for k, v in totals.items()}
    out["total_bytes"] = sum(v["bytes"] for k, v in totals.items())
    return out


# ======================================================================
# 3. roofline terms
# ======================================================================

def roofline(flops_global: float, hbm_bytes_global: float,
             coll_bytes_per_dev: float, n_devices: int,
             model_flops: float, hw: Dict[str, float]) -> Dict[str, float]:
    compute_s = flops_global / (n_devices * hw["peak_flops_bf16"])
    memory_s = hbm_bytes_global / (n_devices * hw["hbm_bw"])
    coll_s = coll_bytes_per_dev / hw["ici_bw_per_link"]
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", coll_s)), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops": model_flops,
        "useful_flops_ratio": model_flops / max(flops_global, 1.0),
        "roofline_frac": max(compute_s, 1e-30)
        / max(compute_s, memory_s, coll_s),
    }
