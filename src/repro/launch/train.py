"""Training launcher.

Container-scale (real devices):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --smoke --steps 50 --celeris --ckpt-dir /tmp/run1

On a real TPU pod this same entrypoint runs under the production mesh
(--mesh single|multi picks 16x16 or 2x16x16); jax.distributed handles
multi-host process groups outside this container.
"""
import argparse

import jax

import repro.configs as C
from repro import sharding as shd
from repro.data.pipeline import DataConfig
from repro.launch import mesh as mesh_mod
from repro.optim.adamw import OptConfig
from repro.train.trainer import Trainer
from repro.train.train_step import CelerisConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--celeris", action="store_true")
    ap.add_argument("--lossy-moe", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["none", "host", "single", "multi"],
                    default="none")
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch) if args.smoke else C.get(args.arch)
    mesh = None
    if args.mesh == "host":
        n = len(jax.devices())
        mesh = mesh_mod.make_host_mesh((max(n // 2, 1), min(2, n)))
    elif args.mesh == "single":
        mesh = mesh_mod.make_production_mesh()
    elif args.mesh == "multi":
        mesh = mesh_mod.make_production_mesh(multi_pod=True)
    if mesh is not None:
        shd.set_global_mesh(mesh)

    tr = Trainer(
        cfg,
        data_cfg=DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                            global_batch=args.global_batch),
        opt_cfg=OptConfig(lr=args.lr, total_steps=args.steps),
        celeris=CelerisConfig(enabled=args.celeris,
                              lossy_moe=args.lossy_moe),
        mesh=mesh, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    tr.run(args.steps, on_metrics=lambda s, m: print(
        f"step {s:4d} loss {m['loss']:.4f} nll {m['nll']:.4f} "
        f"recv {m['recv_frac']:.3f} lr {m['lr']:.2e} ({m['wall_s']:.2f}s)",
        flush=True))


if __name__ == "__main__":
    main()
