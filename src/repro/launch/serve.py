"""Serving launcher: batched prefill + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
        --batch 4 --prompt-len 32 --gen 16

On a real pod, drop --smoke and pick --mesh single|multi (the decode
cells of the dry-run prove the production lowering; this CLI is the
runnable host loop).
"""
import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro import sharding as shd
from repro.launch import mesh as mesh_mod
from repro.models import model as M
from repro.serve import serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch) if args.smoke else C.get(args.arch)
    if args.mesh != "none":
        mesh = mesh_mod.make_production_mesh(multi_pod=args.mesh == "multi")
        shd.set_global_mesh(mesh)

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    s_max = args.prompt_len + args.gen
    prefill = serve_step.make_prefill(cfg, s_max)
    decode = serve_step.make_decode(cfg)

    t0 = time.perf_counter()
    logits, caches = prefill(params, {"tokens": prompt})
    jax.block_until_ready(logits)
    print(f"prefill: {time.perf_counter()-t0:.2f}s")

    tok = jnp.argmax(logits, -1)[:, None]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, caches = decode(params, caches, {"tokens": tok},
                                jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits, -1)[:, None]
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    n = args.batch * (args.gen - 1)
    print(f"decode: {n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
