"""Production mesh definitions (TPU v5e-pod-scale).

Defined as FUNCTIONS so importing this module never touches jax device
state.  Single pod: 16x16 = 256 chips (data, model).  Multi-pod: P pods
x 256 with a leading "pod" axis; "pod" composes with "data" for
gradient reduction (DP = pod x data) and is the axis Celeris's lossy
sync cares about most (cross-pod DCI links are the slow, lossy hops).

All construction goes through :func:`repro.sharding.make_mesh` so the
jax 0.4/0.8 API split stays in one place.
"""
from __future__ import annotations

import jax

from repro import sharding as shd


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return shd.make_mesh(shape, axes)


def make_scale_mesh(n_devices: int) -> jax.sharding.Mesh:
    """Simulated scale-out mesh for the lossy-collective dry runs.

    256 stays the single-pod (data, model) layout; 512/1024/... stack
    pods of 16x16 chips (pod, data, model) — the DP group the lossy
    gradient sync reduces over is pod x data = n_devices / 16.
    """
    if n_devices == 256:
        return shd.make_mesh((16, 16), ("data", "model"))
    if n_devices % 256 or n_devices < 512:
        raise ValueError(f"n_devices={n_devices} must be 256 or a "
                         "multiple of 256 >= 512")
    return shd.make_mesh((n_devices // 256, 16, 16),
                         ("pod", "data", "model"))


def make_host_mesh(shape=(4, 2), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh for container-scale integration tests."""
    return shd.make_mesh(shape, axes)


def make_pod_mesh(n_pods: int, data: int = 16, model: int = 16
                  ) -> jax.sharding.Mesh:
    """An N-pod (pod, data, model) mesh at arbitrary per-pod size.

    The production shape is ``make_pod_mesh(P)`` = P x 16 x 16 (what
    ``make_scale_mesh`` builds for 512+ devices); small ``data``/
    ``model`` values give container-scale hierarchical test meshes,
    e.g. ``make_pod_mesh(2, 2, 2)`` on 8 simulated devices.
    """
    if n_pods < 2:
        raise ValueError(f"n_pods={n_pods}: a hierarchical mesh needs >= 2 "
                         "pods (use make_production_mesh for one pod)")
    return shd.make_mesh((n_pods, data, model), ("pod", "data", "model"))


HW = {
    # TPU v5e-like hardware constants for the roofline (per chip)
    "peak_flops_bf16": 197e12,      # FLOP/s
    "hbm_bw": 819e9,                # B/s
    "ici_bw_per_link": 50e9,        # B/s per link direction
}
