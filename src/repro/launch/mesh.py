"""Production mesh definitions (TPU v5e-pod-scale).

Defined as FUNCTIONS so importing this module never touches jax device
state.  Single pod: 16x16 = 256 chips (data, model).  Multi-pod: 2 pods
x 256 = 512 chips with a leading "pod" axis; "pod" composes with "data"
for gradient reduction (DP = pod x data = 32) and is the axis Celeris's
lossy sync cares about most (cross-pod DCI links are the slow, lossy
hops).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(shape=(4, 2), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh for container-scale integration tests."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


HW = {
    # TPU v5e-like hardware constants for the roofline (per chip)
    "peak_flops_bf16": 197e12,      # FLOP/s
    "hbm_bw": 819e9,                # B/s
    "ici_bw_per_link": 50e9,        # B/s per link direction
}
