import os
import sys

# --scale-check needs 1024 simulated devices; everything else keeps the
# 512-device default (REPRO_DRYRUN_DEVICES overrides).  Must be decided
# before jax is imported.
_N_DEV = int(os.environ.get(
    "REPRO_DRYRUN_DEVICES",
    1024 if "--scale-check" in sys.argv else 512))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_N_DEV} "
    # CPU-only workaround: AllReducePromotion CHECK-crashes on the
    # mixed-dtype variadic all-reduces the combiner builds from bf16
    # wire + f32 count syncs (irrelevant on TPU).
    "--xla_disable_hlo_passes=all-reduce-promotion")

"""Multi-pod dry run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: parameter
and activation shardings must partition, collectives must be legal on
the mesh, and the compiled module's memory analysis must fit the chips.

Usage:
    python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
    python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k --multi-pod
    python -m repro.launch.dryrun --all          # every runnable cell, both meshes

Each run appends a JSON record (memory analysis, cost analysis,
collective-byte breakdown parsed from the post-SPMD HLO) to
``results/dryrun/<arch>__<shape>__<mesh>.json`` for EXPERIMENTS.md and
the roofline benchmark to consume.
"""
import argparse
import json
import re
import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro import sharding as shd
from repro.configs.base import SHAPES
from repro.launch import costs
from repro.launch import mesh as mesh_mod
from repro.launch import specs
from repro.optim import adamw
from repro.serve import serve_step
from repro.train import train_step as ts

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

def _serve_fn_args(cfg, shape, mesh):
    """(jitted fn, abstract args) for a serve cell — the single lowering
    recipe shared by lower_cell and serve_check_cell."""
    params = specs.abstract_params(cfg, mesh)
    if shape.kind == "prefill":
        batch = specs.prefill_input_specs(cfg, shape, mesh)
        return serve_step.make_prefill(cfg, shape.seq_len), (params, batch)
    if shape.kind == "decode":
        batch, caches, index = specs.decode_input_specs(cfg, shape, mesh)
        return serve_step.make_decode(cfg), (params, caches, batch, index)
    raise ValueError(f"{shape.name} is not a serve shape")


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               celeris: bool = True, quantize_wire: bool = False):
    cfg = C.get(arch)
    shape = SHAPES[shape_name]
    if shape_name not in C.runnable_shapes(cfg):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "long_500k needs sub-quadratic attention"}

    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    shd.set_global_mesh(mesh)
    t0 = time.time()

    # gradient accumulation so multi-B-param train cells fit 16 GB HBM
    n_params = cfg.param_count()
    micro = 4 if n_params >= 6e9 else (2 if n_params >= 2e9 else 1)

    if shape.kind == "train":
        state = specs.abstract_state(cfg, mesh)
        batch = specs.train_input_specs(cfg, shape, mesh)
        key = jax.ShapeDtypeStruct((2,), jnp.uint32,
                                   sharding=jax.sharding.NamedSharding(
                                       mesh, jax.sharding.PartitionSpec()))
        drop = jax.ShapeDtypeStruct((), jnp.float32,
                                    sharding=jax.sharding.NamedSharding(
                                        mesh, jax.sharding.PartitionSpec()))
        step_fn = ts.make_train_step(
            cfg, mesh, adamw.OptConfig(),
            ts.CelerisConfig(enabled=celeris,
                             lossy_moe=celeris and cfg.moe is not None,
                             quantize_wire=quantize_wire),
            donate=True, microbatches=micro)
        lowered = step_fn.lower(state, batch, key, drop)
        jax_costs = costs.trace_costs(step_fn, state, batch, key, drop)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * cfg.active_param_count() * tokens
    else:   # prefill / decode
        fn, args = _serve_fn_args(cfg, shape, mesh)
        lowered = fn.lower(*args)
        jax_costs = costs.trace_costs(fn, *args)
        if shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
            model_flops = 2.0 * cfg.active_param_count() * tokens
        else:
            model_flops = 2.0 * cfg.active_param_count() * shape.global_batch

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax 0.4.x: list of one dict
        cost = cost[0] if cost else None
    colls = costs.hlo_collective_bytes(compiled.as_text())

    n_dev = mesh.devices.size
    coll_per_dev = colls.get("total_bytes", 0.0)
    rl = costs.roofline(jax_costs["flops"], jax_costs["hbm_bytes"],
                        coll_per_dev, int(n_dev), model_flops,
                        mesh_mod.HW)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(n_dev),
        "celeris": celeris,
        "kind": shape.kind,
        "microbatches": micro if shape.kind == "train" else None,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
                          + (getattr(mem, "argument_size_in_bytes", 0) or 0),
        },
        "cost": {k: cost.get(k) for k in
                 ("flops", "bytes accessed", "transcendentals",
                  "bytes accessed output")} if cost else {},
        "collectives": {k: v for k, v in colls.items()
                        if k != "total_bytes"},
        "collective_bytes_total": coll_per_dev,
        "jaxpr_costs": jax_costs,
        "model_flops": model_flops,
        "roofline": rl,
    }
    return rec


# ----------------------------------------------------------------------
# Transport-coupled scale check: lower the lossy(+Hadamard) train step
# on simulated 512- and 1024-device meshes and prove the emitted program
# contains nothing but PLAIN collectives — the paper's §III-B claim that
# best-effort transport changes no compiler contract: Celeris semantics
# live entirely in elementwise masking + unbiasing around ordinary
# psum / all_gather / all_to_all.
# ----------------------------------------------------------------------

# every collective-ish StableHLO/HLO op we could possibly emit
_COLLECTIVE_OPS = (
    "all_reduce", "all_gather", "all_to_all", "reduce_scatter",
    "collective_permute", "collective_broadcast", "partition_id",
    "replica_id", "send", "recv",
)
_PLAIN_COLLECTIVES = {"all_reduce", "all_gather", "all_to_all",
                      "reduce_scatter"}


def collective_ops_in(text: str):
    """{op_name: count} over the collective ops present in lowered IR.

    Matches both spellings: StableHLO underscores (``all_reduce``, what
    ``lower().as_text()`` emits) and post-SPMD HLO hyphens
    (``all-reduce``, what ``compile().as_text()`` emits).
    """
    out = {}
    for op in _COLLECTIVE_OPS:
        pat = op.replace("_", "[-_]")
        n = len(re.findall(rf"\b(?:stablehlo\.|mhlo\.)?{pat}\b", text))
        if n:
            out[op] = n
    return out


def scale_check_cell(arch: str, n_devices: int, mode: str = "lossy_hadamard",
                     shape_name: str = "train_4k"):
    """Lower (no compile) one lossy train-step cell at ``n_devices``."""
    from repro.core.transport.coupling import CollectiveMode

    cfg = C.get(arch)
    shape = SHAPES[shape_name]
    mesh = mesh_mod.make_scale_mesh(n_devices)
    shd.set_global_mesh(mesh)
    t0 = time.time()
    state = specs.abstract_state(cfg, mesh)
    batch = specs.train_input_specs(cfg, shape, mesh)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32,
                               sharding=jax.sharding.NamedSharding(
                                   mesh, jax.sharding.PartitionSpec()))
    # hierarchical mode takes the per-pod (n_pods + 1,) drop vector
    # ([intra_pod..., cross], coupling.AxisSchedules.per_pod) so the
    # scale check lowers the per-pod mask-rate combine too; other
    # modes take the scalar
    drop_shape = ()
    if CollectiveMode.parse(mode).hierarchical:
        n_pods = mesh.shape.get(shd.POD_AXIS, 1)
        if n_pods > 1:
            drop_shape = (n_pods + 1,)
    drop = jax.ShapeDtypeStruct(drop_shape, jnp.float32,
                                sharding=jax.sharding.NamedSharding(
                                    mesh, jax.sharding.PartitionSpec()))
    step_fn = ts.make_train_step(
        cfg, mesh, adamw.OptConfig(),
        ts.CelerisConfig(mode=mode,
                         lossy_moe=(CollectiveMode.parse(mode).lossy
                                    and cfg.moe is not None)),
        donate=True)
    lowered = step_fn.lower(state, batch, key, drop)
    t_lower = time.time() - t0
    colls = collective_ops_in(lowered.as_text())
    illegal = {k: v for k, v in colls.items()
               if k not in _PLAIN_COLLECTIVES}
    rec = {
        "arch": arch, "shape": shape_name, "mode": mode,
        "n_devices": n_devices,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "dp_degree": int(mesh.devices.size) // 16,
        "lower_s": round(t_lower, 1),
        "collective_ops": colls,
        "illegal_collectives": illegal,
        "ok": not illegal and "all_reduce" in colls,
    }
    return rec


def scale_check(n_devices_list=(512, 1024), arch: str = "qwen2-0.5b",
                mode: str = "lossy_hadamard"):
    recs = []
    for n in n_devices_list:
        rec = scale_check_cell(arch, n, mode=mode)
        recs.append(rec)
        print(f"{'OK ' if rec['ok'] else 'BAD'} {arch} {mode} "
              f"n_devices={n} mesh={rec['mesh']} "
              f"lower={rec['lower_s']}s collectives={rec['collective_ops']}",
              flush=True)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"scale_check__{arch}__{mode}.json")
    with open(path, "w") as f:
        json.dump(recs, f, indent=1)
    print(f"saved -> {path}")
    if not all(r["ok"] for r in recs):
        raise SystemExit("scale check FAILED: non-plain collectives "
                         "in the lowered lossy train step")
    return recs


# ----------------------------------------------------------------------
# Serve-path dry run: lower prefill + decode on single- and multi-pod
# meshes and prove the emitted programs carry nothing but plain
# collectives — the serving analogue of --scale-check (the serve path
# never opens a shard_map island, so any exotic op here would mean the
# GSPMD specs leak manual collectives).
# ----------------------------------------------------------------------

def serve_check_cell(arch: str, shape_name: str, multi_pod: bool):
    """Lower AND compile one serve cell; census the post-SPMD HLO.

    Unlike the train island (whose collectives are explicit at trace
    time), the serve path is pure GSPMD — the partitioner inserts its
    collectives during compile, so the check must read the compiled
    module's HLO, not the lowered StableHLO.
    """
    cfg = C.get(arch)
    shape = SHAPES[shape_name]
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    shd.set_global_mesh(mesh)
    t0 = time.time()
    fn, args = _serve_fn_args(cfg, shape, mesh)
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    colls = collective_ops_in(compiled.as_text())
    # post-SPMD HLO always contains partition-id: GSPMD addresses each
    # device's shard via dynamic-slice(partition-id) — compiler-internal
    # bookkeeping, not a collective.  (The train scale-check censuses
    # pre-SPMD StableHLO, where partition_id WOULD mean a manual
    # lowering leaked; it stays strict.)
    benign = _PLAIN_COLLECTIVES | {"partition_id", "replica_id"}
    illegal = {k: v for k, v in colls.items() if k not in benign}
    return {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(mesh.devices.size),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "collective_ops": colls,
        "illegal_collectives": illegal,
        # TP (model-sharded matmuls) must reduce somewhere: a census
        # with no all_reduce/reduce_scatter at all means the specs
        # silently replicated the weights
        "ok": not illegal and any(k in colls for k in
                                  ("all_reduce", "reduce_scatter")),
    }


def serve_check(arch: str = "qwen2-0.5b",
                shapes=("prefill_32k", "decode_32k")):
    recs = []
    for multi_pod in (False, True):
        for shape_name in shapes:
            rec = serve_check_cell(arch, shape_name, multi_pod)
            recs.append(rec)
            print(f"{'OK ' if rec['ok'] else 'BAD'} {arch} {shape_name:12s} "
                  f"mesh={rec['mesh']:8s} lower={rec['lower_s']}s "
                  f"collectives={rec['collective_ops']}", flush=True)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"serve_check__{arch}.json")
    with open(path, "w") as f:
        json.dump(recs, f, indent=1)
    print(f"saved -> {path}")
    if not all(r["ok"] for r in recs):
        raise SystemExit("serve check FAILED: non-plain collectives in "
                         "the lowered serve path")
    return recs


def run_and_save(arch, shape_name, multi_pod, celeris=True,
                 quantize_wire=False):
    rec = lower_cell(arch, shape_name, multi_pod, celeris, quantize_wire)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    tag = f"{C.canonical(arch)}__{shape_name}__" \
          f"{'2x16x16' if multi_pod else '16x16'}"
    path = os.path.join(RESULTS_DIR, tag + ".json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec, path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str)
    ap.add_argument("--shape", type=str)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-celeris", action="store_true",
                    help="baseline (exact collectives) variant")
    ap.add_argument("--quantize-wire", action="store_true",
                    help="H6: int8 wire w/ s16 reduction")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--scale-check", action="store_true",
                    help="lower the lossy train step at 512 and 1024 "
                         "simulated devices; assert plain collectives only")
    ap.add_argument("--serve-check", action="store_true",
                    help="lower prefill + decode on the single- and "
                         "multi-pod production meshes; assert plain "
                         "collectives only")
    ap.add_argument("--mode", type=str, default="lossy_hadamard",
                    help="collective mode for --scale-check")
    args = ap.parse_args()

    if args.scale_check:
        scale_check(arch=args.arch or "qwen2-0.5b", mode=args.mode)
        return

    if args.serve_check:
        serve_check(arch=args.arch or "qwen2-0.5b")
        return

    if args.all:
        cells = []
        for arch in C.ARCHS:
            cfg = C.get(arch)
            for shape_name in C.runnable_shapes(cfg):
                for mp in (False, True):
                    cells.append((arch, shape_name, mp))
        failures = 0
        for arch, shape_name, mp in cells:
            try:
                rec, _ = run_and_save(arch, shape_name, mp,
                                      celeris=not args.no_celeris)
                mm = rec["memory"]["peak_bytes"]
                print(f"OK  {arch:24s} {shape_name:12s} "
                      f"{'2x16x16' if mp else '16x16':8s} "
                      f"compile={rec['compile_s']:7.1f}s "
                      f"peak/dev={mm/2**30:6.2f}GiB "
                      f"coll={rec['collective_bytes_total']/2**20:8.1f}MiB",
                      flush=True)
            except Exception as e:  # noqa: BLE001
                failures += 1
                print(f"FAIL {arch} {shape_name} mp={mp}: "
                      f"{type(e).__name__}: {e}", flush=True)
        sys.exit(1 if failures else 0)

    rec, path = run_and_save(args.arch, args.shape, args.multi_pod,
                             celeris=not args.no_celeris,
                             quantize_wire=args.quantize_wire)
    print(json.dumps(rec, indent=1))
    print(f"saved -> {path}")


if __name__ == "__main__":
    main()
