"""Public jit'd wrappers around the Pallas kernels.

Shape-polymorphic entry points: callers pass any (rows, n) with n a
power of two; padding to kernel tile multiples happens here.  On this
CPU container the kernels execute in interpret mode (the kernel body
runs in Python op-by-op); on TPU set ``REPRO_PALLAS_INTERPRET=0`` to
compile for the MXU.  ``use_pallas=False`` routes to the pure-jnp oracle
(used by the dry-run lowering, where interpret-mode callbacks cannot be
staged for a TPU mesh).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import fwht as _fwht
from repro.kernels import quantize as _quant
from repro.kernels import ref
from repro.kernels import unbias as _unbias

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _pad_rows(x: jax.Array, mult: int) -> tuple[jax.Array, int]:
    rows = x.shape[0]
    pad = (-rows) % mult
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x, rows


def fwht(x: jax.Array, *, signs: jax.Array | None = None, scale: float = 1.0,
         use_pallas: bool = True, block_rows: int = 128) -> jax.Array:
    """FWHT along the last axis of a 2-D array (unnormalized by default).

    ``signs`` (n,) and ``scale`` fuse the Rademacher pre-multiply and
    the normalization into the kernel (the Pallas path keeps them in
    VMEM / folds the scale into a Hadamard factor); the jnp-oracle path
    applies them unfused with identical semantics.
    """
    if not use_pallas:
        out = ref.fwht(x if signs is None else x * signs[None, :])
        return out if scale == 1.0 else out * scale
    rows, n = x.shape
    block_rows = min(block_rows, max(8, rows))
    xp, rows0 = _pad_rows(x, block_rows)
    out = _fwht.fwht_pallas(xp, signs, scale=scale, block_rows=block_rows,
                            interpret=INTERPRET)
    return out[:rows0]


def fwht_quantize(x: jax.Array, noise: jax.Array, *,
                  signs: jax.Array | None = None, scale: float = 1.0,
                  use_pallas: bool = True, block_rows: int = 128):
    """Fused rotate-then-quantize: the FWHT output feeds the per-row
    absmax int8 quantizer without a round trip through HBM (what
    ``coding.encode_quantized`` issues).  Semantically identical to
    ``quantize_int8(fwht(x, signs=..., scale=...), noise)``.
    """
    if not use_pallas:
        y = ref.fwht(x if signs is None else x * signs[None, :])
        if scale != 1.0:
            y = y * scale
        return ref.quantize_int8(y, noise)
    rows, n = x.shape
    block_rows = min(block_rows, max(8, rows))
    xp, rows0 = _pad_rows(x, block_rows)
    np_, _ = _pad_rows(noise, block_rows)
    q, s = _fwht.fwht_quantize_pallas(xp, np_, signs, scale=scale,
                                      block_rows=block_rows,
                                      interpret=INTERPRET)
    return q[:rows0], s[:rows0]


def quantize_int8(x: jax.Array, noise: jax.Array, *, use_pallas: bool = True,
                  block_rows: int = 256):
    if not use_pallas:
        return ref.quantize_int8(x, noise)
    rows, n = x.shape
    block_rows = min(block_rows, max(8, rows))
    xp, rows0 = _pad_rows(x, block_rows)
    np_, _ = _pad_rows(noise, block_rows)
    q, scale = _quant.quantize_int8_pallas(xp, np_, block_rows=block_rows,
                                           interpret=INTERPRET)
    return q[:rows0], scale[:rows0]


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return ref.dequantize_int8(q, scale)


def masked_unbias(y_sum: jax.Array, counts: jax.Array, total: int, *,
                  use_pallas: bool = True, block_rows: int = 256) -> jax.Array:
    if not use_pallas:
        return ref.masked_unbias(y_sum, counts, total)
    rows, n = y_sum.shape
    block_rows = min(block_rows, max(8, rows))
    yp, rows0 = _pad_rows(y_sum, block_rows)
    cp, _ = _pad_rows(counts, block_rows)
    out = _unbias.masked_unbias_pallas(yp, cp, total=total,
                                       block_rows=block_rows, interpret=INTERPRET)
    return out[:rows0]
