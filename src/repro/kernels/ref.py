"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def hadamard_matrix(n: int, dtype=jnp.float32) -> jax.Array:
    """Sylvester Hadamard matrix H_n (unnormalized, entries +-1)."""
    assert _is_pow2(n), n
    h = jnp.ones((1, 1), dtype=dtype)
    while h.shape[0] < n:
        h = jnp.block([[h, h], [h, -h]])
    return h


def fwht(x: jax.Array) -> jax.Array:
    """Unnormalized fast Walsh-Hadamard transform along the last axis.

    Equivalent to ``x @ hadamard_matrix(n)`` (H is symmetric).
    """
    n = x.shape[-1]
    assert _is_pow2(n), n
    orig_shape = x.shape
    x = x.reshape(-1, n)
    m = 1
    while m < n:
        x = x.reshape(-1, n // (2 * m), 2, m)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2).reshape(-1, n)
        m *= 2
    return x.reshape(orig_shape)


def quantize_int8(x: jax.Array, noise: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-row absmax int8 stochastic quantization.

    ``noise`` is uniform[0,1) with the same shape as ``x`` (supplied by the
    caller so that the kernel and the oracle consume identical bits).
    Returns (q_int8, scale_per_row).
    """
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    scaled = x / scale
    q = jnp.floor(scaled + noise)              # stochastic rounding
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q, scale[..., 0]


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None]


def masked_unbias(y_sum: jax.Array, counts: jax.Array, total: int) -> jax.Array:
    """Decode-side unbiasing: scale received sums by total/count (0 where none).

    ``y_sum``  (rows, n): summed received contributions.
    ``counts`` (rows,) or (rows, n): how many contributions arrived.
    """
    if counts.ndim == y_sum.ndim - 1:
        counts = counts[..., None]
    safe = jnp.maximum(counts, 1)
    return jnp.where(counts > 0, y_sum * (total / safe), 0.0)
