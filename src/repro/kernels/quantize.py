"""Pallas TPU kernel: per-row absmax int8 stochastic quantization.

Gradient compression stage (beyond-paper distributed-optimization trick;
composes with the Hadamard rotation a la QSGD).  Elementwise + row
reduction, so the kernel is memory-bound by design: one HBM read of the
f32 tile, one int8 write, one small scale write - a 4x traffic cut on
the collective payload.

Uniform[0,1) rounding noise is passed in as an operand (generated with
jax.random outside) so that oracle and kernel consume identical bits and
the kernel needs no TPU PRNG primitives (keeps interpret-mode parity).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, noise_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.floor(x / scale + noise_ref[...].astype(jnp.float32))
    q_ref[...] = jnp.clip(q, -127, 127).astype(jnp.int8)
    scale_ref[...] = scale[:, 0]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize_int8_pallas(x: jax.Array, noise: jax.Array, *,
                         block_rows: int = 256,
                         interpret: bool = True):
    rows, n = x.shape
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, n), jnp.int8),
            jax.ShapeDtypeStruct((rows,), jnp.float32),
        ],
        interpret=interpret,
    )(x, noise)
