"""Pallas TPU kernel: blocked fast Walsh-Hadamard transform.

TPU-native design (this is the HW adaptation of the paper's Hadamard
recovery, which OptiReduce runs on GPU with CUDA butterflies):

- The Sylvester Hadamard matrix factors as a Kronecker product,
  ``H_n = H_a (x) H_b`` with ``n = a*b``.  Reshaping each length-``n``
  row to ``(a, b)``, the transform becomes **two dense matmuls**::

      Y = H_a @ X @ H_b

  Both land on the MXU (128x128 systolic array) instead of log2(n)
  strided butterfly passes, which would be VPU-bound and HBM-unfriendly.
  For the default n=4096 tile: a = b = 64, so the per-row cost is two
  64x64 matmuls - arithmetic intensity ~64 FLOPs/byte, comfortably
  compute-bound on the MXU.

- Grid tiles rows; each kernel instance holds a ``(block_rows, n)`` tile
  plus the two (a,a)/(b,b) Hadamard factors in VMEM.  With the default
  ``block_rows=128`` and n=4096 (f32) the working set is
  128*4096*4 * 2 (in+out) + small factors ~= 4.2 MB << 16 MB VMEM.

All matmul dims are multiples of (8,128) sublane/lane tiling for f32 as
long as n >= 128 and block_rows % 8 == 0 (enforced by ops.py padding).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref


def _kron_factors(n: int) -> tuple[int, int]:
    """Split n = a*b with a, b as close as possible (both pow2)."""
    log = n.bit_length() - 1
    la = (log + 1) // 2
    return 1 << la, 1 << (log - la)


def _fwht_kernel(x_ref, ha_ref, hb_ref, *rest, a: int, b: int):
    (o_ref,) = rest[-1:]
    signs_ref = rest[0] if len(rest) == 2 else None
    rows = x_ref.shape[0]
    x = x_ref[...].astype(jnp.float32).reshape(rows, a, b)
    if signs_ref is not None:
        # fused Rademacher pre-multiply: one VPU op on the VMEM-resident
        # tile instead of a separate HBM round-trip before the transform
        x = x * signs_ref[...].reshape(a, b)[None]
    ha = ha_ref[...]
    hb = hb_ref[...]
    # t[r,k,j] = sum_l x[r,k,l] * hb[l,j]   (contract over l)
    t = jax.lax.dot_general(
        x, hb, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    # y[r,i,j] = sum_k ha[i,k] * t[r,k,j]   (contract over k)
    y = jax.lax.dot_general(
        t, ha, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    # dot_general output order is (r, j, i) -> transpose back to (r, i, j)
    y = jnp.swapaxes(y, 1, 2)
    o_ref[...] = y.reshape(rows, a * b).astype(o_ref.dtype)


def _fwht_quant_kernel(x_ref, ha_ref, hb_ref, *rest, a: int, b: int):
    q_ref, scale_ref = rest[-2:]
    if len(rest) == 4:
        signs_ref, noise_ref = rest[0], rest[1]
    else:
        signs_ref, noise_ref = None, rest[0]
    rows = x_ref.shape[0]
    x = x_ref[...].astype(jnp.float32).reshape(rows, a, b)
    if signs_ref is not None:
        x = x * signs_ref[...].reshape(a, b)[None]
    ha = ha_ref[...]
    hb = hb_ref[...]
    t = jax.lax.dot_general(
        x, hb, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(
        t, ha, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y = jnp.swapaxes(y, 1, 2).reshape(rows, a * b)
    # quantize while the rotated tile is still in VMEM: the unfused
    # pair writes the f32 rotation to HBM and reads it straight back —
    # this kernel's whole point is skipping that round trip, leaving
    # one f32 read (input) + one int8 write (output) per element
    absmax = jnp.max(jnp.abs(y), axis=-1, keepdims=True)
    qscale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.floor(y / qscale + noise_ref[...].astype(jnp.float32))
    q_ref[...] = jnp.clip(q, -127, 127).astype(jnp.int8)
    scale_ref[...] = qscale[:, 0]


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret", "scale"))
def fwht_quantize_pallas(x: jax.Array, noise: jax.Array,
                         signs: jax.Array | None = None, *,
                         scale: float = 1.0, block_rows: int = 128,
                         interpret: bool = True):
    """Fused FWHT + per-row absmax int8 quantization in one pass.

    The rotate stage is exactly :func:`fwht_pallas` (same two-matmul
    Kronecker body, same optional Rademacher/scale fusions); its VMEM
    tile feeds the :mod:`quantize` stage directly.  Returns
    ``(q int8 (rows, n), scale f32 (rows,))`` — the wire payload of
    ``coding.encode_quantized``.
    """
    rows, n = x.shape
    assert rows % block_rows == 0, (rows, block_rows)
    a, b = _kron_factors(n)
    ha = ref.hadamard_matrix(a) * jnp.float32(scale)
    hb = ref.hadamard_matrix(b)
    grid = (rows // block_rows,)
    in_specs = [
        pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        pl.BlockSpec((a, a), lambda i: (0, 0)),
        pl.BlockSpec((b, b), lambda i: (0, 0)),
    ]
    operands = [x, ha, hb]
    if signs is not None:
        in_specs.append(pl.BlockSpec((1, n), lambda i: (0, 0)))
        operands.append(signs.reshape(1, n).astype(jnp.float32))
    in_specs.append(pl.BlockSpec((block_rows, n), lambda i: (i, 0)))
    operands.append(noise)
    return pl.pallas_call(
        functools.partial(_fwht_quant_kernel, a=a, b=b),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, n), jnp.int8),
            jax.ShapeDtypeStruct((rows,), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret", "scale"))
def fwht_pallas(x: jax.Array, signs: jax.Array | None = None, *,
                scale: float = 1.0, block_rows: int = 128,
                interpret: bool = True) -> jax.Array:
    """FWHT along the last axis of a 2-D array via pallas_call.

    ``x`` must be (rows, n) with n a power of two >= 2 and rows a
    multiple of ``block_rows`` (ops.py handles padding).

    Optional fusions (used by ``coding.encode``, which otherwise pays
    two extra full HBM round-trips per call):

    - ``signs`` (n,): Rademacher diagonal multiplied into the input tile
      in VMEM before the transform;
    - ``scale``: static scalar folded into the left Hadamard factor
      (entries become ±scale), so the normalization costs zero extra
      FLOPs on the MXU path.
    """
    rows, n = x.shape
    assert rows % block_rows == 0, (rows, block_rows)
    a, b = _kron_factors(n)
    ha = ref.hadamard_matrix(a) * jnp.float32(scale)
    hb = ref.hadamard_matrix(b)
    grid = (rows // block_rows,)
    in_specs = [
        pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        pl.BlockSpec((a, a), lambda i: (0, 0)),
        pl.BlockSpec((b, b), lambda i: (0, 0)),
    ]
    operands = [x, ha, hb]
    if signs is not None:
        in_specs.append(pl.BlockSpec((1, n), lambda i: (0, 0)))
        operands.append(signs.reshape(1, n).astype(jnp.float32))
    return pl.pallas_call(
        functools.partial(_fwht_kernel, a=a, b=b),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), x.dtype),
        interpret=interpret,
    )(*operands)
