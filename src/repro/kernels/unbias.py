"""Pallas TPU kernel: fused decode-side unbiasing.

After a lossy collective, each rotation-block row has a received-count;
the unbiased estimate scales the summed contributions by total/count.
Fusing the scale with the (count>0) select avoids an extra HBM round
trip over the gradient buffer between the collective and the inverse
Hadamard pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unbias_kernel(y_ref, c_ref, o_ref, *, total: int):
    y = y_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)[:, None]
    safe = jnp.maximum(c, 1.0)
    o_ref[...] = jnp.where(c > 0, y * (total / safe), 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("total", "block_rows", "interpret"))
def masked_unbias_pallas(y_sum: jax.Array, counts: jax.Array, *, total: int,
                         block_rows: int = 256, interpret: bool = True) -> jax.Array:
    rows, n = y_sum.shape
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_unbias_kernel, total=total),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), y_sum.dtype),
        interpret=interpret,
    )(y_sum, counts)
