"""Deterministic synthetic data pipeline (shardable, restart-safe).

Two sources:

- :class:`MarkovLM` — a fixed random bigram/trigram process with
  Zipf-distributed marginals.  It has real learnable structure (a model
  that learns the transition table drops loss well below the unigram
  entropy), which is what the Fig.-1 loss-tolerance benchmark needs.
- :class:`UniformTokens` — i.i.d. tokens for shape/throughput tests.

Determinism/sharding contract: batch ``step`` on shard ``(i of n)`` is a
pure function of (seed, step, i, n) — any node can regenerate any shard
after a restart (no data-state checkpointing needed), and the global
batch is identical regardless of topology (elastic re-sharding safe).
Batches are laid out host-side as numpy; the trainer device_puts them
with the right sharding (prefetch happens on a background thread in the
Trainer).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "markov"     # "markov" | "uniform"
    branching: int = 16      # candidate successors per token (markov)


class MarkovLM:
    """Fixed sparse bigram process with Zipfian stationary bias."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v, b = cfg.vocab_size, cfg.branching
        # successors per token + their (unnormalized Zipf) weights
        self.succ = rng.integers(0, v, size=(v, b))
        w = 1.0 / np.arange(1, b + 1) ** 1.2
        self.probs = (w / w.sum()).astype(np.float64)

    def bigram_entropy(self) -> float:
        return float(-(self.probs * np.log(self.probs)).sum())

    def _gen(self, rng: np.random.Generator, batch: int) -> np.ndarray:
        v, s = self.cfg.vocab_size, self.cfg.seq_len
        out = np.empty((batch, s), dtype=np.int32)
        out[:, 0] = rng.integers(0, v, size=batch)
        for t in range(1, s):
            pick = rng.choice(self.cfg.branching, size=batch, p=self.probs)
            out[:, t] = self.succ[out[:, t - 1], pick]
        return out

    def shard_batch(self, step: int, shard: int, n_shards: int) -> dict:
        assert self.cfg.global_batch % n_shards == 0
        rng = np.random.default_rng(
            (self.cfg.seed, step, shard, n_shards))
        toks = self._gen(rng, self.cfg.global_batch // n_shards)
        return {"tokens": toks, "labels": toks}

    def global_batch(self, step: int, n_shards: int = 1) -> dict:
        shards = [self.shard_batch(step, i, n_shards) for i in range(n_shards)]
        return {k: np.concatenate([s[k] for s in shards])
                for k in shards[0]}


class UniformTokens:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def shard_batch(self, step: int, shard: int, n_shards: int) -> dict:
        rng = np.random.default_rng((self.cfg.seed, step, shard, n_shards))
        toks = rng.integers(0, self.cfg.vocab_size,
                            size=(self.cfg.global_batch // n_shards,
                                  self.cfg.seq_len), dtype=np.int32)
        return {"tokens": toks, "labels": toks}

    def global_batch(self, step: int, n_shards: int = 1) -> dict:
        shards = [self.shard_batch(step, i, n_shards) for i in range(n_shards)]
        return {k: np.concatenate([s[k] for s in shards])
                for k in shards[0]}


def make_source(cfg: DataConfig):
    return MarkovLM(cfg) if cfg.kind == "markov" else UniformTokens(cfg)
