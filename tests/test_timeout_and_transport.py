"""Timeout controller + transport simulator behavior (paper §III-B, §IV)."""
try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:                     # container lacks hypothesis
    from _propcheck import hypothesis, st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import timeout as tmod
from repro.core.transport import (CollectiveSimulator, SimParams,
                                  NetworkParams)
from repro.core.transport.network import ClosFabric
from repro.core.transport import dcqcn
from repro.core.transport.params import DcqcnParams


# ---------------------------------------------------------------- timeout

@hypothesis.given(st.floats(1e-3, 5.0), st.floats(0.01, 1.0))
@hypothesis.settings(max_examples=50, deadline=None)
def test_timeout_always_in_bounds(duration, frac):
    cfg = tmod.TimeoutConfig()
    c = tmod.TimeoutController(cfg)
    for _ in range(5):
        to = c.update(duration, frac)
        assert cfg.min_timeout <= to <= cfg.max_timeout


def test_timeout_tracks_full_delivery():
    c = tmod.TimeoutController(tmod.TimeoutConfig(init_timeout=1.0))
    for _ in range(200):
        c.update(0.2, 1.0)
    assert abs(c.timeout - 0.2) < 0.01      # converges to observed duration


def test_timeout_grows_under_partial_delivery():
    cfg = tmod.TimeoutConfig(init_timeout=0.1, max_timeout=10.0)
    c = tmod.TimeoutController(cfg)
    before = c.timeout
    for _ in range(50):
        c.update(0.1, 0.5)                  # only half the data arrives
    assert c.timeout > before * 1.5         # extrapolates toward full


def test_jax_controller_matches_host():
    cfg = tmod.TimeoutConfig()
    host = tmod.TimeoutController(cfg)
    state = tmod.init_jax(cfg)
    for i, (d, f) in enumerate([(0.3, 1.0), (0.5, 0.8), (0.2, 0.99),
                                (1.0, 0.4)]):
        host.update(d, f)
        state = tmod.update_jax(state, jnp.float32(d), jnp.float32(f), cfg)
        np.testing.assert_allclose(float(state[0]), host.timeout, rtol=1e-5)


def test_median_coordination_robust_to_stragglers():
    tos = [0.1] * 9 + [50.0]                # one node went crazy
    assert tmod.coordinate(tos) == pytest.approx(0.1)


# -------------------------------------------------------------- transport

@pytest.fixture(scope="module")
def small_sim():
    # 32-node downscale: raise the per-ToR burst rate so bursts/round
    # match the 128-node default (fewer ToRs x fewer ring steps)
    return CollectiveSimulator(SimParams(net=NetworkParams(
        n_nodes=32, burst_on_prob=0.0008)))


def test_fig2_tail_reduction(small_sim):
    """Core paper claim at reduced scale: Celeris cuts p99 >= 1.5x with
    <2% loss and preserved median."""
    stats = small_sim.paper_protocol(n_rounds=150, seed=0)
    roce, cel = stats["roce"], stats["celeris"]
    assert roce.p99 / roce.p50 > 2.0        # baseline has a real tail
    assert roce.p99 / cel.p99 > 1.5         # Celeris cuts it
    # <1% loss is a 128-node property (benchmarks/fig2); at 32 nodes
    # the same burst duration covers a larger round fraction -> more loss
    assert cel.mean_loss < 0.06
    assert 0.9 < cel.p50 / roce.p50 < 1.1   # median preserved


def test_reliable_designs_lose_nothing(small_sim):
    for d in ("roce", "irn", "srnic"):
        st_ = small_sim.run(d, 30, seed=1)
        assert st_.mean_loss == 0.0


def test_celeris_step_window_flattens_tail(small_sim):
    base = small_sim.run("roce", 120, seed=2)
    cel = small_sim.run("celeris", 120, adaptive=True, window="step", seed=2)
    assert cel.p99 / cel.p50 < base.p99 / base.p50
    assert cel.mean_loss < 0.01


def test_fabric_occupancy_bounded():
    net = NetworkParams(n_nodes=32)
    fab = ClosFabric(net, seed=0)
    for _ in range(500):
        fab.advance()
        assert np.all(fab.state.occupancy >= 0)
        assert np.all(fab.state.occupancy <= 1.0)


def test_dcqcn_rate_dynamics():
    p = DcqcnParams()
    st_ = dcqcn.DcqcnState.init(8)
    # sustained congestion cuts rates
    for _ in range(20):
        st_ = dcqcn.step(st_, np.ones(8, bool), p)
    assert np.all(st_.rate < 1.0)
    low = st_.rate.copy()
    # calm period recovers
    for _ in range(100):
        st_ = dcqcn.step(st_, np.zeros(8, bool), p)
    assert np.all(st_.rate > low)
    assert np.all(st_.rate <= 1.0)
