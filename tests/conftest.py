import os
# Smoke tests and benches must see 1 device (the dry-run sets its own
# 512-device flag in its own process) — never set device-count here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
