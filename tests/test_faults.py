"""Seeded NIC fault injection (ISSUE 6).

- zero-fault guard: the default (all-rates-zero) ``FaultParams``
  constructs no fault generators and the engine reproduces the
  committed ring-schedule seed stats bit-exactly — the fault path can
  never perturb existing figures;
- seeded determinism: the same (seed, FaultParams) produces identical
  faulted traces, different seeds differ, and every design in one
  trace pass sees the same fault trace;
- monotone coupling: raising a fault rate with the seed held fixed
  only *adds* fault events (the substream's uniforms are compared to a
  larger threshold), so delivered fractions fall monotonically;
- blast radius: a dead rail 0 kills the whole leader DCI exchange
  under ``hier`` (leaders are rank 0) but only 1/m of the rails under
  ``perrail``;
- end-to-end: a fault targeted at pod 0's nodes raises pod 0's drop
  rate in ``split_schedule_from_engine(fault=...)``, and the
  (n_pods+1,) vector reaches the gradients through the hierarchical
  train step on an 8-device mesh.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.transport import (BatchedEngine, FaultParams, NetworkParams,
                                  SimParams, coupling, sweep, topology)
from repro.core.transport.engine import BatchedSimParams

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SMALL = SimParams(net=NetworkParams(n_nodes=32, burst_on_prob=0.0008))


def _stats(p, design="celeris", n_rounds=40, seed=11, timeout_us=None,
           designs=("roce", "celeris")):
    eng = BatchedEngine(p)
    tr = eng.traces(list(designs), n_rounds, seed, legacy_streams=False)
    if design == "celeris":
        if timeout_us is None:
            base = eng.assemble(tr["roce"], seed)
            timeout_us = float(np.percentile(base.times_us, 50)
                               + base.times_us.std())
        return eng.assemble(tr[design], seed, celeris_timeout_us=timeout_us,
                            adaptive=False)
    return eng.assemble(tr[design], seed)


# ---------------------------------------------------- zero-fault guard

def test_zero_fault_bitexact_vs_committed_seed_stats():
    """Default FaultParams (explicit or implicit) leaves the engine
    bit-identical to the committed pre-fault seed stats."""
    with open(os.path.join(REPO, "tests", "data",
                           "ring_schedule_seed_stats.json")) as f:
        ref = json.load(f)["flat"]
    for p in (SMALL, dataclasses.replace(SMALL, fault=FaultParams())):
        eng = BatchedEngine(p)
        tr = eng.traces(["roce", "celeris"], 40, 11, legacy_streams=False)
        base = eng.assemble(tr["roce"], 11)
        to = float((np.percentile(base.times_us, 50)
                    + base.times_us.std()) * 0.8)
        cel = eng.assemble(tr["celeris"], 11, celeris_timeout_us=to,
                           adaptive=False)
        assert np.array_equal(base.times_us, ref["roce_times_us"])
        assert np.array_equal(cel.times_us, ref["celeris_times_us"])
        assert np.array_equal(cel.recv_frac, ref["celeris_recv_frac"])
        assert to == pytest.approx(ref["celeris_timeout_us"])
        # no fault accounting is materialized on the clean path
        assert cel.fault_steps is None
        assert not cel.faulted.any()
        assert cel.goodput_under_failure == 1.0
        assert cel.recovery_rounds() == 0.0


def test_fault_params_validation_and_parse():
    assert not FaultParams().active
    assert FaultParams().tag == "none"
    fp = FaultParams.parse("stall:0.001+flap:0.0005")
    assert fp.stall_rate == pytest.approx(0.001)
    assert fp.flap_rate == pytest.approx(0.0005)
    assert fp.active and fp.tag == "stall:0.001+flap:0.0005"
    assert FaultParams.parse(fp) is fp
    assert FaultParams.of_kind("rail", 0.3).rail_fail_rate == 0.3
    assert FaultParams.of_kind("straggler", 0.25).straggler_frac == 0.25
    with pytest.raises(ValueError):
        FaultParams.of_kind("meteor", 0.1)
    with pytest.raises(ValueError):
        FaultParams(stall_rate=1.5)
    with pytest.raises(ValueError):
        FaultParams(stall_steps=0)
    with pytest.raises(ValueError):
        FaultParams(straggler_slowdown=0.5)


def test_faults_require_shared_streams():
    p = dataclasses.replace(SMALL,
                            fault=FaultParams.of_kind("stall", 1e-3))
    eng = BatchedEngine(p)
    with pytest.raises(ValueError, match="legacy_streams"):
        eng.traces(["roce"], 5, 0, legacy_streams=True)
    # run() auto-switches instead of raising
    st = eng.run("roce", 5, seed=0)
    assert st.times_us.shape == (5,)
    # and the sweep layer rejects the combination outright
    with pytest.raises(ValueError, match="fault"):
        sweep(BatchedSimParams(n_nodes=(32,), seeds=(0,), n_rounds=2,
                               legacy_streams=True, faults=("stall:1e-3",),
                               base=SMALL))


# ------------------------------------------------- seeded determinism

def test_seeded_fault_determinism_and_shared_fault_trace():
    fp = FaultParams.of_kind("stall", 2e-3)
    p = dataclasses.replace(SMALL, fault=fp)
    a = _stats(p, "celeris", seed=11, timeout_us=9000.0)
    b = _stats(p, "celeris", seed=11, timeout_us=9000.0)
    assert np.array_equal(a.times_us, b.times_us)
    assert np.array_equal(a.recv_frac, b.recv_frac)
    assert np.array_equal(a.fault_steps, b.fault_steps)
    assert np.array_equal(a.affected_flows, b.affected_flows)
    assert a.fault_steps.sum() > 0
    c = _stats(p, "celeris", seed=12, timeout_us=9000.0)
    assert not np.array_equal(a.fault_steps, c.fault_steps)
    # every design in one pass rides the same fault trace
    eng = BatchedEngine(p)
    tr = eng.traces(["roce", "irn", "celeris"], 20, 11,
                    legacy_streams=False)
    roce = eng.assemble(tr["roce"], 11)
    irn = eng.assemble(tr["irn"], 11)
    assert np.array_equal(roce.fault_steps, irn.fault_steps)
    assert np.array_equal(roce.affected_flows, irn.affected_flows)


def test_design_reactions_differ():
    """Reliable designs pay retransmission time for the same fault
    trace on which Celeris cuts data: RoCE's times grow, its delivery
    stays full; Celeris's times hold, its delivery drops."""
    fp = FaultParams.of_kind("stall", 2e-3)
    p = dataclasses.replace(SMALL, fault=fp)
    clean_roce = _stats(SMALL, "roce", seed=11)
    roce = _stats(p, "roce", seed=11)
    f = roce.faulted
    assert f.any()
    assert (roce.times_us[f] > clean_roce.times_us[f]).all()
    assert roce.recv_frac.min() == 1.0
    to = float(np.percentile(clean_roce.times_us, 50)
               + clean_roce.times_us.std())
    clean_cel = _stats(SMALL, "celeris", seed=11, timeout_us=to)
    cel = _stats(p, "celeris", seed=11, timeout_us=to)
    assert cel.p99 <= clean_cel.p99 + 1e-9      # bounded window holds
    assert cel.recv_frac[f].mean() < clean_cel.recv_frac[f].mean()


# ------------------------------------------------ monotone fault rate

def test_goodput_monotone_in_stall_rate():
    """Same seed, rising stall rate: fault events are supersets (the
    substream's uniforms cross a larger threshold), so Celeris delivers
    monotonically less data."""
    recv = []
    for rate in (0.0, 1e-3, 4e-3, 1.6e-2):
        fp = FaultParams(stall_rate=rate)
        p = dataclasses.replace(SMALL, fault=fp)
        recv.append(_stats(p, "celeris", seed=11,
                           timeout_us=9000.0).recv_frac.mean())
    assert all(a >= b - 1e-12 for a, b in zip(recv, recv[1:])), recv
    assert recv[-1] < recv[0]


def test_straggler_slows_reliable_designs():
    fp = FaultParams(straggler_frac=0.25, straggler_slowdown=4.0)
    p = dataclasses.replace(SMALL, fault=fp)
    clean = _stats(SMALL, "roce", seed=11)
    slow = _stats(p, "roce", seed=11)
    assert slow.times_us.mean() > clean.times_us.mean()
    # static rate scaling marks no discrete fault events
    assert not slow.faulted.any()


def test_crash_restart_bounds_outage():
    """A permanent crash (restart=0) degrades every later round; with
    a restart the degradation is transient."""
    base = dataclasses.replace(SMALL, fault=FaultParams(crash_rate=2e-4))
    perm = _stats(base, "celeris", seed=11, timeout_us=9000.0)
    rest = _stats(dataclasses.replace(
        SMALL, fault=FaultParams(crash_rate=2e-4, crash_restart_steps=8)),
        "celeris", seed=11, timeout_us=9000.0)
    assert perm.faulted.sum() >= rest.faulted.sum()
    assert perm.recv_frac.mean() <= rest.recv_frac.mean() + 1e-12
    assert perm.faulted.any()


# ----------------------------------------------------- rail failures

def test_rail_blast_radius_smaller_under_perrail():
    """rail 0 permanently down: under hier every leader (rank 0) rides
    rail 0 and the whole DCI exchange dies; under perrail only 1/m of
    the rails do."""
    fp = FaultParams(rail_fail_rate=1.0, rail=0)
    loss = {}
    for sched in ("hier", "perrail"):
        p = topology.hier_params(2, base=SMALL, schedule=sched, fault=fp)
        loss[sched] = _stats(p, "celeris", seed=11,
                             timeout_us=60000.0).tier_loss("dci")
    assert loss["hier"] > 0.9                    # leader phase dead
    m = 16                                       # 32 nodes / 2 pods
    assert loss["perrail"] < loss["hier"] / 3
    assert loss["perrail"] >= 1.0 / m - 1e-9


def test_rail_affects_only_cross_tier():
    fp = FaultParams(rail_fail_rate=1.0, rail=0)
    p = topology.hier_params(2, base=SMALL, schedule="hier", fault=fp)
    st = _stats(p, "celeris", seed=11, timeout_us=60000.0)
    sched = coupling.split_schedule_from_round_stats(st)
    assert sched.cross.mean > 0.4                # clamped at MAX_DROP
    assert sched.intra.mean < 0.2


# ------------------------------------------------------- sweep keys

def test_sweep_fault_dimension_keys_and_clean_match():
    bp = BatchedSimParams(
        n_nodes=(32,), seeds=(11,), n_rounds=10,
        designs=("roce", "celeris"), celeris_timeout_us=9000.0,
        legacy_streams=False, base=SMALL,
        faults=(None, "stall:4e-3"))
    res = sweep(bp)
    k_clean = ("roce", 32, 25.0, 11, "none")
    k_fault = ("roce", 32, 25.0, 11, "stall:0.004")
    assert k_clean in res.stats and k_fault in res.stats
    # the clean cell matches a fault-free sweep bit-exactly
    ref = sweep(dataclasses.replace(bp, faults=(None,)))
    assert np.array_equal(res.stats[k_clean].times_us,
                          ref.stats[("roce", 32, 25.0, 11)].times_us)
    assert (res.stats[k_fault].times_us
            >= res.stats[k_clean].times_us - 1e-9).all()


# --------------------------------------------------- end-to-end (8dev)

def _run(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_faulted_pod_drop_mask_reaches_gradients_8dev():
    """Stalls targeted at pod 0's nodes -> engine -> axis-split
    schedule: pod 0's drop rate exceeds pod 1's, and the (n_pods+1,)
    vector drives the hierarchical train step's arrival masks — the
    faulted pod's mask reaches the gradients and the realized received
    fraction drops accordingly."""
    _run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        import repro.configs as C
        from repro import sharding as shd
        from repro.core.transport import (FaultParams, NetworkParams,
                                          SimParams, coupling)
        from repro.data.pipeline import DataConfig, make_source
        from repro.optim.adamw import OptConfig
        from repro.train import train_step as ts, sharding_rules as rules

        SMALL = SimParams(net=NetworkParams(n_nodes=32,
                                            burst_on_prob=0.0008))
        fp = FaultParams(stall_rate=6e-3, stall_steps=8,
                         target_nodes=tuple(range(16)))   # pod 0 only
        sched = coupling.split_schedule_from_engine(
            24, seed=11, params=SMALL, n_pods=2, n_nodes=32,
            timeout_scale=0.8, fault=fp)
        pp = sched.per_pod
        assert pp is not None and len(pp) == 2
        assert 'fault=stall:0.006' in sched.source
        r0 = pp[0].mean + sched.cross.mean
        r1 = pp[1].mean + sched.cross.mean
        assert pp[0].mean > pp[1].mean + 0.01, (pp[0].mean, pp[1].mean)

        mesh = shd.make_mesh((2, 4), ('pod', 'data'))
        shd.set_global_mesh(mesh)
        cfg = C.get_smoke('qwen2-0.5b')
        src = make_source(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                     global_batch=8, seed=1))
        host = src.global_batch(0, 8)
        sp = rules.batch_specs(mesh, host)
        batch = {k: jax.device_put(
                     v, jax.sharding.NamedSharding(mesh, sp[k]))
                 for k, v in host.items()}
        fn = ts.make_train_step(cfg, mesh, OptConfig(lr=1e-3),
                                ts.CelerisConfig(mode='hierarchical',
                                                 min_coded_size=1024))
        st = ts.init_state(jax.random.PRNGKey(0), cfg)
        st = jax.device_put(st, ts.state_shardings(st, mesh))
        dr = jnp.asarray(np.concatenate([
            [p.mean for p in pp], [sched.cross.mean]]), jnp.float32)
        st, m = fn(st, batch, jax.random.PRNGKey(1), dr)
        frac = float(m['recv_frac'])
        comb = [min(1 - (1 - p.mean) * (1 - sched.cross.mean), 0.5)
                for p in pp]
        want = 1.0 - sum(comb) / len(comb)
        assert abs(frac - want) < 0.06, (frac, want)
        assert frac < 1.0
        assert np.isfinite(float(m['loss']))
        print('OK')
    """)
