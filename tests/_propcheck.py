"""Minimal stand-in for `hypothesis` on containers where it isn't installed.

The real library is used when available (import these names via::

    try:
        import hypothesis
        import hypothesis.strategies as st
    except ImportError:
        from _propcheck import hypothesis, st

); otherwise this module provides a deterministic mini property-runner with
the same decorator surface (``given`` / ``settings``) and the few strategies
the test-suite uses (``integers``, ``floats``, ``booleans``,
``sampled_from``).  Each test runs ``max_examples`` samples drawn from a
seeded RNG, always including the strategy endpoints first so boundary cases
are exercised on every run.
"""
from __future__ import annotations

import functools
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw, endpoints=()):
        self.draw = draw
        self.endpoints = tuple(endpoints)


def _integers(lo: int, hi: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)),
                     endpoints=(lo, hi))


def _floats(lo: float, hi: float, **_kw) -> _Strategy:
    return _Strategy(lambda rng: float(rng.uniform(lo, hi)),
                     endpoints=(lo, hi))


def _booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(0, 2)),
                     endpoints=(False, True))


def _sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))],
                     endpoints=seq[:2])


class _StrategiesModule:
    integers = staticmethod(_integers)
    floats = staticmethod(_floats)
    booleans = staticmethod(_booleans)
    sampled_from = staticmethod(_sampled_from)


st = _StrategiesModule()


class _HypothesisModule:
    @staticmethod
    def settings(max_examples: int = 10, **_kw):
        def deco(fn):
            fn._propcheck_max_examples = max_examples
            return fn
        return deco

    @staticmethod
    def given(*strategies: _Strategy):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # settings() may sit above given() (decorating wrapper)
                # or below it (decorating fn) — honor both orders
                n = getattr(wrapper, "_propcheck_max_examples",
                            getattr(fn, "_propcheck_max_examples", 10))
                # crc32, not hash(): PYTHONHASHSEED randomizes the latter
                # per process, which would make failures irreproducible
                rng = np.random.default_rng(
                    zlib.crc32(fn.__name__.encode()))
                # endpoint combinations first (diagonal), then random draws
                n_ep = max((len(s.endpoints) for s in strategies), default=0)
                cases = []
                for i in range(n_ep):
                    cases.append(tuple(
                        s.endpoints[min(i, len(s.endpoints) - 1)]
                        for s in strategies))
                while len(cases) < n:
                    cases.append(tuple(s.draw(rng) for s in strategies))
                for case in cases[:max(n, n_ep)]:
                    fn(*args, *case, **kwargs)
            # pytest follows __wrapped__ when introspecting the signature
            # and would treat the original parameters as fixtures
            del wrapper.__wrapped__
            return wrapper
        return deco


hypothesis = _HypothesisModule()
