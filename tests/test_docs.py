"""Docs layer stays healthy (ISSUE 7 satellite).

Runs ``benchmarks/check_docs.py`` — intra-repo markdown links in
README/ROADMAP/docs/ resolve, every ``benchmarks/fig*.py`` imports and
exposes ``run()``, every ``examples/*.py`` imports and exposes
``main()`` — exactly what the CI docs job runs, so a broken link or a
stale example fails tier-1 locally first."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_docs_links_and_entry_points():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", "check_docs.py")],
        capture_output=True, text=True, timeout=420, env=env, cwd=REPO)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr


def test_docs_exist_and_readme_points_at_them():
    for f in ("docs/ARCHITECTURE.md", "docs/RESULTS.md"):
        assert os.path.exists(os.path.join(REPO, f)), f
    readme = open(os.path.join(REPO, "README.md")).read()
    assert "docs/ARCHITECTURE.md" in readme
    assert "docs/RESULTS.md" in readme
