"""Distribution-layer integration: lossy collectives under shard_map,
Celeris train island on a real (host-device) mesh, dry-run lowering.

Runs in a subprocess with 8 forced host devices so the main pytest
process keeps its single-device view for the smoke tests.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_lossy_psum_zero_drop_equals_exact():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import sharding as shd
        from repro.core import coding, lossy_collectives as lc
        mesh = shd.make_mesh((8,), ('data',))
        N = 5000
        code = coding.plan(N)
        signs = coding.rademacher(jax.random.PRNGKey(7), code)
        xs = jax.random.normal(jax.random.PRNGKey(0), (8, N))
        def f(x, key, p):
            est, frac = lc.lossy_psum(x[0], 'data', key=key, drop_rate=p,
                                      signs=signs, code=code,
                                      use_pallas=False)
            return est[None], frac[None]
        sm = shd.shard_map(f, mesh=mesh, in_specs=(P('data', None), P(), P()),
                           out_specs=(P('data', None), P('data')),
                           check_vma=False)
        est, frac = jax.jit(sm)(xs, jax.random.PRNGKey(1), jnp.float32(0.0))
        np.testing.assert_allclose(np.asarray(est[0]), np.asarray(xs.sum(0)),
                                   rtol=2e-3, atol=2e-3)
        est5, frac5 = jax.jit(sm)(xs, jax.random.PRNGKey(2), jnp.float32(0.05))
        assert abs(float(frac5[0]) - 0.95) < 0.04
        rel = np.linalg.norm(np.asarray(est5[0] - xs.sum(0)))
        rel /= np.linalg.norm(np.asarray(xs.sum(0)))
        assert rel < 0.5, rel
        print('OK')
    """)
    assert "OK" in out


def test_celeris_train_on_mesh_learns():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        import repro.configs as C
        from repro import sharding as shd
        from repro.data.pipeline import DataConfig, make_source
        from repro.train import train_step as ts, sharding_rules as rules
        from repro.optim.adamw import OptConfig
        mesh = shd.make_mesh((4, 2), ('data', 'model'))
        shd.set_global_mesh(mesh)
        cfg = C.get_smoke('qwen2-0.5b')
        src = make_source(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                     global_batch=8, seed=1))
        st = ts.init_state(jax.random.PRNGKey(0), cfg)
        st = jax.device_put(st, ts.state_shardings(st, mesh))
        fn = ts.make_train_step(cfg, mesh, OptConfig(lr=1e-3),
                                ts.CelerisConfig(enabled=True,
                                                 min_coded_size=1024))
        losses = []
        for i in range(14):
            host = src.global_batch(i, 4)
            sp = rules.batch_specs(mesh, host)
            b = {k: jax.device_put(v, jax.sharding.NamedSharding(mesh, sp[k]))
                 for k, v in host.items()}
            st, m = fn(st, b, jax.random.fold_in(jax.random.PRNGKey(3), i),
                       jnp.float32(0.05))
            losses.append(float(m['loss']))
        assert np.isfinite(losses).all()
        # robust to step-level noise from the lossy sync: trend must be down
        assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses
        assert 0.9 < float(m['recv_frac']) < 1.0
        print('OK')
    """)
    assert "OK" in out


def test_moe_ep_on_mesh_matches_single_device():
    out = _run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        import repro.configs as C
        from repro import sharding as shd
        from repro.models import moe as MOE
        cfg = C.get_smoke('qwen2-moe-a2.7b')
        # generous capacity: no token dropping -> paths must agree exactly
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=50.0))
        p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                              jnp.float32) * 0.3
        shd.set_global_mesh(None)
        y_local, aux_local = MOE.moe_block(p, cfg, x)
        mesh = shd.make_mesh((4, 2), ('data', 'model'))
        shd.set_global_mesh(mesh)
        y_ep, aux_ep = jax.jit(lambda p_, x_: MOE.moe_block(p_, cfg, x_))(p, x)
        shd.set_global_mesh(None)
        np.testing.assert_allclose(np.asarray(y_ep, np.float32),
                                   np.asarray(y_local, np.float32),
                                   rtol=1e-4, atol=1e-5)
        print('OK')
    """)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_cell_compiles_and_fits():
    """One full production-mesh dry-run cell end-to-end (512 devices)."""
    out = _run("""
        from repro.launch import dryrun
        rec = dryrun.lower_cell('qwen2-0.5b', 'train_4k', multi_pod=False)
        assert rec['memory']['peak_bytes'] < 16 * 2**30, rec['memory']
        assert rec['roofline']['useful_flops_ratio'] > 0.3
        assert rec['collective_bytes_total'] > 0
        print('OK')
    """, devices=512, timeout=560)
    assert "OK" in out


def test_elastic_restart_across_meshes(tmp_path):
    """Checkpoint saved under one topology restores under another."""
    out = _run(f"""
        import jax, jax.numpy as jnp, numpy as np
        import repro.configs as C
        from repro import sharding as shd
        from repro.checkpoint import checkpoint as ckpt
        from repro.train import train_step as ts
        cfg = C.get_smoke('qwen2-0.5b')
        st = ts.init_state(jax.random.PRNGKey(0), cfg)
        mesh1 = shd.make_mesh((4, 2), ('data', 'model'))
        st1 = jax.device_put(st, ts.state_shardings(st, mesh1))
        ckpt.save({str(tmp_path)!r}, 3, st1)
        # restore onto a different mesh shape
        mesh2 = shd.make_mesh((2, 4), ('data', 'model'))
        st2, step, _ = ckpt.restore({str(tmp_path)!r}, st,
                                    shardings=ts.state_shardings(st, mesh2))
        assert step == 3
        for a, b in zip(jax.tree.leaves(st1), jax.tree.leaves(st2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print('OK')
    """)
    assert "OK" in out


def test_lossy_psum_quantized_wire_close_to_f32():
    """quantize_wire=True (fused rotate+quantize int8 wire) stays an
    unbiased-ish estimate: zero-drop reduce matches the exact sum to
    quantization tolerance."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import sharding as shd
        from repro.core import coding, lossy_collectives as lc
        mesh = shd.make_mesh((8,), ('data',))
        N = 5000
        code = coding.plan(N)
        signs = coding.rademacher(jax.random.PRNGKey(7), code)
        xs = jax.random.normal(jax.random.PRNGKey(0), (8, N))
        def f(x, key, p):
            est, frac = lc.lossy_psum(x[0], 'data', key=key, drop_rate=p,
                                      signs=signs, code=code,
                                      use_pallas=False, quantize_wire=True)
            return est[None], frac[None]
        sm = shd.shard_map(f, mesh=mesh, in_specs=(P('data', None), P(), P()),
                           out_specs=(P('data', None), P('data')),
                           check_vma=False)
        est, frac = jax.jit(sm)(xs, jax.random.PRNGKey(1), jnp.float32(0.0))
        assert float(frac[0]) == 1.0
        want = np.asarray(xs.sum(0))
        err = np.linalg.norm(np.asarray(est[0]) - want) / np.linalg.norm(want)
        assert err < 0.05, err
        print('OK')
    """)
    assert "OK" in out
