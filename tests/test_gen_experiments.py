"""benchmarks/gen_experiments.py (moved from the stale repo root in
ISSUE 5): importable without side effects, and its table builders run
on synthetic inputs matching the current artifact formats."""
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:                 # benchmarks is a repo-root pkg
    sys.path.insert(0, REPO)

from benchmarks import gen_experiments  # noqa: E402


def test_import_has_no_side_effects(tmp_path):
    """The old script wrote results/ at import time; the port must not
    (importing it above already proved it doesn't crash)."""
    assert callable(gen_experiments.main)
    assert not os.path.exists(os.path.join(str(tmp_path),
                                           "experiments_tables.md"))


def test_dryrun_table_from_scale_check_records(tmp_path):
    """Builds from the actual scale_check record format (a JSON list of
    per-mesh records), not the retired jaxpr_costs/roofline shape."""
    import json
    recs = [{"arch": "qwen2-0.5b", "shape": "train_4k",
             "mode": "hierarchical", "n_devices": 512, "mesh": "2x16x16",
             "lower_s": 2.2, "collective_ops": {"all_reduce": 33},
             "illegal_collectives": {}, "ok": True}]
    with open(tmp_path / "scale_check__x.json", "w") as f:
        json.dump(recs, f)
    lines = gen_experiments.build_dryrun_tables(str(tmp_path))
    assert any("qwen2-0.5b" in ln and "2x16x16" in ln for ln in lines)
    assert any("all_reducex33" in ln for ln in lines)


def test_transport_table_uses_current_sweep_grid():
    """The fig6 table rows come from the benchmark module's own grid
    constants (schedules x windows x nodes) — feed a synthetic bench
    dict keyed like BENCH_sim.json and expect one row per (node,
    oversub, schedule) cell present."""
    from benchmarks import fig6_scale_schedule as f6
    bench = {}
    tag = f"n{f6.NODES[0]}_o{int(f6.OVERSUBS[0])}"
    for w in f6.WINDOWS:
        bench[f"fig6_p99_ms_hier_{w}_{tag}"] = 1.0
        bench[f"fig6_dci_loss_hier_{w}_{tag}"] = 0.01
    lines = gen_experiments.build_transport_tables(bench)
    rows = [ln for ln in lines if ln.startswith(f"| {f6.NODES[0]} ")]
    assert len(rows) == 1 and " hier " in rows[0]
