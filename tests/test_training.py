"""End-to-end training behavior: learning, lossy-parity, checkpoint/restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.data.pipeline import DataConfig, MarkovLM, make_source
from repro.optim import adamw
from repro.optim.adamw import OptConfig
from repro.train.train_step import CelerisConfig
from repro.train.trainer import Trainer
from repro.checkpoint import checkpoint as ckpt


def _trainer(tmp=None, celeris=None, seed=0, arch="qwen2-0.5b", **kw):
    cfg = C.get_smoke(arch)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                    seed=1)
    return Trainer(cfg, data_cfg=dc,
                   opt_cfg=OptConfig(lr=1e-3, warmup_steps=10,
                                     total_steps=500),
                   celeris=celeris or CelerisConfig(),
                   ckpt_dir=tmp, seed=seed, **kw)


def test_loss_decreases_on_markov_data():
    h = _trainer().run(30)
    assert h["loss"][-1] < h["loss"][0] - 0.4


def test_data_pipeline_deterministic_and_shardable():
    dc = DataConfig(vocab_size=128, seq_len=16, global_batch=8, seed=3)
    src = MarkovLM(dc)
    a = src.shard_batch(5, 2, 4)
    b = src.shard_batch(5, 2, 4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # different shards / steps differ
    c = src.shard_batch(5, 3, 4)
    d = src.shard_batch(6, 2, 4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert not np.array_equal(a["tokens"], d["tokens"])


def test_optimizer_clips_and_steps():
    params = {"w": jnp.ones((4, 4))}
    st = adamw.init_opt_state(params)
    g = {"w": jnp.full((4, 4), 100.0)}
    cfg = OptConfig(lr=1e-2, clip_norm=1.0, warmup_steps=0)
    newp, newst, m = adamw.apply_updates(params, g, st, cfg)
    assert float(m["grad_norm"]) == pytest.approx(400.0)
    assert bool(jnp.all(newp["w"] < params["w"]))
    assert int(newst["count"]) == 1


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": [jnp.ones((4,)), {"c": jnp.float32(3.5)}]}
    ckpt.save(str(tmp_path), 7, tree, extra={"note": "x"})
    like = jax.tree.map(jnp.zeros_like, tree)
    got, step, extra = ckpt.restore(str(tmp_path), like)
    assert step == 7 and extra["note"] == "x"
    for x, y in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_atomicity(tmp_path):
    tree = {"a": jnp.ones((3,))}
    ckpt.save(str(tmp_path), 1, tree)
    # a crash mid-save leaves a .tmp dir; LATEST still points at step 1
    os.makedirs(tmp_path / "step_2.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 1
    got, step, _ = ckpt.restore(str(tmp_path), {"a": jnp.zeros((3,))})
    assert step == 1


def test_fault_restart_resumes(tmp_path):
    """Simulated node failure: a fresh Trainer resumes from LATEST and
    continues from the checkpointed step."""
    t1 = _trainer(str(tmp_path), ckpt_every=5)
    with pytest.raises(RuntimeError, match="simulated node failure"):
        t1.run(20, simulate_fault_at=12)
    assert ckpt.latest_step(str(tmp_path)) == 10
    t2 = _trainer(str(tmp_path), ckpt_every=5)
    assert t2.start_step == 10
    h = t2.run(5)
    assert len(h["loss"]) == 5 and np.isfinite(h["loss"]).all()


def test_lossy_training_parity_small_drop():
    """Fig.-1 claim at smoke scale: <=5% drop w/ Hadamard recovery stays
    within noise of lossless (single-device: drop applies to MoE path /
    degenerate dp, so this mainly checks plumbing + stability)."""
    h_exact = _trainer(seed=3).run(25)
    h_lossy = _trainer(seed=3, celeris=CelerisConfig(enabled=True)).run(25)
    assert abs(h_lossy["loss"][-1] - h_exact["loss"][-1]) < 0.3


def test_trainer_timeout_adapts():
    t = _trainer(celeris=CelerisConfig(enabled=True))
    h = t.run(10)
    assert all(0.5 <= x <= 8.0 for x in h["timeout"])


def test_train_step_microbatched_matches_full():
    """Gradient accumulation must give the same update as one batch."""
    from repro.train import train_step as ts
    cfg = C.get_smoke("qwen2-0.5b")
    src = make_source(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                 global_batch=8, seed=5))
    batch = {k: jnp.asarray(v) for k, v in src.global_batch(0).items()}
    key = jax.random.PRNGKey(0)
    s1 = ts.init_state(key, cfg)
    s2 = jax.tree.map(jnp.copy, s1)
    f1 = ts.make_train_step(cfg, None, OptConfig(lr=1e-3), donate=False)
    f2 = ts.make_train_step(cfg, None, OptConfig(lr=1e-3), donate=False,
                            microbatches=4)
    o1, m1 = f1(s1, batch, key, jnp.float32(0))
    o2, m2 = f2(s2, batch, key, jnp.float32(0))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-3)
    for a, b in zip(jax.tree.leaves(o1["params"]),
                    jax.tree.leaves(o2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-2, atol=3e-4)
