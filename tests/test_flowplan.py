"""Point-to-point flow plans (ISSUE 7 tentpole acceptance tests).

- the built-in ring expressed as an explicit :func:`schedule.flow_plan`
  reproduces the committed ring seed stats bit-for-bit through the
  engine's plan-override path (collective schedule = degenerate flow
  plan, zero drift);
- :func:`schedule.flow_plan` validation: shape mismatches, duplicate
  senders, self-flows, empty plans;
- incast accounting: ``fan_in``/``max_fan_in`` on the serve KV plan,
  byte conservation between plan and engine packet exposure;
- incast physics: the same flow set with a higher fan-in receiver pod
  is strictly slower per round, and a fan-in-1 plan matches the
  no-overlay baseline streams (the overlay draws nothing).
"""
import json
import os

import dataclasses
import numpy as np
import pytest

from repro.core.transport import (BatchedEngine, NetworkParams, SimParams,
                                  schedule)
from repro.serve import traffic

SMALL = SimParams(net=NetworkParams(n_nodes=32, burst_on_prob=0.0008))


def _pinned():
    path = os.path.join(os.path.dirname(__file__), "data",
                        "ring_schedule_seed_stats.json")
    return json.load(open(path))


def _ring_as_flow_plan(n: int, message_bytes: int) -> schedule.FlowPlan:
    """The flat ring rebuilt from raw (src, dst, payload) flows."""
    src = np.arange(n)
    ring = schedule.SchedulePhase(
        name="ring", src=src, dst=(src + 1) % n,
        n_steps=2 * (n - 1), payload_bytes=message_bytes // n)
    return schedule.flow_plan("ring_explicit", (ring,))


def test_ring_flow_plan_bitexact_vs_committed_seed_stats():
    """Engine with plan= the explicit ring == committed ring stats,
    bit for bit (times, recv_frac, derived window)."""
    ref = _pinned()["flat"]
    n = SMALL.net.n_nodes
    plan = _ring_as_flow_plan(n, SMALL.work.message_bytes)
    eng = BatchedEngine(SMALL, plan=plan)
    tr = eng.traces(["roce", "celeris"], 40, seed=11, legacy_streams=False)
    base = eng.assemble(tr["roce"], 11)
    np.testing.assert_array_equal(base.times_us,
                                  np.array(ref["roce_times_us"]))
    to = float(np.percentile(base.times_us, 50) + base.times_us.std()) * 0.8
    assert to == ref["celeris_timeout_us"]
    cel = eng.assemble(tr["celeris"], 11, celeris_timeout_us=to,
                       adaptive=False, window="round")
    np.testing.assert_array_equal(cel.times_us,
                                  np.array(ref["celeris_times_us"]))
    np.testing.assert_array_equal(cel.recv_frac,
                                  np.array(ref["celeris_recv_frac"]))


def test_plan_override_refuses_legacy_streams():
    plan = _ring_as_flow_plan(32, SMALL.work.message_bytes)
    eng = BatchedEngine(SMALL, plan=plan)
    with pytest.raises(ValueError, match="legacy"):
        eng.traces(["roce"], 4, 0, legacy_streams=True)


# ------------------------------------------------ flow_plan validation

def test_flow_plan_validation_errors():
    ph = dict(n_steps=2, payload_bytes=1 << 10)
    mk = lambda src, dst, **kw: schedule.SchedulePhase(
        name="x", src=np.asarray(src), dst=np.asarray(dst), **{**ph, **kw})
    with pytest.raises(ValueError, match="length"):
        schedule.flow_plan("bad", (mk([0, 1], [2]),))
    with pytest.raises(ValueError, match="sender"):
        schedule.flow_plan("bad", (mk([0, 0], [1, 2]),))
    with pytest.raises(ValueError, match="self"):
        schedule.flow_plan("bad", (mk([0, 1], [0, 2]),))
    with pytest.raises(ValueError, match="payload"):
        schedule.flow_plan("bad", (mk([0], [1], payload_bytes=0),))
    with pytest.raises(ValueError, match="non-empty"):
        schedule.flow_plan("bad", ())


def test_flow_plan_drops_empty_phases():
    ph = schedule.SchedulePhase(name="kv", src=np.array([0]),
                                dst=np.array([1]), n_steps=2,
                                payload_bytes=1 << 10)
    empty = schedule.SchedulePhase(name="idle", src=np.array([], int),
                                   dst=np.array([], int), n_steps=0,
                                   payload_bytes=1 << 10)
    plan = schedule.flow_plan("p", (empty, ph))
    assert len(plan.phases) == 1 and plan.phases[0].name == "kv"


# ------------------------------------------------- incast accounting

def test_kv_plan_fan_in_and_byte_conservation():
    tp = traffic.ServeTrafficParams(n_prefill=28, n_decode=4)
    plan = traffic.kv_flow_plan(tp)
    (ph,) = plan.phases
    # every prefill node sends exactly once; receivers are decode nodes
    assert ph.src.size == tp.n_prefill
    assert np.array_equal(np.sort(ph.src), np.arange(tp.n_prefill))
    assert set(ph.dst) <= set(range(tp.n_prefill, tp.n_nodes))
    # fan-in: each decode node takes n_prefill/n_decode senders
    fan = ph.fan_in()
    assert fan.shape == ph.src.shape
    assert fan.sum() == sum(np.count_nonzero(ph.dst == d) ** 2
                            for d in np.unique(ph.dst))
    assert plan.max_fan_in() == tp.fan_in == 7
    # plan bytes == blocks the queue model ships per round
    assert (plan.bytes_per_round()
            == tp.capacity_blocks_per_round * tp.kv_block_bytes)
    # engine packet exposure matches the plan's own accounting
    net = traffic.serve_net_params(tp)
    eng = BatchedEngine(SimParams(net=net), plan=plan)
    tr = eng.traces(["celeris"], 2, 0, legacy_streams=False)
    assert tr["celeris"].total.sum() == 2 * ph.src.size * ph.n_steps \
        * ph.n_pkts(net)


def test_incast_monotone_in_fan():
    """Same 24 senders, decode pod shrunk 8 -> 2: per-round natural
    time grows strictly with fan-in (receiver egress serialization)."""
    t = {}
    for ndec in (8, 2):
        tp = traffic.ServeTrafficParams(n_prefill=24, n_decode=ndec,
                                        steps_per_round=4)
        net = traffic.serve_net_params(tp)
        eng = BatchedEngine(
            SimParams(net=dataclasses.replace(net, burst_on_prob=0.0008)),
            plan=traffic.kv_flow_plan(tp))
        tr = eng.traces(["celeris"], 20, 3, legacy_streams=False)
        t[ndec] = np.median(tr["celeris"].nat_us.reshape(20, -1).sum(1))
    assert t[2] > 2.5 * t[8]


def test_fan_in_one_plan_keeps_baseline_streams():
    """A point-to-point plan with no incast (fan 1) must not consume
    the incast substream: its trace equals one where the overlay code
    is unreachable (disjoint pairs = permutation subset)."""
    src = np.arange(8)
    ph = schedule.SchedulePhase(name="p2p", src=src, dst=src + 8,
                                n_steps=4, payload_bytes=1 << 18)
    plan = schedule.flow_plan("pairs", (ph,))
    assert plan.max_fan_in() == 1
    eng = BatchedEngine(SMALL, plan=plan)
    tr = eng.traces(["roce", "celeris"], 10, 7, legacy_streams=False)
    # deterministic replay: same seed, same plan -> identical trace
    tr2 = BatchedEngine(SMALL, plan=plan).traces(
        ["roce", "celeris"], 10, 7, legacy_streams=False)
    for d in ("roce", "celeris"):
        np.testing.assert_array_equal(tr[d].nat_us, tr2[d].nat_us)
        np.testing.assert_array_equal(tr[d].deliv, tr2[d].deliv)
