"""Flight-recorder telemetry (ISSUE 8 acceptance tests).

- The recorder is a pure overlay: with it attached, the seeded engine
  stats stay bit-exact against the committed pre-telemetry seed stats
  (flat and 2-pod pinned configs) — recording only reads.
- The attribution conserves: per-(phase, tier, cause) component times
  and lost packets sum exactly to the pinned ``RoundStats`` totals
  (``audit_round``), clean and under injected NIC faults — and the
  audit *catches* tampered records (the PR-7 ``.ravel→.flat``
  silent-undercount bug class now fails loudly).
- The Chrome/Perfetto export round-trips through its own schema
  validator; corrupted events are rejected.
- Drop provenance survives the stack boundary: ``schedule_from_engine
  (record=True)`` → ``DropSchedule.provenance`` explains exactly the
  clipped rates the trainer masks with, through to a real 8-device
  hierarchical train step.
- The serve path attributes per-request KV loss by cause without
  perturbing the FIFO simulation.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.transport import (BatchedEngine, ConservationError,
                                  FaultParams, NetworkParams, SimParams,
                                  TraceRecorder, coupling, telemetry,
                                  topology, trace_export)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SMALL = SimParams(net=NetworkParams(n_nodes=32, burst_on_prob=0.0008))


def _pinned():
    path = os.path.join(os.path.dirname(__file__), "data",
                        "ring_schedule_seed_stats.json")
    return json.load(open(path))


def _recorded_flat(params=SMALL, n_rounds=40, seed=11, scale=0.8):
    rec = TraceRecorder()
    eng = BatchedEngine(params, recorder=rec)
    tr = eng.traces(["roce", "celeris"], n_rounds, seed,
                    legacy_streams=False)
    base = eng.assemble(tr["roce"], seed)
    to = float(np.percentile(base.times_us, 50)
               + base.times_us.std()) * scale
    cel = eng.assemble(tr["celeris"], seed, celeris_timeout_us=to,
                       adaptive=False, window="round")
    return base, cel, to, rec


# ------------------------------------------------ pure-overlay contract

def test_recorder_on_flat_bitexact_and_conserves():
    """Recorder attached: stats bit-identical to the committed seed
    stats, and the recorded attribution conserves to them."""
    ref = _pinned()["flat"]
    base, cel, to, rec = _recorded_flat()
    np.testing.assert_array_equal(base.times_us,
                                  np.array(ref["roce_times_us"]))
    assert to == ref["celeris_timeout_us"]
    np.testing.assert_array_equal(cel.times_us,
                                  np.array(ref["celeris_times_us"]))
    np.testing.assert_array_equal(cel.recv_frac,
                                  np.array(ref["celeris_recv_frac"]))
    for st, d in ((base, "roce"), (cel, "celeris")):
        out = telemetry.audit_round(st, rec.record(d))
        assert out["time_rel_err"] < 2e-5
        assert out["pkt_rel_err"] < 1e-9
        assert out["offered_vs_plan_rel_err"] < 1e-9
    # the reliable design loses nothing; celeris's loss is attributed —
    # the per-cause split sums back to the stats' scalar loss exactly
    r = rec.record("celeris")
    assert rec.record("roce").loss_rates().sum() == 0.0
    np.testing.assert_allclose(r.loss_rates().sum(axis=1),
                               1.0 - cel.recv_frac, atol=1e-9)
    cut = r.loss_rates()[:, telemetry.CAUSES.index("window_cut")]
    assert cut.sum() > 0.0


def test_recorder_on_two_pods_bitexact_and_conserves():
    ref = _pinned()["pods2"]
    rec = TraceRecorder()
    hp = topology.hier_params(2, base=SMALL, dci_oversubscription=8.0)
    stats = topology.hier_protocol(hp, n_rounds=40, seed=11,
                                   timeout_scale=0.8, recorder=rec)
    np.testing.assert_array_equal(stats["celeris"].times_us,
                                  np.array(ref["celeris_times_us"]))
    np.testing.assert_array_equal(stats["celeris"].tier_recv_frac,
                                  np.array(ref["celeris_tier_recv_frac"]))
    out = telemetry.audit_round(stats["celeris"], rec.record("celeris"))
    assert out["pkt_rel_err"] < 1e-9
    assert "pod_recomb_rel_err" in out


def test_faulted_runs_conserve_and_attribute():
    """NIC stalls show up as fault *time* on the reliable design and
    fault *loss* on Celeris — and everything still conserves."""
    p = SimParams(net=NetworkParams(n_nodes=32, burst_on_prob=0.0008),
                  fault=FaultParams(stall_rate=3e-4, stall_steps=40))
    base, cel, _, rec = _recorded_flat(params=p, seed=7)
    for st, d in ((base, "roce"), (cel, "celeris")):
        telemetry.audit_round(st, rec.record(d))
    fcomp = rec.record("roce").round_components()[
        :, telemetry.COMPONENTS.index("fault")]
    assert fcomp.sum() > 0.0
    floss = rec.record("celeris").loss_rates()[
        :, telemetry.CAUSES.index("fault")]
    assert floss.sum() > 0.0


def test_audit_catches_tampered_record():
    """A silently dropped in-place update (the `.ravel()[idx] +=` bug
    class) undercounts a component or a loss column — both must raise."""
    base, cel, _, rec = _recorded_flat()
    r = rec.record("celeris")
    keep = r.comp_crit.copy()
    r.comp_crit[:, 0] *= 0.5                  # lose half the serialize time
    with pytest.raises(ConservationError):
        telemetry.audit_round(cel, r)
    r.comp_crit[:] = keep
    r.lost_pkts[:, :, 0] += 7.0               # phantom wire loss
    with pytest.raises(ConservationError):
        telemetry.audit_round(cel, r)


def test_recorder_rejects_legacy_streams():
    eng = BatchedEngine(SMALL, recorder=TraceRecorder())
    with pytest.raises(ValueError, match="legacy_streams"):
        eng.traces(["roce"], 10, 0, legacy_streams=True)
    # run() silently routes to shared mode instead of raising
    st = eng.run("roce", 5, seed=3)
    assert st.times_us.shape == (5,)


def test_unassembled_record_fails_audit():
    rec = TraceRecorder()
    eng = BatchedEngine(SMALL, recorder=rec)
    eng.traces(["roce"], 5, 0, legacy_streams=False)
    st = BatchedEngine(SMALL).run("roce", 5, seed=0)
    with pytest.raises(ConservationError, match="not assembled"):
        telemetry.audit_round(st, rec.record("roce"))


# ------------------------------------------------------- export schema

def test_trace_export_roundtrips(tmp_path):
    _, _, _, rec = _recorded_flat(n_rounds=10)
    path = tmp_path / "trace.json"
    trace_export.write_trace(rec, str(path), meta={"test": "yes"})
    loaded = json.load(open(path))
    counts = trace_export.validate_trace(loaded)
    assert counts["X"] > 0 and counts["M"] > 0
    # one rounds track + one per phase, per design
    pids = {e["pid"] for e in loaded["traceEvents"] if e["ph"] == "X"}
    assert len(pids) == 2
    # every slice's component args are schema-listed components
    for e in loaded["traceEvents"]:
        if e["ph"] == "X" and "components_us" in e.get("args", {}):
            assert set(e["args"]["components_us"]) <= set(
                telemetry.COMPONENTS)


def test_trace_validator_rejects_corruption(tmp_path):
    _, _, _, rec = _recorded_flat(n_rounds=5)
    obj = trace_export.to_trace_events(rec)
    ok = json.loads(json.dumps(obj))
    trace_export.validate_trace(ok)

    bad = json.loads(json.dumps(obj))
    del bad["traceEvents"][0]["name"]
    with pytest.raises(ValueError):
        trace_export.validate_trace(bad)

    bad = json.loads(json.dumps(obj))
    for e in bad["traceEvents"]:
        if e["ph"] == "X":
            e["dur"] = -1.0
            break
    with pytest.raises(ValueError):
        trace_export.validate_trace(bad)

    bad = json.loads(json.dumps(obj))
    for e in bad["traceEvents"]:
        if e["ph"] == "X" and "components_us" in e.get("args", {}):
            e["args"]["components_us"]["not_a_component"] = 1.0
            break
    with pytest.raises(ValueError):
        trace_export.validate_trace(bad)

    with pytest.raises(ValueError, match="no records"):
        trace_export.to_trace_events(TraceRecorder())


# --------------------------------------------------- drop provenance

def test_flat_schedule_provenance_recorded_vs_heuristic():
    rec_sched = coupling.schedule_from_engine(
        40, 11, params=SMALL, timeout_scale=0.8, record=True)
    heu_sched = coupling.schedule_from_engine(
        40, 11, params=SMALL, timeout_scale=0.8, record=False)
    # provenance never changes the schedule itself
    np.testing.assert_array_equal(rec_sched.rates, heu_sched.rates)
    p = rec_sched.provenance
    assert p.source == "recorded" and heu_sched.provenance.source == \
        "heuristic"
    # the unclipped per-cause split explains exactly the clipped rates
    np.testing.assert_allclose(
        np.clip(p.total(), 0.0, coupling.MAX_DROP), rec_sched.rates,
        atol=1e-9)
    assert p.dominant_cause() == "window_cut"
    assert p.phases and p.phase_rates is not None
    assert "window_cut" in p.describe()


def test_split_schedule_provenance_per_axis():
    sp = coupling.split_schedule_from_engine(
        30, seed=4, params=SMALL, n_pods=2, dci_oversubscription=8.0,
        timeout_scale=0.8, record=True)
    for axis, sched in (("intra", sp.intra), ("cross", sp.cross)):
        p = sched.provenance
        assert p is not None and p.axis == axis and p.source == "recorded"
        np.testing.assert_allclose(
            np.clip(p.total(), 0.0, coupling.MAX_DROP), sched.rates,
            atol=1e-9)
    assert sp.cross.provenance.tiers == ("dci",)


def _run(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_provenance_reaches_train_step_masks_8dev():
    """End-to-end tag survival: a recorded axis-split schedule drives a
    real 8-device hierarchical train step, and the realized cross-pod
    received fraction matches the very rate the provenance explains."""
    _run("""
        import numpy as np
        import jax, jax.numpy as jnp
        import repro.configs as C
        from repro import sharding as shd
        from repro.core.transport import NetworkParams, SimParams, coupling
        from repro.data.pipeline import DataConfig, make_source
        from repro.optim.adamw import OptConfig
        from repro.train import train_step as ts, sharding_rules as rules

        small = SimParams(net=NetworkParams(n_nodes=32,
                                            burst_on_prob=0.0008))
        sp = coupling.split_schedule_from_engine(
            30, seed=4, params=small, n_pods=2, dci_oversubscription=8.0,
            timeout_scale=0.5, record=True)
        prov = sp.cross.provenance
        assert prov is not None and prov.source == 'recorded'
        # pick the worst cross-pod step: the mask the trainer will draw
        i = int(np.argmax(sp.cross.rates))
        rate = sp.cross.rate(i)
        assert rate > 0.05, (rate, 'cell too mild to assert anything')
        np.testing.assert_allclose(
            np.clip(prov.total(), 0.0, coupling.MAX_DROP)[i], rate,
            atol=1e-9)
        cause = prov.causes[int(np.argmax(prov.rates[i]))]
        assert cause in ('window_cut', 'wire_drop', 'fault')

        mesh = shd.make_mesh((2, 4), ('pod', 'data'))
        shd.set_global_mesh(mesh)
        cfg = C.get_smoke('qwen2-0.5b')
        src = make_source(DataConfig(vocab_size=cfg.vocab_size,
                                     seq_len=32, global_batch=8, seed=1))
        host = src.global_batch(0, 8)
        spb = rules.batch_specs(mesh, host)
        batch = {k: jax.device_put(
                     v, jax.sharding.NamedSharding(mesh, spb[k]))
                 for k, v in host.items()}
        fn = ts.make_train_step(
            cfg, mesh, OptConfig(lr=1e-3),
            ts.CelerisConfig(mode='hierarchical', min_coded_size=1024))
        st = ts.init_state(jax.random.PRNGKey(0), cfg)
        st = jax.device_put(st, ts.state_shardings(st, mesh))
        st, m = fn(st, batch, jax.random.PRNGKey(1),
                   jnp.asarray([sp.intra.rate(i), rate], jnp.float32))
        got = float(m['recv_frac'])
        assert abs(got - (1.0 - rate)) < 0.1, (got, rate)
        assert np.isfinite(float(m['loss']))
        print('OK', rate, cause, got)
    """)


# -------------------------------------------------------- serve path

def test_serve_loss_attribution_is_pure_overlay():
    from repro.serve.traffic import (ServeTrafficParams, request_trace,
                                     simulate_serving)
    base, cel, _, rec = _recorded_flat()
    lr = rec.record("celeris").loss_rates()
    tp = ServeTrafficParams()
    ref = float(np.median(cel.times_us))
    trace = request_trace(tp, float(cel.times_us.sum()), ref, seed=3)
    res0 = simulate_serving(tp, cel.times_us, cel.recv_frac, trace)
    res = simulate_serving(tp, cel.times_us, cel.recv_frac, trace,
                           loss_rates=lr)
    np.testing.assert_array_equal(res.latency_us, res0.latency_us)
    np.testing.assert_array_equal(res.kv_frac, res0.kv_frac)
    np.testing.assert_array_equal(res.completed, res0.completed)
    assert res0.kv_loss_by_cause is None
    assert res.kv_loss_by_cause is not None
    # per-request: attributed loss sums to the KV hole, by construction
    done = res.completed
    np.testing.assert_allclose(
        res.kv_loss_by_cause[done].sum(axis=1),
        1.0 - res.kv_frac[done], atol=1e-9)
    attr = res.loss_attribution()
    assert set(attr) == set(telemetry.CAUSES)
    assert attr["window_cut"] >= 0.0


# ------------------------------------------------- fig9 determinism

def test_fig9_smoke_deterministic():
    if REPO not in sys.path:
        sys.path.insert(0, REPO)
    import contextlib
    import io
    from benchmarks import fig9_tail_attribution as f9
    with contextlib.redirect_stdout(io.StringIO()):
        a = f9.run(smoke=True, prefix="smoke_fig9")
        b = f9.run(smoke=True, prefix="smoke_fig9")
    assert a == b
    keys = [k for k, _, _ in a]
    assert "smoke_fig9_audit_pass" in keys
    claims = {k: (v, r) for k, v, r in a if r is not None}
    for k, (v, r) in claims.items():
        assert v == r, (k, v, r)


def test_trace_export_streams_in_chunks(tmp_path):
    """The chunked generator path is event-identical to the monolithic
    object, write_trace's streamed file parses to the same trace for
    any chunk size, and each chunk passes the per-chunk schema gate."""
    _, _, _, rec = _recorded_flat(n_rounds=10)
    whole = trace_export.to_trace_events(rec, meta={"test": "yes"})

    chunks = list(trace_export.iter_trace_events(rec, chunk_rounds=3))
    assert len(chunks) > 2          # metadata chunk + several round chunks
    for c in chunks:
        trace_export.validate_events(c)   # every chunk stands alone
    assert [e for c in chunks for e in c] == whole["traceEvents"]

    ref = None
    for chunk_rounds in (1, 3, 1000):
        path = tmp_path / f"trace_{chunk_rounds}.json"
        counts = trace_export.write_trace(rec, str(path), meta={"test": "yes"},
                                          chunk_rounds=chunk_rounds)
        loaded = json.load(open(path))
        assert trace_export.validate_trace(loaded) == counts
        assert loaded["traceEvents"] == whole["traceEvents"]
        assert loaded["otherData"] == json.loads(
            json.dumps(whole["otherData"]))
        ref = ref or loaded
        assert loaded == ref            # chunking never changes the file

    with pytest.raises(ValueError, match="chunk_rounds"):
        list(trace_export.iter_trace_events(rec, chunk_rounds=0))


def test_write_trace_removes_partial_file_on_invalid_chunk(tmp_path,
                                                           monkeypatch):
    """A schema violation mid-stream must not leave a truncated JSON on
    disk masquerading as a trace."""
    _, _, _, rec = _recorded_flat(n_rounds=6)
    real_iter = trace_export.iter_trace_events

    def poisoned(recorder, **kw):
        for i, chunk in enumerate(real_iter(recorder, **kw)):
            if i == 1:      # corrupt the first round chunk, after metadata
                chunk[0] = dict(chunk[0], ph="Z")
            yield chunk

    monkeypatch.setattr(trace_export, "iter_trace_events", poisoned)
    path = tmp_path / "trace.json"
    with pytest.raises(ValueError, match="unknown ph"):
        trace_export.write_trace(rec, str(path), chunk_rounds=2)
    assert not path.exists()
