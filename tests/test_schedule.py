"""Pluggable collective schedules (ISSUE 4 acceptance tests).

- RingSchedule is a bit-exact replica of the pre-schedule engine:
  seeded traces/stats match the committed pre-refactor values exactly
  (flat shared mode, legacy stream mode, and ring-over-2-pods);
- HierarchicalSchedule obeys the standard ring-RS/AG accounting: step
  count ``2(m-1) + 2(n_pods-1)`` and total offered bytes conserved at
  ``2(N-1) * message`` per round, with only the dci phase crossing pods;
- the engine's tier attribution follows the plan's step→tier map, and
  the hierarchical schedule beats the flat ring's p99 on an
  oversubscribed DCI (the Fig.-5 claim);
- per-pod oversubscription vectors: scalar == uniform vector
  bit-exactly, hot pods inflate the tail;
- the hierarchical train step composes with DCI-only wire quantization
  on a real 8-device (pod, data) mesh.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.transport import (BatchedEngine, BatchedSimParams,
                                  NetworkParams, SimParams, TopologyParams,
                                  coupling, schedule, sweep, topology)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SMALL = SimParams(net=NetworkParams(n_nodes=32, burst_on_prob=0.0008))


def _run(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


# ------------------------------------------- RingSchedule bit-compat

def _pinned():
    path = os.path.join(os.path.dirname(__file__), "data",
                        "ring_schedule_seed_stats.json")
    return json.load(open(path))


def test_ring_schedule_bitexact_flat_shared():
    """Shared-fabric traces + both window assemblies reproduce the
    committed pre-refactor stats bit-for-bit."""
    ref = _pinned()["flat"]
    eng = BatchedEngine(SMALL)
    tr = eng.traces(["roce", "celeris"], 40, seed=11, legacy_streams=False)
    base = eng.assemble(tr["roce"], 11)
    np.testing.assert_array_equal(base.times_us,
                                  np.array(ref["roce_times_us"]))
    to = float(np.percentile(base.times_us, 50) + base.times_us.std()) * 0.8
    assert to == ref["celeris_timeout_us"]
    cel = eng.assemble(tr["celeris"], 11, celeris_timeout_us=to,
                       adaptive=False, window="round")
    np.testing.assert_array_equal(cel.times_us,
                                  np.array(ref["celeris_times_us"]))
    np.testing.assert_array_equal(cel.recv_frac,
                                  np.array(ref["celeris_recv_frac"]))


def test_ring_schedule_bitexact_legacy_streams():
    ref = _pinned()
    irn = BatchedEngine(SMALL).run("irn", 30, seed=5)
    np.testing.assert_array_equal(irn.times_us,
                                  np.array(ref["legacy_irn_times_us"]))


def test_ring_schedule_bitexact_two_pods():
    """Ring timing over the 2-pod DCI overlay (the PR-3 behavior) is
    untouched by the schedule plumbing, per-tier fractions included."""
    ref = _pinned()["pods2"]
    hp = topology.hier_params(2, base=SMALL, dci_oversubscription=8.0)
    stats = topology.hier_protocol(hp, n_rounds=40, seed=11,
                                   timeout_scale=0.8)
    np.testing.assert_array_equal(stats["roce"].times_us,
                                  np.array(ref["roce_times_us"]))
    np.testing.assert_array_equal(stats["celeris"].times_us,
                                  np.array(ref["celeris_times_us"]))
    np.testing.assert_array_equal(stats["celeris"].tier_recv_frac,
                                  np.array(ref["celeris_tier_recv_frac"]))


# ------------------------------------- HierarchicalSchedule properties

@pytest.mark.parametrize("n,npods", [(32, 2), (32, 4), (64, 2), (128, 8),
                                     (32, 32)])
def test_hier_plan_step_count_and_payload_conservation(n, npods):
    """2(m-1) + 2(n_pods-1) steps; total offered bytes == the flat
    ring's 2(N-1) * message regardless of pod count; intra phases move
    M/m per step, the dci phase M/n_pods."""
    p = topology.hier_params(
        npods, n_nodes=n, schedule="hier",
        base=SimParams(net=NetworkParams(n_nodes=n, nodes_per_tor=1)))
    plan = schedule.make_plan(p.net, p.topo, p.work)
    m = n // npods
    assert plan.steps_per_round == 2 * (m - 1) + 2 * (npods - 1)
    ring = schedule.RingSchedule().plan(p.net, p.topo, p.work)
    assert ring.steps_per_round == 2 * (n - 1)
    assert plan.bytes_per_round() == ring.bytes_per_round()
    M = p.work.message_bytes
    for ph in plan.phases:
        if ph.name == "dci":
            assert ph.payload_bytes == M // npods
            assert ph.src.size == npods
        else:
            assert ph.payload_bytes == M // m
            assert ph.src.size == n


def test_hier_plan_tier_map():
    """Only the dci phase crosses pods; rs/ag stay on tor/spine.  The
    per-step table and the per-tier packet exposure agree with it."""
    p = topology.hier_params(
        4, n_nodes=32, schedule="hier",
        base=SimParams(net=NetworkParams(n_nodes=32, nodes_per_tor=4)))
    plan = schedule.make_plan(p.net, p.topo, p.work)
    by_name = {ph.name: hg for ph, hg in
               zip(plan.phases, plan.geometries(p.net, p.topo))}
    assert by_name["dci"].tier_counts[2] == 4          # all leader flows
    assert by_name["dci"].tier_counts[:2].sum() == 0
    assert by_name["rs"].tier_counts[2] == 0           # nothing crosses
    table = plan.step_table(p.net, p.topo)
    assert len(table) == plan.steps_per_round
    dci_steps = [row for row in table if (row[2] == 2).any()]
    assert len(dci_steps) == 2 * (p.topo.n_pods - 1)
    pkts = plan.tier_pkts_round(p.net, p.topo)
    dci_pkts = max(1, (p.work.message_bytes // 4) // p.net.mtu_bytes)
    assert pkts[2] == 4 * 2 * (4 - 1) * dci_pkts


def test_hier_plan_one_pod_degenerates_to_ring():
    p = topology.hier_params(1, base=SMALL, schedule="hier")
    plan = schedule.make_plan(p.net, p.topo, p.work)
    ring = schedule.RingSchedule().plan(p.net, p.topo, p.work)
    assert plan.single_phase and plan.schedule == "hier"
    assert plan.steps_per_round == ring.steps_per_round
    np.testing.assert_array_equal(plan.phases[0].dst, ring.phases[0].dst)
    assert plan.phases[0].payload_bytes == ring.phases[0].payload_bytes


def test_unknown_schedule_and_legacy_guards():
    with pytest.raises(ValueError, match="unknown collective schedule"):
        schedule.get_schedule("butterfly")
    hp = topology.hier_params(2, base=SMALL, schedule="hier")
    with pytest.raises(ValueError, match="legacy_streams"):
        BatchedEngine(hp).traces(["celeris"], 5, 0, legacy_streams=True)
    with pytest.raises(ValueError, match="non-ring schedule"):
        sweep(BatchedSimParams(n_nodes=(32,), schedules=("ring", "hier"),
                               legacy_streams=True, base=SMALL))


# -------------------------------------- engine under the hier schedule

def test_hier_schedule_tier_accounting_follows_plan():
    """RoundStats tier exposure equals the plan's step→tier packet
    formula, and the scalar fraction recombines from the tier
    fractions weighted by offered packets."""
    hp = topology.hier_params(2, base=SMALL, dci_oversubscription=8.0,
                              schedule="hier")
    eng = BatchedEngine(hp)
    tr = eng.traces(["celeris"], 30, seed=2, legacy_streams=False)
    plan = schedule.make_plan(hp.net, hp.topo, hp.work)
    want_pkts = plan.tier_pkts_round(hp.net, hp.topo)
    steps = plan.steps_per_round
    t_total = tr["celeris"].tier_total.reshape(-1, steps, 3)
    np.testing.assert_array_equal(t_total.sum(axis=1),
                                  np.broadcast_to(want_pkts, (30, 3)))
    st = eng.assemble(tr["celeris"], 2, celeris_timeout_us=50_000.0,
                      adaptive=False, window="round")
    np.testing.assert_array_equal(st.tier_pkts, want_pkts)
    recomb = ((st.tier_recv_frac * want_pkts).sum(axis=1)
              / want_pkts.sum())
    np.testing.assert_allclose(recomb, st.recv_frac, atol=1e-9)


def test_hier_schedule_beats_ring_under_oversubscription():
    """The Fig.-5 claim at test scale: on the same oversubscribed
    fabric the hierarchical schedule's celeris p99 lands below the
    flat ring's (the DCI penalty hits 2(n_pods-1) steps, not all)."""
    cells = {}
    for sched in ("ring", "hier"):
        hp = topology.hier_params(2, base=SMALL, dci_oversubscription=8.0,
                                  schedule=sched)
        cells[sched] = topology.hier_protocol(hp, n_rounds=60,
                                              seed=0)["celeris"]
    assert cells["hier"].p99 < cells["ring"].p99
    # the hier round is also shorter step-wise: 2(m-1)+2 vs 2(n-1)
    assert cells["hier"].times_us.shape == cells["ring"].times_us.shape


def test_sweep_schedule_dimension():
    common = dict(n_nodes=(32,), message_mb=(4.0,), seeds=(0,),
                  designs=("roce", "celeris"), n_rounds=20,
                  base=topology.hier_params(2, base=SMALL,
                                            dci_oversubscription=8.0))
    flat = sweep(BatchedSimParams(n_pods=(2,), **common))
    assert ("celeris", 32, 4.0, 0, 2) in flat.stats    # pod-keyed only
    res = sweep(BatchedSimParams(n_pods=(2,), schedules=("ring", "hier"),
                                 **common))
    assert ("celeris", 32, 4.0, 0, 2, "hier") in res.stats
    by_sched = res.p99_vs_schedule("celeris")
    assert set(by_sched) == {"ring", "hier"}
    # the ring cell of a schedule sweep matches the schedule-less sweep
    # bit-exactly (ring stays the default, untouched path)
    np.testing.assert_array_equal(
        res.stats[("celeris", 32, 4.0, 0, 2, "ring")].times_us,
        flat.stats[("celeris", 32, 4.0, 0, 2)].times_us)
    rows = res.summary_rows()
    assert len(rows) == 4 and all(len(r) == 9 for r in rows)


def test_split_schedule_uses_plan_exposure():
    """Axis-split coupling weights tiers by the schedule's offered
    packets (tier_pkts), and works on the hier schedule."""
    sched = coupling.split_schedule_from_engine(
        30, seed=4, params=SMALL, n_pods=2, dci_oversubscription=8.0,
        schedule="hier", timeout_scale=0.8)
    assert "sched=hier" in sched.source
    assert sched.cross.rates.size == 30
    assert sched.cross.mean >= 0.0
    # parity with the engine's own tier stats under pkts weighting
    hp = topology.hier_params(2, base=SMALL, dci_oversubscription=8.0,
                              schedule="hier")
    cel = topology.hier_protocol(hp, n_rounds=30, seed=4,
                                 timeout_scale=0.8)["celeris"]
    w = cel.tier_pkts
    want_intra = 1.0 - ((cel.tier_recv_frac[:, :2] * w[:2]).sum(axis=1)
                        / w[:2].sum())
    np.testing.assert_allclose(
        sched.intra.rates, np.clip(want_intra, 0, coupling.MAX_DROP),
        atol=1e-12)
    np.testing.assert_allclose(
        sched.cross.rates,
        np.clip(1.0 - cel.tier_recv_frac[:, 2], 0, coupling.MAX_DROP),
        atol=1e-12)


def test_step_window_runs_on_multi_phase_plan():
    """The old single-phase guard is subsumed by the per-phase budget
    machinery (ISSUE 5): the step window now divides each phase's
    ``budget_frac`` share over its steps, so it runs on the hier plan
    — and still demands per-flow data."""
    hp = topology.hier_params(2, base=SMALL, schedule="hier")
    eng = BatchedEngine(hp)
    st = eng.run("celeris", 10, window="step", adaptive=False,
                 legacy_streams=False, celeris_timeout_us=50_000.0)
    assert st.times_us.shape == (10,)
    assert np.all(st.times_us <= 50_000.0 + 1e-6)
    assert np.all((st.recv_frac >= 0) & (st.recv_frac <= 1))
    assert st.tier_recv_frac.shape == (10, 3)
    assert st.pod_recv_frac.shape == (10, 2)
    # per-flow data is still required
    tr = eng.traces(["celeris"], 5, 0, legacy_streams=False)
    with pytest.raises(ValueError, match="per-flow"):
        eng.assemble(tr["celeris"], 0, window="step", adaptive=False)


# ------------------------------------------- per-pod oversubscription

def test_per_pod_oversub_scalar_vector_parity():
    """A uniform per-pod vector must be bit-identical to the scalar,
    and a hot pod must inflate the tail beyond the uniform baseline."""
    p99 = {}
    for key, ov in (("scalar", 4.0), ("vec", (4.0, 4.0)),
                    ("hot", (16.0, 4.0))):
        hp = topology.hier_params(2, base=SMALL, dci_oversubscription=ov)
        st = topology.hier_protocol(hp, n_rounds=40, seed=3)["roce"]
        p99[key] = st.times_us
    np.testing.assert_array_equal(p99["scalar"], p99["vec"])
    assert np.percentile(p99["hot"], 99) > np.percentile(p99["scalar"], 99)


def test_per_pod_vector_validation():
    with pytest.raises(ValueError, match="per-pod dci_oversubscription"):
        topology.validate(NetworkParams(n_nodes=32),
                          TopologyParams(n_pods=2,
                                         dci_oversubscription=(2.0, 2.0,
                                                               2.0)))
    with pytest.raises(ValueError, match="oversubscription must be >= 1"):
        topology.validate(NetworkParams(n_nodes=32),
                          TopologyParams(n_pods=2,
                                         dci_oversubscription=(2.0, 0.5)))
    with pytest.raises(ValueError, match="dci_burst_on_prob"):
        topology.validate(NetworkParams(n_nodes=32),
                          TopologyParams(n_pods=2,
                                         dci_burst_on_prob=(0.1, 1.5)))


def test_per_pod_burst_rate_vector_runs():
    """Hot-pod burst vector: at a fixed window budget the hot pod's
    extra DCI bursts raise the cross-pod loss vs an all-calm vector.
    The budget is pinned from the calm scenario — the adaptive rule
    derives it from RoCE's median + sigma, and hot bursts inflate that
    sigma (PFC cascades) faster than they slow Celeris, which would
    compare the two scenarios at very different windows."""
    loss = {}
    to = None
    for key, on in (("calm", (0.0, 0.0)), ("hot", (0.3, 0.3))):
        hp = topology.hier_params(2, base=SMALL, dci_oversubscription=8.0,
                                  dci_burst_on_prob=on)
        eng = BatchedEngine(hp)
        tr = eng.traces(["roce", "celeris"], 40, 1, legacy_streams=False)
        if to is None:      # calm-scenario window, held fixed for both
            base = eng.assemble(tr["roce"], 1)
            to = float((np.percentile(base.times_us, 50)
                        + base.times_us.std()) * 0.8)
        cel = eng.assemble(tr["celeris"], 1, celeris_timeout_us=to,
                           adaptive=False)
        loss[key] = cel.tier_loss("dci")
    assert loss["hot"] > loss["calm"]


# ------------------- priority classes & priority-ordered window cuts

def _priority_cell(npods, n_rounds=40, seed=7, scale=0.5):
    """One hier cell assembled under both cut orders at the same
    (tight) budget, with layer-depth priority classes attached."""
    base = SimParams(net=NetworkParams(n_nodes=32, nodes_per_tor=32 // npods,
                                       burst_on_prob=0.0008))
    hp = topology.hier_params(npods, base=base, dci_oversubscription=8.0,
                              schedule="hier")
    eng = BatchedEngine(hp)
    plan = schedule.make_plan(hp.net, hp.topo, hp.work)
    cls = schedule.layer_priorities(plan)
    tr = eng.traces(["roce", "celeris"], n_rounds, seed,
                    legacy_streams=False)
    cel = dataclasses.replace(tr["celeris"], step_priority=cls)
    base = eng.assemble(tr["roce"], seed)
    to = float((np.percentile(base.times_us, 50)
                + base.times_us.std()) * scale)
    stats = {o: eng.assemble(cel, seed, celeris_timeout_us=to,
                             adaptive=False, window="round", cut_order=o)
             for o in ("arrival", "priority")}
    return plan, cls, stats


@pytest.mark.parametrize("npods", [2, 4])
def test_priority_cut_conserves_totals_vs_arrival(npods):
    """Property: at an equal budget the priority order cuts the SAME
    total bytes as arrival — times, scalar fractions, and the
    per-class delivered-packet sum are all conserved; only *which*
    class the cut lands on moves (low classes absorb it)."""
    plan, cls, stats = _priority_cell(npods)
    arr, pri = stats["arrival"], stats["priority"]
    np.testing.assert_array_equal(arr.times_us, pri.times_us)
    np.testing.assert_array_equal(arr.recv_frac, pri.recv_frac)
    # both orders slice one survive vector: identical offered pkts
    # per class (the layer_priorities override gives 3 classes here)...
    assert arr.prio_pkts.size == int(cls.max()) + 1 == 3
    np.testing.assert_array_equal(arr.prio_pkts, pri.prio_pkts)
    # ...and identical total delivered packets per round
    got_arr = (arr.prio_recv_frac * arr.prio_pkts).sum(axis=1)
    got_pri = (pri.prio_recv_frac * pri.prio_pkts).sum(axis=1)
    np.testing.assert_allclose(got_pri, got_arr, rtol=1e-12, atol=1e-6)
    # the budget binds in this cell, and the reorder moves the cut
    # down the class ladder: top class never loses more, class 0
    # never loses less
    top = arr.prio_pkts.size - 1
    assert arr.prio_loss(top) > 0.0          # arrival cuts exact shards
    assert pri.prio_loss(top) <= arr.prio_loss(top)
    assert pri.prio_loss(0) >= arr.prio_loss(0)


def test_priority_cut_uniform_classes_match_arrival_bitexact():
    """A single-class plan (flat ring) makes the priority cut land on
    the same trailing steps as arrival: times and scalar fractions are
    bit-identical, and the recomputed group allocations agree to float
    round-off (the reallocation sums in a different order)."""
    eng = BatchedEngine(SMALL)
    tr = eng.traces(["roce", "celeris"], 40, seed=11,
                    legacy_streams=False)
    base = eng.assemble(tr["roce"], 11)
    to = float(np.percentile(base.times_us, 50)
               + base.times_us.std()) * 0.8
    kw = dict(celeris_timeout_us=to, adaptive=False, window="round")
    arr = eng.assemble(tr["celeris"], 11, cut_order="arrival", **kw)
    pri = eng.assemble(tr["celeris"], 11, cut_order="priority", **kw)
    np.testing.assert_array_equal(arr.times_us, pri.times_us)
    np.testing.assert_array_equal(arr.recv_frac, pri.recv_frac)
    np.testing.assert_allclose(pri.tier_recv_frac, arr.tier_recv_frac,
                               rtol=0, atol=1e-12)
    np.testing.assert_allclose(pri.prio_recv_frac, arr.prio_recv_frac,
                               rtol=0, atol=1e-12)


def test_arrival_cut_bitexact_vs_pinned_with_priority_metadata():
    """cut_order='arrival' (explicit) over a trace that CARRIES
    priority metadata still reproduces the committed pre-priority seed
    stats bit-for-bit — priority is assembly-time metadata and must
    never perturb the pinned arrival path."""
    ref = _pinned()["flat"]
    eng = BatchedEngine(SMALL)
    tr = eng.traces(["roce", "celeris"], 40, seed=11,
                    legacy_streams=False)
    assert tr["celeris"].step_priority is not None   # engine-attached
    base = eng.assemble(tr["roce"], 11)
    to = float(np.percentile(base.times_us, 50)
               + base.times_us.std()) * 0.8
    cel = eng.assemble(tr["celeris"], 11, celeris_timeout_us=to,
                       adaptive=False, window="round",
                       cut_order="arrival")
    np.testing.assert_array_equal(cel.times_us,
                                  np.array(ref["celeris_times_us"]))
    np.testing.assert_array_equal(cel.recv_frac,
                                  np.array(ref["celeris_recv_frac"]))


def test_layer_priorities_structure():
    """dci steps stay class 0, the trailing half of the all-gather is
    promoted to a new top class, and plans without an ag phase come
    back unchanged."""
    hp = topology.hier_params(2, base=SMALL, schedule="hier")
    plan = schedule.make_plan(hp.net, hp.topo, hp.work)
    cls = schedule.layer_priorities(plan)
    phase_cls = plan.step_priority()
    assert cls.max() == phase_cls.max() + 1
    dci = np.array([plan.phases[k].name == "dci"
                    for k in plan.phase_of_step])
    np.testing.assert_array_equal(cls[dci], 0)
    ag = np.array([plan.phases[k].name.startswith("ag")
                   for k in plan.phase_of_step])
    n_top = int(round(ag.sum() * 0.5))
    assert (cls == cls.max()).sum() == n_top
    assert np.all(np.where(cls == cls.max())[0]
                  >= np.where(ag)[0][-1] - n_top)
    ring = schedule.RingSchedule().plan(SMALL.net, SMALL.topo, SMALL.work)
    np.testing.assert_array_equal(schedule.layer_priorities(ring),
                                  ring.step_priority())


def test_priority_cut_guards():
    eng = BatchedEngine(SMALL)
    tr = eng.traces(["celeris"], 5, 0, legacy_streams=False)
    with pytest.raises(ValueError, match="cut_order must be"):
        eng.assemble(tr["celeris"], 0, cut_order="random")
    bare = dataclasses.replace(tr["celeris"], step_priority=None)
    with pytest.raises(ValueError, match="step_priority"):
        eng.assemble(bare, 0, cut_order="priority",
                     celeris_timeout_us=30_000.0, adaptive=False)
    with pytest.raises(ValueError, match="step window"):
        eng.assemble(tr["celeris"], 0, cut_order="priority",
                     window="step", celeris_timeout_us=30_000.0,
                     adaptive=False)
    plan = schedule.make_plan(SMALL.net, SMALL.topo, SMALL.work)
    with pytest.raises(ValueError, match="shape"):
        schedule.with_step_priorities(plan, np.zeros(3, dtype=int))
    with pytest.raises(ValueError, match=">= 0"):
        schedule.with_step_priorities(
            plan, -np.ones(plan.steps_per_round, dtype=int))


# ------------------------- hierarchical mode + DCI-only quantization

def test_hierarchical_mode_dci_quantized_roundtrip_8dev():
    """Train step under CollectiveMode.HIERARCHICAL with
    quantize_wire=True on a 2-pod x 4-data mesh: the cross-pod shards
    ship int8 while intra-pod sync stays f32.  Zero cross-drop must
    track the exact baseline closely (quantization noise only), and at
    a real cross rate the realized received fraction tracks 1 - drop."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        import repro.configs as C
        from repro import sharding as shd
        from repro.data.pipeline import DataConfig, make_source
        from repro.optim.adamw import OptConfig
        from repro.train import train_step as ts, sharding_rules as rules
        mesh = shd.make_mesh((2, 4), ('pod', 'data'))
        shd.set_global_mesh(mesh)
        cfg = C.get_smoke('qwen2-0.5b')
        src = make_source(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                     global_batch=8, seed=1))
        host = src.global_batch(0, 8)
        sp = rules.batch_specs(mesh, host)
        batch = {k: jax.device_put(
                     v, jax.sharding.NamedSharding(mesh, sp[k]))
                 for k, v in host.items()}

        def step_with(mode, drop, quant):
            fn = ts.make_train_step(cfg, mesh, OptConfig(lr=1e-3),
                                    ts.CelerisConfig(mode=mode,
                                                     min_coded_size=1024,
                                                     quantize_wire=quant))
            st = ts.init_state(jax.random.PRNGKey(0), cfg)
            st = jax.device_put(st, ts.state_shardings(st, mesh))
            st, m = fn(st, batch, jax.random.PRNGKey(1),
                       jnp.asarray(drop, jnp.float32))
            return {k: float(v) for k, v in m.items()}

        m_ex = step_with('exact', 0.0, False)
        m_q0 = step_with('hierarchical', [0.0, 0.0], True)
        assert m_q0['recv_frac'] == 1.0, m_q0
        # int8 wire noise on the DCI axis only: loss stays close to
        # exact, far tighter than any drop-induced deviation
        assert abs(m_q0['loss'] - m_ex['loss']) < 5e-3, (m_ex, m_q0)
        m_qd = step_with('hierarchical', [0.0, 0.25], True)
        assert abs(m_qd['recv_frac'] - 0.75) < 0.05, m_qd
        assert np.isfinite(m_qd['loss'])
        print('OK')
    """)
