"""Hierarchical multi-pod topology subsystem (ISSUE 3 acceptance tests).

- flat (n_pods=1) runs are bit-compatible with the pre-topology engine:
  the DCI tier must never perturb flat traces, whatever its parameters;
- per-tier delivered fractions are consistent with the scalar fraction
  and ordered (cross-pod <= intra-pod under DCI oversubscription);
- the axis-split coupling reproduces engine tier output exactly;
- the hierarchical collective mode round-trips on a real 8-device
  (pod, data) mesh, and (slow) lowers at 512 simulated devices with
  plain collectives only.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.transport import (BatchedEngine, BatchedSimParams,
                                  NetworkParams, SimParams, TopologyParams,
                                  coupling, sweep, topology)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SMALL = SimParams(net=NetworkParams(n_nodes=32, burst_on_prob=0.0008))


def _run(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


# --------------------------------------------------- n_pods=1 bit-compat

def test_flat_traces_immune_to_dci_params():
    """A 1-pod topology with arbitrarily hostile DCI parameters must
    reproduce the default engine bit-exactly: the DCI tier may not
    consume fabric randomness or touch any flow column when no flow
    crosses a pod boundary."""
    hostile = TopologyParams(n_pods=1, dci_oversubscription=64.0,
                             dci_burst_on_prob=0.5, dci_idle_occupancy=0.9,
                             dci_rtt_us=1e6)
    base = BatchedEngine(SMALL)
    mod = BatchedEngine(dataclasses.replace(SMALL, topo=hostile))
    for legacy in (False, True):
        tb = base.traces(["roce", "celeris"], 30, seed=7,
                         legacy_streams=legacy)
        tm = mod.traces(["roce", "celeris"], 30, seed=7,
                        legacy_streams=legacy)
        for d in ("roce", "celeris"):
            np.testing.assert_array_equal(tb[d].nat_us, tm[d].nat_us)
            np.testing.assert_array_equal(tb[d].deliv, tm[d].deliv)
            np.testing.assert_array_equal(tb[d].tier_deliv,
                                          tm[d].tier_deliv)


def test_flat_round_stats_match_seeded_engine():
    """RoundStats through the topology-aware assemble equal the seeded
    engine's scalar stats, and the new tier axis is self-consistent:
    tier fractions recombine (delivered-weighted) into recv_frac."""
    eng = BatchedEngine(SMALL)
    tr = eng.traces(["roce", "celeris"], 50, seed=3, legacy_streams=False)
    base = eng.assemble(tr["roce"], 3)
    to = float(np.percentile(base.times_us, 50) + base.times_us.std()) * 0.8
    for st in (base, eng.assemble(tr["celeris"], 3, celeris_timeout_us=to,
                                  adaptive=False, window="round")):
        assert st.tier_recv_frac is not None
        assert st.tier_counts.sum() == SMALL.net.n_nodes
        assert st.tier_counts[2] == 0          # no cross-pod flows flat
        # empty tiers report fraction 1 (nothing to lose)
        np.testing.assert_array_equal(st.tier_recv_frac[:, 2], 1.0)
    # tier consistency on the windowed celeris stats: per-round payload
    # recombines because tiers partition the flows
    cel = eng.assemble(tr["celeris"], 3, celeris_timeout_us=to,
                       adaptive=False, window="round")
    steps = tr["celeris"].steps_per_round
    t_total = tr["celeris"].tier_total.reshape(-1, steps, 3).sum(axis=1)
    recombined = ((cel.tier_recv_frac * t_total).sum(axis=1)
                  / np.maximum(t_total.sum(axis=1), 1.0))
    np.testing.assert_allclose(recombined, cel.recv_frac, atol=1e-9)


def test_hier_requires_shared_mode_and_valid_geometry():
    hp = topology.hier_params(2, base=SMALL)
    with pytest.raises(ValueError, match="legacy_streams"):
        BatchedEngine(hp).traces(["celeris"], 5, 0, legacy_streams=True)
    with pytest.raises(ValueError, match="multiple"):
        topology.validate(NetworkParams(n_nodes=48), TopologyParams(n_pods=5))
    with pytest.raises(ValueError, match="oversubscription"):
        topology.validate(NetworkParams(),
                          TopologyParams(n_pods=2,
                                         dci_oversubscription=0.5))


# --------------------------------------------------- per-tier sanity

def test_cross_pod_delivers_no_more_than_intra():
    """Under an oversubscribed, busier DCI the cross-pod tier's mean
    delivered fraction must not exceed the intra-pod tiers'."""
    hp = topology.hier_params(2, base=SMALL, dci_oversubscription=8.0)
    cel = topology.hier_protocol(hp, n_rounds=80, seed=0,
                                 timeout_scale=0.8)["celeris"]
    sched = coupling.split_schedule_from_round_stats(cel)
    assert sched.cross.mean >= sched.intra.mean
    assert sched.cross.mean > 0.0
    # the dci tier itself is the lossiest of the three
    assert cel.tier_loss("dci") >= cel.tier_loss("tor")
    assert cel.tier_loss("dci") >= cel.tier_loss("spine")


def test_dci_oversubscription_inflates_cross_pod_tail():
    p99 = {}
    for ov in (1.0, 8.0):
        hp = topology.hier_params(2, base=SMALL, dci_oversubscription=ov)
        p99[ov] = topology.hier_protocol(hp, n_rounds=60,
                                         seed=0)["roce"].p99
    assert p99[8.0] > 1.5 * p99[1.0]


def test_sweep_pod_dimension():
    common = dict(n_nodes=(32,), message_mb=(4.0,), seeds=(0,),
                  designs=("roce", "celeris"), n_rounds=20, base=SMALL)
    flat = sweep(BatchedSimParams(**common))
    assert ("celeris", 32, 4.0, 0) in flat.stats      # legacy 4-keys
    res = sweep(BatchedSimParams(n_pods=(1, 2), **common))
    assert ("celeris", 32, 4.0, 0, 2) in res.stats    # pod-keyed
    pods = res.p99_vs_pods("celeris")
    assert set(pods) == {1, 2} and pods[2][0] > 0
    # the 1-pod cell of a pod sweep matches the flat sweep bit-exactly
    np.testing.assert_array_equal(
        res.stats[("celeris", 32, 4.0, 0, 1)].times_us,
        flat.stats[("celeris", 32, 4.0, 0)].times_us)


# --------------------------------------------- axis-split schedule parity

def test_split_schedule_matches_engine_tiers():
    """coupling must not distort the engine's tier output: cross rate at
    step i == 1 - dci recv_frac of round i (clipped), intra == the
    count-weighted tor+spine combination."""
    hp = topology.hier_params(2, base=SMALL, dci_oversubscription=8.0)
    cel = topology.hier_protocol(hp, n_rounds=40, seed=5,
                                 timeout_scale=0.8)["celeris"]
    sched = coupling.split_schedule_from_engine(
        40, seed=5, params=SMALL, n_pods=2, dci_oversubscription=8.0,
        timeout_scale=0.8)
    np.testing.assert_allclose(
        sched.cross.rates,
        np.clip(1.0 - cel.tier_recv_frac[:, 2], 0, coupling.MAX_DROP),
        atol=1e-12)
    c = cel.tier_counts.astype(float)
    want_intra = 1.0 - ((cel.tier_recv_frac[:, :2] * c[:2]).sum(axis=1)
                        / c[:2].sum())
    np.testing.assert_allclose(
        sched.intra.rates, np.clip(want_intra, 0, coupling.MAX_DROP),
        atol=1e-12)

    # the trainer adapter walks every axis in lockstep; since ISSUE 5
    # multi-pod engine runs refine intra into per-pod schedules, so the
    # vector is (n_pods + 1,) with cross still the last element
    m = coupling.HierStragglerModel(sched)
    v0 = m.drop_rate(2.0, None)
    assert v0.shape == (3,)
    for p in range(2):
        assert v0[p] == pytest.approx(sched.per_pod[p].rate(0))
    assert v0[-1] == pytest.approx(sched.cross.rate(0))
    assert m.drop_rate(2.0, None)[-1] == pytest.approx(sched.cross.rate(1))
    # and the per-pod rates recombine to the aggregate intra rate
    w = cel.pod_pkts
    np.testing.assert_allclose(
        (np.array([sched.per_pod[p].rates for p in range(2)]).T
         * w).sum(axis=1) / w.sum(),
        np.clip(want_intra, 0, coupling.MAX_DROP), atol=1e-9)


def test_split_schedule_requires_tier_stats():
    from repro.core.transport.engine import RoundStats
    bare = RoundStats(times_us=np.ones(3), recv_frac=np.ones(3),
                      design="celeris")
    with pytest.raises(ValueError, match="tier"):
        coupling.split_schedule_from_round_stats(bare)


# ------------------------------------- hierarchical mode (8-device mesh)

def test_hierarchical_mode_roundtrip_8dev():
    """Full train step under CollectiveMode.HIERARCHICAL on a 2-pod x
    4-data mesh: zero cross-drop is exact (recv_frac 1, same first-step
    loss as exact mode), and at an engine-style cross rate the realized
    received fraction tracks 1 - drop."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        import repro.configs as C
        from repro import sharding as shd
        from repro.data.pipeline import DataConfig, make_source
        from repro.optim.adamw import OptConfig
        from repro.train import train_step as ts, sharding_rules as rules
        mesh = shd.make_mesh((2, 4), ('pod', 'data'))
        shd.set_global_mesh(mesh)
        cfg = C.get_smoke('qwen2-0.5b')
        src = make_source(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                     global_batch=8, seed=1))
        host = src.global_batch(0, 8)
        sp = rules.batch_specs(mesh, host)
        batch = {k: jax.device_put(
                     v, jax.sharding.NamedSharding(mesh, sp[k]))
                 for k, v in host.items()}

        def step_with(mode, drop):
            fn = ts.make_train_step(cfg, mesh, OptConfig(lr=1e-3),
                                    ts.CelerisConfig(mode=mode,
                                                     min_coded_size=1024))
            st = ts.init_state(jax.random.PRNGKey(0), cfg)
            st = jax.device_put(st, ts.state_shardings(st, mesh))
            st, m = fn(st, batch, jax.random.PRNGKey(1),
                       jnp.asarray(drop, jnp.float32))
            return {k: float(v) for k, v in m.items()}

        m_ex = step_with('exact', 0.0)
        m_h0 = step_with('hierarchical', [0.0, 0.0])
        assert m_h0['recv_frac'] == 1.0, m_h0
        assert abs(m_h0['loss'] - m_ex['loss']) < 1e-4, (m_ex, m_h0)
        m_hd = step_with('hierarchical', [0.0, 0.2])
        assert abs(m_hd['recv_frac'] - 0.8) < 0.05, m_hd
        assert np.isfinite(m_hd['loss'])
        print('OK')
    """)


def test_hierarchical_mode_needs_pod_axis():
    from repro.optim.adamw import OptConfig
    from repro.train import train_step as ts
    import repro.configs as C

    class FakeMesh:      # axis introspection only; never traced
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}

    with pytest.raises(ValueError, match="pod"):
        ts.make_train_step(C.get_smoke("qwen2-0.5b"), FakeMesh(),
                           OptConfig(),
                           ts.CelerisConfig(mode="hierarchical"))


@pytest.mark.slow
def test_scale_check_512_hierarchical_lowers_plain_collectives():
    """dryrun scale check with mode=hierarchical at 512 devices: the
    intra-exact + cross-coded island lowers to plain collectives."""
    out = _run("""
        from repro.launch import dryrun
        rec = dryrun.scale_check_cell('qwen2-0.5b', 512,
                                      mode='hierarchical')
        assert rec['ok'], rec
        assert rec['illegal_collectives'] == {}, rec
        assert 'all_reduce' in rec['collective_ops'], rec
        print('OK')
    """, devices=512, timeout=560)
    assert "OK" in out
