"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:                     # container lacks hypothesis
    from _propcheck import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(8, 128), (3, 256), (100, 4096), (1, 2), (16, 1024), (257, 512)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("rows,n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fwht_matches_oracle(rows, n, dtype):
    x = jax.random.normal(jax.random.PRNGKey(rows * n), (rows, n), dtype)
    got = ops.fwht(x)
    want = ref.fwht(x)
    tol = 1e-4 if dtype == jnp.float32 else 8e-2 * np.sqrt(n)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_fwht_matches_hadamard_matmul():
    n = 256
    x = jax.random.normal(jax.random.PRNGKey(0), (5, n))
    h = ref.hadamard_matrix(n)
    np.testing.assert_allclose(np.asarray(ops.fwht(x)), np.asarray(x @ h),
                               rtol=1e-4, atol=1e-3)


@hypothesis.given(st.integers(1, 40), st.integers(1, 9))
@hypothesis.settings(max_examples=12, deadline=None)
def test_fwht_involution(rows, log_n):
    """H(H(x)) = n * x  (Hadamard is an involution up to scale)."""
    n = 1 << log_n
    x = jax.random.normal(jax.random.PRNGKey(rows + log_n), (rows, n))
    y = ops.fwht(ops.fwht(x)) / n
    np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                               rtol=2e-4, atol=2e-4)


@hypothesis.given(st.integers(1, 6))
@hypothesis.settings(max_examples=6, deadline=None)
def test_fwht_orthogonality(log_n):
    """Parseval: ||Hx||^2 = n ||x||^2."""
    n = 1 << log_n
    x = jax.random.normal(jax.random.PRNGKey(log_n), (4, n))
    lhs = jnp.sum(jnp.square(ops.fwht(x)), -1)
    rhs = n * jnp.sum(jnp.square(x), -1)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4)


@pytest.mark.parametrize("rows,n", [(8, 128), (64, 512), (3, 64)])
def test_quantize_matches_oracle(rows, n):
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (rows, n)) * 3
    noise = jax.random.uniform(jax.random.fold_in(key, 1), (rows, n))
    q1, s1 = ops.quantize_int8(x, noise)
    q2, s2 = ref.quantize_int8(x, noise)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(2), (16, 256))
    noise = jax.random.uniform(jax.random.PRNGKey(3), (16, 256))
    q, s = ops.quantize_int8(x, noise)
    err = jnp.abs(ops.dequantize_int8(q, s) - x)
    # absmax/127 quantum bound per row
    bound = (jnp.max(jnp.abs(x), -1) / 127.0 * 1.001)[:, None]
    assert bool(jnp.all(err <= bound + 1e-6))


@pytest.mark.parametrize("rows,n", [(8, 128), (32, 64)])
def test_masked_unbias_matches_oracle(rows, n):
    y = jax.random.normal(jax.random.PRNGKey(4), (rows, n))
    c = jax.random.randint(jax.random.PRNGKey(5), (rows,), 0, 5).astype(
        jnp.float32)
    got = ops.masked_unbias(y, c, total=4)
    want = ref.masked_unbias(y, c, 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ------------------------------------------------ fused rotate+quantize

@pytest.mark.parametrize("rows,n", [(8, 128), (3, 256), (100, 1024)])
def test_fwht_quantize_fused_matches_unfused_pallas(rows, n):
    """The fused kernel's rotate stage is the same two-matmul body as
    fwht_pallas, so fused == (pallas fwht -> pallas quantize) exactly."""
    key = jax.random.PRNGKey(rows + n)
    x = jax.random.normal(key, (rows, n))
    signs = jax.random.rademacher(jax.random.fold_in(key, 1), (n,),
                                  dtype=jnp.float32)
    noise = jax.random.uniform(jax.random.fold_in(key, 2), (rows, n))
    q1, s1 = ops.fwht_quantize(x, noise, signs=signs, scale=n ** -0.5)
    y = ops.fwht(x, signs=signs, scale=n ** -0.5)
    q2, s2 = ops.quantize_int8(y, noise)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-7)


def test_fwht_quantize_matches_oracle_dequantized():
    """Against the jnp oracle pair the int8 codes may differ by 1 where
    the butterfly vs matmul rotation differs at f32 ulp; the
    dequantized payloads agree to quantization-step tolerance."""
    rows, n = (16, 512)
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (rows, n))
    noise = jax.random.uniform(jax.random.fold_in(key, 2), (rows, n))
    q1, s1 = ops.fwht_quantize(x, noise, scale=n ** -0.5)
    q2, s2 = ops.fwht_quantize(x, noise, scale=n ** -0.5,
                               use_pallas=False)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5)
    d1 = np.asarray(ops.dequantize_int8(q1, s1))
    d2 = np.asarray(ops.dequantize_int8(q2, s2))
    step = np.asarray(s2)[:, None]
    assert np.all(np.abs(d1 - d2) <= 1.001 * step)


def test_encode_quantized_roundtrip():
    """encode_quantized -> dequantize_wire -> decode recovers the
    payload to quantization tolerance when nothing is dropped."""
    from repro.core import coding
    code = coding.plan(1000, n_rot=256)
    key = jax.random.PRNGKey(11)
    signs = coding.rademacher(jax.random.fold_in(key, 0), code)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1000,))
    q_wire, scales = coding.encode_quantized(
        x, signs, code, jax.random.fold_in(key, 2))
    assert q_wire.dtype == jnp.int8 and q_wire.shape == code.wire_shape
    wire = coding.dequantize_wire(q_wire, scales)
    counts = jnp.ones(code.n_rot)
    out = coding.decode(wire, counts, signs, code, total_peers=1)
    # absmax/127 per block, rotated back: bound the error loosely
    tol = float(jnp.max(scales)) * np.sqrt(code.n_rot) * 1.5
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=tol)
    assert float(jnp.max(jnp.abs(out - x))) < 0.2
