"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:                     # container lacks hypothesis
    from _propcheck import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(8, 128), (3, 256), (100, 4096), (1, 2), (16, 1024), (257, 512)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("rows,n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fwht_matches_oracle(rows, n, dtype):
    x = jax.random.normal(jax.random.PRNGKey(rows * n), (rows, n), dtype)
    got = ops.fwht(x)
    want = ref.fwht(x)
    tol = 1e-4 if dtype == jnp.float32 else 8e-2 * np.sqrt(n)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_fwht_matches_hadamard_matmul():
    n = 256
    x = jax.random.normal(jax.random.PRNGKey(0), (5, n))
    h = ref.hadamard_matrix(n)
    np.testing.assert_allclose(np.asarray(ops.fwht(x)), np.asarray(x @ h),
                               rtol=1e-4, atol=1e-3)


@hypothesis.given(st.integers(1, 40), st.integers(1, 9))
@hypothesis.settings(max_examples=12, deadline=None)
def test_fwht_involution(rows, log_n):
    """H(H(x)) = n * x  (Hadamard is an involution up to scale)."""
    n = 1 << log_n
    x = jax.random.normal(jax.random.PRNGKey(rows + log_n), (rows, n))
    y = ops.fwht(ops.fwht(x)) / n
    np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                               rtol=2e-4, atol=2e-4)


@hypothesis.given(st.integers(1, 6))
@hypothesis.settings(max_examples=6, deadline=None)
def test_fwht_orthogonality(log_n):
    """Parseval: ||Hx||^2 = n ||x||^2."""
    n = 1 << log_n
    x = jax.random.normal(jax.random.PRNGKey(log_n), (4, n))
    lhs = jnp.sum(jnp.square(ops.fwht(x)), -1)
    rhs = n * jnp.sum(jnp.square(x), -1)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4)


@pytest.mark.parametrize("rows,n", [(8, 128), (64, 512), (3, 64)])
def test_quantize_matches_oracle(rows, n):
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (rows, n)) * 3
    noise = jax.random.uniform(jax.random.fold_in(key, 1), (rows, n))
    q1, s1 = ops.quantize_int8(x, noise)
    q2, s2 = ref.quantize_int8(x, noise)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(2), (16, 256))
    noise = jax.random.uniform(jax.random.PRNGKey(3), (16, 256))
    q, s = ops.quantize_int8(x, noise)
    err = jnp.abs(ops.dequantize_int8(q, s) - x)
    # absmax/127 quantum bound per row
    bound = (jnp.max(jnp.abs(x), -1) / 127.0 * 1.001)[:, None]
    assert bool(jnp.all(err <= bound + 1e-6))


@pytest.mark.parametrize("rows,n", [(8, 128), (32, 64)])
def test_masked_unbias_matches_oracle(rows, n):
    y = jax.random.normal(jax.random.PRNGKey(4), (rows, n))
    c = jax.random.randint(jax.random.PRNGKey(5), (rows,), 0, 5).astype(
        jnp.float32)
    got = ops.masked_unbias(y, c, total=4)
    want = ref.masked_unbias(y, c, 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
