"""Hadamard / XOR recovery invariants (hypothesis property tests)."""
try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:                     # container lacks hypothesis
    from _propcheck import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coding


@hypothesis.given(st.integers(10, 30000))
@hypothesis.settings(max_examples=15, deadline=None)
def test_lossless_roundtrip(n):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,))
    code = coding.plan(n)
    signs = coding.rademacher(jax.random.PRNGKey(1), code)
    wire = coding.encode(x, signs, code)
    assert wire.shape == code.wire_shape
    xhat = coding.decode(wire, jnp.ones((code.n_rot,)), signs, code)
    np.testing.assert_allclose(np.asarray(xhat), np.asarray(x),
                               rtol=1e-3, atol=1e-3)


@hypothesis.given(st.integers(0, 10_000), st.floats(0.01, 0.3))
@hypothesis.settings(max_examples=10, deadline=None)
def test_unbiasedness(seed, drop):
    """E[decode(masked encode)] == x over mask draws."""
    n = 3000
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    code = coding.plan(n)
    signs = coding.rademacher(jax.random.PRNGKey(7), code)
    wire = coding.encode(x, signs, code)
    ests = []
    for i in range(48):
        m = (jax.random.uniform(jax.random.PRNGKey(seed * 100 + i),
                                (code.n_rot,)) >= drop)
        ests.append(np.asarray(coding.decode(
            wire * m[:, None], m.astype(jnp.float32), signs, code)))
    bias = np.mean(ests, 0) - np.asarray(x)
    # bias -> 0 as 1/sqrt(#draws); allow 5 sigma of the estimator std
    std = np.std(ests, 0) / np.sqrt(len(ests))
    assert np.mean(np.abs(bias) <= 5 * std + 1e-3) > 0.97


def test_error_scales_with_loss():
    n = 8192
    x = jax.random.normal(jax.random.PRNGKey(3), (n,))
    code = coding.plan(n)
    signs = coding.rademacher(jax.random.PRNGKey(4), code)
    wire = coding.encode(x, signs, code)
    errs = []
    for drop in (0.01, 0.05, 0.2):
        m = (jax.random.uniform(jax.random.PRNGKey(5), (code.n_rot,)) >= drop)
        xh = coding.decode(wire * m[:, None], m.astype(jnp.float32),
                           signs, code)
        errs.append(float(jnp.linalg.norm(xh - x) / jnp.linalg.norm(x)))
    assert errs[0] < errs[1] < errs[2]
    assert errs[0] < 0.15


def test_energy_spreading():
    """A spiky vector's loss error is spread, not concentrated: after
    losing 10% of wire rows no single coordinate keeps a huge error."""
    n = 4096
    x = jnp.zeros((n,)).at[7].set(100.0)          # all energy in one coord
    code = coding.plan(n)
    signs = coding.rademacher(jax.random.PRNGKey(8), code)
    wire = coding.encode(x, signs, code)
    m = (jax.random.uniform(jax.random.PRNGKey(9), (code.n_rot,)) >= 0.1)
    xh = coding.decode(wire * m[:, None], m.astype(jnp.float32), signs, code)
    err = np.abs(np.asarray(xh - x))
    assert err[7] < 25.0                          # spike mostly recovered
    assert np.max(np.delete(err, 7)) < 25.0       # no other spike appears


@hypothesis.given(st.integers(2, 16), st.integers(0, 100))
@hypothesis.settings(max_examples=20, deadline=None)
def test_xor_single_loss_exact(g, seed):
    chunks = jax.random.normal(jax.random.PRNGKey(seed), (g, 32))
    parity = coding.xor_parity_encode(chunks)
    lost = seed % g
    arrived = jnp.ones((g,), bool).at[lost].set(False)
    rec = coding.xor_parity_decode(chunks * arrived[:, None], parity, arrived)
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(chunks))


def test_xor_double_loss_falls_back_to_zero():
    chunks = jax.random.normal(jax.random.PRNGKey(1), (6, 16))
    parity = coding.xor_parity_encode(chunks)
    arrived = jnp.ones((6,), bool).at[1].set(False).at[4].set(False)
    rec = coding.xor_parity_decode(chunks * arrived[:, None], parity, arrived)
    assert np.all(np.asarray(rec[1]) == 0) and np.all(np.asarray(rec[4]) == 0)
    np.testing.assert_array_equal(np.asarray(rec[0]), np.asarray(chunks[0]))
