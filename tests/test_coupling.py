"""Transport→trainer coupling layer (engine-derived drop schedules,
CollectiveMode dispatch, sharded encode→lossy_psum→decode roundtrip)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import sharding as shd
from repro.core.transport import (BatchedEngine, NetworkParams, SimParams,
                                  coupling)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SMOKE_PARAMS = SimParams(net=NetworkParams(n_nodes=32,
                                           burst_on_prob=0.0008))


def _run(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


# ------------------------------------------------------------- schedules

def test_schedule_matches_engine_round_stats():
    """The coupling layer must not distort engine output: schedule rate
    at step i == 1 - recv_frac of engine round i, same window math."""
    eng = BatchedEngine(SMOKE_PARAMS)
    tr = eng.traces(["roce", "celeris"], 40, seed=3, legacy_streams=False)
    base = eng.assemble(tr["roce"], 3)
    to = float(np.percentile(base.times_us, 50) + base.times_us.std()) * 0.8
    stats = eng.assemble(tr["celeris"], 3, celeris_timeout_us=to,
                         adaptive=False, window="round")
    sched = coupling.schedule_from_engine(40, seed=3, params=SMOKE_PARAMS,
                                          timeout_scale=0.8)
    np.testing.assert_allclose(
        sched.rates, np.clip(1.0 - stats.recv_frac, 0, coupling.MAX_DROP),
        atol=1e-12)
    assert sched.mean > 0.0          # the tight window actually drops data


def test_adaptive_schedule_uses_timeout_controller():
    """adaptive=True must reproduce the engine's controller-windowed
    recv_frac — i.e. the schedule really is the timeout controller's
    doing, not the fixed window's."""
    fixed = coupling.schedule_from_engine(60, seed=1, params=SMOKE_PARAMS,
                                          timeout_scale=0.8)
    adap = coupling.schedule_from_engine(60, seed=1, params=SMOKE_PARAMS,
                                         timeout_scale=0.8, adaptive=True)
    eng = BatchedEngine(SMOKE_PARAMS)
    tr = eng.traces(["roce", "celeris"], 60, seed=1, legacy_streams=False)
    base = eng.assemble(tr["roce"], 1)
    to = float(np.percentile(base.times_us, 50) + base.times_us.std()) * 0.8
    ref = eng.assemble(tr["celeris"], 1, celeris_timeout_us=to,
                       adaptive=True, window="round")
    np.testing.assert_allclose(adap.rates,
                               np.clip(1.0 - ref.recv_frac, 0,
                                       coupling.MAX_DROP), atol=1e-12)
    assert not np.allclose(adap.rates, fixed.rates)


def test_closed_form_matches_standalone_straggler_model():
    """LatencyTail is the trainer's StragglerModel with bursts off —
    identical drop for identical timeouts."""
    from repro.train.trainer import StragglerModel
    sm = StragglerModel(median_latency=1.3, sigma=0.45, burst_prob=0.0)
    tail = coupling.LatencyTail(median_latency=1.3, sigma=0.45)
    rng = np.random.default_rng(0)
    timeouts = np.linspace(0.2, 6.0, 23)
    want = np.array([sm.drop_rate(t, rng) for t in timeouts])
    got = coupling.closed_form_schedule(timeouts, tail).rates
    np.testing.assert_allclose(got, want, atol=1e-12)


def test_lossless_designs_give_zero_schedule():
    for d in ("roce", "irn"):
        s = coupling.schedule_from_engine(10, seed=0, params=SMOKE_PARAMS,
                                          design=d)
        assert s.mean == 0.0


def test_drop_schedule_clip_wrap_and_straggler_walk():
    s = coupling.DropSchedule(rates=np.array([0.1, 0.9, -0.2]), source="t")
    assert s.rates.max() <= coupling.MAX_DROP and s.rates.min() >= 0.0
    assert s.rate(0) == s.rate(3) == pytest.approx(0.1)    # wraps
    m = coupling.EngineStragglerModel(s)
    seen = [m.drop_rate(2.0, None) for _ in range(4)]
    assert seen[:3] == [s.rate(i) for i in range(3)]
    assert seen[3] == s.rate(0)
    assert m.steps_taken == 4


def test_collective_mode_parse():
    CM = coupling.CollectiveMode
    assert CM.parse("lossy+hadamard") is CM.LOSSY_HADAMARD
    assert CM.parse("LOSSY-HADAMARD") is CM.LOSSY_HADAMARD
    assert CM.parse(CM.EXACT) is CM.EXACT
    assert not CM.EXACT.lossy and CM.LOSSY.lossy
    assert CM.LOSSY_HADAMARD.coded and not CM.LOSSY.coded
    with pytest.raises(ValueError):
        CM.parse("bogus")


def test_celeris_config_mode_resolution():
    from repro.train.train_step import CelerisConfig
    CM = coupling.CollectiveMode
    assert CelerisConfig().collective_mode() is CM.EXACT
    assert CelerisConfig(enabled=True).collective_mode() is CM.LOSSY_HADAMARD
    assert CelerisConfig(mode="lossy").collective_mode() is CM.LOSSY
    # explicit mode wins over the legacy switch
    assert (CelerisConfig(enabled=True, mode="exact").collective_mode()
            is CM.EXACT)


# ------------------------------------- sharded roundtrip (8-device mesh)

def test_sharded_lossy_psum_roundtrip_engine_rate():
    """encode → lossy_psum → decode on an 8-device mesh, drop rate taken
    from an engine schedule, vs the single-device exact sum: zero-drop
    agrees to the coding tolerance (2e-3, see tests/test_coding.py);
    at the engine's realized rate the unbiased estimate stays within
    the documented 50% relative-error envelope and the realized
    received fraction tracks 1 - drop."""
    sched = coupling.schedule_from_engine(20, seed=0, params=SMOKE_PARAMS,
                                          timeout_scale=0.8)
    drop = float(np.clip(sched.mean, 0.02, 0.2))
    _run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import sharding as shd
        from repro.core import coding, lossy_collectives as lc
        mesh = shd.make_mesh((8,), ('data',))
        N = 5000
        code = coding.plan(N)
        signs = coding.rademacher(jax.random.PRNGKey(7), code)
        xs = jax.random.normal(jax.random.PRNGKey(0), (8, N))
        def f(x, key, p):
            est, frac = lc.lossy_psum(x[0], 'data', key=key, drop_rate=p,
                                      signs=signs, code=code,
                                      use_pallas=False)
            return est[None], frac[None]
        sm = shd.shard_map(f, mesh=mesh, in_specs=(P('data', None), P(), P()),
                           out_specs=(P('data', None), P('data')),
                           check_vma=False)
        exact = np.asarray(xs.sum(0))
        est0, _ = jax.jit(sm)(xs, jax.random.PRNGKey(1), jnp.float32(0.0))
        np.testing.assert_allclose(np.asarray(est0[0]), exact,
                                   rtol=2e-3, atol=2e-3)
        est, frac = jax.jit(sm)(xs, jax.random.PRNGKey(2),
                                jnp.float32({drop}))
        assert abs(float(frac[0]) - (1 - {drop})) < 0.05, float(frac[0])
        rel = (np.linalg.norm(np.asarray(est[0]) - exact)
               / np.linalg.norm(exact))
        assert rel < 0.5, rel
        print('OK')
    """)


@pytest.mark.slow
def test_scale_check_512_lowers_plain_collectives():
    """dryrun --scale-check at 512 devices: the lossy+hadamard train
    step lowers with nothing but plain collectives."""
    out = _run("""
        from repro.launch import dryrun
        rec = dryrun.scale_check_cell('qwen2-0.5b', 512)
        assert rec['ok'], rec
        assert rec['illegal_collectives'] == {}, rec
        assert 'all_reduce' in rec['collective_ops'], rec
        print('OK')
    """, devices=512, timeout=560)
    assert "OK" in out


@pytest.mark.skipif(
    not shd.plain_lossy_island_supported(),
    reason="per-(peer,row) plain-lossy island needs the jax >= 0.8 "
           "partitioner (0.4.x CPU CHECK-crashes on the uncoded island); "
           "exercised by the CI jax-0.8 matrix leg")
def test_plain_lossy_island_roundtrip_8dev():
    """jax >= 0.8 only: CollectiveMode.LOSSY runs as a shard_map island
    (``_sync_grads_plain_island``) — per-(peer, wire-row) masks applied
    *before* the plain psum.  Zero drop must match the exact baseline
    (no coding in this path, so equality is tight), and at a real rate
    the realized received fraction tracks 1 - drop."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        import repro.configs as C
        from repro import sharding as shd
        from repro.data.pipeline import DataConfig, make_source
        from repro.optim.adamw import OptConfig
        from repro.train import train_step as ts, sharding_rules as rules
        assert shd.plain_lossy_island_supported()
        mesh = shd.make_mesh((8,), ('data',))
        shd.set_global_mesh(mesh)
        cfg = C.get_smoke('qwen2-0.5b')
        src = make_source(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                     global_batch=8, seed=1))
        host = src.global_batch(0, 8)
        sp = rules.batch_specs(mesh, host)
        batch = {k: jax.device_put(
                     v, jax.sharding.NamedSharding(mesh, sp[k]))
                 for k, v in host.items()}

        def step_with(mode, drop):
            fn = ts.make_train_step(cfg, mesh, OptConfig(lr=1e-3),
                                    ts.CelerisConfig(mode=mode,
                                                     min_coded_size=1024))
            st = ts.init_state(jax.random.PRNGKey(0), cfg)
            st = jax.device_put(st, ts.state_shardings(st, mesh))
            st, m = fn(st, batch, jax.random.PRNGKey(1),
                       jnp.float32(drop))
            return {k: float(v) for k, v in m.items()}

        m_ex = step_with('exact', 0.0)
        m_l0 = step_with('lossy', 0.0)
        assert m_l0['recv_frac'] == 1.0, m_l0
        assert abs(m_l0['loss'] - m_ex['loss']) < 1e-4, (m_ex, m_l0)
        m_ld = step_with('lossy', 0.25)
        assert abs(m_ld['recv_frac'] - 0.75) < 0.05, m_ld
        assert np.isfinite(m_ld['loss'])
        print('OK')
    """)
    # NOTE for the 0.4.x container: this test auto-skips; the CI 0.8
    # leg runs it (see .github/workflows/ci.yml, tier1-jax08 job).
