"""jax engine backend: numpy <-> jax agreement (ISSUE 9 acceptance).

The numpy engine is the bit-pinning reference; the jitted backend
(``engine_jax``) replays its random streams host-side and must match
its physics within the tolerance contract (rtol 1e-5 — observed
agreement is f32-ulp on step traces, exact on deliveries).  The A/B
matrix spans schedule geometry (flat ring / 2-pod hier / per-rail),
fault scenarios, and an incast FlowPlan, across all designs and both
fixed window policies.
"""
import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.transport import engine_jax
from repro.core.transport import (BatchedEngine, BatchedSimParams,
                                  NetworkParams, SimParams, sweep)
from repro.core.transport.params import (FaultParams, TopologyParams,
                                         WorkloadParams)
from repro.serve.traffic import ServeTrafficParams, kv_flow_plan

SMALL = SimParams(net=NetworkParams(n_nodes=32, burst_on_prob=0.0008))
DESIGNS = ("roce", "irn", "srnic", "celeris")
RTOL = 1e-5


def _small(**kw):
    return dataclasses.replace(SMALL, **kw)


MATRIX = {
    "ring_flat": (_small(), None),
    "hier_2pod": (_small(topo=TopologyParams(n_pods=2),
                         work=WorkloadParams(schedule="hier")), None),
    "perrail_faulted": (_small(
        topo=TopologyParams(n_pods=2),
        work=WorkloadParams(schedule="perrail"),
        fault=FaultParams.parse("stall:0.003+straggler:0.1")), None),
    "kv_incast": (_small(), "kv"),
}


def _engines(p, plan_key):
    plan = kv_flow_plan(ServeTrafficParams()) if plan_key else None
    kw = dict(plan=plan) if plan is not None else {}
    return (BatchedEngine(p, **kw),
            BatchedEngine(p, backend="jax", **kw))


@pytest.mark.parametrize("cell", sorted(MATRIX))
def test_traces_match_numpy(cell):
    p, plan_key = MATRIX[cell]
    eng_np, eng_j = _engines(p, plan_key)
    tr_np = eng_np.traces(DESIGNS, 15, 3, legacy_streams=False)
    tr_j = eng_j.traces(DESIGNS, 15, 3, legacy_streams=False)
    for d in DESIGNS:
        a, b = tr_np[d], tr_j[d]
        np.testing.assert_allclose(b.nat_us, a.nat_us, rtol=RTOL,
                                   err_msg=f"{cell}/{d} nat_us")
        # delivered counts are integer-valued sums: exact
        np.testing.assert_array_equal(b.deliv, a.deliv,
                                      err_msg=f"{cell}/{d} deliv")
        np.testing.assert_array_equal(b.total, a.total)
        np.testing.assert_array_equal(b.tier_deliv, a.tier_deliv,
                                      err_msg=f"{cell}/{d} tier")
        np.testing.assert_array_equal(b.tier_total, a.tier_total)
        if a.pod_deliv is not None:
            np.testing.assert_array_equal(b.pod_deliv, a.pod_deliv,
                                          err_msg=f"{cell}/{d} pod")
        if a.fault_flows is not None:
            np.testing.assert_array_equal(b.fault_flows, a.fault_flows)


@pytest.mark.parametrize("window", ["round", "phase"])
@pytest.mark.parametrize("cell", sorted(MATRIX))
def test_assembled_stats_match_numpy(cell, window):
    """p99 / delivered fractions / per-tier and per-pod recombination
    agree through both fixed window assemblies (the jax backend routes
    celeris windows through the jitted twin)."""
    p, plan_key = MATRIX[cell]
    eng_np, eng_j = _engines(p, plan_key)
    tr_np = eng_np.traces(DESIGNS, 15, 3, legacy_streams=False)
    tr_j = eng_j.traces(DESIGNS, 15, 3, legacy_streams=False)
    for d in DESIGNS:
        kw = (dict(celeris_timeout_us=30_000.0, adaptive=False,
                   window=window) if d == "celeris" else {})
        a = eng_np.assemble(tr_np[d], 3, **kw)
        b = eng_j.assemble(tr_j[d], 3, **kw)
        np.testing.assert_allclose(
            np.percentile(b.times_us, 99), np.percentile(a.times_us, 99),
            rtol=RTOL, err_msg=f"{cell}/{d}/{window} p99")
        np.testing.assert_allclose(b.recv_frac, a.recv_frac,
                                   rtol=RTOL, atol=1e-9,
                                   err_msg=f"{cell}/{d}/{window} frac")
        np.testing.assert_allclose(b.tier_recv_frac, a.tier_recv_frac,
                                   rtol=RTOL, atol=1e-9)
        if a.pod_recv_frac is not None:
            np.testing.assert_allclose(b.pod_recv_frac, a.pod_recv_frac,
                                       rtol=RTOL, atol=1e-9)
        np.testing.assert_allclose(b.mean_loss, a.mean_loss,
                                   rtol=RTOL, atol=1e-9)
        np.testing.assert_allclose(b.p99, a.p99, rtol=RTOL)


def test_priority_cut_assembly_matches_numpy():
    """cut_order='priority' through the jitted window: p99 / scalar /
    per-tier / per-class fractions agree with the numpy reference
    within the rtol-1e-5 contract (layer-depth classes on a 2-pod
    hier plan, tight budget so the cut actually binds)."""
    from repro.core.transport.schedule import layer_priorities, make_plan
    p = _small(topo=TopologyParams(n_pods=2),
               work=WorkloadParams(schedule="hier"))
    eng_np, eng_j = _engines(p, None)
    plan = make_plan(p.net, p.topo, p.work)
    cls = layer_priorities(plan)
    tr_np = eng_np.traces(("roce", "celeris"), 15, 3,
                          legacy_streams=False)
    tr_j = eng_j.traces(("roce", "celeris"), 15, 3, legacy_streams=False)
    to = float(np.percentile(eng_np.assemble(tr_np["roce"], 3).times_us,
                             50) * 0.5)
    for order in ("arrival", "priority"):
        a = eng_np.assemble(
            dataclasses.replace(tr_np["celeris"], step_priority=cls), 3,
            celeris_timeout_us=to, adaptive=False, window="round",
            cut_order=order)
        b = eng_j.assemble(
            dataclasses.replace(tr_j["celeris"], step_priority=cls), 3,
            celeris_timeout_us=to, adaptive=False, window="round",
            cut_order=order)
        np.testing.assert_allclose(b.p99, a.p99, rtol=RTOL,
                                   err_msg=f"{order} p99")
        np.testing.assert_allclose(b.recv_frac, a.recv_frac,
                                   rtol=RTOL, atol=1e-9,
                                   err_msg=f"{order} frac")
        np.testing.assert_allclose(b.tier_recv_frac, a.tier_recv_frac,
                                   rtol=RTOL, atol=1e-9)
        np.testing.assert_allclose(b.prio_recv_frac, a.prio_recv_frac,
                                   rtol=RTOL, atol=1e-9,
                                   err_msg=f"{order} per-class frac")
        np.testing.assert_array_equal(b.prio_pkts, a.prio_pkts)


def test_vmapped_batch_equals_per_seed_loop():
    """One vmapped pass over the seed axis gives the same traces as
    three independent single-seed calls."""
    eng = BatchedEngine(SMALL, backend="jax")
    designs = ("roce", "celeris")
    batched = engine_jax.traces_batched(eng, designs, 12, [0, 1, 2])
    for si, s in enumerate((0, 1, 2)):
        single = engine_jax.traces_batched(eng, designs, 12, [s])[0]
        for d in designs:
            np.testing.assert_allclose(batched[si][d].nat_us,
                                       single[d].nat_us, rtol=1e-7)
            np.testing.assert_array_equal(batched[si][d].deliv,
                                          single[d].deliv)


def test_jit_cache_reuse():
    """A second identical call hits the compiled core: the trace-time
    counter must not move."""
    eng = BatchedEngine(SMALL, backend="jax")
    engine_jax.traces_batched(eng, ("irn",), 12, [0, 1])
    before = engine_jax.TRACE_COUNT[0]
    engine_jax.traces_batched(eng, ("irn",), 12, [0, 1])
    assert engine_jax.TRACE_COUNT[0] == before


def test_run_and_sweep_route_through_jax():
    """run() flips legacy_streams itself; sweep(backend='jax') batches
    the seed axis and matches the numpy sweep within tolerance."""
    st_j = BatchedEngine(SMALL, backend="jax").run(
        "celeris", 12, adaptive=False, celeris_timeout_us=30_000.0)
    st_np = BatchedEngine(SMALL).run(
        "celeris", 12, adaptive=False, celeris_timeout_us=30_000.0,
        legacy_streams=False)
    np.testing.assert_allclose(st_j.times_us, st_np.times_us, rtol=RTOL)

    grid = dict(n_nodes=(32,), message_mb=(4.0,), seeds=(0, 1),
                n_rounds=8, base=SMALL)
    msgs = []
    res_j = sweep(BatchedSimParams(backend="jax", **grid),
                  progress=msgs.append)
    res_np = sweep(BatchedSimParams(**grid))
    assert res_j.stats.keys() == res_np.stats.keys()
    for k, b in res_j.stats.items():
        a = res_np.stats[k]
        np.testing.assert_allclose(np.percentile(b.times_us, 99),
                                   np.percentile(a.times_us, 99),
                                   rtol=RTOL, err_msg=str(k))
        np.testing.assert_allclose(b.recv_frac, a.recv_frac,
                                   rtol=RTOL, atol=1e-9)
    # progress reports backend + cells/sec liveness (satellite contract)
    assert msgs and all(m.startswith("[jax] ") for m in msgs)
    assert all("cells/s)" in m for m in msgs)


def test_backend_guards():
    eng = BatchedEngine(SMALL, backend="jax")
    with pytest.raises(ValueError, match="legacy_streams=False"):
        eng.traces(("irn",), 4, 0)          # legacy default
    with pytest.raises(ValueError, match="per_node_for"):
        eng.traces(("celeris",), 4, 0, legacy_streams=False,
                   per_node_for=("celeris",))
    with pytest.raises(ValueError, match="backend"):
        BatchedEngine(SMALL, backend="torch")
