"""Per-arch smoke tests (reduced configs) + layer-level equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import layers as L
from repro.models import model as M
from repro.models import rglru as RG
from repro.models import xlstm as XL


def _batch(cfg, key, b=2, s=32):
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }
    if cfg.frontend == "vision_stub":
        batch["image_embeds"] = jax.random.normal(
            key, (b, cfg.n_frontend_tokens, cfg.frontend_dim))
    if cfg.frontend == "audio_stub":
        batch["frame_embeds"] = jax.random.normal(key, (b, s,
                                                        cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", C.ARCHS)
def test_smoke_forward_train_step(arch):
    """One forward + one grad step on CPU: shapes right, nothing NaN."""
    cfg = C.get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    batch = _batch(cfg, key)
    logits, _, _ = M.forward(params, cfg, batch)
    exp_s = batch["tokens"].shape[1] + (
        cfg.n_frontend_tokens if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (2, exp_s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    loss, (nll, aux) = M.lm_loss(params, cfg, batch)
    g = jax.grad(lambda p: M.lm_loss(p, cfg, batch)[0])(params)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "gemma2-9b",
                                  "recurrentgemma-9b", "xlstm-350m",
                                  "seamless-m4t-medium"])
def test_prefill_decode_consistency(arch):
    """Greedy decode after prefill matches teacher-forced full forward."""
    cfg = C.get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    b, s = 2, 24
    batch = _batch(cfg, key, b, s)
    memory = None
    if cfg.is_encdec:
        memory = M._encode(params, cfg, batch)
    full, _, _ = M.forward(params, cfg, {"tokens": batch["tokens"],
                                         **({"frame_embeds":
                                             batch["frame_embeds"]}
                                            if cfg.is_encdec else {})},
                           memory=memory)

    caches = M.init_caches(cfg, b, s + 4)
    pre, caches, _ = M.forward(
        params, cfg, {"tokens": batch["tokens"][:, :s - 1]}, caches=caches,
        memory=memory,
        positions=jnp.arange(s - 1, dtype=jnp.int32)[None, :])
    dec, caches, _ = M.forward(
        params, cfg, {"tokens": batch["tokens"][:, s - 1:s]},
        caches=caches, cache_index=jnp.int32(s - 1), memory=memory,
        positions=jnp.full((b, 1), s - 1, jnp.int32))
    off = cfg.n_frontend_tokens if cfg.frontend == "vision_stub" else 0
    want = np.asarray(full[:, off + s - 1], np.float32)
    got = np.asarray(dec[:, 0], np.float32)
    scale = max(1.0, np.abs(want).max())
    assert np.abs(got - want).max() / scale < 0.05, arch


def test_local_attention_masks_window():
    cfg = C.get_smoke("gemma2-9b")
    key = jax.random.PRNGKey(2)
    p = L.init_attention(key, cfg)
    x = jax.random.normal(key, (1, 128, cfg.d_model))
    pos = jnp.arange(128, dtype=jnp.int32)[None, :]
    out_l, _ = L.attention(p, cfg, x, kind="local", positions=pos)
    # perturb a token far outside the window of the last query
    x2 = x.at[:, 0].add(10.0)
    out_l2, _ = L.attention(p, cfg, x2, kind="local", positions=pos)
    # last position (window=64) must not see position 0
    np.testing.assert_allclose(np.asarray(out_l[0, -1]),
                               np.asarray(out_l2[0, -1]), atol=1e-5)


def test_partial_rope_rotates_half():
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, 2, 16))
    pos = jnp.arange(8, dtype=jnp.int32)[None, :]
    cos, sin, rot = L.rope_tables(pos, 16, 10_000.0, 0.5)
    assert rot == 8
    y = L.apply_rope(x, cos, sin, rot)
    # pass-through half untouched
    np.testing.assert_array_equal(np.asarray(y[..., 8:]),
                                  np.asarray(x[..., 8:]))
    # rotated half differs for pos > 0
    assert np.abs(np.asarray(y[0, 1:, :, :8] - x[0, 1:, :, :8])).max() > 1e-3


def test_flash_equals_dense():
    import repro.models.layers as ml
    cfg = C.get_smoke("gemma2-9b")
    p = L.init_attention(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 128, cfg.d_model)) * .3
    pos = jnp.arange(128, dtype=jnp.int32)[None, :]
    old = ml.FLASH_THRESHOLD
    try:
        ml.FLASH_THRESHOLD = 1
        flash, _ = L.attention(p, cfg, x, kind="global", positions=pos)
        ml.FLASH_THRESHOLD = 10 ** 12
        dense, _ = L.attention(p, cfg, x, kind="global", positions=pos)
    finally:
        ml.FLASH_THRESHOLD = old
    np.testing.assert_allclose(np.asarray(flash, np.float32),
                               np.asarray(dense, np.float32),
                               rtol=2e-2, atol=2e-4)


def test_mlstm_chunkwise_equals_sequential():
    cfg = C.get_smoke("xlstm-350m")
    p = XL.init_mlstm(jax.random.PRNGKey(6), cfg)
    b, s = 2, 128
    x = jax.random.normal(jax.random.PRNGKey(7), (b, s, cfg.d_model)) * .2
    hh = cfg.n_heads
    u = x @ p["w_up"]
    di = u.shape[-1]
    dh = di // hh
    q = (u @ p["wq"]).reshape(b, s, hh, dh) * dh ** -0.5
    k = (u @ p["wk"]).reshape(b, s, hh, dh) * dh ** -0.5
    v = (u @ p["wv"]).reshape(b, s, hh, dh)
    g = u.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    logi, logf = g[..., :hh], jax.nn.log_sigmoid(g[..., hh:])
    z = jnp.zeros
    c0, n0, m0 = (z((b, hh, dh, dh)), z((b, hh, dh)), z((b, hh)))
    seq, _ = XL._mlstm_seq(q, k, v, logi, logf, c0, n0, m0)
    par, _ = XL.mlstm_parallel(q, k, v, logi, logf, c0, n0, m0)
    np.testing.assert_allclose(np.asarray(par), np.asarray(seq),
                               rtol=1e-4, atol=1e-5)


def test_rglru_chunked_matches_decode_rollout():
    cfg = C.get_smoke("recurrentgemma-9b")
    p = RG.init_rglru(jax.random.PRNGKey(8), cfg)
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 1024, cfg.d_model)) * .2
    full, _ = RG.rglru_block(p, cfg, x)     # chunked path (1024 = 2*512)
    cache = RG.init_cache(cfg, 1)
    outs = []
    for t in range(0, 1024, 256):           # unchunked fallback segments
        o, cache = RG.rglru_block(p, cfg, x[:, t:t + 256], cache=cache)
        outs.append(o)
    seq = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full),
                               rtol=2e-2, atol=2e-3)


def test_ring_cache_wraparound_matches_dense_local():
    """Decode past the window: ring cache must equal dense local attn."""
    cfg = C.get_smoke("gemma2-9b")        # window 64
    p = L.init_attention(jax.random.PRNGKey(10), cfg)
    b, total = 1, 96                       # wraps a 64-slot ring
    x = jax.random.normal(jax.random.PRNGKey(11), (b, total, cfg.d_model))
    pos = jnp.arange(total, dtype=jnp.int32)[None, :]
    dense, _ = L.attention(p, cfg, x, kind="local", positions=pos)

    cache = L.AttnCache(
        k=jnp.zeros((b, 64, cfg.n_kv_heads, cfg.resolved_head_dim),
                    jnp.float32),
        v=jnp.zeros((b, 64, cfg.n_kv_heads, cfg.resolved_head_dim),
                    jnp.float32),
        pos=jnp.full((64,), -1, jnp.int32))
    _, cache = L.attention(p, cfg, x[:, :64], kind="local",
                           positions=pos[:, :64], cache=cache)
    for t in range(64, total):
        out, cache = L.attention(
            p, cfg, x[:, t:t + 1], kind="local",
            positions=jnp.full((b, 1), t, jnp.int32),
            cache=cache, cache_index=jnp.int32(t))
    np.testing.assert_allclose(np.asarray(out[:, 0], np.float32),
                               np.asarray(dense[:, -1], np.float32),
                               rtol=2e-2, atol=2e-3)
