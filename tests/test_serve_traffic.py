"""Serving traffic + coded-KV transfer (ISSUE 7 serve-path tests).

- request process: seeded determinism, open-loop design independence,
  load scaling of the arrival rate;
- queue simulation: block conservation (shipped == delivered demand of
  completed requests plus partial progress), latency monotone in
  round times, censoring accounted;
- KV hole masks: seeded, mean tracks the delivered fraction;
- degraded decode: full-mask roundtrip is exact for both wire layouts,
  and at a lossy fraction the Hadamard layout's usable-context
  fraction beats the uncoded contiguous layout (the fig8 recovery
  claim in miniature).
"""
import numpy as np
import pytest

from repro.core.transport import coupling
from repro.serve import traffic

TP = traffic.ServeTrafficParams(n_prefill=12, n_decode=3,
                                steps_per_round=4)


def _trace(load=0.7, seed=0, horizon=3e5, ref=1e4):
    tp = traffic.ServeTrafficParams(
        n_prefill=12, n_decode=3, steps_per_round=4, load=load)
    return tp, traffic.request_trace(tp, horizon, ref, seed)


def test_request_trace_deterministic_and_open_loop():
    tp, tr1 = _trace(seed=3)
    _, tr2 = _trace(seed=3)
    np.testing.assert_array_equal(tr1.arrival_us, tr2.arrival_us)
    np.testing.assert_array_equal(tr1.kv_blocks, tr2.kv_blocks)
    _, tr3 = _trace(seed=4)
    assert not np.array_equal(tr1.arrival_us, tr3.arrival_us)
    # arrivals are sorted, inside the horizon, lengths positive
    assert (np.diff(tr1.arrival_us) >= 0).all()
    assert tr1.arrival_us[-1] < 3e5
    assert (tr1.kv_blocks >= 1).all() and (tr1.decode_tokens >= 1).all()
    assert (tr1.ready_us >= tr1.arrival_us).all()


def test_arrival_rate_scales_with_load():
    tp_lo, tr_lo = _trace(load=0.4, seed=1)
    tp_hi, tr_hi = _trace(load=0.8, seed=1)
    r = tr_hi.n_requests / max(tr_lo.n_requests, 1)
    assert 1.6 < r < 2.4          # ~2x requests at 2x load
    assert (traffic.arrival_rate_per_us(tp_hi, 1e4)
            == pytest.approx(2 * traffic.arrival_rate_per_us(tp_lo, 1e4)))


def test_simulate_serving_conservation_and_censoring():
    tp, tr = _trace(load=0.7, seed=5)
    times = np.full(30, 1e4)
    recv = np.ones(30)
    sim = traffic.simulate_serving(tp, times, recv, tr)
    # conservation: total shipped == full demand of completed requests
    # + partial progress of the censored ones (recv_frac == 1 here)
    got_blocks = np.round(sim.kv_frac * tr.kv_blocks).astype(int)
    assert sim.blocks_shipped == got_blocks.sum()
    assert (got_blocks[sim.completed] == tr.kv_blocks[sim.completed]).all()
    assert sim.blocks_shipped <= 30 * tp.capacity_blocks_per_round
    # completed requests: latency positive; censored: horizon remainder
    assert (sim.latency_us[sim.completed] > 0).all()
    horizon = times.sum()
    cens = ~sim.completed
    np.testing.assert_allclose(
        sim.latency_us[cens],
        np.maximum(horizon - tr.arrival_us[cens], 0.0))


def test_serving_latency_monotone_in_round_time():
    """Same trace over 2x slower rounds -> worse p99 (the backlog is
    the figure's design discriminator)."""
    tp, tr = _trace(load=0.8, seed=2)
    fast = traffic.simulate_serving(tp, np.full(30, 1e4), np.ones(30), tr)
    slow = traffic.simulate_serving(tp, np.full(30, 2e4), np.ones(30), tr)
    assert slow.p99_latency_us > fast.p99_latency_us


def test_recv_frac_flows_into_kv_frac():
    tp, tr = _trace(load=0.5, seed=6)
    cut = np.full(30, 0.9)
    sim = traffic.simulate_serving(tp, np.full(30, 1e4), cut, tr)
    done = sim.completed
    assert done.any()
    np.testing.assert_allclose(sim.kv_frac[done], 0.9, rtol=1e-12)
    assert sim.mean_kv_frac == pytest.approx(0.9)


def test_kv_hole_masks_seeded_and_calibrated():
    f = np.array([0.25, 0.6, 0.95, 1.0])
    m1 = coupling.kv_hole_masks(f, 4096, seed=9)
    m2 = coupling.kv_hole_masks(f, 4096, seed=9)
    np.testing.assert_array_equal(m1, m2)
    assert m1.shape == (4, 4096) and m1.dtype == bool
    np.testing.assert_allclose(m1.mean(axis=1), f, atol=0.03)
    assert m1[3].all()                      # frac 1.0 -> no holes
    m3 = coupling.kv_hole_masks(f, 4096, seed=10)
    assert not np.array_equal(m1, m3)


# ----------------------------------------------- degraded-KV decode

@pytest.mark.slow
def test_kv_wire_roundtrip_exact_and_coded_beats_uncoded():
    """Full mask -> bitwise-faithful roundtrip both ways; lossy mask ->
    the coded layout keeps more usable context than contiguous chunks
    (fig8's recovery metric, one payload in miniature)."""
    import jax
    import jax.numpy as jnp
    from repro.core import coding
    from repro.serve import serve_step

    n_rot = 64
    x = jax.random.normal(jax.random.PRNGKey(0), (n_rot * 37,))
    code = coding.plan(int(x.size), n_rot=n_rot)
    signs = coding.rademacher(jax.random.PRNGKey(1), code)

    full = jnp.ones(n_rot)
    for coded in (True, False):
        y = serve_step.kv_wire_roundtrip(x, full, signs, code, coded=coded)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                                   atol=1e-5)

    mask = jnp.asarray(
        coupling.kv_hole_masks(np.array([0.85]), n_rot, seed=0)[0])
    lost = n_rot - int(mask.sum())
    assert 0 < lost < n_rot
    # "positions" = contiguous spans, one per uncoded wire chunk; the
    # usable-context metric is per-position relative L2 (fig8's TAU)
    usable = {}
    for coded in (True, False):
        y = serve_step.kv_wire_roundtrip(x, mask, signs, code, coded=coded)
        d = np.asarray(y - x).reshape(n_rot, -1)
        r = np.asarray(x).reshape(n_rot, -1)
        rel = np.linalg.norm(d, axis=1) / np.linalg.norm(r, axis=1)
        usable[coded] = float((rel <= 0.6).mean())
    # uncoded: each lost chunk annihilates exactly one position span
    assert usable[False] == pytest.approx(1.0 - lost / n_rot)
    # coded: the same loss lands as dense small noise across all spans
    assert usable[True] > usable[False]
    assert usable[True] >= 0.9


@pytest.mark.slow
def test_degrade_caches_full_mask_is_identity():
    import jax
    import jax.numpy as jnp
    import repro.configs as C
    from repro.models import model as M
    from repro.serve import serve_step

    cfg = C.get_smoke("qwen2-0.5b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                                cfg.vocab_size)
    prefill = serve_step.make_prefill(cfg, 24)
    _, caches = prefill(params, {"tokens": prompt})
    full = jnp.ones(64)
    same = serve_step.degrade_caches(caches, full, jax.random.PRNGKey(2))
    err = serve_step.kv_position_error(caches, same, 16)
    assert float(err.max()) < 1e-2          # bf16 roundtrip noise only
