"""Table I / Table II reproduction: QP state, BRAM, MTBF."""
import pytest

from repro.core import qp_state, resource_model as rm


def test_qp_bytes_match_paper_table1():
    for d, want in qp_state.PAPER_QP_BYTES.items():
        assert qp_state.qp_bytes(d) == want, d


def test_celeris_base_context_is_20_bytes():
    base = [f for f in qp_state.celeris_context() if f.category != "cc"]
    assert sum(f.bytes for f in base) == 20


def test_celeris_has_no_reliability_state():
    assert qp_state.reliability_state_bytes("celeris") == 0
    for d in ("roce", "irn", "srnic"):
        assert qp_state.reliability_state_bytes(d) > 0


def test_qp_scalability_ordering():
    caps = {d: qp_state.qp_capacity(d) for d in qp_state.DESIGNS}
    assert caps["celeris"] > caps["srnic"] > caps["roce"] > caps["irn"]
    # paper: Celeris supports ~8x the QPs of RoCE (80K vs 10K)
    assert caps["celeris"] / caps["roce"] == pytest.approx(
        qp_state.PAPER_QP_SCALABILITY["celeris"]
        / qp_state.PAPER_QP_SCALABILITY["roce"], rel=0.05)


def test_bram_matches_paper_table2():
    for d, want in rm.PAPER_BRAM.items():
        assert rm.bram_blocks(d) == pytest.approx(want, rel=1e-3), d


def test_bram_celeris_reduction_63_to_73_percent():
    c = rm.bram_blocks("celeris")
    assert 0.60 < 1 - c / rm.bram_blocks("roce") < 0.68    # paper: 63.5%
    assert 0.70 < 1 - c / rm.bram_blocks("irn") < 0.75     # paper: 72.7%


def test_mtbf_predictions_within_2pct_of_paper():
    """Calibrated on RoCE only; IRN/SRNIC/Celeris are predictions."""
    for d, want in rm.PAPER_MTBF_HRS.items():
        got = rm.cluster_mtbf_hours(d)
        assert abs(got - want) / want < 0.02, (d, got, want)


def test_mtbf_doubles_roce_to_celeris():
    ratio = rm.cluster_mtbf_hours("celeris") / rm.cluster_mtbf_hours("roce")
    assert 1.8 < ratio < 2.0                               # paper: ~1.9x


def test_mtbf_scales_inverse_with_nodes():
    a = rm.cluster_mtbf_hours("celeris", n_nodes=1000)
    b = rm.cluster_mtbf_hours("celeris", n_nodes=10_000)
    assert a / b == pytest.approx(10.0)


def test_asic_area_ordering():
    """Paper: Celeris ~57% less silicon than IRN, ~28% less than SRNIC."""
    c = rm.asic_area_au("celeris")
    assert 0.45 < 1 - c / rm.asic_area_au("irn") < 0.65
    assert 0.18 < 1 - c / rm.asic_area_au("srnic") < 0.38


def test_bram_scales_with_qp_count():
    assert rm.bram_blocks("celeris", 80_000) < rm.bram_blocks("roce", 80_000)
    # at equal SRAM-feasible QP counts the gap widens with scale
    gap10k = rm.bram_blocks("roce", 10_000) - rm.bram_blocks("celeris", 10_000)
    gap40k = rm.bram_blocks("roce", 40_000) - rm.bram_blocks("celeris", 40_000)
    assert gap40k > 3 * gap10k
