"""Per-phase window budgets + per-rail hierarchical exchange (ISSUE 5).

- ``PerRailHierarchicalSchedule`` obeys the exact step-count formula
  ``2(m-1) + 2(n_pods-1)`` and conserves total bytes per round against
  both the flat ring and the leader-exchange hier plan; its DCI phase
  sends *all* ``m * n_pods`` nodes with ``M/(m*n_pods)`` shards, every
  flow on the dci tier;
- per-phase budget fracs: single-phase plans are exactly ``[1.0]``,
  hier fracs normalize to 1 with the DCI share weighted up by
  oversubscription + extra RTT;
- ``window="round"`` reproduces the committed pre-refactor seed stats
  bit-exactly after the window refactor, and ``window="phase"`` on a
  single-phase plan is bit-identical to ``"round"``;
- the fixed per-phase window obeys ``times = sum_k min(phase_time_k,
  frac_k * budget)`` and, under a tight budget on the hier schedule,
  saves intra-pod data the per-round cut destroys;
- the sweep grows a ``windows`` dimension whose "round" cells match
  the window-less sweep bit-exactly;
- per-pod coupling: ``RoundStats.pod_recv_frac`` recombines (weighted
  by ``pod_pkts``) to the tier-aggregate intra rate exactly, and
  ``AxisSchedules.per_pod`` feeds the trainer ``(n_pods+1,)`` rates
  that the hierarchical train step consumes per pod (8-device mesh).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.transport import (BatchedEngine, BatchedSimParams,
                                  NetworkParams, SimParams, WindowPolicy,
                                  coupling, schedule, sweep, topology)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SMALL = SimParams(net=NetworkParams(n_nodes=32, burst_on_prob=0.0008))


def _run(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


# ---------------------------------------- PerRailHierarchicalSchedule

@pytest.mark.parametrize("n,npods", [(32, 2), (32, 4), (64, 2), (128, 8)])
def test_perrail_step_count_and_byte_conservation(n, npods):
    """Exact step formula 2(m-1) + 2(n_pods-1); total offered bytes
    equal to both the flat ring's 2(N-1)*M and the hier plan's."""
    base = SimParams(net=NetworkParams(n_nodes=n, nodes_per_tor=1))
    p = topology.hier_params(npods, n_nodes=n, schedule="perrail",
                             base=base)
    plan = schedule.make_plan(p.net, p.topo, p.work)
    m = n // npods
    assert plan.steps_per_round == 2 * (m - 1) + 2 * (npods - 1)
    ring = schedule.RingSchedule().plan(p.net, p.topo, p.work)
    hier = schedule.HierarchicalSchedule().plan(p.net, p.topo, p.work)
    assert plan.bytes_per_round() == ring.bytes_per_round()
    assert plan.bytes_per_round() == hier.bytes_per_round()
    assert plan.steps_per_round == hier.steps_per_round
    M = p.work.message_bytes
    by_name = {ph.name: ph for ph in plan.phases}
    assert by_name["dci"].src.size == n            # every node crosses
    assert by_name["dci"].payload_bytes == M // (m * npods)
    for name in ("rs", "ag"):
        assert by_name[name].payload_bytes == M // m
        assert by_name[name].src.size == n


def test_perrail_tier_map_and_exposure():
    """All per-rail DCI flows ride the dci tier; the plan's per-tier
    packet exposure follows n * 2(n_pods-1) * pkts(M/(m*n_pods))."""
    p = topology.hier_params(
        4, n_nodes=32, schedule="perrail",
        base=SimParams(net=NetworkParams(n_nodes=32, nodes_per_tor=4)))
    plan = schedule.make_plan(p.net, p.topo, p.work)
    by_name = {ph.name: hg for ph, hg in
               zip(plan.phases, plan.geometries(p.net, p.topo))}
    assert by_name["dci"].tier_counts[2] == 32     # all 32 flows cross
    assert by_name["dci"].tier_counts[:2].sum() == 0
    assert by_name["rs"].tier_counts[2] == 0
    pkts = plan.tier_pkts_round(p.net, p.topo)
    shard = p.work.message_bytes // 32
    dci_pkts = max(1, shard // p.net.mtu_bytes)
    assert pkts[2] == 32 * 2 * (4 - 1) * dci_pkts


def test_perrail_one_pod_degenerates_to_ring():
    p = topology.hier_params(1, base=SMALL, schedule="perrail")
    plan = schedule.make_plan(p.net, p.topo, p.work)
    ring = schedule.RingSchedule().plan(p.net, p.topo, p.work)
    assert plan.single_phase and plan.schedule == "perrail"
    assert plan.steps_per_round == ring.steps_per_round
    np.testing.assert_array_equal(plan.phases[0].dst, ring.phases[0].dst)


def test_budget_fracs():
    """Single-phase plans split exactly [1.0]; hier fracs normalize to
    1 with the DCI share weighted up by oversubscription (an 8:1 DCI
    earns a larger share than a 2:1 on the same plan)."""
    ringp = schedule.RingSchedule().plan(SMALL.net, SMALL.topo, SMALL.work)
    np.testing.assert_array_equal(ringp.budget_fracs(), np.array([1.0]))
    fr = {}
    for ov in (2.0, 8.0):
        p = topology.hier_params(2, base=SMALL, schedule="hier",
                                 dci_oversubscription=ov)
        plan = schedule.make_plan(p.net, p.topo, p.work)
        f = plan.budget_fracs()
        assert f.shape == (3,) and abs(f.sum() - 1.0) < 1e-12
        assert f[0] == f[2]                        # rs and ag symmetric
        fr[ov] = f[1]
    assert fr[8.0] > fr[2.0]                       # slower fabric waits


# ------------------------------------ window policy bit-compat + pins

def _pinned():
    path = os.path.join(os.path.dirname(__file__), "data",
                        "ring_schedule_seed_stats.json")
    return json.load(open(path))


def test_round_window_bitexact_vs_committed_seed_stats():
    """The pinned pre-refactor stats reproduce bit-for-bit through the
    refactored window assembly, WindowPolicy form included."""
    ref = _pinned()["flat"]
    eng = BatchedEngine(SMALL)
    tr = eng.traces(["roce", "celeris"], 40, seed=11, legacy_streams=False)
    base = eng.assemble(tr["roce"], 11)
    np.testing.assert_array_equal(base.times_us,
                                  np.array(ref["roce_times_us"]))
    to = float(np.percentile(base.times_us, 50) + base.times_us.std()) * 0.8
    cel = eng.assemble(tr["celeris"], 11, celeris_timeout_us=to,
                       adaptive=False, window=WindowPolicy("round"))
    np.testing.assert_array_equal(cel.times_us,
                                  np.array(ref["celeris_times_us"]))
    np.testing.assert_array_equal(cel.recv_frac,
                                  np.array(ref["celeris_recv_frac"]))


def test_phase_window_single_phase_equals_round_bitexact():
    """On the flat ring plan the phase split is [1.0], so the phase
    window is the round window bit-for-bit — fixed and adaptive."""
    eng = BatchedEngine(SMALL)
    tr = eng.traces(["celeris"], 30, seed=7, legacy_streams=False)
    for adaptive in (False, True):
        a = eng.assemble(tr["celeris"], 7, celeris_timeout_us=20_000.0,
                         adaptive=adaptive, window="round")
        b = eng.assemble(tr["celeris"], 7, celeris_timeout_us=20_000.0,
                         adaptive=adaptive, window="phase")
        np.testing.assert_array_equal(a.times_us, b.times_us)
        np.testing.assert_array_equal(a.recv_frac, b.recv_frac)
        np.testing.assert_array_equal(a.tier_recv_frac, b.tier_recv_frac)


def test_phase_window_budget_split_semantics():
    """Fixed per-phase window: round time is exactly the sum over
    phases of min(phase block time, frac_k * budget)."""
    hp = topology.hier_params(2, base=SMALL, dci_oversubscription=8.0,
                              schedule="hier")
    eng = BatchedEngine(hp)
    tr = eng.traces(["celeris"], 20, seed=3, legacy_streams=False)
    budget = 10_000.0
    st = eng.assemble(tr["celeris"], 3, celeris_timeout_us=budget,
                      adaptive=False, window="phase")
    plan = schedule.make_plan(hp.net, hp.topo, hp.work)
    fr = plan.budget_fracs()
    steps = plan.steps_per_round
    nat = tr["celeris"].nat_us.reshape(-1, steps)
    want = np.zeros(nat.shape[0])
    for k in range(len(plan.phases)):
        rows = np.flatnonzero(plan.phase_of_step == k)
        want += np.minimum(nat[:, rows].sum(axis=1), budget * fr[k])
    np.testing.assert_allclose(st.times_us, want, rtol=1e-12)
    # the budget is fully allocated: phase deadlines sum to the budget
    np.testing.assert_allclose(budget * fr.sum(), budget, rtol=1e-12)


def test_phase_window_saves_intra_data_under_tight_budget():
    """The ISSUE-5 headline at test scale: with a tail-controlling
    budget on the hier schedule, the per-round cut lands on the
    trailing intra phase whenever the DCI runs long, while the
    per-phase budget bounds each tier separately — same p99, far less
    total loss."""
    hp = topology.hier_params(2, base=SMALL, dci_oversubscription=8.0,
                              schedule="hier")
    stats = {w: topology.hier_protocol(hp, n_rounds=40, seed=0,
                                       timeout_scale=0.4,
                                       window=w)["celeris"]
             for w in ("round", "phase")}
    assert stats["phase"].p99 <= stats["round"].p99 * 1.001
    assert stats["phase"].mean_loss < stats["round"].mean_loss
    # the residual loss concentrates on the cross-pod (DCI) axis, where
    # the trainer's coded recovery operates
    assert (stats["phase"].tier_loss("dci")
            >= stats["round"].tier_loss("dci") * 0.5)


def test_window_sweep_dimension():
    common = dict(n_nodes=(32,), message_mb=(4.0,), seeds=(0,),
                  designs=("roce", "celeris"), n_rounds=20,
                  n_pods=(2,), schedules=("ring", "hier"),
                  base=topology.hier_params(2, base=SMALL,
                                            dci_oversubscription=8.0))
    plain = sweep(BatchedSimParams(**common))
    res = sweep(BatchedSimParams(windows=("round", "phase"), **common))
    key = ("celeris", 32, 4.0, 0, 2, "hier")
    assert key in plain.stats
    assert key + ("round",) in res.stats and key + ("phase",) in res.stats
    # the round cells of a window sweep match the window-less sweep
    # bit-exactly (round stays the default, untouched path)
    np.testing.assert_array_equal(
        res.stats[key + ("round",)].times_us,
        plain.stats[key].times_us)
    by_win = res.p99_vs_window("celeris", schedule="hier")
    assert set(by_win) == {"round", "phase"}
    rows = res.summary_rows()
    assert all(len(r) == 10 for r in rows)
    with pytest.raises(ValueError, match="per-flow"):
        sweep(BatchedSimParams(windows=("round", "step"), **common))


def test_window_policy_validation():
    with pytest.raises(ValueError, match="unknown window policy"):
        WindowPolicy("banana")
    eng = BatchedEngine(SMALL)
    tr = eng.traces(["celeris"], 5, 0, legacy_streams=False)
    with pytest.raises(ValueError, match="unknown window policy"):
        eng.assemble(tr["celeris"], 0, window="banana")


# --------------------------------------------------- per-pod coupling

def test_pod_recv_frac_recombines_to_intra_aggregate():
    """Per-pod fractions weighted by the plan's per-pod packet
    exposure recombine to the tier-aggregate intra rate exactly (the
    same delivered packets, regrouped by pod instead of by tier) —
    under both window policies."""
    hp = topology.hier_params(2, base=SMALL, dci_oversubscription=8.0,
                              schedule="hier")
    for window in ("round", "phase"):
        cel = topology.hier_protocol(hp, n_rounds=30, seed=4,
                                     timeout_scale=0.8,
                                     window=window)["celeris"]
        assert cel.pod_recv_frac.shape == (30, 2)
        w_pod = cel.pod_pkts
        w_tier = cel.tier_pkts
        from_pods = (cel.pod_recv_frac * w_pod).sum(axis=1) / w_pod.sum()
        from_tiers = ((cel.tier_recv_frac[:, :2] * w_tier[:2]).sum(axis=1)
                      / w_tier[:2].sum())
        np.testing.assert_allclose(from_pods, from_tiers, atol=1e-9)


def test_split_schedule_carries_per_pod_vector():
    sched = coupling.split_schedule_from_engine(
        20, seed=4, params=SMALL, n_pods=2, dci_oversubscription=8.0,
        schedule="hier", window="phase", timeout_scale=0.6)
    assert sched.n_pods == 2
    assert len(sched.per_pod) == 2
    r = sched.rates(0)
    assert r.shape == (3,)
    assert (r >= 0).all() and (r <= coupling.MAX_DROP).all()
    # cross stays the last element (the trainer convention)
    assert r[-1] == sched.cross.rate(0)
    # a flat (no pod tracking) split keeps the (2,) aggregate form
    flat = coupling.schedule_from_engine(10, seed=1, params=SMALL)
    assert flat.rates.size == 10     # plain DropSchedule, no pod axis


def test_hier_straggler_model_feeds_pod_vector():
    sched = coupling.split_schedule_from_engine(
        10, seed=2, params=SMALL, n_pods=2, dci_oversubscription=8.0,
        schedule="hier", timeout_scale=0.6)
    model = coupling.HierStragglerModel(sched)
    r0 = model.drop_rate(1.0, None)
    r1 = model.drop_rate(1.0, None)
    assert r0.shape == (3,) and r1.shape == (3,)
    np.testing.assert_array_equal(r0, sched.rates(0))
    np.testing.assert_array_equal(r1, sched.rates(1))


def test_hierarchical_mode_consumes_per_pod_rates_8dev():
    """Train step under CollectiveMode.HIERARCHICAL with a
    (n_pods+1,) = (3,) drop vector on a 2-pod x 4-data mesh: each
    pod's DCI mask rate combines its own intra rate with the shared
    cross rate — rate_p = 1 - (1-intra_p)(1-cross) — so the realized
    received fraction tracks 1 - mean_p(rate_p)."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        import repro.configs as C
        from repro import sharding as shd
        from repro.data.pipeline import DataConfig, make_source
        from repro.optim.adamw import OptConfig
        from repro.train import train_step as ts, sharding_rules as rules
        mesh = shd.make_mesh((2, 4), ('pod', 'data'))
        shd.set_global_mesh(mesh)
        cfg = C.get_smoke('qwen2-0.5b')
        src = make_source(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                     global_batch=8, seed=1))
        host = src.global_batch(0, 8)
        sp = rules.batch_specs(mesh, host)
        batch = {k: jax.device_put(
                     v, jax.sharding.NamedSharding(mesh, sp[k]))
                 for k, v in host.items()}
        fn = ts.make_train_step(cfg, mesh, OptConfig(lr=1e-3),
                                ts.CelerisConfig(mode='hierarchical',
                                                 min_coded_size=1024))
        st = ts.init_state(jax.random.PRNGKey(0), cfg)
        st = jax.device_put(st, ts.state_shardings(st, mesh))
        # [intra_pod0, intra_pod1, cross] = [0.4, 0.0, 0.25]; pod 0's
        # combined rate 1-(0.6)(0.75)=0.55 clamps at coupling.MAX_DROP
        st, m = fn(st, batch, jax.random.PRNGKey(1),
                   jnp.asarray([0.4, 0.0, 0.25], jnp.float32))
        frac = float(m['recv_frac'])
        want = 1.0 - (min(1 - (1-0.4)*(1-0.25), 0.5)
                      + (1 - (1-0.0)*(1-0.25))) / 2
        assert abs(frac - want) < 0.06, (frac, want)
        assert np.isfinite(float(m['loss']))
        print('OK')
    """)
