"""Batched transport engine: seeded regression + batched/sequential
agreement properties (ISSUE 1 acceptance tests).

The sequential pre-refactor loop is preserved as
:class:`repro.core.transport.reference.SequentialCollectiveSimulator`;
the engine's legacy-stream mode must reproduce its seeded statistics —
bit-near-exactly for irn/srnic/celeris-fixed (their random streams are
replayed), within a few percent for RoCE (engine-native transfer draws
on a bit-exact fabric trace).
"""
import json
import os

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:                     # container lacks hypothesis
    from _propcheck import hypothesis, st
import numpy as np
import pytest

from repro.core import timeout as tmod
from repro.core.transport import (BatchedEngine, BatchedSimParams,
                                  CollectiveSimulator, NetworkParams,
                                  SimParams, sweep)
from repro.core.transport import dcqcn, network, replay
from repro.core.transport.params import DcqcnParams
from repro.core.transport.reference import SequentialCollectiveSimulator

SMALL = SimParams(net=NetworkParams(n_nodes=32, burst_on_prob=0.0008))


# ------------------------------------------------- engine vs sequential

def test_selective_repeat_matches_sequential_exactly():
    """irn/srnic streams are replayed bit-exactly -> per-round times
    agree to float32 rounding, round by round."""
    for design in ("irn", "srnic"):
        seq = SequentialCollectiveSimulator(SMALL).run(design, 60, seed=3)
        bat = BatchedEngine(SMALL).run(design, 60, seed=3)
        np.testing.assert_allclose(bat.times_us, seq.times_us, rtol=2e-5)
        np.testing.assert_array_equal(bat.recv_frac, seq.recv_frac)


def test_celeris_fixed_window_matches_sequential_exactly():
    seq = SequentialCollectiveSimulator(SMALL).run(
        "celeris", 60, celeris_timeout_us=20_000.0, adaptive=False,
        window="round", seed=4)
    bat = BatchedEngine(SMALL).run(
        "celeris", 60, celeris_timeout_us=20_000.0, adaptive=False,
        window="round", seed=4)
    np.testing.assert_allclose(bat.times_us, seq.times_us, rtol=2e-5)
    np.testing.assert_allclose(bat.recv_frac, seq.recv_frac, atol=1e-6)


def test_roce_matches_sequential_statistically():
    """RoCE transfer draws are engine-native (its `integers` consumption
    is irreproducible) but ride a bit-exact fabric trace: medians agree
    tightly, tails within transfer-draw noise."""
    seq = SequentialCollectiveSimulator(SMALL).run("roce", 120, seed=5)
    bat = BatchedEngine(SMALL).run("roce", 120, seed=5)
    assert abs(bat.p50 / seq.p50 - 1) < 0.01
    assert abs(bat.p99 / seq.p99 - 1) < 0.15
    # idle rounds carry no randomness at all -> identical
    idle = seq.times_us == np.median(seq.times_us)
    np.testing.assert_allclose(bat.times_us[idle], seq.times_us[idle],
                               rtol=2e-5)


@pytest.mark.slow
def test_paper_protocol_pinned_to_prerefactor_values():
    """Fig.-2 protocol (300 rounds, 128 nodes) vs recorded pre-refactor
    stats: p50/p99 within 5%, loss within 0.5pp (acceptance criterion)."""
    ref_path = os.path.join(os.path.dirname(__file__), "data",
                            "paper_protocol_seed_stats.json")
    ref = json.load(open(ref_path))
    stats = CollectiveSimulator(SimParams()).paper_protocol(
        n_rounds=300, seed=0)
    for d, s in stats.items():
        assert abs(s.p50 / ref[d]["p50_us"] - 1) < 0.01, d
        assert abs(s.p99 / ref[d]["p99_us"] - 1) < 0.05, d
        assert abs(s.mean_loss - ref[d]["data_loss"]) < 0.005, d


# ------------------------------------------------- component properties

@hypothesis.given(st.integers(0, 2**31 - 1))
@hypothesis.settings(max_examples=8, deadline=None)
def test_occupancy_trace_bitexact_vs_advance(seed):
    p = NetworkParams(n_nodes=32, burst_on_prob=0.003)
    K = p.n_nodes // p.nodes_per_tor
    T = 400
    fab = network.ClosFabric(p, seed=seed)
    seq_occ = np.empty((T, K))
    seq_b = np.empty((T, K), bool)
    for t in range(T):
        fab.advance()
        seq_occ[t] = fab.state.occupancy
        seq_b[t] = fab.state.bursting
    u = np.random.default_rng(seed).random((T, 3, K))
    st0 = network.FabricState(bursting=np.zeros(K, bool),
                              occupancy=np.full(K, p.idle_occupancy))
    b, occ, fin = network.occupancy_trace(p, u, st0)
    np.testing.assert_array_equal(b, seq_b)
    np.testing.assert_array_equal(occ, seq_occ)     # bitwise
    np.testing.assert_array_equal(fin.bursting, seq_b[-1])


@hypothesis.given(st.integers(0, 2**31 - 1))
@hypothesis.settings(max_examples=4, deadline=None)
def test_roce_fabric_trace_bitexact(seed):
    p = NetworkParams(n_nodes=32, burst_on_prob=0.003)
    n = p.n_nodes
    src = np.arange(n)
    dst = (src + 1) % n
    T = 600
    fab = network.ClosFabric(p, seed=seed)
    seq_occ = np.empty((T, 2))
    seq_pfc = np.empty((T, n))
    for t in range(T):
        fab.advance()
        seq_occ[t] = fab.state.occupancy
        seq_pfc[t] = fab.pfc_pause_us(fab.path_occupancy(src, dst))
    occ, pfc = network.roce_fabric_trace(p, seed, src, dst, T, window=64)
    np.testing.assert_array_equal(occ, seq_occ)     # bitwise
    np.testing.assert_array_equal(pfc, seq_pfc)


def _random_cnp(seed, burst_prob, T=300, n=12):
    rng = np.random.default_rng(seed)
    prob = np.zeros((T, n))
    for s in rng.integers(0, T - 20, 6):
        prob[s: s + 15] = burst_prob
    return rng.random((T, n)) < prob


@hypothesis.given(st.integers(0, 2**31 - 1), st.floats(0.05, 0.6))
@hypothesis.settings(max_examples=8, deadline=None)
def test_rate_trace_matches_step_loop(seed, burst_prob):
    p = DcqcnParams()
    cnp = _random_cnp(seed, burst_prob)
    state = dcqcn.DcqcnState.init(cnp.shape[1])
    ref_out = np.empty(cnp.shape)
    for t in range(cnp.shape[0]):
        ref_out[t] = state.rate
        state = dcqcn.step(state, cnp[t], p)
    got, fin = dcqcn.rate_trace(cnp, p)
    np.testing.assert_allclose(got, ref_out, atol=1e-12)
    np.testing.assert_allclose(fin.rate, state.rate, atol=1e-12)
    np.testing.assert_array_equal(fin.good_stages, state.good_stages)


def test_replay_matches_generator_order():
    """The stream replay reproduces the sequential simulator's exact
    draw sequence (binomials + tail + cnp uniforms)."""
    rng = np.random.default_rng(9)
    T, n, n_pkts = 200, 16, 50
    drop_p = np.zeros((T, n))
    hot = rng.integers(0, T, 30)
    drop_p[hot] = rng.uniform(0, 0.025, (hot.size, n)) * (
        rng.random((hot.size, n)) < 0.5)
    ecn = np.clip(drop_p * 20 + rng.uniform(-0.5, 0.02, (T, n)), 0, 1)

    # sequential consumption, exactly like the old irn loop
    seed = 12345
    gen = np.random.default_rng(seed)
    gen.integers(2**31)
    k_ref = np.zeros((T, n), int)
    tail_ref = np.zeros((T, n), bool)
    k2_ref = np.zeros((T, n), int)
    cnp_ref = np.zeros((T, n), bool)
    for t in range(T):
        k_ref[t] = gen.binomial(n_pkts, drop_p[t])
        tail_ref[t] = gen.random(n) < drop_p[t]
        k2_ref[t] = gen.binomial(k_ref[t], drop_p[t])
        cnp_ref[t] = gen.random(n) < ecn[t]
    sr = replay.replay_selective_repeat(seed, n_pkts, drop_p, ecn)
    np.testing.assert_array_equal(sr.k, k_ref)
    np.testing.assert_array_equal(sr.tail_lost, tail_ref)
    np.testing.assert_array_equal(sr.k2, k2_ref)
    np.testing.assert_array_equal(sr.cnp, cnp_ref)

    # celeris layout: [binomial | cnp]
    gen = np.random.default_rng(seed)
    gen.integers(2**31)
    kc_ref = np.zeros((T, n), int)
    cnpc_ref = np.zeros((T, n), bool)
    for t in range(T):
        kc_ref[t] = gen.binomial(n_pkts, drop_p[t])
        cnpc_ref[t] = gen.random(n) < ecn[t]
    cel = replay.replay_celeris(seed, n_pkts, drop_p, ecn)
    np.testing.assert_array_equal(cel.k, kc_ref)
    np.testing.assert_array_equal(cel.cnp, cnpc_ref)


def test_vectorized_timeout_matches_controllers():
    cfg = tmod.TimeoutConfig(init_timeout=0.05)
    ctrls = [tmod.TimeoutController(cfg) for _ in range(7)]
    smoothed = np.full(7, cfg.init_timeout)
    timeout = cfg.init_timeout
    rng = np.random.default_rng(0)
    for _ in range(30):
        dur = float(rng.uniform(0.01, 0.2))
        fracs = rng.uniform(0.3, 1.0, 7)
        local = [c.update(dur, fracs[i]) for i, c in enumerate(ctrls)]
        agreed = tmod.coordinate(local)
        for c in ctrls:
            c.adopt(agreed)
        vec_local, smoothed = tmod.update_array(smoothed, dur, fracs, cfg)
        timeout = tmod.adopt_scalar(tmod.coordinate(vec_local), cfg)
        np.testing.assert_allclose(vec_local, local, rtol=1e-12)
        assert timeout == pytest.approx(ctrls[0].timeout, rel=1e-12)


# ------------------------------------------------- sweep API

def test_sweep_api_smoke():
    res = sweep(BatchedSimParams(
        n_nodes=(32,), message_mb=(4.0,), seeds=(0, 1),
        designs=("roce", "celeris"), n_rounds=20,
        base=SimParams(net=NetworkParams(n_nodes=32,
                                         burst_on_prob=0.0008))))
    assert len(res.stats) == 4
    scale = res.p99_vs_scale("celeris", 4.0)
    assert 32 in scale and scale[32][0] > 0
    rows = res.summary_rows()
    assert len(rows) == 4 and all(len(r) == 7 for r in rows)


@pytest.mark.slow
def test_sweep_scales_to_512():
    res = sweep(BatchedSimParams(n_nodes=(512,), seeds=(0,),
                                 designs=("roce", "celeris"), n_rounds=30))
    s = res.stats[("celeris", 512, 25.0, 0)]
    assert s.p99 > 0 and 0 <= s.mean_loss < 0.2
